//! Workload-aware routing (the paper's Section VII case study): train the
//! Table VI difficulty classifier, route a suite with both the rule-based
//! and the learned router, and compare energy/quality against monolithic
//! baselines.
//!
//! Run: `cargo run --release --example workload_router`

use ewatt::config::{GpuSpec, ModelTier};
use ewatt::coordinator::{DvfsPolicy, Router, Scheduler};
use ewatt::quality::{easy_hard_labels, QualityMatrix, QualityModel};
use ewatt::stats::{LogisticRegression, Standardizer};
use ewatt::workload::{Dataset, ReplaySuite};

fn main() -> anyhow::Result<()> {
    let suite = ReplaySuite::quick(21, 150);
    let gpu = GpuSpec::rtx_pro_6000();

    // Ground-truth difficulty labels from the quality surrogate.
    let qm = QualityMatrix::build(&suite, &QualityModel::new());
    let labels = easy_hard_labels(&suite, &qm);
    let hard: Vec<bool> = labels.iter().map(|&e| !e).collect();

    // Train the paper's logistic-regression difficulty classifier on
    // semantic features (standardized, C = 1.0).
    let x: Vec<Vec<f64>> = suite
        .features
        .iter()
        .map(|f| f.semantic_array().to_vec())
        .collect();
    let scaler = Standardizer::fit(&x);
    let xz = scaler.transform_all(&x);
    let mut lr = LogisticRegression::new(1.0);
    lr.fit(&xz, &hard);
    println!("learned difficulty classifier train accuracy: {:.1}%",
             100.0 * lr.accuracy(&xz, &hard));

    // Quality yardstick: classification accuracy (BoolQ+HellaSwag).
    let cls_quality = |tier: ModelTier| {
        let mut acc = 0.0;
        for d in [Dataset::BoolQ, Dataset::HellaSwag] {
            let idx = suite.dataset_indices(d);
            acc += qm.mean_raw_over(tier, &idx) / 2.0;
        }
        acc
    };

    let policy = DvfsPolicy::paper_phase_aware(&gpu);
    let configs: Vec<(&str, Router)> = vec![
        ("32B monolith", Router::with_tiers(ModelTier::B32, ModelTier::B32)),
        ("3B monolith", Router::with_tiers(ModelTier::B3, ModelTier::B3)),
        ("rule router (entity<0.20 & causal<0.05)", Router::paper_default()),
        (
            "learned router (LR on semantic features)",
            Router::paper_default().with_learned(lr, scaler),
        ),
    ];

    let baseline = Scheduler::new(
        gpu.clone(),
        Router::with_tiers(ModelTier::B32, ModelTier::B32),
        DvfsPolicy::baseline(&gpu),
        1,
    )
    .run(&suite)?;
    println!("\nbaseline (32B @ 2842 MHz): {:.1} J total\n", baseline.total_energy_j);
    println!("{:<42} {:>10} {:>9} {:>9} {:>14}", "config", "energy(J)", "savings", "quality", "routed tiers");
    for (name, router) in configs {
        let report = Scheduler::new(gpu.clone(), router, policy, 1).run(&suite)?;
        let tiers: Vec<String> = report
            .routed
            .iter()
            .map(|(t, n)| format!("{}:{}", t.label(), n))
            .collect();
        // Quality of the mix: weight per-tier classification quality by share.
        let total: usize = report.routed.values().sum();
        let quality: f64 = report
            .routed
            .iter()
            .map(|(t, n)| cls_quality(*t) * *n as f64 / total as f64)
            .sum();
        println!(
            "{:<42} {:>10.1} {:>8.1}% {:>8.1}% {:>14}",
            name,
            report.total_energy_j,
            100.0 * (1.0 - report.total_energy_j / baseline.total_energy_j),
            100.0 * quality,
            tiers.join(" ")
        );
    }
    println!("\n(paper Table XVIII: combined ≈ 88% savings at 77.0% vs 83.8% quality)");
    Ok(())
}
