//! Serving under traffic: the closed-loop DVFS governor end-to-end.
//!
//! Generates a bursty (MMPP) arrival stream over the generation-task
//! corpus, serves it through the discrete-event loop under three policies
//! — `Static(f_max)`, the paper's open-loop `PhaseAware` profile, and the
//! closed-loop `Governed` band — and prints energy, tail latency, and SLO
//! attainment for each. Exits non-zero unless the governed policy saves
//! ≥ 25% active energy vs the static baseline while holding the p99
//! end-to-end SLO (the PR's acceptance bar).
//!
//! Run: `cargo run --release --example slo_serve`

use ewatt::config::model::{model_for_tier, ModelTier};
use ewatt::config::GpuSpec;
use ewatt::coordinator::DvfsPolicy;
use ewatt::serve::{ServeSim, ServeSimConfig, TrafficPattern};
use ewatt::workload::{Dataset, ReplaySuite};

fn main() -> anyhow::Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(42, 60);
    let mut pool = suite.dataset_indices(Dataset::TruthfulQa);
    pool.extend(suite.dataset_indices(Dataset::NarrativeQa));

    let pattern = TrafficPattern::Bursty { base_rps: 1.5, burst_rps: 7.0, mean_dwell_s: 3.0 };
    let arrivals = pattern.generate_from(&pool, 160, 0xC10C);
    let sim = ServeSim::new(gpu.clone(), model_for_tier(ModelTier::B8), ServeSimConfig::default());
    let slo = sim.cfg.slo;

    println!(
        "traffic: {} | {} requests over {:.1}s | tier {} | max batch {}",
        pattern.label(),
        arrivals.len(),
        arrivals.last().unwrap().t_s,
        ModelTier::B8.label(),
        sim.cfg.max_batch
    );
    println!(
        "SLO: ttft p95 ≤ {:.1}s, tbt p95 ≤ {:.0}ms, e2e p99 ≤ {:.1}s\n",
        slo.ttft_p95_s,
        1e3 * slo.tbt_p95_s,
        slo.e2e_p99_s
    );

    let mut static_energy = None;
    let mut governed = None;
    for policy in [
        DvfsPolicy::baseline(&gpu),
        DvfsPolicy::paper_phase_aware(&gpu),
        DvfsPolicy::governed(&gpu),
    ] {
        let o = sim.run(&suite, &arrivals, &policy)?;
        let base = *static_energy.get_or_insert(o.energy_j);
        println!("[{}]", policy.label());
        println!(
            "  energy {:.0} J ({:.2} J/req active, {:.2} attributed){}  |  idle {:.0} J, \
             switch {:.2} J over {} switches",
            o.energy_j,
            o.active_joules_per_request(),
            o.joules_per_request(),
            if o.energy_j == base {
                "".to_string()
            } else {
                format!(", {:.1}% vs static", 100.0 * (1.0 - o.energy_j / base))
            },
            o.idle_j,
            o.switch_j,
            o.freq_switches
        );
        println!(
            "  ttft p95 {:.0} ms | e2e p50/p95/p99 {:.2}/{:.2}/{:.2} s | attainment {:.1}% | mean decode {:.0} MHz",
            1e3 * o.slo.ttft_p95(),
            o.slo.e2e_p50(),
            o.slo.e2e_p95(),
            o.slo.e2e_p99(),
            100.0 * o.slo.attainment(),
            o.mean_decode_freq_mhz
        );
        if matches!(policy, DvfsPolicy::Governed { .. }) {
            governed = Some(o);
        }
    }

    let gov = governed.expect("governed run present");
    let savings = 1.0 - gov.energy_j / static_energy.unwrap();
    let within_slo = gov.slo.e2e_p99() <= slo.e2e_p99_s;
    println!(
        "\ngoverned: {:.1}% energy savings vs static@{}MHz, p99 {}",
        100.0 * savings,
        gpu.f_max_mhz,
        if within_slo { "within SLO" } else { "OVER SLO" }
    );
    if savings < 0.25 {
        anyhow::bail!("energy savings {:.1}% below the 25% acceptance bar", 100.0 * savings);
    }
    if !within_slo {
        anyhow::bail!("governed p99 {:.2}s breached the end-to-end SLO", gov.slo.e2e_p99());
    }
    println!("acceptance criteria met.");
    Ok(())
}
