//! The heterogeneous governed fleet, end-to-end.
//!
//! Serves one bursty mixed-difficulty arrival stream through two
//! deployments of equal replica count:
//!
//! - **monolithic-static**: 4 × 14B replicas at the frequency ceiling,
//!   least-loaded routing — the configuration a paper-unaware operator
//!   runs;
//! - **routed-governed**: 2 × 3B + 2 × 14B replicas, semantic-difficulty
//!   routing, each replica under the closed-loop hysteresis DVFS governor
//!   — Section VII's co-design as an online system.
//!
//! Prints per-replica accounting and the attributed per-request energy
//! distribution, then exits non-zero unless (a) the routed+governed fleet
//! achieves lower attributed joules/request than monolithic-static, (b)
//! both deployments hold the p99 end-to-end SLO, and (c) per-request
//! attribution sums to total fleet energy within 1e-6 relative error.
//!
//! Run: `cargo run --release --example fleet_serve`

use ewatt::config::{GpuSpec, ModelTier};
use ewatt::coordinator::DvfsPolicy;
use ewatt::fleet::{
    DifficultyTiered, FleetConfig, FleetOutcome, FleetRouter, FleetSim, LeastLoaded, ReplicaSpec,
};
use ewatt::serve::TrafficPattern;
use ewatt::workload::ReplaySuite;

fn describe(name: &str, o: &FleetOutcome) {
    println!("[{name}]");
    println!(
        "  fleet: {:.0} J total ({:.0} active + {:.0} idle), {:.1} J/req attributed, \
         p50/p99 {:.1}/{:.1} J/req",
        o.total_j(),
        o.energy_j,
        o.idle_j,
        o.attributed_joules_per_request(),
        o.attributed_joules_per_request_quantile(0.50),
        o.attributed_joules_per_request_quantile(0.99),
    );
    println!(
        "  slo: ttft p95 {:.0} ms | e2e p99 {:.2} s | attainment {:.1}% | makespan {:.1} s",
        1e3 * o.slo.ttft_p95(),
        o.slo.e2e_p99(),
        100.0 * o.slo.attainment(),
        o.makespan_s
    );
    for (i, r) in o.replicas.iter().enumerate() {
        println!(
            "  replica {i}: {:4} [{}] served {:3} ({:5} tok) busy {:6.1}s \
             {:7.0}J active, mean decode {:4.0} MHz, {} switches",
            r.tier.label(),
            r.policy_label,
            r.served,
            r.tokens_out,
            r.busy_s,
            r.energy_j,
            r.mean_decode_freq_mhz,
            r.freq_switches
        );
    }
    let b = &o.breakdown;
    println!(
        "  attribution: prefill {:.0} J + decode {:.0} J + switch {:.1} J + idle {:.0} J\n",
        b.prefill_j, b.decode_j, b.switch_j, b.idle_j
    );
}

fn conservation_error(o: &FleetOutcome) -> f64 {
    let attributed: f64 = o.joules.iter().sum();
    (attributed - o.total_j()).abs() / o.total_j().max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(42, 60);
    let pattern = TrafficPattern::Bursty { base_rps: 3.0, burst_rps: 10.0, mean_dwell_s: 3.0 };
    let arrivals = pattern.generate(&suite, 200, 0xF1EE7);

    println!(
        "traffic: {} | {} requests over {:.1}s | full dataset mix\n",
        pattern.label(),
        arrivals.len(),
        arrivals.last().unwrap().t_s
    );

    let mono_cfg = FleetConfig::builder()
        .replicas(4, ReplicaSpec::tiered(ModelTier::B14, DvfsPolicy::baseline(&gpu)))
        .build()?;
    let slo = mono_cfg.slo;
    let mono = FleetSim::new(gpu.clone(), mono_cfg).run(&suite, &arrivals, &mut LeastLoaded)?;
    describe("monolithic-14B · static@fmax · least-loaded", &mono);

    let routed_cfg = FleetConfig::builder()
        .replicas(2, ReplicaSpec::tiered(ModelTier::B3, DvfsPolicy::governed(&gpu)))
        .replicas(2, ReplicaSpec::tiered(ModelTier::B14, DvfsPolicy::governed(&gpu)))
        .build()?;
    let mut router = DifficultyTiered::default();
    let routed = FleetSim::new(gpu.clone(), routed_cfg).run(&suite, &arrivals, &mut router)?;
    describe(
        &format!("routed-3B/14B · governed DVFS · {}", router.label()),
        &routed,
    );

    let savings =
        1.0 - routed.attributed_joules_per_request() / mono.attributed_joules_per_request();
    println!(
        "routed+governed: {:.1}% lower attributed J/req than monolithic-static \
         ({:.1} vs {:.1} J/req)",
        100.0 * savings,
        routed.attributed_joules_per_request(),
        mono.attributed_joules_per_request()
    );
    for (name, o) in [("monolithic-static", &mono), ("routed-governed", &routed)] {
        let err = conservation_error(o);
        println!(
            "{name}: p99 {:.2}s vs {:.1}s SLO | attribution conservation error {err:.2e}",
            o.slo.e2e_p99(),
            slo.e2e_p99_s
        );
        if o.slo.e2e_p99() > slo.e2e_p99_s {
            anyhow::bail!("{name} breached the p99 end-to-end SLO");
        }
        if err > 1e-6 {
            anyhow::bail!("{name}: attributed energy diverges from measured total ({err:.2e})");
        }
    }
    if savings <= 0.0 {
        anyhow::bail!("routed+governed fleet did not beat monolithic-static on joules/request");
    }
    println!("acceptance criteria met.");
    Ok(())
}
