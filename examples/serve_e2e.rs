//! End-to-end driver: every layer composing on a real workload.
//!
//! Loads the AOT-compiled tiny-LM artifacts (JAX + Pallas kernels → HLO text
//! → PJRT CPU), serves batched requests from the calibrated synthetic suite
//! through the threaded leader/worker coordinator with the phase-aware DVFS
//! simulator attached, and reports latency / throughput / J-per-token /
//! ROUGE-L — then repeats at the static-max policy to show the energy delta
//! end-to-end.
//!
//! Requires `make artifacts` first. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_e2e [-- <tier> <requests> <batch>]`

use ewatt::config::GpuSpec;
use ewatt::coordinator::{DvfsPolicy, ServeConfig, Server};
use ewatt::workload::{Query, ReplaySuite};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let tier = args.next().unwrap_or_else(|| "t3".to_string());
    let n_req: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(24);
    let batch: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);

    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(77, n_req.div_ceil(4).max(2));
    let queries: Vec<(usize, &Query)> = (0..suite.len().min(n_req))
        .map(|i| (i, &suite.queries[i]))
        .collect();

    for (label, policy) in [
        ("baseline  static@2842MHz", DvfsPolicy::baseline(&gpu)),
        ("phase-aware 2842/180MHz ", DvfsPolicy::paper_phase_aware(&gpu)),
    ] {
        let server = Server::new(ServeConfig {
            tier: tier.clone(),
            batch,
            max_new_tokens: 32,
            policy,
            ..Default::default()
        });
        let (outcomes, m) = server.serve(&queries)?;
        let mean_rouge: f64 =
            outcomes.iter().map(|o| o.rouge_l).sum::<f64>() / outcomes.len() as f64;
        println!("\n[{label}] tier={tier} batch={batch} requests={}", m.requests);
        println!(
            "  wall {:.2}s | {:.2} req/s | {:.1} tok/s | latency p50 {:.0}ms p95 {:.0}ms",
            m.wall_s,
            m.throughput_rps(),
            m.tokens_per_s(),
            1e3 * m.percentile(50.0),
            1e3 * m.percentile(95.0)
        );
        println!(
            "  sim-GPU energy: {:.2} J/request, {:.4} J/token | mean ROUGE-L {:.3}",
            m.joules_per_request(),
            m.joules_per_token(),
            mean_rouge
        );
        if policy == DvfsPolicy::baseline(&gpu) {
            println!("  sample: {:?}", outcomes[0].text.chars().take(64).collect::<String>());
        }
    }
    println!("\n(tiny-LM weights are seeded-random — ROUGE-L exercises the scoring\n plumbing; study-scale quality comes from the calibrated surrogate.)");
    Ok(())
}
