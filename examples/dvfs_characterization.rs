//! DVFS characterization walk-through (the paper's Section VI on your
//! terminal): frequency sweep, the frequency cliff, phase asymmetry, and
//! the EDP sweet spot for one model.
//!
//! Run: `cargo run --release --example dvfs_characterization [-- <queries>]`

use ewatt::config::model::{model_for_tier, ModelTier};
use ewatt::config::GpuSpec;
use ewatt::coordinator::DvfsPolicy;
use ewatt::engine::ReplayEngine;
use ewatt::perf::edp;
use ewatt::workload::ReplaySuite;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(7, n);
    let idx: Vec<usize> = (0..suite.len()).collect();

    println!("model         freq(MHz)  energy(J)  latency(s)  preΔ%    decΔ%   EDP");
    for tier in [ModelTier::B1, ModelTier::B8, ModelTier::B32] {
        let engine = ReplayEngine::new(gpu.clone(), model_for_tier(tier));
        let base = engine.run(&suite, &idx, 1, &DvfsPolicy::Static(gpu.f_max_mhz))?;
        let mut best: Option<(u32, f64)> = None;
        for &f in &gpu.freq_levels_mhz {
            let m = engine.run(&suite, &idx, 1, &DvfsPolicy::Static(f))?;
            let e = edp(m.energy_j, m.latency_s);
            if best.map_or(true, |(_, be)| e < be) {
                best = Some((f, e));
            }
            println!(
                "{:12} {:>8}  {:>9.1}  {:>9.3}  {:>+7.1}  {:>+7.2}  {:>8.1}",
                model_for_tier(tier).name,
                f,
                m.energy_j,
                m.latency_s,
                100.0 * (m.prefill_s - base.prefill_s) / base.prefill_s,
                100.0 * (m.decode_s - base.decode_s) / base.decode_s,
                e
            );
        }
        let (bf, _) = best.unwrap();
        println!("  → EDP-optimal set point for {}: {bf} MHz (paper: ~960 MHz)\n",
                 model_for_tier(tier).name);
    }

    // Phase-aware policy vs static baseline (Fig. 6 behaviour).
    let engine = ReplayEngine::new(gpu.clone(), model_for_tier(ModelTier::B8));
    let base = engine.run(&suite, &idx, 1, &DvfsPolicy::baseline(&gpu))?;
    let pa = engine.run(&suite, &idx, 1, &DvfsPolicy::paper_phase_aware(&gpu))?;
    println!(
        "phase-aware [2842 prefill / 180 decode]: energy {:.1}% below baseline, latency {:+.2}%",
        100.0 * (1.0 - pa.energy_j / base.energy_j),
        100.0 * (pa.latency_s - base.latency_s) / base.latency_s
    );
    Ok(())
}
