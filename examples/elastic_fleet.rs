//! The elastic fleet, end-to-end.
//!
//! Serves one diurnal arrival stream (deep troughs, peaks sized to need
//! most of the provisioned fleet) through three deployments of the same
//! four 8B replicas under the governed DVFS band and least-loaded routing:
//!
//! - **static-peak**: all four replicas live for the whole run — the
//!   configuration an operator provisions for the peak and leaves on;
//! - **autoscaled**: one replica live at the trough, the reactive
//!   autoscaler warming and draining the rest against load, every
//!   scale-up charged its cold-start energy and warm-up delay;
//! - **autoscaled + failures**: the same, with a seeded MTBF/MTTR crash/
//!   recovery process injected — crashes requeue in-flight requests
//!   through the router with their original arrival timestamps.
//!
//! Prints the lifecycle ledger per deployment, then exits non-zero unless
//! (a) the autoscaled fleet achieves lower attributed joules/request than
//! static peak provisioning, (b) both stay within the p99 end-to-end SLO,
//! (c) cold-start energy was actually charged, and (d) per-request energy
//! attribution sums to the metered total within 1e-6 relative error even
//! under failure injection, with no request lost or double-served.
//!
//! Run: `cargo run --release --example elastic_fleet`

use ewatt::config::model::model_for_tier;
use ewatt::config::{GpuSpec, ModelTier};
use ewatt::coordinator::DvfsPolicy;
use ewatt::fleet::{
    FailureConfig, FleetConfig, FleetOutcome, FleetSim, LeastLoaded, ReactiveConfig, ReplicaSpec,
    ReplicaState,
};
use ewatt::serve::TrafficPattern;
use ewatt::workload::ReplaySuite;

const N_PEAK: usize = 4;
const REQUESTS: usize = 900;

fn describe(name: &str, o: &FleetOutcome) {
    println!("[{name}]");
    println!(
        "  energy: {:.0} J total = {:.0} active + {:.0} idle + {:.0} cold-start | \
         {:.1} J/req attributed (p99 {:.1})",
        o.total_j(),
        o.energy_j,
        o.idle_j,
        o.coldstart_j,
        o.attributed_joules_per_request(),
        o.attributed_joules_per_request_quantile(0.99),
    );
    println!(
        "  slo: e2e p99 {:.2} s | attainment {:.1}% | makespan {:.1} s",
        o.slo.e2e_p99(),
        100.0 * o.slo.attainment(),
        o.makespan_s
    );
    println!(
        "  lifecycle: {} up / {} down | {} crashes, {} recoveries, {} requeued | \
         mean live replicas {:.2}",
        o.lifecycle.scale_ups,
        o.lifecycle.scale_downs,
        o.lifecycle.failures,
        o.lifecycle.recoveries,
        o.lifecycle.requeued,
        o.mean_live_replicas
    );
    for (i, r) in o.replicas.iter().enumerate() {
        println!(
            "  replica {i}: served {:3} ({:5} tok) busy {:6.1}s {:7.0}J active \
             {:6.0}J idle {:5.0}J cold | ends {}",
            r.served, r.tokens_out, r.busy_s, r.energy_j, r.idle_j, r.coldstart_j,
            r.state.label()
        );
    }
    println!();
}

fn conservation_error(o: &FleetOutcome) -> f64 {
    let attributed: f64 = o.joules.iter().sum();
    (attributed - o.total_j()).abs() / o.total_j().max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(42, 60);
    let pattern = TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 8.0, period_s: 120.0 };
    let arrivals = pattern.generate(&suite, REQUESTS, 0xE1A57);
    println!(
        "traffic: {} | {} requests over {:.0}s | full dataset mix\n",
        pattern.label(),
        arrivals.len(),
        arrivals.last().unwrap().t_s
    );

    let gov = DvfsPolicy::governed(&gpu);
    let model = model_for_tier(ModelTier::B8);
    let scale = ReactiveConfig { min_live: 1, max_live: N_PEAK, ..ReactiveConfig::default() };

    let live = ReplicaSpec { model, policy: gov, state: ReplicaState::Live };
    let cold = ReplicaSpec { state: ReplicaState::Cold, ..live.clone() };

    let static_cfg =
        FleetConfig::builder().replicas(N_PEAK, live.clone()).build()?;
    let slo = static_cfg.slo;
    let st = FleetSim::new(gpu.clone(), static_cfg).run(&suite, &arrivals, &mut LeastLoaded)?;
    describe(&format!("static-{N_PEAK} · governed · least-loaded"), &st);

    let elastic = || {
        FleetConfig::builder()
            .replica(live.clone())
            .replicas(N_PEAK - 1, cold.clone())
            .reactive(ReactiveConfig { max_live: N_PEAK, ..scale })
    };
    let auto_cfg = elastic().build()?;
    let au = FleetSim::new(gpu.clone(), auto_cfg).run(&suite, &arrivals, &mut LeastLoaded)?;
    describe("autoscaled 1..4 · governed · least-loaded", &au);

    let fail_cfg = elastic()
        .failures(FailureConfig { mtbf_s: 60.0, mttr_s: 20.0, seed: 0xFA11 })
        .build()?;
    let fa = FleetSim::new(gpu, fail_cfg).run(&suite, &arrivals, &mut LeastLoaded)?;
    describe("autoscaled + failures (MTBF 60s, MTTR 20s)", &fa);

    let savings = 1.0 - au.attributed_joules_per_request() / st.attributed_joules_per_request();
    println!(
        "autoscaled: {:.1}% lower attributed J/req than static peak provisioning \
         ({:.1} vs {:.1} J/req), mean live {:.2} vs {:.2}",
        100.0 * savings,
        au.attributed_joules_per_request(),
        st.attributed_joules_per_request(),
        au.mean_live_replicas,
        st.mean_live_replicas
    );

    // ---- acceptance criteria ----
    for (name, o) in [("static", &st), ("autoscaled", &au), ("autoscaled+failures", &fa)] {
        if o.served != arrivals.len() {
            anyhow::bail!("{name}: served {}/{} requests", o.served, arrivals.len());
        }
        let err = conservation_error(o);
        println!(
            "{name}: p99 {:.2}s vs {:.1}s SLO | conservation error {err:.2e}",
            o.slo.e2e_p99(),
            slo.e2e_p99_s
        );
        if err > 1e-6 {
            anyhow::bail!("{name}: attributed energy diverges from metered total ({err:.2e})");
        }
    }
    for (name, o) in [("static", &st), ("autoscaled", &au)] {
        if o.slo.e2e_p99() > slo.e2e_p99_s {
            anyhow::bail!("{name} breached the p99 end-to-end SLO");
        }
    }
    if au.coldstart_j <= 0.0 {
        anyhow::bail!("autoscaled run never charged a cold start — scaling did not happen");
    }
    if au.lifecycle.scale_ups == 0 || au.lifecycle.scale_downs == 0 {
        anyhow::bail!("autoscaler never cycled capacity: {:?}", au.lifecycle);
    }
    if savings <= 0.0 {
        anyhow::bail!("autoscaling did not beat static peak provisioning on joules/request");
    }
    // Exactly-once under failures: every request completed by one replica.
    let total_served: usize = fa.replicas.iter().map(|r| r.served).sum();
    if total_served != arrivals.len() || fa.served_by.iter().any(|&r| r == usize::MAX) {
        anyhow::bail!("failure injection lost or double-served requests");
    }
    println!("acceptance criteria met.");
    Ok(())
}
