//! Quickstart: the library in ~40 lines.
//!
//! Generates a small calibrated workload, extracts the paper's semantic
//! features, runs one DVFS comparison (180 vs 2842 MHz) on the simulated
//! testbed, and prints the headline numbers.
//!
//! Run: `cargo run --release --example quickstart`

use ewatt::config::model::{model_for_tier, ModelTier};
use ewatt::config::GpuSpec;
use ewatt::coordinator::DvfsPolicy;
use ewatt::engine::ReplayEngine;
use ewatt::workload::ReplaySuite;

fn main() -> anyhow::Result<()> {
    // 1. A reproducible, feature-annotated workload (40 queries/dataset).
    let suite = ReplaySuite::quick(42, 40);
    println!("suite: {} queries across 4 datasets", suite.len());
    let f = &suite.features[0];
    println!(
        "first query features: len={} entity={:.2} causal={} entropy={:.2}",
        f.input_length, f.entity_density, f.causal_question, f.token_entropy
    );

    // 2. Replay it on Llama-3.1-8B at both frequency extremes.
    let engine = ReplayEngine::new(GpuSpec::rtx_pro_6000(), model_for_tier(ModelTier::B8));
    let idx: Vec<usize> = (0..suite.len()).collect();
    let hi = engine.run(&suite, &idx, 1, &DvfsPolicy::Static(2842))?;
    let lo = engine.run(&suite, &idx, 1, &DvfsPolicy::Static(180))?;

    // 3. The paper's headline: big energy savings, tiny latency cost.
    println!(
        "2842 MHz: {:.1} J total, {:.2} s;   180 MHz: {:.1} J, {:.2} s",
        hi.energy_j, hi.latency_s, lo.energy_j, lo.latency_s
    );
    println!(
        "energy savings {:.1}%  latency change {:+.1}%  (decode share {:.0}%)",
        100.0 * (1.0 - lo.energy_j / hi.energy_j),
        100.0 * (lo.latency_s - hi.latency_s) / hi.latency_s,
        100.0 * hi.decode_share()
    );
    Ok(())
}
