//! Experiment sweep grids — the study's controlled variables (Section IV).

use super::gpu::FreqMHz;
use crate::workload::Dataset;

/// One full study configuration (Section IV of the paper).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Queries per dataset (paper: 1,000; TruthfulQA capped at 817).
    pub queries_per_dataset: usize,
    /// Repetitions per configuration (paper: 3, means reported).
    pub repetitions: usize,
    /// Batch sizes evaluated (paper: 1, 4, 8).
    pub batch_sizes: Vec<usize>,
    /// Max new tokens for generation tasks (paper: 100, greedy, EOS stop).
    pub max_new_tokens: usize,
    /// Master seed for all derived randomness.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            queries_per_dataset: 1000,
            repetitions: 3,
            batch_sizes: vec![1, 4, 8],
            max_new_tokens: 100,
            seed: 0xE_1A5,
        }
    }
}

impl ExperimentConfig {
    /// A reduced configuration for fast tests/benches (same shape, fewer
    /// queries/reps). Experiment outputs remain within the calibration bands.
    pub fn quick() -> Self {
        ExperimentConfig {
            queries_per_dataset: 200,
            repetitions: 1,
            ..Default::default()
        }
    }
}

/// Cartesian sweep grid for the DVFS characterization (Section VI).
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub freqs_mhz: Vec<FreqMHz>,
    pub batch_sizes: Vec<usize>,
    pub datasets: Vec<Dataset>,
}

impl SweepGrid {
    pub fn full(freqs: &[FreqMHz]) -> Self {
        SweepGrid {
            freqs_mhz: freqs.to_vec(),
            batch_sizes: vec![1, 4, 8],
            datasets: Dataset::ALL.to_vec(),
        }
    }

    /// Number of (freq, batch, dataset) cells.
    pub fn cells(&self) -> usize {
        self.freqs_mhz.len() * self.batch_sizes.len() * self.datasets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_setup() {
        let c = ExperimentConfig::default();
        assert_eq!(c.queries_per_dataset, 1000);
        assert_eq!(c.repetitions, 3);
        assert_eq!(c.batch_sizes, vec![1, 4, 8]);
        assert_eq!(c.max_new_tokens, 100);
    }

    #[test]
    fn grid_cell_count() {
        let g = SweepGrid::full(&[180, 960, 2842]);
        assert_eq!(g.cells(), 3 * 3 * 4);
    }
}
