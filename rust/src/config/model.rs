//! Model architecture specifications.
//!
//! The five paper models (Table I) are described by their published
//! architecture hyperparameters; parameter counts are *derived* from the
//! architecture (and unit-tested against the published totals) so the
//! FLOP/byte cost model in [`crate::perf`] is exact rather than fitted.

/// Size tier of a model in the paper's study (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ModelTier {
    /// Llama-3.2-1B
    B1,
    /// Llama-3.2-3B
    B3,
    /// Llama-3.1-8B
    B8,
    /// Qwen2.5-14B
    B14,
    /// Qwen2.5-32B
    B32,
}

impl ModelTier {
    pub const ALL: [ModelTier; 5] = [
        ModelTier::B1,
        ModelTier::B3,
        ModelTier::B8,
        ModelTier::B14,
        ModelTier::B32,
    ];

    /// Paper's column label ("1B".."32B").
    pub fn label(self) -> &'static str {
        match self {
            ModelTier::B1 => "1B",
            ModelTier::B3 => "3B",
            ModelTier::B8 => "8B",
            ModelTier::B14 => "14B",
            ModelTier::B32 => "32B",
        }
    }

    /// Index 0..5 in scaling order.
    pub fn index(self) -> usize {
        match self {
            ModelTier::B1 => 0,
            ModelTier::B3 => 1,
            ModelTier::B8 => 2,
            ModelTier::B14 => 3,
            ModelTier::B32 => 4,
        }
    }
}

/// Decoder-only transformer architecture description.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Human name, e.g. "Llama-3.2-1B".
    pub name: String,
    pub tier: ModelTier,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// Bytes per weight element as served (FP16 in the paper).
    pub weight_bytes: usize,
    /// Whether input and output embeddings share weights.
    pub tied_embeddings: bool,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// KV-cache bytes per token per sequence (both K and V, all layers).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim() * self.weight_bytes
    }

    /// Exact parameter count derived from the architecture.
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let dh = self.head_dim() as u64;
        let h = self.n_heads as u64;
        let hkv = self.n_kv_heads as u64;
        let f = self.d_ff as u64;
        let l = self.n_layers as u64;
        let v = self.vocab as u64;
        let per_layer = d * (h * dh)        // wq
            + 2 * d * (hkv * dh)            // wk, wv
            + (h * dh) * d                  // wo
            + 3 * d * f                     // gate, up, down
            + 2 * d; // two RMSNorm gains
        let embed = v * d;
        let head = if self.tied_embeddings { 0 } else { v * d };
        embed + head + l * per_layer + d
    }

    /// Total weight bytes resident in GPU memory.
    pub fn weight_footprint_bytes(&self) -> u64 {
        self.param_count() * self.weight_bytes as u64
    }
}

/// The paper's five evaluated models (Table I) with published architectures.
pub fn paper_models() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "Llama-3.2-1B".into(),
            tier: ModelTier::B1,
            n_layers: 16,
            d_model: 2048,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 8192,
            vocab: 128_256,
            weight_bytes: 2,
            tied_embeddings: true,
        },
        ModelSpec {
            name: "Llama-3.2-3B".into(),
            tier: ModelTier::B3,
            n_layers: 28,
            d_model: 3072,
            n_heads: 24,
            n_kv_heads: 8,
            d_ff: 8192,
            vocab: 128_256,
            weight_bytes: 2,
            tied_embeddings: true,
        },
        ModelSpec {
            name: "Llama-3.1-8B".into(),
            tier: ModelTier::B8,
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            d_ff: 14_336,
            vocab: 128_256,
            weight_bytes: 2,
            tied_embeddings: false,
        },
        ModelSpec {
            name: "Qwen2.5-14B".into(),
            tier: ModelTier::B14,
            n_layers: 48,
            d_model: 5120,
            n_heads: 40,
            n_kv_heads: 8,
            d_ff: 13_824,
            vocab: 152_064,
            weight_bytes: 2,
            tied_embeddings: false,
        },
        ModelSpec {
            name: "Qwen2.5-32B".into(),
            tier: ModelTier::B32,
            n_layers: 64,
            d_model: 5120,
            n_heads: 40,
            n_kv_heads: 8,
            d_ff: 27_648,
            vocab: 152_064,
            weight_bytes: 2,
            tied_embeddings: false,
        },
    ]
}

/// Look up a paper model by tier.
pub fn model_for_tier(tier: ModelTier) -> ModelSpec {
    paper_models()
        .into_iter()
        .find(|m| m.tier == tier)
        .expect("all tiers present")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // Derived counts must land near the marketing sizes the paper uses.
        let expect = [
            (ModelTier::B1, 1.24e9, 0.05),
            (ModelTier::B3, 3.2e9, 0.05),
            (ModelTier::B8, 8.0e9, 0.05),
            (ModelTier::B14, 14.7e9, 0.05),
            (ModelTier::B32, 32.5e9, 0.05),
        ];
        for (tier, target, tol) in expect {
            let m = model_for_tier(tier);
            let p = m.param_count() as f64;
            assert!(
                (p - target).abs() / target < tol,
                "{}: derived {p:.3e} vs published {target:.3e}",
                m.name
            );
        }
    }

    #[test]
    fn param_counts_strictly_increase_with_tier() {
        let models = paper_models();
        for w in models.windows(2) {
            assert!(w[0].param_count() < w[1].param_count());
        }
    }

    #[test]
    fn kv_bytes_per_token_sane() {
        let m = model_for_tier(ModelTier::B8);
        // Llama-3.1-8B: 2 * 32 layers * 8 kv heads * 128 dim * 2 bytes = 131072.
        assert_eq!(m.kv_bytes_per_token(), 131_072);
    }

    #[test]
    fn tier_labels_and_indices() {
        for (i, t) in ModelTier::ALL.iter().enumerate() {
            assert_eq!(t.index(), i);
        }
        assert_eq!(ModelTier::B32.label(), "32B");
    }
}
