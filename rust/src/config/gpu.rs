//! Simulated GPU testbed specification.
//!
//! Substitutes the paper's NVIDIA RTX PRO 6000 (Blackwell, 96 GB) — see
//! DESIGN.md §3. All constants are either public Blackwell datasheet numbers
//! or calibrated against the paper's own measurements (Table XI bands), and
//! the calibration is asserted by `rust/tests/calibration.rs`.

/// SM frequency in MHz.
pub type FreqMHz = u32;

/// Static description of the simulated GPU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    /// Supported SM frequency levels (the paper's seven DVFS set points).
    pub freq_levels_mhz: Vec<FreqMHz>,
    /// Maximum SM frequency — the paper's baseline configuration.
    pub f_max_mhz: FreqMHz,
    /// Peak dense FP16 throughput at `f_max`, FLOP/s.
    pub peak_flops_fp16: f64,
    /// Sustained HBM/GDDR bandwidth, bytes/s (memory clock is *not* scaled;
    /// the paper keeps memory frequency at default to isolate SM scaling).
    pub mem_bw_bytes: f64,
    /// Device memory capacity in bytes.
    pub mem_capacity_bytes: u64,
    /// Idle (static + uncore) power draw, watts.
    pub p_idle_w: f64,
    /// Memory-subsystem power at full bandwidth utilization, watts.
    pub p_mem_w: f64,
    /// SM dynamic power at `f_max`, full voltage, full activity, watts.
    pub p_sm_w: f64,
    /// Board sustained power cap (duty-cycle throttling above this), watts.
    pub p_sustain_w: f64,
    /// Core voltage at `f_max` (relative units).
    pub v_max: f64,
    /// Minimum core voltage — the floor below `f_v0`.
    pub v_min: f64,
    /// Frequency below which voltage sits at `v_min` (the "cliff" knee), MHz.
    pub f_v0_mhz: FreqMHz,
    /// Fraction of memory activity that keeps the SM clock domain toggling
    /// (data movement through L2/registers) — drives decode-phase power.
    pub kappa_mem_activity: f64,
    /// Host-side launch overhead per kernel, seconds (eager-mode serving
    /// stack, as in the paper's HF/torch harness).
    pub t_launch_s: f64,
    /// Fixed host framework overhead per phase step (python dispatch,
    /// sampling, bookkeeping), seconds.
    pub t_framework_s: f64,
    /// Additional host overhead per sequence in the batch per step
    /// (per-row sampling, stopping-criteria checks, detokenization).
    pub t_host_per_seq_s: f64,
    /// Kernels launched per transformer layer per phase step.
    pub kernels_per_layer: f64,
    /// Clock-sensitivity model η = min(1, coeff / (rows·width)^pow):
    /// at low occupancy kernels are DRAM-latency-bound and respond
    /// sub-linearly to SM clock (DESIGN.md §5). Calibrated against the
    /// paper's Table XI prefill/decode deltas.
    pub clock_sens_coeff: f64,
    pub clock_sens_pow: f64,
    /// Latency of an SM-clock set-point change (phase-aware DVFS cost).
    pub f_switch_overhead_s: f64,
    /// NVML-style power sampling period, seconds (paper: 10 ms).
    pub telemetry_period_s: f64,
}

impl GpuSpec {
    /// The study's testbed: RTX PRO 6000 Blackwell-class simulator.
    pub fn rtx_pro_6000() -> Self {
        GpuSpec {
            name: "SimRTX-PRO-6000-Blackwell".into(),
            freq_levels_mhz: vec![180, 487, 960, 1500, 2000, 2505, 2842],
            f_max_mhz: 2842,
            peak_flops_fp16: 250e12,
            mem_bw_bytes: 1.6e12,
            mem_capacity_bytes: 96 * (1 << 30),
            p_idle_w: 90.0,
            p_mem_w: 130.0,
            p_sm_w: 330.0,
            p_sustain_w: 460.0,
            v_max: 1.05,
            v_min: 0.70,
            f_v0_mhz: 960,
            kappa_mem_activity: 0.62,
            t_launch_s: 6e-6,
            t_framework_s: 0.35e-3,
            t_host_per_seq_s: 0.2e-3,
            kernels_per_layer: 10.0,
            clock_sens_coeff: 3000.0,
            clock_sens_pow: 0.7,
            f_switch_overhead_s: 2e-4,
            telemetry_period_s: 0.010,
        }
    }

    /// Core voltage at frequency `f` (linear above the floor knee).
    pub fn voltage(&self, f: FreqMHz) -> f64 {
        if f <= self.f_v0_mhz {
            self.v_min
        } else {
            let t = (f - self.f_v0_mhz) as f64 / (self.f_max_mhz - self.f_v0_mhz) as f64;
            self.v_min + t * (self.v_max - self.v_min)
        }
    }

    /// Peak FLOP/s at frequency `f` (compute scales with the SM clock).
    pub fn peak_flops_at(&self, f: FreqMHz) -> f64 {
        self.peak_flops_fp16 * f as f64 / self.f_max_mhz as f64
    }

    /// Validate a requested set point against the supported ladder.
    pub fn supports(&self, f: FreqMHz) -> bool {
        self.freq_levels_mhz.contains(&f)
    }

    pub fn f_min_mhz(&self) -> FreqMHz {
        *self.freq_levels_mhz.iter().min().expect("non-empty ladder")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_matches_paper() {
        let g = GpuSpec::rtx_pro_6000();
        assert_eq!(g.freq_levels_mhz, vec![180, 487, 960, 1500, 2000, 2505, 2842]);
        assert_eq!(g.f_max_mhz, 2842);
        assert_eq!(g.f_min_mhz(), 180);
    }

    #[test]
    fn voltage_curve_has_floor_and_is_monotone() {
        let g = GpuSpec::rtx_pro_6000();
        assert_eq!(g.voltage(180), g.v_min);
        assert_eq!(g.voltage(960), g.v_min);
        assert!((g.voltage(2842) - g.v_max).abs() < 1e-12);
        let mut prev = 0.0;
        for &f in &g.freq_levels_mhz {
            let v = g.voltage(f);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn peak_flops_scale_linearly() {
        let g = GpuSpec::rtx_pro_6000();
        let half = g.peak_flops_at(1421);
        assert!((half / g.peak_flops_fp16 - 0.5).abs() < 0.01);
    }

    #[test]
    fn capacity_fits_largest_paper_model() {
        use crate::config::model::{model_for_tier, ModelTier};
        let g = GpuSpec::rtx_pro_6000();
        let m = model_for_tier(ModelTier::B32);
        assert!(m.weight_footprint_bytes() < g.mem_capacity_bytes);
    }
}
