//! Configuration system: model architectures, the simulated GPU testbed, and
//! experiment sweep grids.
//!
//! Everything the study measures is derived from these specs — the paper's
//! five models are encoded with their *real* architecture hyperparameters
//! (layer count, widths, GQA factor, FFN size, vocab) so the cost model works
//! from exact FLOP/byte counts, not guessed totals.

pub mod experiment;
pub mod gpu;
pub mod model;

pub use experiment::{ExperimentConfig, SweepGrid};
pub use gpu::{FreqMHz, GpuSpec};
pub use model::{ModelSpec, ModelTier, paper_models};
