//! `ewatt diff`: attribute the energy/latency delta between two runs.
//!
//! The paper's central claims are *comparative* — governed DVFS vs a
//! static pin, one replica vs a fleet, one workload mix vs another. The
//! evidence layer already makes each run auditable (`traces.jsonl` +
//! `manifest.json`); this module makes a *pair* of runs auditable: it
//! loads both artifact directories, recomputes per-phase and per-replica
//! energy from the finalize-time request bills, and attributes the
//! ΔJ/req and Δlatency between them across phases
//! (prefill/decode/switch/idle/coldstart), replicas, and decode
//! frequency regimes.
//!
//! Everything is recomputed from the spans — the manifest is used for
//! identity (seed, config digest) and cross-checks only — so `ewatt
//! diff` catches a manifest that disagrees with its own trace. Diffing a
//! run against itself yields exact `0.0` deltas (same floats subtracted),
//! which CI uses as a smoke test, and `--min-decode-share` turns the
//! attribution into an assertion: the governed-vs-static comparison must
//! attribute at least that fraction of the energy delta to the decode
//! phase, or the command fails.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context as _, Result};

use crate::obs::export::{num, obj, text, uint, validate_trace_jsonl};
use crate::stats::exact_quantile;
use crate::util::cli::Args;
use crate::util::json::JsonValue;

/// Version of the `diff.json` field layout.
pub const DIFF_SCHEMA_VERSION: u64 = 1;

/// Per-phase J/req totals (numerators are sums over `request_summary`
/// bills; the caller divides by request count).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTotals {
    pub prefill_j: f64,
    pub decode_j: f64,
    pub switch_j: f64,
    pub migration_j: f64,
    pub idle_j: f64,
    pub coldstart_j: f64,
}

impl PhaseTotals {
    pub fn total_j(&self) -> f64 {
        self.prefill_j
            + self.decode_j
            + self.switch_j
            + self.migration_j
            + self.idle_j
            + self.coldstart_j
    }

    /// `(label, value)` in the fixed phase order every table uses.
    fn named(&self) -> [(&'static str, f64); 6] {
        [
            ("prefill", self.prefill_j),
            ("decode", self.decode_j),
            ("switch", self.switch_j),
            ("migration", self.migration_j),
            ("idle", self.idle_j),
            ("coldstart", self.coldstart_j),
        ]
    }
}

/// Everything `diff` needs from one run directory, recomputed from the
/// validated trace.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub dir: PathBuf,
    /// Header identity: run label, hex seed, config digest.
    pub run: String,
    pub seed: String,
    pub config_digest: String,
    /// Requests billed at finalize (== requests in the run).
    pub requests: usize,
    /// Completions observed as `served` spans.
    pub served: usize,
    pub makespan_s: f64,
    pub freq_switches: usize,
    /// Σ per-request bills by phase (the ledger total, reattributed).
    pub phase: PhaseTotals,
    /// Σ billed joules per replica.
    pub per_replica: BTreeMap<usize, f64>,
    /// Billed energy per traffic class: `label → (requests, joules)`.
    /// Traces written before the class tag existed bill as `interactive`,
    /// matching the engine's historical single-class assumption.
    pub per_class: BTreeMap<String, (usize, f64)>,
    /// Measured decode energy by SM frequency: `mhz → (steps, joules)`.
    pub decode_by_freq: BTreeMap<u32, (usize, f64)>,
    /// Completion latencies for exact quantiles.
    pub ttft_s: Vec<f64>,
    pub e2e_s: Vec<f64>,
    /// Alert firings recorded in the manifest (0 when absent).
    pub alerts: usize,
}

impl RunSummary {
    pub fn j_per_req(&self) -> f64 {
        self.phase.total_j() / self.requests.max(1) as f64
    }

    pub fn ttft_p95_s(&self) -> f64 {
        exact_quantile(&self.ttft_s, 0.95)
    }

    pub fn e2e_p99_s(&self) -> f64 {
        exact_quantile(&self.e2e_s, 0.99)
    }
}

/// Load and summarize one run directory (`traces.jsonl` + `manifest.json`,
/// as written by `ewatt trace`). The trace is re-validated line-by-line;
/// a directory holding a tampered or foreign file is an error, not a
/// garbage table.
pub fn load_run(dir: &Path) -> Result<RunSummary> {
    let trace_path = dir.join("traces.jsonl");
    let body = std::fs::read_to_string(&trace_path)
        .with_context(|| format!("reading {}", trace_path.display()))?;
    validate_trace_jsonl(&body)
        .with_context(|| format!("validating {}", trace_path.display()))?;

    let manifest_path = dir.join("manifest.json");
    let manifest_text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let manifest = JsonValue::parse(manifest_text.trim_end())
        .map_err(|e| anyhow::anyhow!("{}: {e}", manifest_path.display()))?;

    let mut lines = body.lines();
    let header = JsonValue::parse(lines.next().context("empty trace")?)
        .map_err(|e| anyhow::anyhow!("trace header: {e}"))?;
    let header_str = |key: &str| {
        header.get(key).and_then(JsonValue::as_str).unwrap_or("?").to_string()
    };

    let mut out = RunSummary {
        dir: dir.to_path_buf(),
        run: header_str("run"),
        seed: header_str("seed"),
        config_digest: manifest
            .get("config")
            .and_then(|c| c.get("digest"))
            .and_then(JsonValue::as_str)
            .unwrap_or("?")
            .to_string(),
        requests: 0,
        served: 0,
        makespan_s: 0.0,
        freq_switches: 0,
        phase: PhaseTotals::default(),
        per_replica: BTreeMap::new(),
        per_class: BTreeMap::new(),
        decode_by_freq: BTreeMap::new(),
        ttft_s: Vec::new(),
        e2e_s: Vec::new(),
        alerts: manifest
            .get("alerts")
            .and_then(|a| a.get("count"))
            .and_then(JsonValue::as_usize)
            .unwrap_or(0),
    };

    let f = |v: &JsonValue, key: &str| v.get(key).and_then(JsonValue::as_f64).unwrap_or(0.0);
    for line in lines {
        // Already validated above: parse cannot fail here.
        let v = JsonValue::parse(line).map_err(|e| anyhow::anyhow!("span line: {e}"))?;
        let t_s = f(&v, "t_s");
        out.makespan_s = out.makespan_s.max(t_s);
        match v.get("kind").and_then(JsonValue::as_str).unwrap_or("") {
            "served" => {
                out.served += 1;
                out.ttft_s.push(f(&v, "ttft_s"));
                out.e2e_s.push(f(&v, "e2e_s"));
            }
            "decode_step" => {
                let mhz = f(&v, "freq_mhz") as u32;
                let slot = out.decode_by_freq.entry(mhz).or_insert((0, 0.0));
                slot.0 += 1;
                slot.1 += f(&v, "joules");
            }
            "freq_switch" => out.freq_switches += 1,
            "request_summary" => {
                out.requests += 1;
                let e = v.get("energy").context("request_summary without energy")?;
                out.phase.prefill_j += f(e, "prefill_j");
                out.phase.decode_j += f(e, "decode_j");
                out.phase.switch_j += f(e, "switch_j");
                // Absent on pre-migration traces: `f` defaults to 0.0.
                out.phase.migration_j += f(e, "migration_j");
                out.phase.idle_j += f(e, "idle_j");
                out.phase.coldstart_j += f(e, "coldstart_j");
                let rep = f(&v, "replica") as usize;
                *out.per_replica.entry(rep).or_insert(0.0) += f(e, "total_j");
                let class = v.get("class").and_then(JsonValue::as_str).unwrap_or("interactive");
                let slot = out.per_class.entry(class.to_string()).or_insert((0, 0.0));
                slot.0 += 1;
                slot.1 += f(e, "total_j");
            }
            _ => {}
        }
    }
    ensure!(out.requests > 0, "{}: trace has no request_summary spans", dir.display());

    // Cross-check the recomputation against the manifest's own rollup.
    let rollup = manifest.get("energy_rollup").and_then(|r| r.get("ledger_total_j"));
    if let Some(ledger) = rollup.and_then(JsonValue::as_f64) {
        let rel = (out.phase.total_j() - ledger).abs() / ledger.max(f64::MIN_POSITIVE);
        ensure!(
            rel <= 1e-6,
            "{}: trace bills sum to {} J but manifest ledger holds {} J",
            dir.display(),
            out.phase.total_j(),
            ledger
        );
    }
    Ok(out)
}

/// One phase's row of the delta table.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseDelta {
    pub phase: &'static str,
    /// J/req in run A and run B.
    pub a_j_per_req: f64,
    pub b_j_per_req: f64,
    /// `b - a` (negative = B saves energy).
    pub delta: f64,
    /// `|delta| / Σ|delta|` across phases — where the change lives.
    pub share: f64,
}

/// The full comparison of two runs.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub a: RunSummary,
    pub b: RunSummary,
    pub phases: Vec<PhaseDelta>,
    /// Shorthand for the decode row's attribution share.
    pub decode_share: f64,
    /// Σ|Δphase J/req| — zero for a self-diff.
    pub total_abs_delta: f64,
}

/// Compare two summaries. Pure arithmetic: identical inputs give exact
/// `0.0` deltas, not `±ε`.
pub fn diff(a: RunSummary, b: RunSummary) -> DiffReport {
    let (na, nb) = (a.requests.max(1) as f64, b.requests.max(1) as f64);
    let rows: Vec<(&'static str, f64, f64)> = a
        .phase
        .named()
        .iter()
        .zip(b.phase.named().iter())
        .map(|(&(name, av), &(_, bv))| (name, av / na, bv / nb))
        .collect();
    let total_abs_delta: f64 = rows.iter().map(|(_, av, bv)| (bv - av).abs()).sum();
    let phases: Vec<PhaseDelta> = rows
        .into_iter()
        .map(|(phase, a_j, b_j)| PhaseDelta {
            phase,
            a_j_per_req: a_j,
            b_j_per_req: b_j,
            delta: b_j - a_j,
            share: if total_abs_delta > 0.0 { (b_j - a_j).abs() / total_abs_delta } else { 0.0 },
        })
        .collect();
    let decode_share = phases.iter().find(|p| p.phase == "decode").map_or(0.0, |p| p.share);
    DiffReport { a, b, phases, decode_share, total_abs_delta }
}

impl DiffReport {
    /// The headline number: Δ J/req, `B - A`.
    pub fn d_j_per_req(&self) -> f64 {
        self.b.j_per_req() - self.a.j_per_req()
    }

    /// Render the ASCII delta table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "run A: {} ({}, seed {}, digest {})",
            self.a.dir.display(), self.a.run, self.a.seed, self.a.config_digest);
        let _ = writeln!(out, "run B: {} ({}, seed {}, digest {})",
            self.b.dir.display(), self.b.run, self.b.seed, self.b.config_digest);
        out.push('\n');
        let _ =
            writeln!(out, "{:18} {:>14} {:>14} {:>14} {:>8}", "metric", "A", "B", "B - A", "share");
        let row = |out: &mut String, label: &str, a: f64, b: f64| {
            let _ = writeln!(out, "{label:18} {a:>14.4} {b:>14.4} {:>14.4}", b - a);
        };
        row(&mut out, "served", self.a.served as f64, self.b.served as f64);
        row(&mut out, "J/req total", self.a.j_per_req(), self.b.j_per_req());
        for p in &self.phases {
            let _ = writeln!(
                out,
                "  {:16} {:>14.4} {:>14.4} {:>14.4} {:>7.1}%",
                p.phase,
                p.a_j_per_req,
                p.b_j_per_req,
                p.delta,
                p.share * 100.0
            );
        }
        row(&mut out, "ttft p95 (s)", self.a.ttft_p95_s(), self.b.ttft_p95_s());
        row(&mut out, "e2e p99 (s)", self.a.e2e_p99_s(), self.b.e2e_p99_s());
        row(&mut out, "makespan (s)", self.a.makespan_s, self.b.makespan_s);
        row(&mut out, "freq switches", self.a.freq_switches as f64, self.b.freq_switches as f64);
        row(&mut out, "alerts", self.a.alerts as f64, self.b.alerts as f64);

        out.push('\n');
        if self.total_abs_delta > 0.0 {
            let attribution: Vec<String> = self
                .phases
                .iter()
                .filter(|p| p.share > 0.0)
                .map(|p| format!("{} {:.1}%", p.phase, p.share * 100.0))
                .collect();
            let _ = writeln!(out, "ΔJ/req attribution: {}", attribution.join(" · "));
        } else {
            let _ = writeln!(out, "ΔJ/req attribution: runs are energy-identical");
        }

        let mhzs: Vec<u32> = {
            let mut m: Vec<u32> = self
                .a
                .decode_by_freq
                .keys()
                .chain(self.b.decode_by_freq.keys())
                .copied()
                .collect();
            m.sort_unstable();
            m.dedup();
            m
        };
        if !mhzs.is_empty() {
            out.push('\n');
            let _ = writeln!(out, "decode energy by frequency regime:");
            let _ = writeln!(
                out,
                "  {:>6} {:>10} {:>14} {:>10} {:>14}",
                "MHz", "A steps", "A (J)", "B steps", "B (J)"
            );
            for mhz in mhzs {
                let (an, aj) = self.a.decode_by_freq.get(&mhz).copied().unwrap_or((0, 0.0));
                let (bn, bj) = self.b.decode_by_freq.get(&mhz).copied().unwrap_or((0, 0.0));
                let _ = writeln!(out, "  {mhz:>6} {an:>10} {aj:>14.2} {bn:>10} {bj:>14.2}");
            }
        }

        let reps: Vec<usize> = {
            let mut r: Vec<usize> =
                self.a.per_replica.keys().chain(self.b.per_replica.keys()).copied().collect();
            r.sort_unstable();
            r.dedup();
            r
        };
        out.push('\n');
        let _ = writeln!(out, "per-replica billed energy (J):");
        for rep in reps {
            let aj = self.a.per_replica.get(&rep).copied().unwrap_or(0.0);
            let bj = self.b.per_replica.get(&rep).copied().unwrap_or(0.0);
            let _ = writeln!(out, "  replica {rep}: A {aj:.2}  B {bj:.2}  Δ {:.2}", bj - aj);
        }

        let classes = self.class_labels();
        if !classes.is_empty() {
            out.push('\n');
            let _ = writeln!(out, "per-class billed energy (J/req):");
            for class in classes {
                let (an, aj) = self.a.per_class.get(&class).copied().unwrap_or((0, 0.0));
                let (bn, bj) = self.b.per_class.get(&class).copied().unwrap_or((0, 0.0));
                let a_per = aj / an.max(1) as f64;
                let b_per = bj / bn.max(1) as f64;
                let _ = writeln!(
                    out,
                    "  {class:12} A {a_per:>12.4} ({an:>4})  B {b_per:>12.4} ({bn:>4})  Δ {:.4}",
                    b_per - a_per
                );
            }
        }
        out
    }

    /// Union of class labels billed in either run, sorted for stable
    /// table and JSON ordering.
    fn class_labels(&self) -> Vec<String> {
        let mut c: Vec<String> =
            self.a.per_class.keys().chain(self.b.per_class.keys()).cloned().collect();
        c.sort();
        c.dedup();
        c
    }

    /// The machine-readable `diff.json` document.
    pub fn to_json(&self) -> JsonValue {
        let run_id = |r: &RunSummary| {
            obj(vec![
                ("dir", text(&r.dir.display().to_string())),
                ("run", text(&r.run)),
                ("seed", text(&r.seed)),
                ("config_digest", text(&r.config_digest)),
                ("requests", uint(r.requests)),
                ("served", uint(r.served)),
                ("j_per_req", num(r.j_per_req())),
                ("ttft_p95_s", num(r.ttft_p95_s())),
                ("e2e_p99_s", num(r.e2e_p99_s())),
                ("makespan_s", num(r.makespan_s)),
                ("freq_switches", uint(r.freq_switches)),
                ("alerts", uint(r.alerts)),
            ])
        };
        let freq_rows: Vec<JsonValue> = {
            let mut mhzs: Vec<u32> = self
                .a
                .decode_by_freq
                .keys()
                .chain(self.b.decode_by_freq.keys())
                .copied()
                .collect();
            mhzs.sort_unstable();
            mhzs.dedup();
            mhzs.into_iter()
                .map(|mhz| {
                    let (an, aj) = self.a.decode_by_freq.get(&mhz).copied().unwrap_or((0, 0.0));
                    let (bn, bj) = self.b.decode_by_freq.get(&mhz).copied().unwrap_or((0, 0.0));
                    obj(vec![
                        ("mhz", uint(mhz as usize)),
                        ("a_steps", uint(an)),
                        ("a_j", num(aj)),
                        ("b_steps", uint(bn)),
                        ("b_j", num(bj)),
                    ])
                })
                .collect()
        };
        let replica_rows: Vec<JsonValue> = {
            let mut reps: Vec<usize> =
                self.a.per_replica.keys().chain(self.b.per_replica.keys()).copied().collect();
            reps.sort_unstable();
            reps.dedup();
            reps.into_iter()
                .map(|rep| {
                    let aj = self.a.per_replica.get(&rep).copied().unwrap_or(0.0);
                    let bj = self.b.per_replica.get(&rep).copied().unwrap_or(0.0);
                    obj(vec![
                        ("replica", uint(rep)),
                        ("a_j", num(aj)),
                        ("b_j", num(bj)),
                        ("delta_j", num(bj - aj)),
                    ])
                })
                .collect()
        };
        let class_rows: Vec<JsonValue> = self
            .class_labels()
            .into_iter()
            .map(|class| {
                let (an, aj) = self.a.per_class.get(&class).copied().unwrap_or((0, 0.0));
                let (bn, bj) = self.b.per_class.get(&class).copied().unwrap_or((0, 0.0));
                obj(vec![
                    ("class", text(&class)),
                    ("a_requests", uint(an)),
                    ("a_j", num(aj)),
                    ("b_requests", uint(bn)),
                    ("b_j", num(bj)),
                ])
            })
            .collect();
        obj(vec![
            ("schema", text("ewatt.diff")),
            ("version", uint(DIFF_SCHEMA_VERSION as usize)),
            ("a", run_id(&self.a)),
            ("b", run_id(&self.b)),
            (
                "delta",
                obj(vec![
                    ("j_per_req", num(self.d_j_per_req())),
                    ("ttft_p95_s", num(self.b.ttft_p95_s() - self.a.ttft_p95_s())),
                    ("e2e_p99_s", num(self.b.e2e_p99_s() - self.a.e2e_p99_s())),
                    ("makespan_s", num(self.b.makespan_s - self.a.makespan_s)),
                    ("served", num(self.b.served as f64 - self.a.served as f64)),
                ]),
            ),
            (
                "attribution",
                obj(vec![
                    ("decode_share", num(self.decode_share)),
                    ("total_abs_delta_j_per_req", num(self.total_abs_delta)),
                    (
                        "phases",
                        JsonValue::Array(
                            self.phases
                                .iter()
                                .map(|p| {
                                    obj(vec![
                                        ("phase", text(p.phase)),
                                        ("a_j_per_req", num(p.a_j_per_req)),
                                        ("b_j_per_req", num(p.b_j_per_req)),
                                        ("delta_j_per_req", num(p.delta)),
                                        ("share", num(p.share)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("freq_regimes", JsonValue::Array(freq_rows)),
            ("replicas", JsonValue::Array(replica_rows)),
            ("classes", JsonValue::Array(class_rows)),
        ])
    }
}

/// `ewatt diff <run_a> <run_b> [--out DIR] [--min-decode-share X]`.
///
/// Loads two artifact directories written by `ewatt trace`, prints the
/// delta table, and writes `diff.json` under `--out` (default
/// `target/diff`). With `--min-decode-share`, fails unless at least that
/// fraction of the ΔJ/req attributes to the decode phase (a self-diff
/// with zero delta passes trivially — there is nothing to attribute).
pub fn run_cli(args: &Args) -> Result<()> {
    let [run_a, run_b] = args.positional.as_slice() else {
        bail!("usage: ewatt diff <run_a> <run_b> [--out DIR] [--min-decode-share X]");
    };
    let report = execute(Path::new(run_a), Path::new(run_b))?;
    print!("{}", report.render());

    let out_dir = PathBuf::from(args.get("out").unwrap_or("target/diff"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let out_path = out_dir.join("diff.json");
    std::fs::write(&out_path, format!("{}\n", report.to_json()))
        .with_context(|| format!("writing {}", out_path.display()))?;
    println!("\nwrote {}", out_path.display());

    let min_share = args.get_f64("min-decode-share", -1.0);
    if min_share >= 0.0 && report.total_abs_delta > 0.0 {
        ensure!(
            report.decode_share >= min_share,
            "decode phase carries {:.1}% of the ΔJ/req (required ≥ {:.1}%)",
            report.decode_share * 100.0,
            min_share * 100.0
        );
        println!(
            "decode share {:.1}% ≥ required {:.1}%",
            report.decode_share * 100.0,
            min_share * 100.0
        );
    }
    Ok(())
}

/// Load both runs and diff them (the testable core of [`run_cli`]).
pub fn execute(dir_a: &Path, dir_b: &Path) -> Result<DiffReport> {
    let a = load_run(dir_a)?;
    let b = load_run(dir_b)?;
    Ok(diff(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(tag: &str, decode_j: f64, idle_j: f64) -> RunSummary {
        RunSummary {
            dir: PathBuf::from(format!("target/fake-{tag}")),
            run: format!("trace/{tag}"),
            seed: "0x5ce1".into(),
            config_digest: "0xabc".into(),
            requests: 10,
            served: 10,
            makespan_s: 30.0,
            freq_switches: 4,
            phase: PhaseTotals {
                prefill_j: 5.0,
                decode_j,
                switch_j: 0.5,
                migration_j: 0.0,
                idle_j,
                coldstart_j: 0.0,
            },
            per_replica: [(0usize, 5.0 + decode_j + 0.5 + idle_j)].into_iter().collect(),
            per_class: [
                ("batch".to_string(), (4usize, 2.0 + decode_j * 0.4)),
                ("interactive".to_string(), (6usize, 3.5 + idle_j + decode_j * 0.6)),
            ]
            .into_iter()
            .collect(),
            decode_by_freq: [(2842u32, (100usize, decode_j))].into_iter().collect(),
            ttft_s: (0..10).map(|i| 0.05 + i as f64 * 0.01).collect(),
            e2e_s: (0..10).map(|i| 0.5 + i as f64 * 0.05).collect(),
            alerts: 0,
        }
    }

    #[test]
    fn self_diff_is_exactly_zero() {
        let r = diff(summary("a", 40.0, 2.0), summary("a", 40.0, 2.0));
        assert_eq!(r.d_j_per_req(), 0.0);
        assert_eq!(r.total_abs_delta, 0.0);
        for p in &r.phases {
            assert_eq!(p.delta, 0.0, "{}", p.phase);
            assert_eq!(p.share, 0.0, "{}", p.phase);
        }
        let j = r.to_json();
        assert_eq!(j.get("delta").unwrap().get("j_per_req").unwrap().as_f64(), Some(0.0));
        assert!(r.render().contains("energy-identical"));
    }

    #[test]
    fn decode_saving_attributes_to_decode() {
        // B saves 15 J/run of decode and pays 1 J more idle: the decode
        // share dominates.
        let r = diff(summary("static", 40.0, 2.0), summary("governed", 25.0, 3.0));
        assert!(r.d_j_per_req() < 0.0, "B must be cheaper: {}", r.d_j_per_req());
        assert!(r.decode_share > 0.9, "decode share {}", r.decode_share);
        let shares: f64 = r.phases.iter().map(|p| p.share).sum();
        assert!((shares - 1.0).abs() < 1e-12, "shares sum to {shares}");
        let table = r.render();
        assert!(table.contains("decode"), "{table}");
        assert!(table.contains("ΔJ/req attribution"), "{table}");
        assert!(table.contains("per-class billed energy"), "{table}");
        // Class rows export in sorted label order with both runs' bills.
        let classes = r.to_json().get("classes").unwrap().as_array().unwrap().to_vec();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[0].get("class").unwrap().as_str(), Some("batch"));
        assert_eq!(classes[0].get("a_requests").unwrap().as_usize(), Some(4));
        assert_eq!(classes[1].get("class").unwrap().as_str(), Some("interactive"));
    }

    #[test]
    fn json_document_is_versioned_and_deterministic() {
        let r = diff(summary("a", 40.0, 2.0), summary("b", 25.0, 3.0));
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("ewatt.diff"));
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(j.to_string(), r.to_json().to_string());
        // Round-trips through the parser.
        assert!(JsonValue::parse(&j.to_string()).is_ok());
        let share = j
            .get("attribution")
            .unwrap()
            .get("decode_share")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(share > 0.9);
    }

    #[test]
    fn load_run_rejects_missing_or_invalid_dirs() {
        let err = load_run(Path::new("target/does-not-exist")).unwrap_err().to_string();
        assert!(err.contains("traces.jsonl"), "{err}");
    }
}
