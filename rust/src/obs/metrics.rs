//! Static-key metrics registry fed by the span stream.
//!
//! Keys are enums, storage is fixed arrays — recording a counter is a
//! bounds-check-free array index, and the whole registry lives in one
//! allocation. Histograms reuse the P² streaming quantile estimators from
//! [`crate::stats::descriptive`], so latency and energy distributions are
//! available without retaining per-sample data.
//!
//! The registry is itself a [`TraceSink`]: attach it to a traced run to
//! aggregate live, or replay a recorded span stream through
//! [`MetricsRegistry::observe`] after the fact — both paths produce
//! identical numbers because every metric is derived from spans alone.

use crate::obs::span::{Span, SpanEvent, TraceSink};
use crate::stats::descriptive::StreamingQuantiles;

/// Monotone event counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    Queued,
    Routed,
    Requeued,
    /// Sequences checkpointed off a draining or crashed replica.
    Migrations,
    /// Checkpointed sequences replayed and resumed on a target replica.
    Resumes,
    Admissions,
    PrefillPasses,
    DecodeSteps,
    TokensOut,
    Served,
    FreqSwitches,
    ScaleUps,
    ColdStarts,
    ScaleDowns,
    WarmDones,
    Failures,
    Recoveries,
}

impl Counter {
    pub const ALL: [Counter; 17] = [
        Counter::Queued,
        Counter::Routed,
        Counter::Requeued,
        Counter::Migrations,
        Counter::Resumes,
        Counter::Admissions,
        Counter::PrefillPasses,
        Counter::DecodeSteps,
        Counter::TokensOut,
        Counter::Served,
        Counter::FreqSwitches,
        Counter::ScaleUps,
        Counter::ColdStarts,
        Counter::ScaleDowns,
        Counter::WarmDones,
        Counter::Failures,
        Counter::Recoveries,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Counter::Queued => "queued",
            Counter::Routed => "routed",
            Counter::Requeued => "requeued",
            Counter::Migrations => "migrations",
            Counter::Resumes => "resumes",
            Counter::Admissions => "admissions",
            Counter::PrefillPasses => "prefill_passes",
            Counter::DecodeSteps => "decode_steps",
            Counter::TokensOut => "tokens_out",
            Counter::Served => "served",
            Counter::FreqSwitches => "freq_switches",
            Counter::ScaleUps => "scale_ups",
            Counter::ColdStarts => "cold_starts",
            Counter::ScaleDowns => "scale_downs",
            Counter::WarmDones => "warm_dones",
            Counter::Failures => "failures",
            Counter::Recoveries => "recoveries",
        }
    }
}

/// Last-write / running-delta values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Largest simulated timestamp observed so far.
    SimTimeS,
    /// Net autoscaler delta: scale-ups minus scale-downs.
    LiveReplicaDelta,
}

impl Gauge {
    pub const ALL: [Gauge; 2] = [Gauge::SimTimeS, Gauge::LiveReplicaDelta];

    pub fn label(self) -> &'static str {
        match self {
            Gauge::SimTimeS => "sim_time_s",
            Gauge::LiveReplicaDelta => "live_replica_delta",
        }
    }
}

/// P²-backed streaming histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    TtftS,
    TbtS,
    E2eS,
    PrefillJ,
    DecodeStepJ,
    /// Prefill-replay energy per resumed sequence.
    MigrationJ,
    ReqTotalJ,
}

impl Hist {
    pub const ALL: [Hist; 7] = [
        Hist::TtftS,
        Hist::TbtS,
        Hist::E2eS,
        Hist::PrefillJ,
        Hist::DecodeStepJ,
        Hist::MigrationJ,
        Hist::ReqTotalJ,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Hist::TtftS => "ttft_s",
            Hist::TbtS => "tbt_s",
            Hist::E2eS => "e2e_s",
            Hist::PrefillJ => "prefill_j",
            Hist::DecodeStepJ => "decode_step_j",
            Hist::MigrationJ => "migration_j",
            Hist::ReqTotalJ => "req_total_j",
        }
    }
}

/// Count/sum/min/max plus P² p50/p95/p99 over a stream of samples.
#[derive(Debug)]
pub struct HistP2 {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    quantiles: StreamingQuantiles,
}

impl Default for HistP2 {
    fn default() -> HistP2 {
        HistP2 {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            quantiles: StreamingQuantiles::new(),
        }
    }
}

impl HistP2 {
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.quantiles.observe(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// `NaN` before the first sample — never the `+∞` accumulator seed.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// `NaN` before the first sample — never the `-∞` accumulator seed.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    pub fn p50(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.quantiles.p50()
        }
    }

    pub fn p95(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.quantiles.p95()
        }
    }

    pub fn p99(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.quantiles.p99()
        }
    }
}

/// Fixed-layout registry: every key is an enum discriminant, every store
/// a direct array index.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: [u64; Counter::ALL.len()],
    gauges: [f64; Gauge::ALL.len()],
    hists: [HistP2; Hist::ALL.len()],
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    #[inline]
    pub fn inc(&mut self, c: Counter) {
        self.counters[c as usize] += 1;
    }

    #[inline]
    pub fn add(&mut self, c: Counter, n: u64) {
        self.counters[c as usize] += n;
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    #[inline]
    pub fn set_gauge(&mut self, g: Gauge, v: f64) {
        self.gauges[g as usize] = v;
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g as usize]
    }

    #[inline]
    pub fn record(&mut self, h: Hist, x: f64) {
        self.hists[h as usize].observe(x);
    }

    pub fn hist(&self, h: Hist) -> &HistP2 {
        &self.hists[h as usize]
    }

    /// Fold one span into the registry. [`TraceSink::emit`] delegates
    /// here, so live aggregation and post-hoc replay agree exactly.
    pub fn observe(&mut self, span: &Span) {
        let t = self.gauge(Gauge::SimTimeS).max(span.t_s);
        self.set_gauge(Gauge::SimTimeS, t);
        match &span.event {
            SpanEvent::Queued { .. } => self.inc(Counter::Queued),
            SpanEvent::Routed { .. } => self.inc(Counter::Routed),
            SpanEvent::Requeued { .. } => self.inc(Counter::Requeued),
            SpanEvent::Migrated { .. } => self.inc(Counter::Migrations),
            SpanEvent::Resumed { joules, .. } => {
                self.inc(Counter::Resumes);
                self.record(Hist::MigrationJ, *joules);
            }
            SpanEvent::Admitted { .. } => self.inc(Counter::Admissions),
            SpanEvent::PrefillStart { .. } => {}
            SpanEvent::PrefillEnd { passes, joules, .. } => {
                self.add(Counter::PrefillPasses, *passes as u64);
                self.record(Hist::PrefillJ, *joules);
            }
            SpanEvent::DecodeStep { joules, .. } => {
                self.inc(Counter::DecodeSteps);
                self.record(Hist::DecodeStepJ, *joules);
            }
            SpanEvent::Served { ttft_s, tbt_s, e2e_s, tokens, .. } => {
                self.inc(Counter::Served);
                self.add(Counter::TokensOut, *tokens as u64);
                self.record(Hist::TtftS, *ttft_s);
                self.record(Hist::TbtS, *tbt_s);
                self.record(Hist::E2eS, *e2e_s);
            }
            SpanEvent::FreqSwitch { .. } => self.inc(Counter::FreqSwitches),
            SpanEvent::ScaleUp { cold_start, .. } => {
                self.inc(Counter::ScaleUps);
                if *cold_start {
                    self.inc(Counter::ColdStarts);
                }
                let d = self.gauge(Gauge::LiveReplicaDelta) + 1.0;
                self.set_gauge(Gauge::LiveReplicaDelta, d);
            }
            SpanEvent::ScaleDown { .. } => {
                self.inc(Counter::ScaleDowns);
                let d = self.gauge(Gauge::LiveReplicaDelta) - 1.0;
                self.set_gauge(Gauge::LiveReplicaDelta, d);
            }
            SpanEvent::WarmDone { .. } => self.inc(Counter::WarmDones),
            SpanEvent::Failed { .. } => self.inc(Counter::Failures),
            SpanEvent::Recovered { .. } => self.inc(Counter::Recoveries),
            SpanEvent::RequestSummary { energy, .. } => {
                self.record(Hist::ReqTotalJ, energy.total_j());
            }
        }
    }

    /// Plain-text dump: counters, gauges, then histogram summaries, in
    /// declaration order (deterministic).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("counters:\n");
        for c in Counter::ALL {
            out.push_str(&format!("  {:16} {}\n", c.label(), self.counter(c)));
        }
        out.push_str("gauges:\n");
        for g in Gauge::ALL {
            out.push_str(&format!("  {:16} {:.3}\n", g.label(), self.gauge(g)));
        }
        out.push_str("histograms (count / mean / p50 / p95 / p99 / max):\n");
        for h in Hist::ALL {
            let hist = self.hist(h);
            if hist.count() == 0 {
                // No samples: every statistic is undefined, shown as `-`
                // (the accessors return NaN, never a sentinel).
                out.push_str(&format!("  {:16} 0 / - / - / - / - / -\n", h.label()));
            } else {
                out.push_str(&format!(
                    "  {:16} {} / {:.4} / {:.4} / {:.4} / {:.4} / {:.4}\n",
                    h.label(),
                    hist.count(),
                    hist.mean(),
                    hist.p50(),
                    hist.p95(),
                    hist.p99(),
                    hist.max(),
                ));
            }
        }
        out
    }
}

impl TraceSink for MetricsRegistry {
    fn emit(&mut self, t_s: f64, event: SpanEvent) {
        self.observe(&Span { t_s, event });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::attribution::PhaseEnergy;
    use crate::serve::traffic::TrafficClass;

    #[test]
    fn counters_and_gauges_track_events() {
        let mut m = MetricsRegistry::new();
        m.emit(1.0, SpanEvent::Queued { req: 0, query_idx: 0, class: TrafficClass::Interactive });
        m.emit(1.0, SpanEvent::Routed { req: 0, replica: 1 });
        m.emit(2.0, SpanEvent::ScaleUp { replica: 2, cold_start: true });
        m.emit(3.0, SpanEvent::ScaleUp { replica: 1, cold_start: false });
        m.emit(4.0, SpanEvent::ScaleDown { replica: 2 });
        assert_eq!(m.counter(Counter::Queued), 1);
        assert_eq!(m.counter(Counter::Routed), 1);
        assert_eq!(m.counter(Counter::ScaleUps), 2);
        assert_eq!(m.counter(Counter::ColdStarts), 1);
        assert_eq!(m.counter(Counter::ScaleDowns), 1);
        assert_eq!(m.gauge(Gauge::LiveReplicaDelta), 1.0);
        assert_eq!(m.gauge(Gauge::SimTimeS), 4.0);
    }

    #[test]
    fn histograms_aggregate_served_and_energy() {
        let mut m = MetricsRegistry::new();
        for i in 0..100usize {
            m.emit(
                i as f64,
                SpanEvent::Served {
                    req: i,
                    replica: 0,
                    class: TrafficClass::Interactive,
                    ttft_s: 0.1 + i as f64 * 1e-3,
                    tbt_s: 0.01,
                    e2e_s: 1.0,
                    tokens: 8,
                },
            );
            m.emit(
                i as f64,
                SpanEvent::RequestSummary {
                    req: i,
                    replica: 0,
                    class: TrafficClass::Interactive,
                    energy: PhaseEnergy { prefill_j: 1.0, ..Default::default() },
                },
            );
        }
        assert_eq!(m.counter(Counter::Served), 100);
        assert_eq!(m.counter(Counter::TokensOut), 800);
        let ttft = m.hist(Hist::TtftS);
        assert_eq!(ttft.count(), 100);
        assert!(ttft.min() >= 0.1 && ttft.max() <= 0.2);
        assert!(ttft.p50() > 0.1 && ttft.p50() < 0.2);
        assert!((m.hist(Hist::ReqTotalJ).mean() - 1.0).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("served"));
        assert!(text.contains("ttft_s"));
        assert!(
            text.contains("decode_step_j    0 / - / - / - / - / -"),
            "decode hist should render as dashes: {text}"
        );
    }

    #[test]
    fn empty_histograms_return_nan_not_sentinels() {
        let h = HistP2::default();
        assert_eq!(h.count(), 0);
        for stat in [h.min(), h.max(), h.mean(), h.p50(), h.p95(), h.p99()] {
            assert!(stat.is_nan(), "empty hist leaked a sentinel: {stat}");
        }
        // One sample collapses every statistic onto it.
        let mut h = HistP2::default();
        h.observe(2.5);
        for stat in [h.min(), h.max(), h.mean(), h.p50(), h.p95(), h.p99()] {
            assert_eq!(stat, 2.5);
        }
        // An empty registry renders a dash row for every histogram.
        let text = MetricsRegistry::new().render();
        for hist in Hist::ALL {
            assert!(
                text.contains(&format!("{:16} 0 / - / - / - / - / -", hist.label())),
                "{}: {text}",
                hist.label()
            );
        }
    }

    #[test]
    fn replay_of_recorded_spans_matches_live_aggregation() {
        let class = TrafficClass::Interactive;
        let spans = vec![
            Span { t_s: 0.0, event: SpanEvent::Queued { req: 0, query_idx: 0, class } },
            Span {
                t_s: 0.5,
                event: SpanEvent::DecodeStep {
                    replica: 0,
                    freq_mhz: 180,
                    batch: vec![0],
                    joules: 2.0,
                },
            },
        ];
        let mut live = MetricsRegistry::new();
        for s in &spans {
            live.emit(s.t_s, s.event.clone());
        }
        let mut replay = MetricsRegistry::new();
        for s in &spans {
            replay.observe(s);
        }
        assert_eq!(live.render(), replay.render());
    }
}
