//! Fixed-cadence heartbeat telemetry: `timeline.jsonl`.
//!
//! The span stream (`traces.jsonl`) is *event*-shaped — it says what
//! happened, but reading "what did the fleet look like at t=40s?" out of
//! it means replaying every event up to 40s. The timeline is the
//! complementary *state*-shaped artifact: a [`TimelineSampler`] attached
//! to the engine ([`crate::fleet::EngineCtx::timeline`]) emits one row
//! per heartbeat boundary (`k · cadence_s`), each carrying per-replica
//! gauges — lifecycle state, frequency set point, telemetry-window power,
//! queue depth, batch occupancy, KV usage — plus the fleet aggregates.
//!
//! Sampling semantics: the engine processes events in nondecreasing time
//! order; immediately before executing an event at time `t` it emits
//! every pending boundary `b < t`, sampling the fleet *as the engine sees
//! it at that instant* (all events before `t` applied). After the run,
//! [`TimelineSampler::finish`] flushes the remaining boundaries up to and
//! including the makespan. The sampler only reads — attaching one leaves
//! the physics bit-identical (pinned alongside tracing by
//! `rust/tests/obs_trace.rs`), and like tracing it disables gap-parallel
//! stepping so every boundary is observed between sequential steps.
//!
//! The artifact mirrors `traces.jsonl`: a schema-versioned header line,
//! one compact sorted-key JSON object per row, byte-deterministic under a
//! fixed seed, and self-validating via [`validate_timeline_jsonl`].

use std::path::Path;

use anyhow::{ensure, Context as _, Result};

use crate::fleet::Replica;
use crate::obs::export::{check_jsonl_header, num, obj, strict_jsonl_lines, text, uint};
use crate::util::json::JsonValue;

/// Version of the `timeline.jsonl` line schema. Bump on any breaking
/// change to row field names or the header shape.
pub const TIMELINE_SCHEMA_VERSION: u64 = 1;

/// Default heartbeat cadence, simulated seconds.
pub const DEFAULT_CADENCE_S: f64 = 0.5;

/// One replica's gauges at a heartbeat boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaSample {
    pub replica: usize,
    /// Lifecycle state label (`live`, `draining`, `cold`, `warming`).
    pub state: &'static str,
    /// Current SM set point, MHz.
    pub freq_mhz: u32,
    /// Mean power over the replica's telemetry window, watts.
    pub power_w: f64,
    /// Requests waiting in the admission queue.
    pub queue_depth: usize,
    /// Queue split per traffic class, indexed by
    /// [`crate::serve::TrafficClass::slot`]. Sums to `queue_depth`.
    pub queued_by_class: [usize; 3],
    /// Sequences currently decoding (batch occupancy).
    pub active_seqs: usize,
    /// Fraction of KV-cache capacity in use, `[0, 1]`.
    pub kv_frac: f64,
    /// Requests completed so far.
    pub served: usize,
}

/// One heartbeat row: fleet aggregates plus every replica's gauges.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineRow {
    /// Boundary time, seconds (`k · cadence_s`).
    pub t_s: f64,
    /// Replicas in a routable (`Live`) state.
    pub live: usize,
    /// Total queued requests across the fleet.
    pub queue_depth: usize,
    /// Total decoding sequences across the fleet.
    pub active_seqs: usize,
    /// Total requests completed so far.
    pub served: usize,
    /// Sum of per-replica telemetry-window mean power, watts.
    pub power_w: f64,
    pub replicas: Vec<ReplicaSample>,
}

/// The heartbeat sampler the engine drives. Purely an observer: it holds
/// no reference into the engine and is handed `&[Replica]` at each tick.
#[derive(Debug)]
pub struct TimelineSampler {
    cadence_s: f64,
    /// Index of the next unemitted boundary (`time = next_k · cadence_s`).
    next_k: u64,
    pub rows: Vec<TimelineRow>,
}

impl TimelineSampler {
    pub fn new(cadence_s: f64) -> TimelineSampler {
        assert!(
            cadence_s.is_finite() && cadence_s > 0.0,
            "heartbeat cadence must be a positive finite duration, got {cadence_s}"
        );
        TimelineSampler { cadence_s, next_k: 0, rows: Vec::new() }
    }

    pub fn cadence_s(&self) -> f64 {
        self.cadence_s
    }

    /// Boundary time of index `k`. Multiplication (not accumulation)
    /// keeps boundary `k` bit-identical regardless of tick history.
    fn boundary(&self, k: u64) -> f64 {
        k as f64 * self.cadence_s
    }

    /// Emit every pending boundary strictly before `t_next` — called by
    /// the engine immediately before it processes an event at `t_next`.
    pub fn advance_to(&mut self, t_next: f64, reps: &[Replica]) {
        while self.boundary(self.next_k) < t_next {
            let b = self.boundary(self.next_k);
            self.sample(b, reps);
            self.next_k += 1;
        }
    }

    /// Flush the remaining boundaries through the makespan (inclusive),
    /// so the timeline always covers the whole run even when the final
    /// events land between boundaries.
    pub fn finish(&mut self, makespan_s: f64, reps: &[Replica]) {
        while self.boundary(self.next_k) <= makespan_s {
            let b = self.boundary(self.next_k);
            self.sample(b, reps);
            self.next_k += 1;
        }
    }

    fn sample(&mut self, t_s: f64, reps: &[Replica]) {
        let mut row = TimelineRow {
            t_s,
            live: 0,
            queue_depth: 0,
            active_seqs: 0,
            served: 0,
            power_w: 0.0,
            replicas: Vec::with_capacity(reps.len()),
        };
        for (i, r) in reps.iter().enumerate() {
            let s = ReplicaSample {
                replica: i,
                state: r.state.label(),
                freq_mhz: r.freq_mhz(),
                power_w: r.window_power_w(),
                queue_depth: r.queue_depth(),
                queued_by_class: r.queued_by_class(),
                active_seqs: r.active_seqs(),
                kv_frac: r.kv_used_frac(),
                served: r.served,
            };
            row.live += usize::from(r.state.routable());
            row.queue_depth += s.queue_depth;
            row.active_seqs += s.active_seqs;
            row.served += s.served;
            row.power_w += s.power_w;
            row.replicas.push(s);
        }
        self.rows.push(row);
    }
}

/// The first `timeline.jsonl` line: schema identity plus run identity.
pub fn timeline_header(run: &str, seed: u64, cadence_s: f64) -> JsonValue {
    obj(vec![
        ("schema", text("ewatt.timeline")),
        ("version", uint(TIMELINE_SCHEMA_VERSION as usize)),
        ("run", text(run)),
        ("seed", text(&format!("{seed:#x}"))),
        ("cadence_s", num(cadence_s)),
    ])
}

fn replica_sample_json(s: &ReplicaSample) -> JsonValue {
    let by_class = s.queued_by_class.iter().map(|&q| uint(q)).collect();
    obj(vec![
        ("replica", uint(s.replica)),
        ("state", text(s.state)),
        ("freq_mhz", uint(s.freq_mhz as usize)),
        ("power_w", num(s.power_w)),
        ("queue_depth", uint(s.queue_depth)),
        ("queued_by_class", JsonValue::Array(by_class)),
        ("active_seqs", uint(s.active_seqs)),
        ("kv_frac", num(s.kv_frac)),
        ("served", uint(s.served)),
    ])
}

/// One row as a flat JSON object: `t_s`, the fleet aggregates, then the
/// per-replica gauge array.
pub fn timeline_row_json(row: &TimelineRow) -> JsonValue {
    obj(vec![
        ("t_s", num(row.t_s)),
        (
            "fleet",
            obj(vec![
                ("live", uint(row.live)),
                ("queue_depth", uint(row.queue_depth)),
                ("active_seqs", uint(row.active_seqs)),
                ("served", uint(row.served)),
                ("power_w", num(row.power_w)),
            ]),
        ),
        ("replicas", JsonValue::Array(row.replicas.iter().map(replica_sample_json).collect())),
    ])
}

/// Render a full timeline file: header line, then one line per row,
/// `\n`-terminated. Deterministic to the byte.
pub fn timeline_jsonl(header: &JsonValue, rows: &[TimelineRow]) -> String {
    let mut out = String::new();
    out.push_str(&header.to_string());
    out.push('\n');
    for row in rows {
        out.push_str(&timeline_row_json(row).to_string());
        out.push('\n');
    }
    out
}

/// Write a timeline file and hand back nothing (the caller knows the
/// path); errors carry the path.
pub fn write_timeline_jsonl(path: &Path, header: &JsonValue, rows: &[TimelineRow]) -> Result<()> {
    std::fs::write(path, timeline_jsonl(header, rows))
        .with_context(|| format!("writing timeline to {}", path.display()))
}

/// Validate a `timeline.jsonl` body: canonical line form, the expected
/// schema/version header, and every row parsing as an object with a
/// finite nondecreasing `t_s`, a `fleet` aggregate object, and a
/// `replicas` array. Returns the row count (0 for a header-only file).
pub fn validate_timeline_jsonl(body: &str) -> Result<usize> {
    let lines = strict_jsonl_lines(body)?;
    let mut lines = lines.into_iter();
    let header = lines.next().context("empty timeline file")?;
    check_jsonl_header(header, "ewatt.timeline", TIMELINE_SCHEMA_VERSION)?;
    let mut n = 0usize;
    let mut prev_t = f64::NEG_INFINITY;
    for (i, line) in lines.enumerate() {
        let v = JsonValue::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: parse error: {e}", i + 2))?;
        let t = v.get("t_s").and_then(JsonValue::as_f64);
        ensure!(t.is_some_and(f64::is_finite), "line {}: missing finite t_s", i + 2);
        let t = t.unwrap();
        ensure!(t > prev_t, "line {}: non-increasing t_s {t} after {prev_t}", i + 2);
        prev_t = t;
        ensure!(
            v.get("fleet").and_then(|f| f.get("live")).and_then(JsonValue::as_f64).is_some(),
            "line {}: missing fleet aggregates",
            i + 2
        );
        ensure!(
            v.get("replicas").and_then(JsonValue::as_array).is_some(),
            "line {}: missing replicas array",
            i + 2
        );
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(t_s: f64) -> TimelineRow {
        TimelineRow {
            t_s,
            live: 1,
            queue_depth: 2,
            active_seqs: 3,
            served: 4,
            power_w: 123.5,
            replicas: vec![ReplicaSample {
                replica: 0,
                state: "live",
                freq_mhz: 2842,
                power_w: 123.5,
                queue_depth: 2,
                queued_by_class: [2, 0, 0],
                active_seqs: 3,
                kv_frac: 0.25,
                served: 4,
            }],
        }
    }

    #[test]
    fn jsonl_round_trips_and_validates() {
        let header = timeline_header("unit", 0x5CE1, 0.5);
        let rows = vec![row(0.0), row(0.5), row(1.0)];
        let body = timeline_jsonl(&header, &rows);
        assert_eq!(validate_timeline_jsonl(&body).unwrap(), rows.len());
        // Byte determinism: rendering twice is identical.
        assert_eq!(body, timeline_jsonl(&header, &rows));
        // Header carries schema + cadence; rows carry the gauge fields.
        let first = body.lines().next().unwrap();
        assert!(first.contains("\"ewatt.timeline\""), "{first}");
        assert!(first.contains("\"cadence_s\":0.5"), "{first}");
        let parsed = JsonValue::parse(body.lines().nth(1).unwrap()).unwrap();
        let rep = &parsed.get("replicas").unwrap().as_array().unwrap()[0];
        assert_eq!(rep.get("state").unwrap().as_str(), Some("live"));
        assert_eq!(rep.get("freq_mhz").unwrap().as_usize(), Some(2842));
        let by_class = rep.get("queued_by_class").unwrap().as_array().unwrap();
        assert_eq!(by_class.len(), 3);
        assert_eq!(by_class[0].as_usize(), Some(2));
        assert_eq!(parsed.get("fleet").unwrap().get("live").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn validation_rejects_malformed_timelines() {
        assert!(validate_timeline_jsonl("").is_err());
        assert!(validate_timeline_jsonl("{\"schema\":\"ewatt.trace\",\"version\":1}\n").is_err());
        let header = timeline_header("x", 1, 0.5).to_string();
        // Header-only is a valid empty timeline.
        assert_eq!(validate_timeline_jsonl(&format!("{header}\n")).unwrap(), 0);
        // Rows must carry fleet aggregates and a replicas array.
        let bad = format!("{header}\n{{\"t_s\":0}}\n");
        assert!(validate_timeline_jsonl(&bad).is_err());
        // Time must strictly increase.
        let r = timeline_row_json(&row(1.0)).to_string();
        let stuck = format!("{header}\n{r}\n{r}\n");
        let err = validate_timeline_jsonl(&stuck).unwrap_err().to_string();
        assert!(err.contains("non-increasing"), "{err}");
        // The strict line form applies here like traces.
        assert!(validate_timeline_jsonl(&format!("{header}\r\n")).is_err());
    }

    #[test]
    fn sampler_emits_boundaries_exactly_once() {
        // No replicas needed to check the boundary arithmetic.
        let mut tl = TimelineSampler::new(0.5);
        tl.advance_to(0.2, &[]); // boundary 0.0 only
        assert_eq!(tl.rows.len(), 1);
        tl.advance_to(0.2, &[]); // idempotent at the same time
        assert_eq!(tl.rows.len(), 1);
        tl.advance_to(1.0, &[]); // 0.5 (1.0 is not strictly before 1.0)
        assert_eq!(tl.rows.len(), 2);
        tl.finish(2.0, &[]); // 1.0, 1.5, 2.0 inclusive
        assert_eq!(tl.rows.len(), 5);
        let ts: Vec<f64> = tl.rows.iter().map(|r| r.t_s).collect();
        assert_eq!(ts, vec![0.0, 0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    #[should_panic(expected = "positive finite")]
    fn zero_cadence_is_rejected() {
        TimelineSampler::new(0.0);
    }
}
