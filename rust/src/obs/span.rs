//! Typed request-lifecycle and engine span events.
//!
//! Every observable moment of a fleet run — a request queueing, routing,
//! admitting, prefilling, decoding, completing; a governor switching
//! frequency; the autoscaler warming or draining a replica; a crash and
//! its requeues — is one [`SpanEvent`] stamped with **simulated** time
//! (never wall clock), so a traced run under a fixed seed reproduces its
//! event stream byte-for-byte.
//!
//! The engine emits through a [`Trace`] handle holding an optional
//! [`TraceSink`]. With no sink attached (the default on every existing
//! entry point) each emit site is a single branch: the event constructor
//! is a closure that never runs, so tracing costs nothing when disabled —
//! the scenario snapshot and `ewatt bench --check` pin both the physics
//! and the perf budget of that path.
//!
//! Timestamp contract (asserted by `rust/tests/proptest_invariants.rs`):
//! per request, event timestamps are monotone non-decreasing within one
//! serving attempt. A crash-requeue ([`SpanEvent::Requeued`]) starts a new
//! attempt and may rewind the clock to the crash instant — a step that
//! straddled the crash completes (and is charged) before the crash is
//! processed, exactly as [`crate::fleet::Replica::crash`] documents — but
//! every event of the new attempt is at or after the requeue timestamp.

use crate::fleet::attribution::PhaseEnergy;
use crate::serve::traffic::TrafficClass;

/// One observable moment of a run: request lifecycle milestones plus
/// engine-level governor/autoscaler/failure transitions.
#[derive(Debug, Clone, PartialEq)]
pub enum SpanEvent {
    /// A request entered the system (original arrival, never a requeue).
    Queued { req: usize, query_idx: usize, class: TrafficClass },
    /// The router bound a request to a live replica.
    Routed { req: usize, replica: usize },
    /// A crash dropped an in-flight request; it re-enters routing with its
    /// original arrival timestamp (`replica` is the replica that died).
    Requeued { req: usize, replica: usize },
    /// An in-flight sequence was checkpointed off `from` (a drain or a
    /// crash rollback) and handed to the router; `tokens` is its decoded
    /// progress carried in the checkpoint.
    Migrated { req: usize, from: usize, tokens: usize },
    /// A checkpointed sequence finished its prefill replay on `replica`
    /// and rejoined the batch; `replay_tokens` is the replayed context
    /// length, `joules` the replay energy (the `migration_j` phase).
    Resumed { req: usize, replica: usize, replay_tokens: usize, joules: f64 },
    /// A replica popped the request off its admission queue.
    Admitted { req: usize, replica: usize },
    /// Prefill began at the governor's chosen set point.
    PrefillStart { req: usize, replica: usize, freq_mhz: u32 },
    /// Prefill finished: `passes` forward passes (one per answer option
    /// for classification), `joules` their total measured energy.
    PrefillEnd { req: usize, replica: usize, freq_mhz: u32, passes: usize, joules: f64 },
    /// One batched decode step; `joules` splits equally across `batch`.
    DecodeStep { replica: usize, freq_mhz: u32, batch: Vec<usize>, joules: f64 },
    /// The request completed on `replica`.
    Served {
        req: usize,
        replica: usize,
        class: TrafficClass,
        ttft_s: f64,
        tbt_s: f64,
        e2e_s: f64,
        tokens: usize,
    },
    /// A DVFS transition: `joules` is the switch-latency energy, charged
    /// to `beneficiaries` (the requests of the step that follows).
    FreqSwitch { replica: usize, to_mhz: u32, joules: f64, beneficiaries: Vec<usize> },
    /// The autoscaler brought capacity up: a drain rescue (`cold_start ==
    /// false`, immediately live) or a cold start (warm-up scheduled).
    ScaleUp { replica: usize, cold_start: bool },
    /// The autoscaler began draining a replica.
    ScaleDown { replica: usize },
    /// A warm-up completed (`Warming → Live`).
    WarmDone { replica: usize },
    /// A replica crashed, dropping `lost` in-flight requests.
    Failed { replica: usize, lost: usize },
    /// A repair completed; the replica begins a fresh cold start.
    Recovered { replica: usize },
    /// Finalize-time bill: the request's exact attributed energy from the
    /// [`crate::fleet::EnergyLedger`], including amortized idle and
    /// cold-start shares. Emitted once per request at the run's makespan.
    RequestSummary { req: usize, replica: usize, class: TrafficClass, energy: PhaseEnergy },
}

impl SpanEvent {
    /// Stable snake_case discriminant used by the `traces.jsonl` schema.
    pub fn kind(&self) -> &'static str {
        match self {
            SpanEvent::Queued { .. } => "queued",
            SpanEvent::Routed { .. } => "routed",
            SpanEvent::Requeued { .. } => "requeued",
            SpanEvent::Migrated { .. } => "migrated",
            SpanEvent::Resumed { .. } => "resumed",
            SpanEvent::Admitted { .. } => "admitted",
            SpanEvent::PrefillStart { .. } => "prefill_start",
            SpanEvent::PrefillEnd { .. } => "prefill_end",
            SpanEvent::DecodeStep { .. } => "decode_step",
            SpanEvent::Served { .. } => "served",
            SpanEvent::FreqSwitch { .. } => "freq_switch",
            SpanEvent::ScaleUp { .. } => "scale_up",
            SpanEvent::ScaleDown { .. } => "scale_down",
            SpanEvent::WarmDone { .. } => "warm_done",
            SpanEvent::Failed { .. } => "failed",
            SpanEvent::Recovered { .. } => "recovered",
            SpanEvent::RequestSummary { .. } => "request_summary",
        }
    }

    /// The request this event belongs to, if it is request-scoped.
    /// `DecodeStep` spans a whole batch and reports `None`; use
    /// [`SpanEvent::batch`] for its members.
    pub fn req(&self) -> Option<usize> {
        match *self {
            SpanEvent::Queued { req, .. }
            | SpanEvent::Routed { req, .. }
            | SpanEvent::Requeued { req, .. }
            | SpanEvent::Migrated { req, .. }
            | SpanEvent::Resumed { req, .. }
            | SpanEvent::Admitted { req, .. }
            | SpanEvent::PrefillStart { req, .. }
            | SpanEvent::PrefillEnd { req, .. }
            | SpanEvent::Served { req, .. }
            | SpanEvent::RequestSummary { req, .. } => Some(req),
            _ => None,
        }
    }

    /// The traffic class of a class-tagged event (`Queued` / `Served` /
    /// `RequestSummary`), `None` otherwise.
    pub fn class(&self) -> Option<TrafficClass> {
        match *self {
            SpanEvent::Queued { class, .. }
            | SpanEvent::Served { class, .. }
            | SpanEvent::RequestSummary { class, .. } => Some(class),
            _ => None,
        }
    }

    /// The co-batched requests of a decode step (empty otherwise).
    pub fn batch(&self) -> &[usize] {
        match self {
            SpanEvent::DecodeStep { batch, .. } => batch,
            _ => &[],
        }
    }
}

/// One emitted event with its simulated timestamp, seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub t_s: f64,
    pub event: SpanEvent,
}

/// Anything that can absorb the engine's span stream.
///
/// Implementations must be order-preserving observers: a sink never feeds
/// back into the physics, so a traced run is bit-identical to an untraced
/// one (pinned by `rust/tests/obs_trace.rs`).
pub trait TraceSink {
    fn emit(&mut self, t_s: f64, event: SpanEvent);
}

/// The zero-cost default: drops everything. Stands in for "tracing
/// disabled" wherever an API requires a sink *value*; the engine itself
/// prefers `Trace::off()`, which skips even the virtual call.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn emit(&mut self, _t_s: f64, _event: SpanEvent) {}
}

/// Collects the full span stream in memory (exporters and tests).
#[derive(Debug, Default)]
pub struct Recorder {
    pub spans: Vec<Span>,
}

impl TraceSink for Recorder {
    fn emit(&mut self, t_s: f64, event: SpanEvent) {
        self.spans.push(Span { t_s, event });
    }
}

/// The borrowed handle the engine threads through a run. `sink == None`
/// makes every [`Trace::emit`] a single branch — the event closure (and
/// any allocation inside it) never runs.
pub struct Trace<'a> {
    sink: Option<&'a mut dyn TraceSink>,
    /// Index of the replica currently stepping — set by the engine before
    /// each step so replica-internal emit sites can name themselves.
    pub replica: usize,
}

impl<'a> Trace<'a> {
    pub fn new(sink: Option<&'a mut dyn TraceSink>) -> Trace<'a> {
        Trace { sink, replica: 0 }
    }

    /// A disabled handle (worker threads, single-replica test drivers).
    pub fn off() -> Trace<'static> {
        Trace { sink: None, replica: 0 }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Emit one event at simulated time `t_s`. The constructor closure is
    /// only invoked when a sink is attached.
    #[inline]
    pub fn emit(&mut self, t_s: f64, event: impl FnOnce() -> SpanEvent) {
        if let Some(sink) = self.sink.as_deref_mut() {
            sink.emit(t_s, event());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_never_runs_the_constructor() {
        let mut trace = Trace::off();
        assert!(!trace.enabled());
        trace.emit(0.0, || unreachable!("constructor must not run without a sink"));
    }

    #[test]
    fn recorder_keeps_emission_order_and_timestamps() {
        let mut rec = Recorder::default();
        {
            let mut trace = Trace::new(Some(&mut rec));
            assert!(trace.enabled());
            trace.emit(0.5, || SpanEvent::Queued {
                req: 0,
                query_idx: 3,
                class: TrafficClass::Interactive,
            });
            trace.replica = 2;
            let rep = trace.replica;
            trace.emit(0.75, || SpanEvent::Admitted { req: 0, replica: rep });
        }
        assert_eq!(rec.spans.len(), 2);
        assert_eq!(rec.spans[0].t_s, 0.5);
        assert_eq!(rec.spans[0].event.kind(), "queued");
        assert_eq!(rec.spans[1].event, SpanEvent::Admitted { req: 0, replica: 2 });
    }

    #[test]
    fn req_and_batch_accessors() {
        let served = SpanEvent::Served {
            req: 7,
            replica: 1,
            class: TrafficClass::Batch,
            ttft_s: 0.1,
            tbt_s: 0.01,
            e2e_s: 0.5,
            tokens: 40,
        };
        assert_eq!(served.req(), Some(7));
        assert_eq!(served.class(), Some(TrafficClass::Batch));
        assert!(served.batch().is_empty());
        let step =
            SpanEvent::DecodeStep { replica: 0, freq_mhz: 180, batch: vec![1, 2], joules: 3.0 };
        assert_eq!(step.req(), None);
        assert_eq!(step.batch(), &[1, 2]);
        assert_eq!(step.kind(), "decode_step");
    }

    #[test]
    fn migration_spans_are_request_scoped() {
        let mig = SpanEvent::Migrated { req: 3, from: 0, tokens: 5 };
        assert_eq!(mig.kind(), "migrated");
        assert_eq!(mig.req(), Some(3));
        let res = SpanEvent::Resumed { req: 3, replica: 1, replay_tokens: 12, joules: 0.5 };
        assert_eq!(res.kind(), "resumed");
        assert_eq!(res.req(), Some(3));
        assert_eq!(res.class(), None);
        assert!(res.batch().is_empty());
    }

    #[test]
    fn null_sink_is_a_no_op() {
        let mut sink = NullSink;
        sink.emit(1.0, SpanEvent::WarmDone { replica: 0 });
    }
}
