//! Deterministic observability: request-span tracing, heartbeat
//! telemetry, a static-key metrics registry, SLO/health alerting, run
//! comparison, and auditable exporters (`traces.jsonl`, `timeline.jsonl`,
//! run manifests, `diff.json`).
//!
//! The engine threads one optional [`TraceSink`] through a run
//! ([`crate::fleet::EngineCtx::trace`]) and, independently, one optional
//! [`TimelineSampler`] ([`crate::fleet::EngineCtx::timeline`]); everything
//! else here is derived from the resulting span stream and heartbeat
//! rows. All timestamps are simulated time, so fixed-seed artifacts are
//! byte-reproducible — and with neither observer attached the whole layer
//! costs one predicted branch per emit site (pinned by the scenario
//! snapshot and `ewatt bench --check`).
//!
//! Layer map: [`span`] defines the event stream, [`timeline`] the
//! fixed-cadence gauge stream, [`metrics`] the in-memory aggregates,
//! [`export`] the on-disk evidence, [`alerts`] the rule engine replaying
//! that evidence, and [`diff`] the two-run comparison (`ewatt diff`).

pub mod alerts;
pub mod diff;
pub mod export;
pub mod metrics;
pub mod span;
pub mod timeline;

pub use alerts::{evaluate as evaluate_alerts, AlertConfig, AlertFiring, AlertRule};
pub use diff::{DiffReport, RunSummary, DIFF_SCHEMA_VERSION};
pub use export::{
    fnv1a_64, span_to_json, trace_header, trace_jsonl, validate_trace_jsonl, write_trace_jsonl,
    RunManifest, MANIFEST_SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
};
pub use metrics::{Counter, Gauge, Hist, HistP2, MetricsRegistry};
pub use span::{NullSink, Recorder, Span, SpanEvent, Trace, TraceSink};
pub use timeline::{
    timeline_header, timeline_jsonl, validate_timeline_jsonl, write_timeline_jsonl, TimelineRow,
    TimelineSampler, DEFAULT_CADENCE_S, TIMELINE_SCHEMA_VERSION,
};
