//! Deterministic observability: request-span tracing, a static-key
//! metrics registry, and auditable exporters (`traces.jsonl` + run
//! manifests).
//!
//! The engine threads one optional [`TraceSink`] through a run
//! ([`crate::fleet::EngineCtx::trace`]); everything else here is derived
//! from the resulting span stream. All timestamps are simulated time, so
//! fixed-seed traces are byte-reproducible — and with no sink attached
//! the whole layer costs one predicted branch per emit site (pinned by
//! the scenario snapshot and `ewatt bench --check`).

pub mod export;
pub mod metrics;
pub mod span;

pub use export::{
    fnv1a_64, span_to_json, trace_header, trace_jsonl, validate_trace_jsonl, write_trace_jsonl,
    RunManifest, MANIFEST_SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
};
pub use metrics::{Counter, Gauge, Hist, HistP2, MetricsRegistry};
pub use span::{NullSink, Recorder, Span, SpanEvent, Trace, TraceSink};
