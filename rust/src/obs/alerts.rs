//! SLO burn-rate and fleet-health alerting over the observability stream.
//!
//! A rule engine that replays a run's evidence — the span stream from
//! [`crate::obs::Recorder`] plus the heartbeat rows from
//! [`crate::obs::TimelineSampler`] — and reports where operator-visible
//! thresholds were crossed. Four rules:
//!
//! - [`AlertRule::SloBurnRate`] — over a sliding window of completions,
//!   the fraction of requests violating the SLO, divided by the error
//!   budget, exceeded the burn-rate threshold (the multi-window burn-rate
//!   alerting idiom from SRE practice, applied to simulated time).
//! - [`AlertRule::FreqFlapping`] — a replica's governor reversed
//!   direction (up→down→up…) too many times inside a window sized from
//!   the hysteresis dwell, i.e. the high/low-water band is too narrow for
//!   the workload and the governor is paying switch energy for nothing.
//! - [`AlertRule::QueueGrowth`] — the fleet-wide admission queue grew
//!   monotonically across consecutive heartbeats to a non-trivial depth:
//!   offered load is outrunning capacity faster than scaling reacts.
//! - [`AlertRule::ConservationDrift`] — the finalize-time per-request
//!   energy bills ([`SpanEvent::RequestSummary`]) no longer sum to the
//!   ledger's total: an accounting bug, never a workload property. This
//!   rule firing on a clean run is a test failure
//!   (`rust/tests/obs_trace.rs` pins it to zero).
//!
//! Evaluation is a pure function of its inputs — no clocks, no RNG — so
//! the firing list is deterministic and byte-stable in the manifest.
//! Rules fire on the *rising edge*: a condition that stays bad for a
//! thousand samples yields one firing when it becomes bad, not a
//! thousand, until it clears and trips again.

use crate::obs::export::{num, obj, text, uint, RunManifest};
use crate::obs::span::{Span, SpanEvent};
use crate::obs::timeline::TimelineRow;
use crate::serve::governor::GovernorConfig;
use crate::serve::slo::{ClassSlos, Slo};
use crate::util::json::JsonValue;

/// Which rule fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AlertRule {
    SloBurnRate,
    FreqFlapping,
    QueueGrowth,
    ConservationDrift,
}

impl AlertRule {
    /// Stable snake_case discriminant used by the manifest schema.
    pub fn label(self) -> &'static str {
        match self {
            AlertRule::SloBurnRate => "slo_burn_rate",
            AlertRule::FreqFlapping => "freq_flapping",
            AlertRule::QueueGrowth => "queue_growth",
            AlertRule::ConservationDrift => "conservation_drift",
        }
    }
}

/// One rising-edge firing.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertFiring {
    pub rule: AlertRule,
    /// Simulated time the condition became true, seconds.
    pub t_s: f64,
    /// The replica at fault, for per-replica rules.
    pub replica: Option<usize>,
    /// The measured value that crossed the threshold (burn rate,
    /// reversal count, queue depth, relative drift).
    pub value: f64,
    pub message: String,
}

/// Thresholds for [`evaluate`]. The defaults are tuned so the clean
/// golden scenarios fire nothing (pinned by `rust/tests/obs_trace.rs`);
/// [`AlertConfig::for_governor`] derives the flap window from the
/// governor's actual dwell so the rule tracks the hysteresis band it
/// polices.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertConfig {
    /// Sliding window for the burn-rate rule, seconds.
    pub burn_window_s: f64,
    /// Fire when `violation_rate / error_budget` exceeds this.
    pub burn_threshold: f64,
    /// Tolerated SLO-violation fraction (e.g. 0.01 = 99% target).
    pub error_budget: f64,
    /// Minimum violations in-window before the burn rule may fire —
    /// keeps one unlucky request in a thin window from paging.
    pub burn_min_violations: usize,
    /// Sliding window for counting governor direction reversals, seconds.
    pub flap_window_s: f64,
    /// Reversals in-window that count as flapping.
    pub flap_reversals: usize,
    /// Consecutive heartbeats of strict fleet-queue growth to fire.
    pub queue_window: usize,
    /// The grown-to depth must also reach this for the rule to matter.
    pub queue_min_depth: usize,
    /// Relative error between Σ request bills and the ledger total.
    pub conservation_tol: f64,
    /// Per-class SLO budgets for the burn-rate rule. `None` (the default)
    /// measures every completion against the single fleet SLO; `Some`
    /// measures each completion against its own class's budget, so a slow
    /// Background request stops burning the Interactive error budget.
    pub class_slos: Option<ClassSlos>,
}

impl Default for AlertConfig {
    fn default() -> AlertConfig {
        AlertConfig {
            burn_window_s: 30.0,
            burn_threshold: 2.0,
            error_budget: 0.01,
            burn_min_violations: 3,
            // 20 dwell periods at the default governor dwell (0.25 s).
            flap_window_s: 5.0,
            flap_reversals: 4,
            queue_window: 6,
            queue_min_depth: 8,
            conservation_tol: 1e-6,
            class_slos: None,
        }
    }
}

impl AlertConfig {
    /// Size the flapping window from the governor the run actually used:
    /// 20 dwell periods, so "reversals per window" measures how often the
    /// governor changed its mind relative to how often it was *allowed* to.
    pub fn for_governor(gov: &GovernorConfig) -> AlertConfig {
        AlertConfig { flap_window_s: 20.0 * gov.dwell_s, ..AlertConfig::default() }
    }
}

/// Replay the evidence and return every rising-edge firing, sorted by
/// `(t_s, rule, replica)`. Pure and deterministic: same inputs, same
/// firings, byte-for-byte.
pub fn evaluate(
    spans: &[Span],
    rows: &[TimelineRow],
    slo: &Slo,
    ledger_total_j: f64,
    cfg: &AlertConfig,
) -> Vec<AlertFiring> {
    let mut firings = Vec::new();
    burn_rate(spans, slo, cfg, &mut firings);
    freq_flapping(spans, cfg, &mut firings);
    queue_growth(rows, cfg, &mut firings);
    conservation(spans, ledger_total_j, cfg, &mut firings);
    firings.sort_by(|a, b| {
        a.t_s
            .total_cmp(&b.t_s)
            .then_with(|| a.rule.cmp(&b.rule))
            .then_with(|| a.replica.cmp(&b.replica))
    });
    firings
}

/// Sliding-window SLO burn rate over completions. Each `served` span is
/// a sample; it violates when TTFT or end-to-end latency exceeds its SLO
/// bound. At each completion we look back `burn_window_s` and fire
/// (rising edge) when the in-window violation rate burns budget faster
/// than `burn_threshold`×.
fn burn_rate(spans: &[Span], slo: &Slo, cfg: &AlertConfig, out: &mut Vec<AlertFiring>) {
    // (t_s, violated) per completion, in emission (= time) order.
    let served: Vec<(f64, bool)> = spans
        .iter()
        .filter_map(|s| match s.event {
            SpanEvent::Served { class, ttft_s, e2e_s, .. } => {
                let budget = match &cfg.class_slos {
                    Some(cs) => cs.for_class(class),
                    None => *slo,
                };
                Some((s.t_s, ttft_s > budget.ttft_p95_s || e2e_s > budget.e2e_p99_s))
            }
            _ => None,
        })
        .collect();
    let mut lo = 0usize;
    let mut in_window_violations = 0usize;
    let mut firing = false;
    for hi in 0..served.len() {
        in_window_violations += usize::from(served[hi].1);
        while served[lo].0 < served[hi].0 - cfg.burn_window_s {
            in_window_violations -= usize::from(served[lo].1);
            lo += 1;
        }
        let total = hi - lo + 1;
        let burn = in_window_violations as f64 / total as f64 / cfg.error_budget;
        let bad = burn > cfg.burn_threshold && in_window_violations >= cfg.burn_min_violations;
        if bad && !firing {
            out.push(AlertFiring {
                rule: AlertRule::SloBurnRate,
                t_s: served[hi].0,
                replica: None,
                value: burn,
                message: format!(
                    "burn rate {burn:.1}x: {in_window_violations}/{total} requests violated \
                     the SLO in the last {:.0}s (budget {:.2}%)",
                    cfg.burn_window_s,
                    cfg.error_budget * 100.0
                ),
            });
        }
        firing = bad;
    }
}

/// Count governor direction reversals per replica inside a sliding
/// window. A reversal is an up-switch following a down-switch or vice
/// versa; same-direction steps (a governor walking multiple bins) are
/// not reversals.
fn freq_flapping(spans: &[Span], cfg: &AlertConfig, out: &mut Vec<AlertFiring>) {
    // Reversal instants per replica: the time of a switch whose direction
    // opposed the previous switch's.
    let mut reversals: Vec<(usize, Vec<f64>)> = Vec::new();
    // (replica, current set point, direction of the last switch).
    let mut last: Vec<(usize, u32, Option<i8>)> = Vec::new();
    for s in spans {
        if let SpanEvent::FreqSwitch { replica, to_mhz, .. } = s.event {
            match last.iter_mut().find(|(r, _, _)| *r == replica) {
                Some((_, mhz, dir)) => {
                    let d: i8 = if to_mhz > *mhz { 1 } else { -1 };
                    if dir.is_some_and(|prev| prev != d) {
                        match reversals.iter_mut().find(|(r, _)| *r == replica) {
                            Some((_, v)) => v.push(s.t_s),
                            None => reversals.push((replica, vec![s.t_s])),
                        }
                    }
                    *mhz = to_mhz;
                    *dir = Some(d);
                }
                // First observed switch has no direction history.
                None => last.push((replica, to_mhz, None)),
            }
        }
    }
    reversals.sort_by_key(|(r, _)| *r);
    for (replica, times) in reversals {
        let mut lo = 0usize;
        let mut firing = false;
        for hi in 0..times.len() {
            while times[lo] < times[hi] - cfg.flap_window_s {
                lo += 1;
            }
            let n = hi - lo + 1;
            let bad = n >= cfg.flap_reversals;
            if bad && !firing {
                out.push(AlertFiring {
                    rule: AlertRule::FreqFlapping,
                    t_s: times[hi],
                    replica: Some(replica),
                    value: n as f64,
                    message: format!(
                        "replica {replica}: {n} governor direction reversals in \
                         {:.2}s — hysteresis band too narrow for this workload",
                        cfg.flap_window_s
                    ),
                });
            }
            firing = bad;
        }
    }
}

/// Fleet-wide queue depth growing strictly across `queue_window`
/// consecutive heartbeats, ending at a depth worth paging about.
fn queue_growth(rows: &[TimelineRow], cfg: &AlertConfig, out: &mut Vec<AlertFiring>) {
    let mut run = 1usize; // length of the current strict-growth streak
    let mut firing = false;
    for i in 1..rows.len() {
        if rows[i].queue_depth > rows[i - 1].queue_depth {
            run += 1;
        } else {
            run = 1;
        }
        let bad = run >= cfg.queue_window && rows[i].queue_depth >= cfg.queue_min_depth;
        if bad && !firing {
            out.push(AlertFiring {
                rule: AlertRule::QueueGrowth,
                t_s: rows[i].t_s,
                replica: None,
                value: rows[i].queue_depth as f64,
                message: format!(
                    "fleet queue grew for {run} consecutive heartbeats to depth {} — \
                     offered load is outrunning capacity",
                    rows[i].queue_depth
                ),
            });
        }
        firing = bad;
    }
}

/// Σ finalize-time request bills must equal the ledger total. Drift is a
/// bookkeeping bug in the simulator, so the rule fires at the makespan
/// (the summaries' shared timestamp) with the relative error as value.
fn conservation(
    spans: &[Span],
    ledger_total_j: f64,
    cfg: &AlertConfig,
    out: &mut Vec<AlertFiring>,
) {
    let mut billed = 0.0f64;
    let mut t_last = 0.0f64;
    let mut any = false;
    for s in spans {
        if let SpanEvent::RequestSummary { ref energy, .. } = s.event {
            billed += energy.total_j();
            t_last = s.t_s;
            any = true;
        }
    }
    if !any {
        return;
    }
    let rel = (billed - ledger_total_j).abs() / ledger_total_j.max(f64::MIN_POSITIVE);
    if rel > cfg.conservation_tol {
        out.push(AlertFiring {
            rule: AlertRule::ConservationDrift,
            t_s: t_last,
            replica: None,
            value: rel,
            message: format!(
                "request bills sum to {billed:.6} J but the ledger holds \
                 {ledger_total_j:.6} J (rel err {rel:.3e}) — energy accounting bug"
            ),
        });
    }
}

fn firing_json(f: &AlertFiring) -> JsonValue {
    let mut fields = vec![
        ("rule", text(f.rule.label())),
        ("t_s", num(f.t_s)),
        ("value", num(f.value)),
        ("message", text(&f.message)),
    ];
    if let Some(r) = f.replica {
        fields.push(("replica", uint(r)));
    }
    obj(fields)
}

impl RunManifest {
    /// Record the alert evaluation in the manifest: a count plus the full
    /// firing list, so a clean run auditable as `"alerts":{"count":0,...}`
    /// and a dirty one carries its evidence.
    pub fn set_alerts(&mut self, firings: &[AlertFiring]) {
        self.set(
            "alerts",
            obj(vec![
                ("count", uint(firings.len())),
                ("firings", JsonValue::Array(firings.iter().map(firing_json).collect())),
            ]),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeline::TimelineRow;
    use crate::serve::traffic::TrafficClass;

    fn served(t_s: f64, e2e_s: f64) -> Span {
        served_class(t_s, e2e_s, TrafficClass::Interactive)
    }

    fn served_class(t_s: f64, e2e_s: f64, class: TrafficClass) -> Span {
        Span {
            t_s,
            event: SpanEvent::Served {
                req: 0,
                replica: 0,
                class,
                ttft_s: 0.01,
                tbt_s: 0.005,
                e2e_s,
                tokens: 8,
            },
        }
    }

    fn switch(t_s: f64, to_mhz: u32) -> Span {
        Span {
            t_s,
            event: SpanEvent::FreqSwitch { replica: 0, to_mhz, joules: 0.1, beneficiaries: vec![] },
        }
    }

    fn queue_row(t_s: f64, depth: usize) -> TimelineRow {
        TimelineRow {
            t_s,
            live: 1,
            queue_depth: depth,
            active_seqs: 0,
            served: 0,
            power_w: 0.0,
            replicas: vec![],
        }
    }

    fn slo() -> Slo {
        Slo { ttft_p95_s: 1.0, tbt_p95_s: 0.1, e2e_p99_s: 2.0 }
    }

    #[test]
    fn clean_stream_fires_nothing() {
        let spans: Vec<Span> = (0..40).map(|i| served(i as f64, 0.5)).collect();
        let rows: Vec<TimelineRow> = (0..20).map(|i| queue_row(i as f64 * 0.5, 1)).collect();
        let f = evaluate(&spans, &rows, &slo(), 0.0, &AlertConfig::default());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn burn_rate_fires_once_per_rising_edge() {
        // 10 good completions, then a sustained run of violations: one
        // firing at the edge, not one per bad request.
        let mut spans: Vec<Span> = (0..10).map(|i| served(i as f64, 0.5)).collect();
        spans.extend((10..20).map(|i| served(i as f64, 5.0)));
        let f = evaluate(&spans, &[], &slo(), 0.0, &AlertConfig::default());
        let burns: Vec<_> = f.iter().filter(|f| f.rule == AlertRule::SloBurnRate).collect();
        assert_eq!(burns.len(), 1, "{f:?}");
        // Third violation (min_violations) lands at t=12.
        assert_eq!(burns[0].t_s, 12.0);
        assert!(burns[0].value > 2.0);
        // A fourth identical evaluation is byte-deterministic.
        assert_eq!(f, evaluate(&spans, &[], &slo(), 0.0, &AlertConfig::default()));
    }

    #[test]
    fn class_slos_judge_each_completion_against_its_own_budget() {
        // Background completions at 5s violate the 2s fleet SLO but sit
        // far inside the background budget (180s e2e): with class budgets
        // attached the burn rule stays silent, without them it fires.
        let mut spans: Vec<Span> = (0..10).map(|i| served(i as f64, 0.5)).collect();
        spans.extend((10..20).map(|i| served_class(i as f64, 5.0, TrafficClass::Background)));
        let blind = evaluate(&spans, &[], &slo(), 0.0, &AlertConfig::default());
        assert!(blind.iter().any(|f| f.rule == AlertRule::SloBurnRate), "{blind:?}");
        let cfg = AlertConfig { class_slos: Some(ClassSlos::default()), ..AlertConfig::default() };
        let aware = evaluate(&spans, &[], &slo(), 0.0, &cfg);
        assert!(aware.is_empty(), "{aware:?}");
        // An Interactive completion past its own 8s class budget still
        // burns — the class tag routes it to the strict budget.
        let mut bad = spans.clone();
        bad.extend((20..30).map(|i| served(i as f64, 10.0)));
        let f = evaluate(&bad, &[], &slo(), 0.0, &cfg);
        assert!(f.iter().any(|f| f.rule == AlertRule::SloBurnRate), "{f:?}");
    }

    #[test]
    fn flapping_counts_reversals_not_switches() {
        // A governor walking steadily down never reverses: silent.
        let down: Vec<Span> =
            (0..10).map(|i| switch(i as f64 * 0.3, 2000 - 100 * i as u32)).collect();
        let f = evaluate(&down, &[], &slo(), 0.0, &AlertConfig::default());
        assert!(f.iter().all(|f| f.rule != AlertRule::FreqFlapping), "{f:?}");
        // Oscillating inside the window trips the rule, attributed to the
        // replica.
        let flap: Vec<Span> = (0..10)
            .map(|i| switch(i as f64 * 0.3, if i % 2 == 0 { 2000 } else { 1500 }))
            .collect();
        let f = evaluate(&flap, &[], &slo(), 0.0, &AlertConfig::default());
        let flaps: Vec<_> = f.iter().filter(|f| f.rule == AlertRule::FreqFlapping).collect();
        assert_eq!(flaps.len(), 1, "{f:?}");
        assert_eq!(flaps[0].replica, Some(0));
        assert!(flaps[0].value >= 4.0);
    }

    #[test]
    fn queue_growth_needs_sustained_strict_growth() {
        // Sawtooth never sustains: silent.
        let saw: Vec<TimelineRow> =
            (0..30).map(|i| queue_row(i as f64 * 0.5, if i % 2 == 0 { 2 } else { 9 })).collect();
        let f = evaluate(&[], &saw, &slo(), 0.0, &AlertConfig::default());
        assert!(f.is_empty(), "{f:?}");
        // Monotone growth to a real depth fires once.
        let grow: Vec<TimelineRow> = (0..12).map(|i| queue_row(i as f64 * 0.5, i + 1)).collect();
        let f = evaluate(&[], &grow, &slo(), 0.0, &AlertConfig::default());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, AlertRule::QueueGrowth);
        assert!(f[0].value >= 8.0);
    }

    #[test]
    fn conservation_drift_detects_a_tampered_ledger() {
        use crate::fleet::attribution::PhaseEnergy;
        let bill = PhaseEnergy {
            prefill_j: 1.0,
            decode_j: 2.0,
            switch_j: 0.0,
            migration_j: 0.0,
            idle_j: 0.5,
            coldstart_j: 0.0,
        };
        let spans = vec![Span {
            t_s: 10.0,
            event: SpanEvent::RequestSummary {
                req: 0,
                replica: 0,
                class: TrafficClass::Interactive,
                energy: bill,
            },
        }];
        // Matching ledger: silent.
        let f = evaluate(&spans, &[], &slo(), 3.5, &AlertConfig::default());
        assert!(f.is_empty(), "{f:?}");
        // Tampered ledger: fires with the relative error as evidence.
        let f = evaluate(&spans, &[], &slo(), 3.6, &AlertConfig::default());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, AlertRule::ConservationDrift);
        assert!(f[0].value > 1e-3);
        assert_eq!(f[0].t_s, 10.0);
    }

    #[test]
    fn manifest_records_firings_deterministically() {
        let mut m = RunManifest::new("unit", 0x5CE1);
        m.set_alerts(&[AlertFiring {
            rule: AlertRule::QueueGrowth,
            t_s: 3.0,
            replica: None,
            value: 9.0,
            message: "queue".into(),
        }]);
        let j = m.to_json();
        let alerts = j.get("alerts").unwrap();
        assert_eq!(alerts.get("count").unwrap().as_usize(), Some(1));
        let fir = &alerts.get("firings").unwrap().as_array().unwrap()[0];
        assert_eq!(fir.get("rule").unwrap().as_str(), Some("queue_growth"));
        // Empty evaluation renders the auditable zero.
        m.set_alerts(&[]);
        assert_eq!(m.to_json().get("alerts").unwrap().get("count").unwrap().as_usize(), Some(0));
    }
}
