//! Trace and manifest exporters: the auditable evidence of a run.
//!
//! Two artifacts back every claimed J/req number:
//!
//! - **`traces.jsonl`** — one JSON object per line: a schema-versioned
//!   header first, then every [`Span`] in emission order. Written through
//!   [`crate::util::json`], whose `BTreeMap` objects serialize keys in
//!   sorted order — so a fixed seed reproduces the file *byte-for-byte*,
//!   and two runs can be diffed with plain `diff`.
//! - **`manifest.json`** — a [`RunManifest`]: command, seed, config
//!   digest, build info, outcome summary, and a per-phase/per-replica
//!   joule rollup recomputed from the trace's `request_summary` spans and
//!   cross-checked against the [`crate::fleet::EnergyLedger`] totals to
//!   ≤ 1e-6 relative error. A manifest that fails its own cross-check is
//!   an `Err`, never a silently-wrong file.
//!
//! Seeds are serialized as hex *strings* (`"0x5ce1"`): the JSON layer
//! stores numbers as `f64`, which cannot round-trip all 64-bit seeds.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context as _, Result};

use crate::fleet::attribution::PhaseEnergy;
use crate::fleet::FleetOutcome;
use crate::obs::span::{Span, SpanEvent};
use crate::serve::traffic::TrafficClass;
use crate::util::json::JsonValue;

/// Version of the `traces.jsonl` line schema. Bump on any breaking change
/// to span field names or the header shape.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// Version of the manifest field layout.
pub const MANIFEST_SCHEMA_VERSION: u64 = 1;

/// FNV-1a 64-bit — the config digest hash. Stable across platforms and
/// dependency-free; collisions are irrelevant at "did the config change"
/// granularity.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub(crate) fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub(crate) fn num(x: f64) -> JsonValue {
    JsonValue::Number(x)
}

pub(crate) fn uint(x: usize) -> JsonValue {
    JsonValue::Number(x as f64)
}

pub(crate) fn text(x: &str) -> JsonValue {
    JsonValue::String(x.to_string())
}

pub(crate) fn uints(xs: &[usize]) -> JsonValue {
    JsonValue::Array(xs.iter().map(|&x| uint(x)).collect())
}

pub(crate) fn phase_energy_json(e: &PhaseEnergy) -> JsonValue {
    obj(vec![
        ("prefill_j", num(e.prefill_j)),
        ("decode_j", num(e.decode_j)),
        ("switch_j", num(e.switch_j)),
        ("migration_j", num(e.migration_j)),
        ("idle_j", num(e.idle_j)),
        ("coldstart_j", num(e.coldstart_j)),
        ("total_j", num(e.total_j())),
    ])
}

/// One span as a flat JSON object: `t_s`, `kind`, then the event fields.
pub fn span_to_json(span: &Span) -> JsonValue {
    let mut pairs = vec![("t_s", num(span.t_s)), ("kind", text(span.event.kind()))];
    match &span.event {
        SpanEvent::Queued { req, query_idx, class } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("query_idx", uint(*query_idx)));
            pairs.push(("class", text(class.label())));
        }
        SpanEvent::Routed { req, replica }
        | SpanEvent::Requeued { req, replica }
        | SpanEvent::Admitted { req, replica } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
        }
        SpanEvent::Migrated { req, from, tokens } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("from", uint(*from)));
            pairs.push(("tokens", uint(*tokens)));
        }
        SpanEvent::Resumed { req, replica, replay_tokens, joules } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
            pairs.push(("replay_tokens", uint(*replay_tokens)));
            pairs.push(("joules", num(*joules)));
        }
        SpanEvent::PrefillStart { req, replica, freq_mhz } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
            pairs.push(("freq_mhz", uint(*freq_mhz as usize)));
        }
        SpanEvent::PrefillEnd { req, replica, freq_mhz, passes, joules } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
            pairs.push(("freq_mhz", uint(*freq_mhz as usize)));
            pairs.push(("passes", uint(*passes)));
            pairs.push(("joules", num(*joules)));
        }
        SpanEvent::DecodeStep { replica, freq_mhz, batch, joules } => {
            pairs.push(("replica", uint(*replica)));
            pairs.push(("freq_mhz", uint(*freq_mhz as usize)));
            pairs.push(("batch", uints(batch)));
            pairs.push(("joules", num(*joules)));
        }
        SpanEvent::Served { req, replica, class, ttft_s, tbt_s, e2e_s, tokens } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
            pairs.push(("class", text(class.label())));
            pairs.push(("ttft_s", num(*ttft_s)));
            pairs.push(("tbt_s", num(*tbt_s)));
            pairs.push(("e2e_s", num(*e2e_s)));
            pairs.push(("tokens", uint(*tokens)));
        }
        SpanEvent::FreqSwitch { replica, to_mhz, joules, beneficiaries } => {
            pairs.push(("replica", uint(*replica)));
            pairs.push(("to_mhz", uint(*to_mhz as usize)));
            pairs.push(("joules", num(*joules)));
            pairs.push(("beneficiaries", uints(beneficiaries)));
        }
        SpanEvent::ScaleUp { replica, cold_start } => {
            pairs.push(("replica", uint(*replica)));
            pairs.push(("cold_start", JsonValue::Bool(*cold_start)));
        }
        SpanEvent::ScaleDown { replica }
        | SpanEvent::WarmDone { replica }
        | SpanEvent::Recovered { replica } => {
            pairs.push(("replica", uint(*replica)));
        }
        SpanEvent::Failed { replica, lost } => {
            pairs.push(("replica", uint(*replica)));
            pairs.push(("lost", uint(*lost)));
        }
        SpanEvent::RequestSummary { req, replica, class, energy } => {
            pairs.push(("req", uint(*req)));
            pairs.push(("replica", uint(*replica)));
            pairs.push(("class", text(class.label())));
            pairs.push(("energy", phase_energy_json(energy)));
        }
    }
    obj(pairs)
}

/// The first `traces.jsonl` line: schema identity plus run identity.
pub fn trace_header(run: &str, seed: u64, config_digest: &str) -> JsonValue {
    obj(vec![
        ("schema", text("ewatt.trace")),
        ("version", uint(TRACE_SCHEMA_VERSION as usize)),
        ("run", text(run)),
        ("seed", text(&format!("{seed:#x}"))),
        ("config_digest", text(config_digest)),
    ])
}

/// Render a full trace file: header line, then one line per span, each a
/// compact JSON object, `\n`-terminated. Deterministic to the byte.
pub fn trace_jsonl(header: &JsonValue, spans: &[Span]) -> String {
    let mut out = String::new();
    out.push_str(&header.to_string());
    out.push('\n');
    for s in spans {
        out.push_str(&span_to_json(s).to_string());
        out.push('\n');
    }
    out
}

/// Write a trace file and hand back its path.
pub fn write_trace_jsonl(path: &Path, header: &JsonValue, spans: &[Span]) -> Result<()> {
    std::fs::write(path, trace_jsonl(header, spans))
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Split a JSONL body into its lines, enforcing the canonical byte form:
/// LF terminators only (a `\r` anywhere is a CRLF-converted file, not the
/// artifact the run wrote) and no trailing whitespace on any line —
/// byte-determinism is the whole point of these files, so near-miss
/// encodings are rejected loudly instead of parsed leniently.
pub(crate) fn strict_jsonl_lines(body: &str) -> Result<Vec<&str>> {
    let mut lines: Vec<&str> = body.split('\n').collect();
    // A terminating newline leaves one empty tail element; its absence is
    // tolerated (the writers always terminate, but validation is for
    // foreign files too).
    if lines.last() == Some(&"") {
        lines.pop();
    }
    for (i, line) in lines.iter().enumerate() {
        ensure!(
            !line.contains('\r'),
            "line {}: carriage return (CRLF line ending?) — jsonl artifacts are LF-terminated",
            i + 1
        );
        ensure!(
            *line == line.trim_end(),
            "line {}: trailing whitespace breaks byte-determinism",
            i + 1
        );
    }
    Ok(lines)
}

/// Check a JSONL header object for the expected schema name and version.
pub(crate) fn check_jsonl_header(header: &str, schema: &str, version: u64) -> Result<()> {
    let h = JsonValue::parse(header).map_err(|e| anyhow::anyhow!("bad header: {e}"))?;
    ensure!(
        h.get("schema").and_then(JsonValue::as_str) == Some(schema),
        "header is not an {schema} object: {header}"
    );
    let got = h.get("version").and_then(JsonValue::as_f64);
    ensure!(
        got == Some(version as f64),
        "unsupported {schema} schema version {got:?} (expected {version})"
    );
    Ok(())
}

/// Validate a `traces.jsonl` body: canonical line form
/// ([`strict_jsonl_lines`]), a header carrying the expected
/// schema/version, and every span line parsing as an object with a
/// finite numeric `t_s` and a string `kind`. Returns the span-line count
/// (0 for a header-only file).
pub fn validate_trace_jsonl(body: &str) -> Result<usize> {
    let lines = strict_jsonl_lines(body)?;
    let mut lines = lines.into_iter();
    let header = lines.next().context("empty trace file")?;
    check_jsonl_header(header, "ewatt.trace", TRACE_SCHEMA_VERSION)?;
    let mut n = 0usize;
    for (i, line) in lines.enumerate() {
        let v = JsonValue::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: parse error: {e}", i + 2))?;
        ensure!(
            v.get("t_s").and_then(JsonValue::as_f64).is_some_and(f64::is_finite),
            "line {}: missing finite t_s",
            i + 2
        );
        ensure!(
            v.get("kind").and_then(JsonValue::as_str).is_some(),
            "line {}: missing kind",
            i + 2
        );
        n += 1;
    }
    Ok(n)
}

/// The auditable identity card of one run. Keys live in a `BTreeMap`, so
/// serialization order is deterministic; nothing here reads a wall clock.
#[derive(Debug, Clone)]
pub struct RunManifest {
    fields: BTreeMap<String, JsonValue>,
}

impl RunManifest {
    /// A manifest for one invocation of `command` under `seed`. Stamps the
    /// manifest schema version and git-describe-style build info (crate
    /// version, plus the `EWATT_GIT_DESCRIBE` build-time override when a
    /// packaging step provides one).
    pub fn new(command: &str, seed: u64) -> RunManifest {
        let mut fields = BTreeMap::new();
        fields.insert("schema".to_string(), text("ewatt.manifest"));
        fields.insert("version".to_string(), uint(MANIFEST_SCHEMA_VERSION as usize));
        fields.insert("command".to_string(), text(command));
        fields.insert("seed".to_string(), text(&format!("{seed:#x}")));
        let describe =
            option_env!("EWATT_GIT_DESCRIBE").unwrap_or(concat!("v", env!("CARGO_PKG_VERSION")));
        fields.insert(
            "build".to_string(),
            obj(vec![
                ("package", text(env!("CARGO_PKG_NAME"))),
                ("pkg_version", text(env!("CARGO_PKG_VERSION"))),
                ("describe", text(describe)),
            ]),
        );
        RunManifest { fields }
    }

    /// Attach an arbitrary top-level field.
    pub fn set(&mut self, key: &str, value: JsonValue) {
        self.fields.insert(key.to_string(), value);
    }

    /// Digest the canonical text of the run's configuration. The digest
    /// (FNV-1a 64, hex) is what two manifests compare; the length is a
    /// cheap second opinion.
    pub fn set_config_digest(&mut self, canonical: &str) {
        self.set(
            "config",
            obj(vec![
                ("digest", text(&format!("{:#018x}", fnv1a_64(canonical.as_bytes())))),
                ("canonical_len", uint(canonical.len())),
            ]),
        );
    }

    /// Record which reports the command produced, as `(id, rows)` pairs.
    pub fn set_reports(&mut self, reports: &[(String, usize)]) {
        self.set(
            "reports",
            JsonValue::Array(
                reports
                    .iter()
                    .map(|(id, rows)| obj(vec![("id", text(id)), ("rows", uint(*rows))]))
                    .collect(),
            ),
        );
    }

    /// Build the per-phase / per-replica joule rollup from the trace's
    /// `request_summary` spans and cross-check it against the ledger
    /// totals carried by `outcome`. Returns the worst relative error;
    /// errors out above 1e-6 — an inconsistent manifest must not exist.
    pub fn set_energy_rollup(&mut self, outcome: &FleetOutcome, spans: &[Span]) -> Result<f64> {
        let mut per_phase = PhaseEnergy::default();
        let mut per_replica: BTreeMap<usize, (usize, f64)> = BTreeMap::new();
        let mut per_class = [(0usize, 0.0f64); 3];
        let mut summaries = 0usize;
        for s in spans {
            if let SpanEvent::RequestSummary { replica, class, energy, .. } = &s.event {
                per_phase.add(energy);
                let slot = per_replica.entry(*replica).or_insert((0, 0.0));
                slot.0 += 1;
                slot.1 += energy.total_j();
                per_class[class.slot()].0 += 1;
                per_class[class.slot()].1 += energy.total_j();
            }
        }
        for s in spans {
            if let SpanEvent::RequestSummary { req, energy, .. } = &s.event {
                summaries += 1;
                let ledger_j = outcome.joules.get(*req).copied().unwrap_or(f64::NAN);
                ensure!(
                    rel_err(energy.total_j(), ledger_j) <= 1e-6,
                    "request {req}: span total {} J diverges from ledger {} J",
                    energy.total_j(),
                    ledger_j
                );
            }
        }
        ensure!(
            summaries == outcome.joules.len(),
            "trace carries {summaries} request summaries for {} requests",
            outcome.joules.len()
        );
        let scale = outcome.total_j().max(1e-12);
        let class_sum: f64 = per_class.iter().map(|&(_, j)| j).sum();
        let max_rel = [
            (per_phase.prefill_j, outcome.breakdown.prefill_j),
            (per_phase.decode_j, outcome.breakdown.decode_j),
            (per_phase.switch_j, outcome.breakdown.switch_j),
            (per_phase.migration_j, outcome.breakdown.migration_j),
            (per_phase.idle_j, outcome.breakdown.idle_j),
            (per_phase.coldstart_j, outcome.breakdown.coldstart_j),
            (per_phase.total_j(), outcome.total_j()),
            // Per-class conservation: the class partition of the bill must
            // sum back to the fleet ledger total.
            (class_sum, outcome.total_j()),
        ]
        .iter()
        .map(|&(got, want)| (got - want).abs() / scale)
        .fold(0.0f64, f64::max);
        ensure!(
            max_rel <= 1e-6,
            "trace rollup diverges from the energy ledger by {max_rel:e} (> 1e-6)"
        );
        self.set(
            "energy_rollup",
            obj(vec![
                ("per_phase", phase_energy_json(&per_phase)),
                (
                    "per_replica",
                    JsonValue::Array(
                        per_replica
                            .iter()
                            .map(|(&rep, &(n, j))| {
                                obj(vec![
                                    ("replica", uint(rep)),
                                    ("requests", uint(n)),
                                    ("total_j", num(j)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "per_class",
                    JsonValue::Array(
                        TrafficClass::ALL
                            .iter()
                            .map(|c| {
                                let (n, j) = per_class[c.slot()];
                                obj(vec![
                                    ("class", text(c.label())),
                                    ("requests", uint(n)),
                                    ("total_j", num(j)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("ledger_total_j", num(outcome.total_j())),
                ("max_rel_err", num(max_rel)),
            ]),
        );
        Ok(max_rel)
    }

    /// Summarize the outcome headline numbers.
    pub fn set_outcome(&mut self, outcome: &FleetOutcome) {
        self.set(
            "outcome",
            obj(vec![
                ("served", uint(outcome.served)),
                ("makespan_s", num(outcome.makespan_s)),
                ("energy_j", num(outcome.energy_j)),
                ("idle_j", num(outcome.idle_j)),
                ("coldstart_j", num(outcome.coldstart_j)),
                ("migration_j", num(outcome.migration_j)),
                ("total_j", num(outcome.total_j())),
                ("freq_switches", uint(outcome.freq_switches)),
                ("mean_live_replicas", num(outcome.mean_live_replicas)),
                ("ttft_p95_s", num(outcome.slo.ttft_p95())),
                ("e2e_p99_s", num(outcome.slo.e2e_p99())),
            ]),
        );
    }

    pub fn to_json(&self) -> JsonValue {
        JsonValue::Object(self.fields.clone())
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        self.fields.get(key)
    }

    /// Write `manifest.json` (compact, newline-terminated) into `dir`.
    pub fn write(&self, dir: &Path, file: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(file);
        std::fs::write(&path, format!("{}\n", self.to_json().to_string()))
            .with_context(|| format!("writing manifest to {}", path.display()))?;
        Ok(path)
    }
}

fn rel_err(got: f64, want: f64) -> f64 {
    (got - want).abs() / want.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        // Reference vectors for FNV-1a 64.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a_64(b"config-a"), fnv1a_64(b"config-b"));
    }

    #[test]
    fn trace_jsonl_round_trips_and_validates() {
        let class = TrafficClass::Interactive;
        let spans = vec![
            Span { t_s: 0.0, event: SpanEvent::Queued { req: 0, query_idx: 5, class } },
            Span { t_s: 0.25, event: SpanEvent::Routed { req: 0, replica: 1 } },
            Span {
                t_s: 0.5,
                event: SpanEvent::DecodeStep {
                    replica: 1,
                    freq_mhz: 180,
                    batch: vec![0, 3],
                    joules: 1.5,
                },
            },
            Span {
                t_s: 1.0,
                event: SpanEvent::RequestSummary {
                    req: 0,
                    replica: 1,
                    class: TrafficClass::Batch,
                    energy: PhaseEnergy { decode_j: 1.5, ..Default::default() },
                },
            },
        ];
        let header = trace_header("unit", 0x5CE1, "0xdead");
        let body = trace_jsonl(&header, &spans);
        assert_eq!(validate_trace_jsonl(&body).unwrap(), spans.len());
        // Byte determinism: rendering twice is identical.
        assert_eq!(body, trace_jsonl(&header, &spans));
        // The seed survives as a hex string.
        let first = body.lines().next().unwrap();
        assert!(first.contains("\"0x5ce1\""), "header: {first}");
        // Spot-check one span line's fields.
        let step = JsonValue::parse(body.lines().nth(3).unwrap()).unwrap();
        assert_eq!(step.get("kind").unwrap().as_str(), Some("decode_step"));
        assert_eq!(step.get("batch").unwrap().as_array().unwrap().len(), 2);
        // Class tags ride along on queued and request_summary lines.
        let queued = JsonValue::parse(body.lines().nth(1).unwrap()).unwrap();
        assert_eq!(queued.get("class").unwrap().as_str(), Some("interactive"));
        let bill = JsonValue::parse(body.lines().nth(4).unwrap()).unwrap();
        assert_eq!(bill.get("class").unwrap().as_str(), Some("batch"));
    }

    #[test]
    fn validation_rejects_bad_headers_and_lines() {
        assert!(validate_trace_jsonl("").is_err());
        assert!(validate_trace_jsonl("{\"schema\":\"other\"}\n").is_err());
        let wrong_version = "{\"schema\":\"ewatt.trace\",\"version\":99}\n";
        assert!(validate_trace_jsonl(wrong_version).is_err());
        let ok_header = trace_header("x", 1, "0x0").to_string();
        assert!(validate_trace_jsonl(&format!("{ok_header}\nnot json\n")).is_err());
        assert!(validate_trace_jsonl(&format!("{ok_header}\n{{\"kind\":\"queued\"}}\n")).is_err());
        assert_eq!(validate_trace_jsonl(&format!("{ok_header}\n")).unwrap(), 0);
    }

    #[test]
    fn validation_rejects_crlf_and_trailing_whitespace() {
        let header = trace_header("x", 1, "0x0").to_string();
        let class = TrafficClass::Interactive;
        let queued = Span { t_s: 0.0, event: SpanEvent::Queued { req: 0, query_idx: 0, class } };
        let span = span_to_json(&queued).to_string();

        // CRLF anywhere — header or span line — is a descriptive error.
        let crlf_header = format!("{header}\r\n{span}\n");
        let err = validate_trace_jsonl(&crlf_header).unwrap_err().to_string();
        assert!(err.contains("carriage return"), "unhelpful CRLF error: {err}");
        let crlf_span = format!("{header}\n{span}\r\n");
        let err = validate_trace_jsonl(&crlf_span).unwrap_err().to_string();
        assert!(err.contains("line 2"), "error must locate the line: {err}");

        // Trailing whitespace on an otherwise-valid line is rejected too:
        // the parser would accept it, but the byte form is not canonical.
        let padded = format!("{header}\n{span}  \n");
        let err = validate_trace_jsonl(&padded).unwrap_err().to_string();
        assert!(err.contains("trailing whitespace"), "{err}");

        // A header-only file is a valid empty trace, before and after the
        // hardening.
        assert_eq!(validate_trace_jsonl(&format!("{header}\n")).unwrap(), 0);
        // The canonical form still validates.
        assert_eq!(validate_trace_jsonl(&format!("{header}\n{span}\n")).unwrap(), 1);
    }

    #[test]
    fn non_finite_manifest_fields_serialize_as_null() {
        // Policy pin: a zero-served run's NaN joules_per_request must
        // produce a *parseable* manifest with an explicit null, never a
        // bare `NaN` token (which no JSON parser accepts).
        let mut m = RunManifest::new("trace empty", 0x0);
        m.set("joules_per_request", num(f64::NAN));
        let text = m.to_json().to_string();
        let parsed = JsonValue::parse(&text).expect("manifest with NaN field must stay valid JSON");
        assert_eq!(parsed.get("joules_per_request"), Some(&JsonValue::Null));
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn manifest_carries_versioned_identity() {
        let mut m = RunManifest::new("trace poisson-1rep-static", 0x5CE1);
        m.set_config_digest("fleet { replicas: 1 }");
        m.set_reports(&[("waterfall".to_string(), 48)]);
        let j = m.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("ewatt.manifest"));
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("seed").unwrap().as_str(), Some("0x5ce1"));
        assert!(j.get("build").unwrap().get("pkg_version").is_some());
        let digest = j.get("config").unwrap().get("digest").unwrap();
        assert!(digest.as_str().unwrap().starts_with("0x"));
        // Deterministic serialization (BTreeMap key order).
        assert_eq!(j.to_string(), m.to_json().to_string());
    }
}
