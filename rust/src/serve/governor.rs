//! Closed-loop DVFS governors.
//!
//! The paper's phase-aware profile is open-loop: it assumes decode can
//! always run at the frequency floor. Under traffic that assumption breaks
//! exactly when it matters — bursts queue requests, and a pinned-low decode
//! clock has no headroom to drain them. The governor closes the loop:
//! it reads the SLO tracker's pressure signal plus queue state at every
//! phase boundary and steps the decode set point along the GPU's supported
//! ladder — up aggressively on violation pressure, down one cautious step
//! at a time when slack persists (fast-up/slow-down with a hysteresis band,
//! the shape GreenLLM-style production controllers use).

use crate::config::{FreqMHz, GpuSpec};
use crate::coordinator::dvfs_policy::{DvfsPolicy, FrequencyPolicy, Phase};

/// Telemetry snapshot the governor reads at each decision point.
#[derive(Debug, Clone, Copy, Default)]
pub struct GovernorSignal {
    /// SLO pressure from [`super::slo::SloTracker::pressure`]
    /// (1.0 = at target, >1 = violating).
    pub pressure: f64,
    /// Requests waiting in the admission queue.
    pub queue_depth: usize,
    /// Sequences currently decoding.
    pub active_seqs: usize,
    /// Requests completed so far (warmup evidence for down-stepping).
    pub completed: usize,
    /// Mean power over the telemetry window, watts.
    pub window_power_w: f64,
}

/// A frequency source consulted at every phase boundary of the serving
/// loop. Stateful implementations (the hysteresis governor) adapt; the
/// [`OpenLoop`] adapter wraps any static [`DvfsPolicy`].
///
/// `Send` because the fleet engine steps independent replicas on worker
/// threads between routing points; a governor is only ever *called* from
/// the thread currently driving its replica.
pub trait FreqGovernor: Send {
    /// Pick the SM set point for the next phase step.
    fn decide(&mut self, now_s: f64, phase: Phase, signal: &GovernorSignal, gpu: &GpuSpec)
        -> FreqMHz;

    fn label(&self) -> String;

    /// Whether this governor reads [`GovernorSignal`]. Open-loop adapters
    /// return `false`, letting the serving loop skip computing the signal
    /// (window percentiles, pressure) on the per-step hot path.
    fn wants_signal(&self) -> bool {
        true
    }
}

/// Build the governor a [`DvfsPolicy`] implies: `Governed` bands get the
/// closed-loop [`HysteresisGovernor`], everything else the [`OpenLoop`]
/// adapter. The single construction point both the fleet replica and the
/// serve facade use, so one policy always means one controller.
pub fn governor_for(policy: &DvfsPolicy, gpu: &GpuSpec) -> Box<dyn FreqGovernor> {
    match *policy {
        DvfsPolicy::Governed { floor, ceil } => {
            Box::new(HysteresisGovernor::new(gpu, GovernorConfig::banded(gpu, floor, ceil)))
        }
        open => Box::new(OpenLoop(open)),
    }
}

/// Open-loop adapter: a fixed policy as a (non-reacting) governor.
pub struct OpenLoop(pub DvfsPolicy);

impl FreqGovernor for OpenLoop {
    fn decide(
        &mut self,
        _now_s: f64,
        phase: Phase,
        _signal: &GovernorSignal,
        gpu: &GpuSpec,
    ) -> FreqMHz {
        self.0.freq_for(phase, gpu)
    }

    fn label(&self) -> String {
        self.0.label()
    }

    fn wants_signal(&self) -> bool {
        false
    }
}

/// Tuning of the closed-loop controller.
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Lowest decode set point the governor may choose.
    pub floor: FreqMHz,
    /// Highest set point; also the prefill and cold-start frequency.
    pub ceil: FreqMHz,
    /// Step decode up when pressure exceeds this fraction of the SLO.
    pub high_water: f64,
    /// Step decode down only when pressure is below this fraction.
    pub low_water: f64,
    /// Minimum seconds between *downward* set-point changes (anti-flap;
    /// upward moves are never delayed).
    pub dwell_s: f64,
    /// Ladder steps jumped per upward move (fast recovery).
    pub steps_up: usize,
    /// Queue depth that counts as violation pressure regardless of
    /// latency percentiles (backlog is a leading indicator).
    pub queue_trigger: usize,
}

impl GovernorConfig {
    /// Defaults over the full supported ladder of `gpu`.
    pub fn for_gpu(gpu: &GpuSpec) -> GovernorConfig {
        GovernorConfig {
            floor: gpu.f_min_mhz(),
            ceil: gpu.f_max_mhz,
            // A narrow band near the target: pressure below 0.80 means real
            // slack (descend), above 0.95 means the tail is about to cross
            // (climb). The fast violation component of the pressure signal
            // jumps past 1.0 the moment recent requests actually violate,
            // so up-steps do not depend on the slow percentiles drifting.
            high_water: 0.95,
            low_water: 0.80,
            dwell_s: 0.25,
            steps_up: 2,
            queue_trigger: 24,
        }
    }

    /// Same defaults restricted to a `[floor, ceil]` band.
    pub fn banded(gpu: &GpuSpec, floor: FreqMHz, ceil: FreqMHz) -> GovernorConfig {
        GovernorConfig { floor, ceil, ..GovernorConfig::for_gpu(gpu) }
    }
}

/// Completions required before the governor trusts low pressure enough to
/// descend — a cold tracker reports zero pressure, which is absence of
/// evidence, not slack.
const WARMUP_COMPLETIONS: usize = 5;

/// The closed-loop controller: hysteresis band over the frequency ladder.
pub struct HysteresisGovernor {
    pub cfg: GovernorConfig,
    /// Supported set points inside the band, ascending.
    ladder: Vec<FreqMHz>,
    /// Current decode set-point index into `ladder`.
    idx: usize,
    last_down_s: f64,
    /// Decode set-point changes made so far.
    pub moves: usize,
}

impl HysteresisGovernor {
    pub fn new(gpu: &GpuSpec, cfg: GovernorConfig) -> HysteresisGovernor {
        assert!(
            gpu.supports(cfg.floor) && gpu.supports(cfg.ceil),
            "governor band [{}, {}] not on the supported ladder {:?}",
            cfg.floor,
            cfg.ceil,
            gpu.freq_levels_mhz
        );
        assert!(cfg.floor <= cfg.ceil, "floor above ceiling");
        assert!(cfg.low_water < cfg.high_water, "inverted hysteresis band");
        assert!(cfg.steps_up >= 1);
        let mut ladder: Vec<FreqMHz> = gpu
            .freq_levels_mhz
            .iter()
            .cloned()
            .filter(|&f| f >= cfg.floor && f <= cfg.ceil)
            .collect();
        ladder.sort_unstable();
        // Cold start at the ceiling: safe until the SLO tracker warms up.
        let idx = ladder.len() - 1;
        HysteresisGovernor { cfg, ladder, idx, last_down_s: 0.0, moves: 0 }
    }

    /// The current decode set point.
    pub fn decode_freq(&self) -> FreqMHz {
        self.ladder[self.idx]
    }
}

impl FreqGovernor for HysteresisGovernor {
    fn decide(
        &mut self,
        now_s: f64,
        phase: Phase,
        signal: &GovernorSignal,
        _gpu: &GpuSpec,
    ) -> FreqMHz {
        // Prefill is compute-bound and frequency-sensitive (Table XI):
        // always run it at the ceiling, as the phase-aware profile does.
        if phase == Phase::Prefill {
            return self.cfg.ceil;
        }
        let overloaded =
            signal.pressure > self.cfg.high_water || signal.queue_depth >= self.cfg.queue_trigger;
        if overloaded {
            let top = self.ladder.len() - 1;
            if self.idx < top {
                self.idx = (self.idx + self.cfg.steps_up).min(top);
                self.moves += 1;
                // Re-arm the dwell so a down-step can't immediately undo it.
                self.last_down_s = now_s;
            }
        } else if signal.pressure < self.cfg.low_water
            && signal.completed >= WARMUP_COMPLETIONS
            && self.idx > 0
            && now_s - self.last_down_s >= self.cfg.dwell_s
        {
            self.idx -= 1;
            self.moves += 1;
            self.last_down_s = now_s;
        }
        self.ladder[self.idx]
    }

    fn label(&self) -> String {
        format!("governed[{}-{}MHz]", self.cfg.floor, self.cfg.ceil)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::rtx_pro_6000()
    }

    fn slack() -> GovernorSignal {
        GovernorSignal {
            pressure: 0.1,
            queue_depth: 0,
            active_seqs: 2,
            completed: 100,
            window_power_w: 150.0,
        }
    }

    fn overload() -> GovernorSignal {
        GovernorSignal {
            pressure: 1.4,
            queue_depth: 40,
            active_seqs: 8,
            completed: 100,
            window_power_w: 400.0,
        }
    }

    #[test]
    fn cold_start_is_the_ceiling_and_prefill_stays_hot() {
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::for_gpu(&g));
        assert_eq!(gov.decode_freq(), 2842);
        assert_eq!(gov.decide(0.0, Phase::Prefill, &slack(), &g), 2842);
        // Prefill decisions never move the decode set point.
        assert_eq!(gov.moves, 0);
    }

    #[test]
    fn sustained_slack_descends_to_the_floor_one_step_per_dwell() {
        let g = gpu();
        let cfg = GovernorConfig::for_gpu(&g);
        let dwell = cfg.dwell_s;
        let mut gov = HysteresisGovernor::new(&g, cfg);
        let mut t = 0.0;
        let mut freqs = Vec::new();
        for _ in 0..20 {
            t += dwell + 1e-3;
            freqs.push(gov.decide(t, Phase::Decode, &slack(), &g));
        }
        assert_eq!(*freqs.last().unwrap(), 180, "did not reach the floor: {freqs:?}");
        // Monotone non-increasing descent, one ladder step at a time.
        assert!(freqs.windows(2).all(|w| w[0] >= w[1]));
        assert_eq!(gov.moves, g.freq_levels_mhz.len() - 1);
    }

    #[test]
    fn cold_tracker_blocks_descent_until_warmed_up() {
        // Zero pressure with zero completions is absence of evidence, not
        // slack: the governor must hold the ceiling until requests finish.
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::for_gpu(&g));
        let cold = GovernorSignal { completed: 0, ..slack() };
        let mut t = 0.0;
        for _ in 0..20 {
            t += 1.0;
            assert_eq!(gov.decide(t, Phase::Decode, &cold, &g), 2842);
        }
        // First warmed-up decision may descend.
        t += 1.0;
        assert!(gov.decide(t, Phase::Decode, &slack(), &g) < 2842);
    }

    #[test]
    fn dwell_blocks_rapid_descent() {
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::for_gpu(&g));
        // Many decisions within one dwell window: at most one down-step.
        for _ in 0..50 {
            gov.decide(0.3, Phase::Decode, &slack(), &g);
        }
        assert!(gov.moves <= 1, "{} moves inside one dwell", gov.moves);
    }

    #[test]
    fn violation_pressure_steps_up_fast() {
        let g = gpu();
        let cfg = GovernorConfig::for_gpu(&g);
        let steps_up = cfg.steps_up;
        let mut gov = HysteresisGovernor::new(&g, cfg);
        let mut t = 0.0;
        // Descend to the floor first.
        while gov.decode_freq() != 180 {
            t += 1.0;
            gov.decide(t, Phase::Decode, &slack(), &g);
        }
        // One overloaded decision jumps `steps_up` rungs immediately.
        let f = gov.decide(t + 1e-6, Phase::Decode, &overload(), &g);
        assert_eq!(f, g.freq_levels_mhz[steps_up]);
        // Sustained overload reaches the ceiling.
        for _ in 0..10 {
            t += 1e-3;
            gov.decide(t, Phase::Decode, &overload(), &g);
        }
        assert_eq!(gov.decode_freq(), 2842);
    }

    #[test]
    fn queue_backlog_alone_triggers_an_up_step() {
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::for_gpu(&g));
        let mut t = 0.0;
        while gov.decode_freq() != 180 {
            t += 1.0;
            gov.decide(t, Phase::Decode, &slack(), &g);
        }
        let sig = GovernorSignal { pressure: 0.1, queue_depth: 30, ..slack() };
        assert!(gov.decide(t + 0.01, Phase::Decode, &sig, &g) > 180);
    }

    #[test]
    fn hysteresis_band_holds_steady() {
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::for_gpu(&g));
        let mid = GovernorSignal { pressure: 0.87, ..slack() }; // inside the band
        let before = gov.decode_freq();
        let mut t = 0.0;
        for _ in 0..40 {
            t += 1.0;
            gov.decide(t, Phase::Decode, &mid, &g);
        }
        assert_eq!(gov.decode_freq(), before);
        assert_eq!(gov.moves, 0);
    }

    #[test]
    fn banded_governor_respects_its_band() {
        let g = gpu();
        let mut gov = HysteresisGovernor::new(&g, GovernorConfig::banded(&g, 487, 2000));
        let mut t = 0.0;
        for _ in 0..30 {
            t += 1.0;
            let f = gov.decide(t, Phase::Decode, &slack(), &g);
            assert!((487..=2000).contains(&f));
        }
        assert_eq!(gov.decode_freq(), 487);
        for _ in 0..10 {
            t += 1.0;
            let f = gov.decide(t, Phase::Decode, &overload(), &g);
            assert!((487..=2000).contains(&f));
        }
        assert_eq!(gov.decode_freq(), 2000);
    }

    #[test]
    #[should_panic(expected = "not on the supported ladder")]
    fn off_ladder_band_panics() {
        let g = gpu();
        HysteresisGovernor::new(&g, GovernorConfig::banded(&g, 200, 2842));
    }

    #[test]
    fn governor_factory_matches_policy_class() {
        let g = gpu();
        let mut closed = governor_for(&DvfsPolicy::governed(&g), &g);
        assert!(closed.wants_signal());
        assert_eq!(closed.decide(0.0, Phase::Prefill, &slack(), &g), g.f_max_mhz);
        let mut open = governor_for(&DvfsPolicy::Static(960), &g);
        assert!(!open.wants_signal());
        assert_eq!(open.decide(0.0, Phase::Decode, &overload(), &g), 960);
    }

    #[test]
    fn open_loop_adapter_mirrors_the_policy() {
        let g = gpu();
        let mut ol = OpenLoop(DvfsPolicy::paper_phase_aware(&g));
        assert_eq!(ol.decide(0.0, Phase::Prefill, &slack(), &g), 2842);
        assert_eq!(ol.decide(0.0, Phase::Decode, &overload(), &g), 180);
        assert_eq!(ol.label(), DvfsPolicy::paper_phase_aware(&g).label());
    }
}
