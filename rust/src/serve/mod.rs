//! SLO-aware serving under traffic: the closed-loop layer above the
//! paper's offline characterization.
//!
//! The study shows decode is frequency-insensitive (≈42% energy savings
//! for 1–6% latency cost) but evaluates only open-loop policies. This
//! module turns that finding into a serving system:
//!
//! - [`traffic`]: arrival-process generators (Poisson, bursty MMPP,
//!   diurnal ramp, trace replay) over the workload corpus,
//! - [`slo`]: TTFT / time-between-tokens / end-to-end objectives with
//!   streaming P² percentile tracking,
//! - [`governor`]: the pluggable [`FreqGovernor`] trait, an open-loop
//!   adapter for any [`crate::coordinator::DvfsPolicy`], and the
//!   closed-loop [`HysteresisGovernor`] (fast-up/slow-down over the
//!   supported frequency ladder, driven by SLO pressure),
//! - [`simloop`]: the serving facade — a one-replica fleet driven through
//!   the shared [`crate::fleet`] continuous-batching loop (queueing delay,
//!   per-phase set points, switch-overhead accounting, KV admission
//!   gating, per-request energy attribution).

pub mod governor;
pub mod simloop;
pub mod slo;
pub mod traffic;

pub use governor::{
    governor_for, FreqGovernor, GovernorConfig, GovernorSignal, HysteresisGovernor, OpenLoop,
};
pub use simloop::{ServeOutcome, ServeSim, ServeSimConfig};
pub use slo::{ClassSloTracker, ClassSlos, RecordSink, Slo, SloTracker};
pub use traffic::{Arrival, ClassLoad, ClassMix, TrafficClass, TrafficPattern};
