//! The traffic-driven serving loop.
//!
//! A discrete-event simulation of one device serving an arrival stream
//! with iteration-level (continuous) batching: queued requests join the
//! running batch at decode-step boundaries, paying their prefill; finished
//! sequences leave immediately. The frequency governor is consulted at
//! every phase boundary, set-point changes charge the DVFS switch
//! overhead at idle power, and per-request TTFT / time-between-tokens /
//! end-to-end latencies stream into the SLO tracker the governor reads —
//! the closed loop the paper's offline upper-bound analysis (Section
//! VII-C) motivates but does not run.

use std::collections::VecDeque;

use anyhow::Result;

use crate::config::{FreqMHz, GpuSpec, ModelSpec};
use crate::coordinator::dvfs_policy::{DvfsPolicy, Phase};
use crate::fleet::attribution::{EnergyLedger, PhaseEnergy};
use crate::gpu::{GpuSim, TelemetryWindow};
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::tokenizer::token_count;
use crate::workload::ReplaySuite;

use super::governor::{FreqGovernor, GovernorConfig, GovernorSignal, HysteresisGovernor, OpenLoop};
use super::slo::{Slo, SloTracker};
use super::traffic::Arrival;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    pub slo: Slo,
    /// Telemetry window horizon fed to the governor, seconds.
    pub window_s: f64,
}

impl Default for ServeSimConfig {
    fn default() -> Self {
        ServeSimConfig { max_batch: 8, slo: Slo::interactive(), window_s: 2.0 }
    }
}

/// Aggregate outcome of one traffic-driven run.
///
/// `energy_j` is *active* energy (prefill + decode + switch transitions):
/// the quantity a policy controls. Idle draw while the device waits for
/// arrivals is identical across policies and reported separately in
/// `idle_j`; `total_j()` is their sum.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub served: usize,
    /// Active energy: prefill + decode + switch, joules.
    pub energy_j: f64,
    /// Idle-power energy while waiting for arrivals, joules.
    pub idle_j: f64,
    /// Energy charged to DVFS set-point transitions (subset of `energy_j`).
    pub switch_j: f64,
    /// Simulated time at which the last request finished.
    pub makespan_s: f64,
    /// Actual SM set-point changes executed.
    pub freq_switches: usize,
    /// Time-weighted mean decode set point, MHz.
    pub mean_decode_freq_mhz: f64,
    /// Deepest admission-queue backlog observed.
    pub max_queue_depth: usize,
    /// Streaming SLO percentiles + attainment.
    pub slo: SloTracker,
    /// Attributed energy per request (arrival order): prefill charged by
    /// tokens processed, decode split by tokens generated across the batch,
    /// switches to the step they precede, idle amortized over all requests.
    /// Sums to [`Self::total_j`] — see [`crate::fleet::attribution`].
    pub joules: Vec<f64>,
    /// The same attribution aggregated by phase across all requests.
    pub attributed_phase_breakdown: PhaseEnergy,
}

impl ServeOutcome {
    pub fn total_j(&self) -> f64 {
        self.energy_j + self.idle_j
    }

    pub fn joules_per_request(&self) -> f64 {
        self.energy_j / self.served.max(1) as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }
}

/// One in-flight sequence.
struct Active {
    /// Index into the arrival stream (the attribution ledger's key).
    req: usize,
    arrival_s: f64,
    /// Completion time of this sequence's prefill (first token out).
    first_token_s: f64,
    tokens: usize,
    remaining: usize,
    ctx: usize,
}

/// The traffic-driven serving simulator.
pub struct ServeSim {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub cfg: ServeSimConfig,
}

impl ServeSim {
    pub fn new(gpu: GpuSpec, model: ModelSpec, cfg: ServeSimConfig) -> ServeSim {
        assert!(cfg.max_batch >= 1);
        ServeSim { gpu, model, cfg }
    }

    /// Serve `arrivals` under `policy`. `Governed` bands run the closed-loop
    /// hysteresis controller; `Static`/`PhaseAware` run open-loop through
    /// the same event loop, so results are directly comparable.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        policy: &DvfsPolicy,
    ) -> Result<ServeOutcome> {
        match *policy {
            DvfsPolicy::Governed { floor, ceil } => {
                let cfg = GovernorConfig::banded(&self.gpu, floor, ceil);
                let mut gov = HysteresisGovernor::new(&self.gpu, cfg);
                self.run_with(suite, arrivals, &mut gov)
            }
            open => self.run_with(suite, arrivals, &mut OpenLoop(open)),
        }
    }

    /// Serve under any [`FreqGovernor`] implementation (the pluggable path).
    pub fn run_with(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        gov: &mut dyn FreqGovernor,
    ) -> Result<ServeOutcome> {
        let mut now = 0.0f64;
        let mut next = 0usize; // cursor into `arrivals`
        let mut queue: VecDeque<(usize, Arrival)> = VecDeque::new();
        let mut active: Vec<Active> = Vec::new();
        let mut ledger = EnergyLedger::new(arrivals.len());
        let mut req_scratch: Vec<usize> = Vec::new();

        let mut tracker = SloTracker::new(self.cfg.slo);
        let mut window = TelemetryWindow::new(self.cfg.window_s);
        // Open-loop governors ignore the signal; skip building it for them
        // (the window percentiles sit on the per-step hot path).
        let wants_signal = gov.wants_signal();

        let first = gov.decide(now, Phase::Prefill, &GovernorSignal::default(), &self.gpu);
        let mut gpu = GpuSim::new(self.gpu.clone(), first);

        let mut out = ServeOutcome {
            served: 0,
            energy_j: 0.0,
            idle_j: 0.0,
            switch_j: 0.0,
            makespan_s: 0.0,
            freq_switches: 0,
            mean_decode_freq_mhz: 0.0,
            max_queue_depth: 0,
            slo: tracker.clone(), // placeholder; replaced at the end
            joules: Vec::new(),
            attributed_phase_breakdown: PhaseEnergy::default(),
        };
        let mut decode_freq_dt = 0.0f64; // Σ f·dt over decode steps
        let mut decode_dt = 0.0f64;

        while next < arrivals.len() || !queue.is_empty() || !active.is_empty() {
            // Pull everything that has arrived by `now` into the queue.
            while next < arrivals.len() && arrivals[next].t_s <= now {
                queue.push_back((next, arrivals[next]));
                next += 1;
            }
            out.max_queue_depth = out.max_queue_depth.max(queue.len());

            if active.is_empty() && queue.is_empty() {
                // Nothing in flight: idle forward to the next arrival.
                let t_next = arrivals[next].t_s; // loop guard ⇒ next is valid
                out.idle_j += (t_next - now) * self.gpu.p_idle_w;
                now = t_next;
                continue;
            }

            // Admit queued requests at the step boundary, each paying its
            // own prefill (iteration-level scheduling).
            while active.len() < self.cfg.max_batch && !queue.is_empty() {
                let (req, arr) = queue.pop_front().unwrap();
                let sig = if wants_signal {
                    signal(&tracker, &queue, &active, &window)
                } else {
                    GovernorSignal::default()
                };
                let f = gov.decide(now, Phase::Prefill, &sig, &self.gpu);
                self.switch_to(&mut gpu, f, &mut now, &mut out, &[req], &mut ledger);
                let q = &suite.queries[arr.query_idx];
                let input = token_count(&q.text).max(1);
                let r = gpu.execute(&prefill_cost(&self.model, 1, input));
                now += r.latency_s;
                out.energy_j += r.energy_j;
                window.record(now, r.latency_s, r.energy_j);
                ledger.charge_prefill(req, r.energy_j);
                active.push(Active {
                    req,
                    arrival_s: arr.t_s,
                    first_token_s: now,
                    tokens: 0,
                    remaining: q.output_tokens.max(1),
                    ctx: input,
                });
                // Requests that arrived during this prefill become eligible.
                while next < arrivals.len() && arrivals[next].t_s <= now {
                    queue.push_back((next, arrivals[next]));
                    next += 1;
                }
                out.max_queue_depth = out.max_queue_depth.max(queue.len());
            }

            // One decode step for the whole running batch.
            let sig = if wants_signal {
                signal(&tracker, &queue, &active, &window)
            } else {
                GovernorSignal::default()
            };
            let f = gov.decide(now, Phase::Decode, &sig, &self.gpu);
            req_scratch.clear();
            req_scratch.extend(active.iter().map(|s| s.req));
            self.switch_to(&mut gpu, f, &mut now, &mut out, &req_scratch, &mut ledger);
            let ctx = active.iter().map(|s| s.ctx).max().unwrap();
            let r = gpu.execute(&decode_step_cost(&self.model, active.len(), ctx));
            now += r.latency_s;
            out.energy_j += r.energy_j;
            window.record(now, r.latency_s, r.energy_j);
            ledger.charge_decode(&req_scratch, r.energy_j);
            decode_freq_dt += f as f64 * r.latency_s;
            decode_dt += r.latency_s;

            for s in active.iter_mut() {
                s.remaining -= 1;
                s.tokens += 1;
                s.ctx += 1;
            }
            active.retain(|s| {
                if s.remaining == 0 {
                    let e2e = now - s.arrival_s;
                    let ttft = s.first_token_s - s.arrival_s;
                    let tbt = (now - s.first_token_s) / s.tokens as f64;
                    tracker.record(ttft, tbt, e2e);
                    out.served += 1;
                    false
                } else {
                    true
                }
            });
        }

        out.makespan_s = now;
        out.mean_decode_freq_mhz = if decode_dt > 0.0 { decode_freq_dt / decode_dt } else { 0.0 };
        out.slo = tracker;
        // Idle draw waits for arrivals, so amortize it across all of them.
        if out.idle_j > 0.0 {
            let everyone: Vec<usize> = (0..arrivals.len()).collect();
            ledger.charge_idle(&everyone, out.idle_j);
        }
        out.joules = ledger.joules();
        out.attributed_phase_breakdown = ledger.totals();
        Ok(out)
    }

    /// Apply a set-point change, charging the switch latency at idle power
    /// to the requests of the step that follows.
    #[allow(clippy::too_many_arguments)]
    fn switch_to(
        &self,
        gpu: &mut GpuSim,
        f: FreqMHz,
        now: &mut f64,
        out: &mut ServeOutcome,
        reqs: &[usize],
        ledger: &mut EnergyLedger,
    ) {
        let dt = gpu.set_freq(f);
        if dt > 0.0 {
            let e = dt * self.gpu.p_idle_w;
            *now += dt;
            out.energy_j += e;
            out.switch_j += e;
            out.freq_switches += 1;
            ledger.charge_switch(reqs, e);
        }
    }
}

fn signal(
    tracker: &SloTracker,
    queue: &VecDeque<(usize, Arrival)>,
    active: &[Active],
    window: &TelemetryWindow,
) -> GovernorSignal {
    GovernorSignal {
        pressure: tracker.pressure(),
        queue_depth: queue.len(),
        active_seqs: active.len(),
        completed: tracker.completed(),
        window_power_w: window.mean_power_w(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::serve::traffic::TrafficPattern;
    use crate::workload::Dataset;

    fn setup() -> (ReplaySuite, ServeSim, Vec<usize>) {
        let suite = ReplaySuite::quick(51, 24);
        let sim = ServeSim::new(
            GpuSpec::rtx_pro_6000(),
            model_for_tier(ModelTier::B8),
            ServeSimConfig::default(),
        );
        let mut pool = suite.dataset_indices(Dataset::TruthfulQa);
        pool.extend(suite.dataset_indices(Dataset::NarrativeQa));
        (suite, sim, pool)
    }

    fn bursty(pool: &[usize], n: usize) -> Vec<Arrival> {
        TrafficPattern::Bursty { base_rps: 1.5, burst_rps: 7.0, mean_dwell_s: 3.0 }
            .generate_from(pool, n, 0xB0B)
    }

    #[test]
    fn serves_every_arrival_and_accounts_energy() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 60);
        for policy in [
            DvfsPolicy::Static(2842),
            DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
            DvfsPolicy::governed(&sim.gpu),
        ] {
            let o = sim.run(&suite, &arrivals, &policy).unwrap();
            assert_eq!(o.served, arrivals.len(), "{}", policy.label());
            assert_eq!(o.slo.completed(), arrivals.len());
            assert!(o.energy_j > 0.0);
            assert!(o.makespan_s >= arrivals.last().unwrap().t_s);
            assert!(o.total_j() >= o.energy_j);
            assert!(o.switch_j <= o.energy_j);
        }
    }

    #[test]
    fn attribution_sums_to_total_energy() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 60);
        for policy in [
            DvfsPolicy::Static(2842),
            DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
            DvfsPolicy::governed(&sim.gpu),
        ] {
            let o = sim.run(&suite, &arrivals, &policy).unwrap();
            assert_eq!(o.joules.len(), arrivals.len());
            let attributed: f64 = o.joules.iter().sum();
            let rel = (attributed - o.total_j()).abs() / o.total_j();
            assert!(rel < 1e-6, "{}: conservation off by {rel:e}", policy.label());
            // Phase components reconcile with the loop's own accounting.
            let b = &o.attributed_phase_breakdown;
            assert!((b.total_j() - o.total_j()).abs() / o.total_j() < 1e-6);
            assert!((b.switch_j - o.switch_j).abs() <= 1e-9 * o.switch_j.max(1.0));
            assert!((b.idle_j - o.idle_j).abs() <= 1e-9 * o.idle_j.max(1.0));
            assert!(
                (b.prefill_j + b.decode_j - (o.energy_j - o.switch_j)).abs()
                    <= 1e-6 * o.energy_j,
                "{}: prefill+decode mismatch",
                policy.label()
            );
            assert!(o.joules.iter().all(|&j| j > 0.0), "every request costs energy");
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 40);
        let p = DvfsPolicy::governed(&sim.gpu);
        let a = sim.run(&suite, &arrivals, &p).unwrap();
        let b = sim.run(&suite, &arrivals, &p).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.freq_switches, b.freq_switches);
        assert_eq!(a.slo.e2e_p99(), b.slo.e2e_p99());
    }

    #[test]
    fn governed_saves_energy_within_slo_under_bursty_traffic() {
        // The PR's acceptance criterion, at test scale: ≥25% active-energy
        // savings vs Static(f_max) with p99 e2e inside the SLO.
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 80);
        let base = sim.run(&suite, &arrivals, &DvfsPolicy::Static(2842)).unwrap();
        let gov = sim.run(&suite, &arrivals, &DvfsPolicy::governed(&sim.gpu)).unwrap();
        let savings = 1.0 - gov.energy_j / base.energy_j;
        assert!(savings >= 0.25, "governed savings {savings:.3}");
        assert!(
            gov.slo.e2e_p99() <= sim.cfg.slo.e2e_p99_s,
            "governed p99 {:.2}s over the {:.2}s SLO",
            gov.slo.e2e_p99(),
            sim.cfg.slo.e2e_p99_s
        );
        // The controller actually moved off the ceiling.
        assert!(gov.mean_decode_freq_mhz < base.mean_decode_freq_mhz * 0.5);
        assert!(gov.freq_switches > 0);
    }

    #[test]
    fn governed_tracks_phase_aware_energy_when_unloaded() {
        // With light traffic the governor should settle at the floor and
        // approach the open-loop phase-aware profile's energy.
        let (suite, sim, pool) = setup();
        let arrivals =
            TrafficPattern::Poisson { rps: 1.0 }.generate_from(&pool, 50, 7);
        let pa = sim
            .run(&suite, &arrivals, &DvfsPolicy::paper_phase_aware(&sim.gpu))
            .unwrap();
        let gov = sim.run(&suite, &arrivals, &DvfsPolicy::governed(&sim.gpu)).unwrap();
        assert!(
            gov.energy_j < pa.energy_j * 1.15,
            "governed {:.0}J vs phase-aware {:.0}J",
            gov.energy_j,
            pa.energy_j
        );
    }

    #[test]
    fn queueing_delay_appears_under_overload() {
        let (suite, sim, pool) = setup();
        let calm = TrafficPattern::Poisson { rps: 0.5 }.generate_from(&pool, 30, 11);
        let slam = TrafficPattern::Poisson { rps: 50.0 }.generate_from(&pool, 30, 11);
        let p = DvfsPolicy::Static(2842);
        let c = sim.run(&suite, &calm, &p).unwrap();
        let s = sim.run(&suite, &slam, &p).unwrap();
        assert!(s.slo.e2e_p99() > c.slo.e2e_p99(), "no queueing effect");
        assert!(s.max_queue_depth > c.max_queue_depth);
        // Idle energy shows up only when the device actually waits.
        assert!(c.idle_j > s.idle_j);
    }

    #[test]
    fn ttft_includes_queue_wait() {
        let (suite, sim, pool) = setup();
        let slam = TrafficPattern::Poisson { rps: 40.0 }.generate_from(&pool, 40, 13);
        let o = sim.run(&suite, &slam, &DvfsPolicy::Static(2842)).unwrap();
        // Under heavy backlog TTFT p95 must exceed a lone prefill's time by
        // a wide margin (queue wait dominates).
        assert!(o.slo.ttft_p95() > 0.05, "ttft p95 {:.4}s", o.slo.ttft_p95());
    }
}
