//! The traffic-driven serving facade.
//!
//! `ServeSim` serves one device under one arrival stream — but it owns no
//! event loop of its own. It constructs a **one-replica fleet** and drives
//! it through [`crate::fleet::engine::drive`], the same continuous-batching
//! core `FleetSim` runs N replicas through: queued requests join the
//! running batch at decode-step boundaries (paying their prefill), the
//! governor is consulted at every phase boundary, set-point changes charge
//! the DVFS switch overhead at idle power, and per-request TTFT /
//! time-between-tokens / end-to-end latencies stream into the SLO tracker
//! the governor reads.
//!
//! Because the loop is shared, the serve path inherits two behaviors it
//! historically lacked: admission is gated on KV-cache capacity, and
//! classification (zero-output) queries are scored with one prefill pass
//! per answer option and complete at admission, with no decode phase.

use anyhow::Result;

use crate::config::{GpuSpec, ModelSpec};
use crate::coordinator::dvfs_policy::DvfsPolicy;
use crate::fleet::attribution::{EnergyLedger, PhaseEnergy};
use crate::fleet::engine::{drive, EngineCtx};
use crate::fleet::lifecycle::{Lifecycle, ReplicaState};
use crate::fleet::replica::{Replica, ReplicaSpec};
use crate::fleet::router::RoundRobin;
use crate::workload::ReplaySuite;

use super::governor::{governor_for, FreqGovernor};
use super::slo::{Slo, SloTracker};
use super::traffic::Arrival;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    pub slo: Slo,
    /// Telemetry window horizon fed to the governor, seconds.
    pub window_s: f64,
}

impl Default for ServeSimConfig {
    fn default() -> Self {
        ServeSimConfig { max_batch: 8, slo: Slo::interactive(), window_s: 2.0 }
    }
}

/// Aggregate outcome of one traffic-driven run.
///
/// `energy_j` is *active* energy (prefill + decode + switch transitions):
/// the quantity a policy controls. Idle draw while the device waits for
/// arrivals is identical across policies and reported separately in
/// `idle_j`; `total_j()` is their sum.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub served: usize,
    /// Active energy: prefill + decode + switch, joules.
    pub energy_j: f64,
    /// Idle-power energy while waiting for arrivals, joules.
    pub idle_j: f64,
    /// Energy charged to DVFS set-point transitions (subset of `energy_j`).
    pub switch_j: f64,
    /// Simulated time at which the last request finished.
    pub makespan_s: f64,
    /// Actual SM set-point changes executed.
    pub freq_switches: usize,
    /// Time-weighted mean decode set point, MHz.
    pub mean_decode_freq_mhz: f64,
    /// Deepest admission-queue backlog observed.
    pub max_queue_depth: usize,
    /// Streaming SLO percentiles + attainment.
    pub slo: SloTracker,
    /// Attributed energy per request (arrival order): prefill charged by
    /// tokens processed, decode split by tokens generated across the batch,
    /// switches to the step they precede, idle amortized over the requests
    /// served. Sums to [`Self::total_j`] — see [`crate::fleet::attribution`].
    pub joules: Vec<f64>,
    /// The same attribution aggregated by phase across all requests.
    pub attributed_phase_breakdown: PhaseEnergy,
}

impl ServeOutcome {
    pub fn total_j(&self) -> f64 {
        self.energy_j + self.idle_j
    }

    /// Mean *attributed* energy per request: the ledger total (active plus
    /// amortized idle) over served requests, so this agrees with summing
    /// [`Self::joules`] — the convention the `ewatt slo` and `ewatt fleet`
    /// tables report. `NaN` when the run served nothing (a degenerate case
    /// the experiment tables assert against rather than silently printing
    /// a number). For the policy-controlled quantity alone use
    /// [`Self::active_joules_per_request`].
    pub fn joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.total_j() / self.served as f64
    }

    /// Mean *active* (prefill + decode + switch) energy per served
    /// request. `NaN` when nothing was served.
    pub fn active_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.energy_j / self.served as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }
}

/// The traffic-driven serving simulator: a thin facade over a one-replica
/// fleet. All batching, governor, and attribution behavior lives in
/// [`crate::fleet::Replica`].
pub struct ServeSim {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub cfg: ServeSimConfig,
}

impl ServeSim {
    pub fn new(gpu: GpuSpec, model: ModelSpec, cfg: ServeSimConfig) -> ServeSim {
        assert!(cfg.max_batch >= 1);
        ServeSim { gpu, model, cfg }
    }

    /// Serve `arrivals` under `policy`. `Governed` bands run the closed-loop
    /// hysteresis controller; `Static`/`PhaseAware` run open-loop through
    /// the same event loop, so results are directly comparable.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        policy: &DvfsPolicy,
    ) -> Result<ServeOutcome> {
        self.run_replica(suite, arrivals, *policy, governor_for(policy, &self.gpu))
    }

    /// Serve under any [`FreqGovernor`] implementation (the pluggable path).
    pub fn run_with(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        gov: Box<dyn FreqGovernor>,
    ) -> Result<ServeOutcome> {
        // The policy is replica metadata only; `gov` makes every decision.
        self.run_replica(suite, arrivals, DvfsPolicy::Static(self.gpu.f_max_mhz), gov)
    }

    /// The facade body: one replica, driven by the shared fleet loop.
    fn run_replica(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        policy: DvfsPolicy,
        gov: Box<dyn FreqGovernor>,
    ) -> Result<ServeOutcome> {
        let spec = ReplicaSpec { model: self.model.clone(), policy, state: ReplicaState::Live };
        let mut reps =
            [Replica::with_governor(&self.gpu, spec, gov, self.cfg.slo, self.cfg.window_s)];
        let mut ledger = EnergyLedger::new(arrivals.len());
        let mut tracker = SloTracker::new(self.cfg.slo);
        let mut router = RoundRobin::default();
        // One always-live replica, no autoscaling, no failures: the inert
        // lifecycle keeps this facade bit-identical to the fixed loop.
        let mut lifecycle = Lifecycle::inert();
        drive(
            &mut reps,
            EngineCtx {
                suite,
                arrivals,
                router: &mut router,
                max_batch: self.cfg.max_batch,
                ledger: &mut ledger,
                tracker: &mut tracker,
                lifecycle: &mut lifecycle,
                trace: None,
                timeline: None,
            },
        )?;
        let [mut rep] = reps;
        let leftover = rep.finalize(&mut ledger);
        debug_assert!(
            leftover.total_j() == 0.0,
            "a lone always-live replica cannot accrue unattributable overhead"
        );
        Ok(ServeOutcome {
            served: rep.served,
            energy_j: rep.energy_j,
            idle_j: rep.idle_j,
            switch_j: rep.switch_j,
            makespan_s: rep.last_finish_s,
            freq_switches: rep.freq_switches,
            mean_decode_freq_mhz: rep.mean_decode_freq_mhz(),
            max_queue_depth: rep.max_queue_depth,
            slo: tracker,
            joules: ledger.joules(),
            attributed_phase_breakdown: ledger.totals(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::serve::traffic::TrafficPattern;
    use crate::workload::Dataset;

    fn setup() -> (ReplaySuite, ServeSim, Vec<usize>) {
        let suite = ReplaySuite::quick(51, 24);
        let sim = ServeSim::new(
            GpuSpec::rtx_pro_6000(),
            model_for_tier(ModelTier::B8),
            ServeSimConfig::default(),
        );
        let mut pool = suite.dataset_indices(Dataset::TruthfulQa);
        pool.extend(suite.dataset_indices(Dataset::NarrativeQa));
        (suite, sim, pool)
    }

    fn bursty(pool: &[usize], n: usize) -> Vec<Arrival> {
        TrafficPattern::Bursty { base_rps: 1.5, burst_rps: 7.0, mean_dwell_s: 3.0 }
            .generate_from(pool, n, 0xB0B)
    }

    #[test]
    fn serves_every_arrival_and_accounts_energy() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 60);
        for policy in [
            DvfsPolicy::Static(2842),
            DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
            DvfsPolicy::governed(&sim.gpu),
        ] {
            let o = sim.run(&suite, &arrivals, &policy).unwrap();
            assert_eq!(o.served, arrivals.len(), "{}", policy.label());
            assert_eq!(o.slo.completed(), arrivals.len());
            assert!(o.energy_j > 0.0);
            assert!(o.makespan_s >= arrivals.last().unwrap().t_s);
            assert!(o.total_j() >= o.energy_j);
            assert!(o.switch_j <= o.energy_j);
        }
    }

    #[test]
    fn attribution_sums_to_total_energy() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 60);
        for policy in [
            DvfsPolicy::Static(2842),
            DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
            DvfsPolicy::governed(&sim.gpu),
        ] {
            let o = sim.run(&suite, &arrivals, &policy).unwrap();
            assert_eq!(o.joules.len(), arrivals.len());
            let attributed: f64 = o.joules.iter().sum();
            let rel = (attributed - o.total_j()).abs() / o.total_j();
            assert!(rel < 1e-6, "{}: conservation off by {rel:e}", policy.label());
            // Phase components reconcile with the loop's own accounting.
            let b = &o.attributed_phase_breakdown;
            assert!((b.total_j() - o.total_j()).abs() / o.total_j() < 1e-6);
            assert!((b.switch_j - o.switch_j).abs() <= 1e-9 * o.switch_j.max(1.0));
            assert!((b.idle_j - o.idle_j).abs() <= 1e-9 * o.idle_j.max(1.0));
            assert!(
                (b.prefill_j + b.decode_j - (o.energy_j - o.switch_j)).abs()
                    <= 1e-6 * o.energy_j,
                "{}: prefill+decode mismatch",
                policy.label()
            );
            assert!(o.joules.iter().all(|&j| j > 0.0), "every request costs energy");
            // J/req agrees with the ledger it is derived from.
            let jreq = attributed / o.served as f64;
            assert!((o.joules_per_request() - jreq).abs() <= 1e-9 * jreq);
            assert!(o.active_joules_per_request() <= o.joules_per_request());
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 40);
        let p = DvfsPolicy::governed(&sim.gpu);
        let a = sim.run(&suite, &arrivals, &p).unwrap();
        let b = sim.run(&suite, &arrivals, &p).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.freq_switches, b.freq_switches);
        assert_eq!(a.slo.e2e_p99(), b.slo.e2e_p99());
    }

    #[test]
    fn governed_saves_energy_within_slo_under_bursty_traffic() {
        // The PR's acceptance criterion, at test scale: ≥25% active-energy
        // savings vs Static(f_max) with p99 e2e inside the SLO.
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 80);
        let base = sim.run(&suite, &arrivals, &DvfsPolicy::Static(2842)).unwrap();
        let gov = sim.run(&suite, &arrivals, &DvfsPolicy::governed(&sim.gpu)).unwrap();
        let savings = 1.0 - gov.energy_j / base.energy_j;
        assert!(savings >= 0.25, "governed savings {savings:.3}");
        assert!(
            gov.slo.e2e_p99() <= sim.cfg.slo.e2e_p99_s,
            "governed p99 {:.2}s over the {:.2}s SLO",
            gov.slo.e2e_p99(),
            sim.cfg.slo.e2e_p99_s
        );
        // The controller actually moved off the ceiling.
        assert!(gov.mean_decode_freq_mhz < base.mean_decode_freq_mhz * 0.5);
        assert!(gov.freq_switches > 0);
    }

    #[test]
    fn governed_tracks_phase_aware_energy_when_unloaded() {
        // With light traffic the governor should settle at the floor and
        // approach the open-loop phase-aware profile's energy.
        let (suite, sim, pool) = setup();
        let arrivals =
            TrafficPattern::Poisson { rps: 1.0 }.generate_from(&pool, 50, 7);
        let pa = sim
            .run(&suite, &arrivals, &DvfsPolicy::paper_phase_aware(&sim.gpu))
            .unwrap();
        let gov = sim.run(&suite, &arrivals, &DvfsPolicy::governed(&sim.gpu)).unwrap();
        assert!(
            gov.energy_j < pa.energy_j * 1.15,
            "governed {:.0}J vs phase-aware {:.0}J",
            gov.energy_j,
            pa.energy_j
        );
    }

    #[test]
    fn queueing_delay_appears_under_overload() {
        let (suite, sim, pool) = setup();
        let calm = TrafficPattern::Poisson { rps: 0.5 }.generate_from(&pool, 30, 11);
        let slam = TrafficPattern::Poisson { rps: 50.0 }.generate_from(&pool, 30, 11);
        let p = DvfsPolicy::Static(2842);
        let c = sim.run(&suite, &calm, &p).unwrap();
        let s = sim.run(&suite, &slam, &p).unwrap();
        assert!(s.slo.e2e_p99() > c.slo.e2e_p99(), "no queueing effect");
        assert!(s.max_queue_depth > c.max_queue_depth);
        // Idle energy shows up only when the device actually waits.
        assert!(c.idle_j > s.idle_j);
    }

    #[test]
    fn ttft_includes_queue_wait() {
        let (suite, sim, pool) = setup();
        let slam = TrafficPattern::Poisson { rps: 40.0 }.generate_from(&pool, 40, 13);
        let o = sim.run(&suite, &slam, &DvfsPolicy::Static(2842)).unwrap();
        // Under heavy backlog TTFT p95 must exceed a lone prefill's time by
        // a wide margin (queue wait dominates).
        assert!(o.slo.ttft_p95() > 0.05, "ttft p95 {:.4}s", o.slo.ttft_p95());
    }

    #[test]
    fn classification_requests_complete_at_admission_without_decode() {
        // Inherited from the shared replica loop: a zero-output query is
        // scored with one prefill pass per answer option and never enters
        // the decode batch.
        let (suite, sim, _) = setup();
        let idx = suite.dataset_indices(Dataset::BoolQ)[0];
        let arrivals = vec![Arrival::at(0.0, idx)];
        let o = sim.run(&suite, &arrivals, &DvfsPolicy::Static(2842)).unwrap();
        assert_eq!(o.served, 1);
        assert_eq!(o.slo.completed(), 1);
        assert!(o.attributed_phase_breakdown.prefill_j > 0.0);
        assert_eq!(o.attributed_phase_breakdown.decode_j, 0.0);
        assert_eq!(o.mean_decode_freq_mhz, 0.0, "no decode step ran");
        assert!(o.makespan_s > 0.0);
    }

    #[test]
    fn zero_served_reports_nan_not_a_silent_number() {
        let (suite, sim, _) = setup();
        let o = sim.run(&suite, &[], &DvfsPolicy::Static(2842)).unwrap();
        assert_eq!(o.served, 0);
        assert!(o.joules_per_request().is_nan());
        assert!(o.active_joules_per_request().is_nan());
        assert!(o.joules.is_empty());
    }

    #[test]
    fn pluggable_governor_path_matches_policy_dispatch() {
        let (suite, sim, pool) = setup();
        let arrivals = bursty(&pool, 30);
        let p = DvfsPolicy::paper_phase_aware(&sim.gpu);
        let via_policy = sim.run(&suite, &arrivals, &p).unwrap();
        let via_gov = sim
            .run_with(&suite, &arrivals, governor_for(&p, &sim.gpu))
            .unwrap();
        assert_eq!(via_policy.energy_j, via_gov.energy_j);
        assert_eq!(via_policy.joules, via_gov.joules);
        assert_eq!(via_policy.makespan_s, via_gov.makespan_s);
    }
}
