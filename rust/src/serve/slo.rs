//! Service-level objectives and their online tracking.
//!
//! Three latency objectives cover the serving phases the paper measures
//! offline: time-to-first-token (queueing + prefill), mean time-between-
//! tokens (decode cadence), and end-to-end latency. Lifetime percentiles
//! are tracked *streaming* with the P² estimators from
//! [`crate::stats::StreamingQuantiles`] (reported by every serving
//! experiment); the governor's control signal is computed over a short
//! recent-completions window instead, because a lifetime p99 never forgets
//! a burst — a controller fed cumulative percentiles ratchets to the
//! ceiling after one bad spell and never recovers the energy savings.

use std::collections::VecDeque;

use crate::stats::{exact_quantile, StreamingQuantiles};

/// Latency objectives for one serving class.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    /// p95 time-to-first-token target, seconds.
    pub ttft_p95_s: f64,
    /// p95 mean time-between-tokens target, seconds.
    pub tbt_p95_s: f64,
    /// p99 end-to-end latency target, seconds.
    pub e2e_p99_s: f64,
}

impl Slo {
    /// An interactive chat-style objective, calibrated to the simulated
    /// testbed's 8B-class service times (decode step ≈ 11 ms at batch 8).
    pub fn interactive() -> Slo {
        Slo { ttft_p95_s: 3.0, tbt_p95_s: 0.06, e2e_p99_s: 8.0 }
    }

    /// A relaxed batch/offline objective.
    pub fn relaxed() -> Slo {
        Slo { ttft_p95_s: 10.0, tbt_p95_s: 0.25, e2e_p99_s: 30.0 }
    }
}

/// How many recently-completed requests feed the control signal.
const RECENT_WINDOW: usize = 32;

/// One completed request's latencies (the recent-window sample).
#[derive(Debug, Clone, Copy)]
struct Completion {
    ttft_s: f64,
    tbt_s: f64,
    e2e_s: f64,
    violated: bool,
}

/// Anything that can absorb per-request completion latencies.
///
/// [`SloTracker`] is the canonical sink; the fleet engine's parallel gap
/// stepping substitutes a thread-local buffer that replays into the real
/// tracker in deterministic order afterwards.
pub trait RecordSink {
    /// Record one completed request's latencies.
    fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64);
}

/// Streaming SLO attainment tracker.
#[derive(Debug, Clone)]
pub struct SloTracker {
    pub slo: Slo,
    ttft: StreamingQuantiles,
    tbt: StreamingQuantiles,
    e2e: StreamingQuantiles,
    completed: usize,
    /// Requests whose end-to-end latency exceeded the e2e target.
    e2e_violations: usize,
    /// The most recent completions (the governor's control window).
    recent: VecDeque<Completion>,
}

impl SloTracker {
    pub fn new(slo: Slo) -> SloTracker {
        SloTracker {
            slo,
            ttft: StreamingQuantiles::new(),
            tbt: StreamingQuantiles::new(),
            e2e: StreamingQuantiles::new(),
            completed: 0,
            e2e_violations: 0,
            recent: VecDeque::with_capacity(RECENT_WINDOW),
        }
    }

    /// Record one completed request.
    pub fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        self.ttft.observe(ttft_s);
        self.tbt.observe(tbt_s);
        self.e2e.observe(e2e_s);
        self.completed += 1;
        let violated = e2e_s > self.slo.e2e_p99_s;
        if violated {
            self.e2e_violations += 1;
        }
        if self.recent.len() == RECENT_WINDOW {
            self.recent.pop_front();
        }
        self.recent.push_back(Completion { ttft_s, tbt_s, e2e_s, violated });
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    pub fn ttft_p95(&self) -> f64 {
        self.ttft.p95()
    }

    pub fn tbt_p95(&self) -> f64 {
        self.tbt.p95()
    }

    pub fn e2e_p50(&self) -> f64 {
        self.e2e.p50()
    }

    pub fn e2e_p95(&self) -> f64 {
        self.e2e.p95()
    }

    pub fn e2e_p99(&self) -> f64 {
        self.e2e.p99()
    }

    /// Fraction of completed requests inside the end-to-end target
    /// (1.0 when nothing has completed yet).
    pub fn attainment(&self) -> f64 {
        if self.completed == 0 {
            return 1.0;
        }
        1.0 - self.e2e_violations as f64 / self.completed as f64
    }

    /// Fraction of the recent window that violated the e2e target.
    pub fn recent_violation_rate(&self) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        self.recent.iter().filter(|c| c.violated).count() as f64 / self.recent.len() as f64
    }

    /// SLO pressure — the governor's control signal.
    ///
    /// The slow component is the worst ratio of a *recent-window*
    /// percentile to its target (1.0 = exactly at target); computing it
    /// over the window rather than the lifetime stream lets the signal
    /// fall back once a burst drains, so the controller recovers its
    /// energy savings. The fast component kicks the pressure above 1 the
    /// moment recent completions actually violate the e2e target.
    pub fn pressure(&self) -> f64 {
        if self.completed < 5 || self.recent.len() < 5 {
            return 0.0;
        }
        let q = |f: fn(&Completion) -> f64, p: f64| {
            let xs: Vec<f64> = self.recent.iter().map(f).collect();
            exact_quantile(&xs, p)
        };
        let ratios = [
            q(|c| c.ttft_s, 0.95) / self.slo.ttft_p95_s,
            q(|c| c.tbt_s, 0.95) / self.slo.tbt_p95_s,
            q(|c| c.e2e_s, 0.99) / self.slo.e2e_p99_s,
        ];
        let slow = ratios.iter().cloned().fold(0.0, f64::max);
        let recent = self.recent_violation_rate();
        let fast = if recent > 0.0 { 1.0 + recent } else { 0.0 };
        slow.max(fast)
    }
}

impl RecordSink for SloTracker {
    fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        SloTracker::record(self, ttft_s, tbt_s, e2e_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainment_counts_e2e_violations() {
        let mut t = SloTracker::new(Slo { ttft_p95_s: 1.0, tbt_p95_s: 0.1, e2e_p99_s: 2.0 });
        assert_eq!(t.attainment(), 1.0);
        for i in 0..10 {
            // Two of ten exceed the 2 s target.
            let e2e = if i < 8 { 1.0 } else { 3.0 };
            t.record(0.2, 0.02, e2e);
        }
        assert_eq!(t.completed(), 10);
        assert!((t.attainment() - 0.8).abs() < 1e-12);
        assert!(t.recent_violation_rate() > 0.15);
    }

    #[test]
    fn pressure_rises_with_violations_and_falls_with_slack() {
        let slo = Slo { ttft_p95_s: 1.0, tbt_p95_s: 0.1, e2e_p99_s: 2.0 };
        let mut slack = SloTracker::new(slo);
        for _ in 0..50 {
            slack.record(0.1, 0.01, 0.5);
        }
        assert!(slack.pressure() < 0.5, "slack pressure {}", slack.pressure());

        let mut hot = SloTracker::new(slo);
        for _ in 0..50 {
            hot.record(0.9, 0.09, 2.5); // violating e2e
        }
        assert!(hot.pressure() > 1.0, "hot pressure {}", hot.pressure());
    }

    #[test]
    fn pressure_is_quiet_during_warmup() {
        let mut t = SloTracker::new(Slo::interactive());
        assert_eq!(t.pressure(), 0.0);
        t.record(100.0, 100.0, 100.0); // one outlier, still warming up
        assert_eq!(t.pressure(), 0.0);
    }

    #[test]
    fn recent_window_recovers_after_a_burst() {
        let slo = Slo { ttft_p95_s: 10.0, tbt_p95_s: 10.0, e2e_p99_s: 2.0 };
        let mut t = SloTracker::new(slo);
        for _ in 0..10 {
            t.record(0.1, 0.01, 3.0); // burst of violations
        }
        assert!(t.pressure() > 1.5);
        for _ in 0..2 * RECENT_WINDOW {
            t.record(0.1, 0.01, 0.3); // burst clears
        }
        assert_eq!(t.recent_violation_rate(), 0.0);
        assert_eq!(t.completed(), 10 + 2 * RECENT_WINDOW);
    }

    #[test]
    fn streaming_percentiles_are_exposed() {
        let mut t = SloTracker::new(Slo::interactive());
        for i in 1..=100 {
            let x = i as f64 / 100.0;
            t.record(x, x / 10.0, x * 2.0);
        }
        assert!(t.ttft_p95() > t.e2e_p50() / 2.0 * 0.5); // sanity: populated
        assert!(t.e2e_p99() <= 2.0 + 1e-9);
        assert!(t.e2e_p50() < t.e2e_p95() && t.e2e_p95() <= t.e2e_p99());
        assert!(t.tbt_p95() < 0.11);
    }
}
