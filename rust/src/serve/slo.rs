//! Service-level objectives and their online tracking.
//!
//! Three latency objectives cover the serving phases the paper measures
//! offline: time-to-first-token (queueing + prefill), mean time-between-
//! tokens (decode cadence), and end-to-end latency. Lifetime percentiles
//! are tracked *streaming* with the P² estimators from
//! [`crate::stats::StreamingQuantiles`] (reported by every serving
//! experiment); the governor's control signal is computed over a short
//! recent-completions window instead, because a lifetime p99 never forgets
//! a burst — a controller fed cumulative percentiles ratchets to the
//! ceiling after one bad spell and never recovers the energy savings.

use std::collections::VecDeque;

use crate::stats::{exact_quantile, StreamingQuantiles};

use super::traffic::TrafficClass;

/// Latency objectives for one serving class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// p95 time-to-first-token target, seconds.
    pub ttft_p95_s: f64,
    /// p95 mean time-between-tokens target, seconds.
    pub tbt_p95_s: f64,
    /// p99 end-to-end latency target, seconds.
    pub e2e_p99_s: f64,
}

impl Slo {
    /// An interactive chat-style objective, calibrated to the simulated
    /// testbed's 8B-class service times (decode step ≈ 11 ms at batch 8).
    pub fn interactive() -> Slo {
        Slo { ttft_p95_s: 3.0, tbt_p95_s: 0.06, e2e_p99_s: 8.0 }
    }

    /// A relaxed batch/offline objective.
    pub fn relaxed() -> Slo {
        Slo { ttft_p95_s: 10.0, tbt_p95_s: 0.25, e2e_p99_s: 30.0 }
    }

    /// A best-effort background objective: latency bounded only loosely,
    /// so the governor can park background-heavy load at the frequency
    /// floor and starvation aging is the real protection.
    pub fn background() -> Slo {
        Slo { ttft_p95_s: 60.0, tbt_p95_s: 0.5, e2e_p99_s: 180.0 }
    }
}

/// Per-class latency objectives: one [`Slo`] per [`TrafficClass`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassSlos {
    pub interactive: Slo,
    pub batch: Slo,
    pub background: Slo,
}

impl Default for ClassSlos {
    fn default() -> ClassSlos {
        ClassSlos {
            interactive: Slo::interactive(),
            batch: Slo::relaxed(),
            background: Slo::background(),
        }
    }
}

impl ClassSlos {
    pub fn for_class(&self, c: TrafficClass) -> Slo {
        match c {
            TrafficClass::Interactive => self.interactive,
            TrafficClass::Batch => self.batch,
            TrafficClass::Background => self.background,
        }
    }
}

/// How many recently-completed requests feed the control signal.
const RECENT_WINDOW: usize = 32;

/// One completed request's latencies (the recent-window sample).
#[derive(Debug, Clone, Copy)]
struct Completion {
    ttft_s: f64,
    tbt_s: f64,
    e2e_s: f64,
    violated: bool,
}

/// Anything that can absorb per-request completion latencies.
///
/// [`SloTracker`] is the canonical sink; the fleet engine's parallel gap
/// stepping substitutes a thread-local buffer that replays into the real
/// tracker in deterministic order afterwards.
pub trait RecordSink {
    /// Record one completed request's latencies.
    fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64);
}

/// Streaming SLO attainment tracker.
#[derive(Debug, Clone)]
pub struct SloTracker {
    pub slo: Slo,
    ttft: StreamingQuantiles,
    tbt: StreamingQuantiles,
    e2e: StreamingQuantiles,
    completed: usize,
    /// Requests whose end-to-end latency exceeded the e2e target.
    e2e_violations: usize,
    /// The most recent completions (the governor's control window).
    recent: VecDeque<Completion>,
}

impl SloTracker {
    pub fn new(slo: Slo) -> SloTracker {
        SloTracker {
            slo,
            ttft: StreamingQuantiles::new(),
            tbt: StreamingQuantiles::new(),
            e2e: StreamingQuantiles::new(),
            completed: 0,
            e2e_violations: 0,
            recent: VecDeque::with_capacity(RECENT_WINDOW),
        }
    }

    /// Record one completed request.
    pub fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        self.ttft.observe(ttft_s);
        self.tbt.observe(tbt_s);
        self.e2e.observe(e2e_s);
        self.completed += 1;
        let violated = e2e_s > self.slo.e2e_p99_s;
        if violated {
            self.e2e_violations += 1;
        }
        if self.recent.len() == RECENT_WINDOW {
            self.recent.pop_front();
        }
        self.recent.push_back(Completion { ttft_s, tbt_s, e2e_s, violated });
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    pub fn ttft_p95(&self) -> f64 {
        self.ttft.p95()
    }

    pub fn ttft_p99(&self) -> f64 {
        self.ttft.p99()
    }

    pub fn tbt_p95(&self) -> f64 {
        self.tbt.p95()
    }

    pub fn e2e_p50(&self) -> f64 {
        self.e2e.p50()
    }

    pub fn e2e_p95(&self) -> f64 {
        self.e2e.p95()
    }

    pub fn e2e_p99(&self) -> f64 {
        self.e2e.p99()
    }

    /// Fraction of completed requests inside the end-to-end target
    /// (1.0 when nothing has completed yet).
    pub fn attainment(&self) -> f64 {
        if self.completed == 0 {
            return 1.0;
        }
        1.0 - self.e2e_violations as f64 / self.completed as f64
    }

    /// Fraction of the recent window that violated the e2e target.
    pub fn recent_violation_rate(&self) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        self.recent.iter().filter(|c| c.violated).count() as f64 / self.recent.len() as f64
    }

    /// SLO pressure — the governor's control signal.
    ///
    /// The slow component is the worst ratio of a *recent-window*
    /// percentile to its target (1.0 = exactly at target); computing it
    /// over the window rather than the lifetime stream lets the signal
    /// fall back once a burst drains, so the controller recovers its
    /// energy savings. The fast component kicks the pressure above 1 the
    /// moment recent completions actually violate the e2e target.
    pub fn pressure(&self) -> f64 {
        if self.completed < 5 || self.recent.len() < 5 {
            return 0.0;
        }
        let q = |f: fn(&Completion) -> f64, p: f64| {
            let xs: Vec<f64> = self.recent.iter().map(f).collect();
            exact_quantile(&xs, p)
        };
        let ratios = [
            q(|c| c.ttft_s, 0.95) / self.slo.ttft_p95_s,
            q(|c| c.tbt_s, 0.95) / self.slo.tbt_p95_s,
            q(|c| c.e2e_s, 0.99) / self.slo.e2e_p99_s,
        ];
        let slow = ratios.iter().cloned().fold(0.0, f64::max);
        let recent = self.recent_violation_rate();
        let fast = if recent > 0.0 { 1.0 + recent } else { 0.0 };
        slow.max(fast)
    }
}

impl RecordSink for SloTracker {
    fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        SloTracker::record(self, ttft_s, tbt_s, e2e_s);
    }
}

/// How much each class's pressure weighs in the combined control signal:
/// interactive distress must dominate, background distress should barely
/// lift frequency (its protection is admission aging, not DVFS).
const CLASS_PRESSURE_WEIGHTS: [f64; 3] = [1.0, 0.6, 0.3];

/// Per-class SLO tracking: one [`SloTracker`] per [`TrafficClass`], each
/// measuring its class against its *own* objective, combined into a
/// class-weighted pressure signal for the governor. This is what lets a
/// background-heavy mix sink to the frequency floor: a class-blind tracker
/// measures background completions against the interactive budget and
/// pins the governor at the ceiling.
#[derive(Debug, Clone)]
pub struct ClassSloTracker {
    trackers: [SloTracker; 3],
}

impl ClassSloTracker {
    pub fn new(slos: ClassSlos) -> ClassSloTracker {
        ClassSloTracker {
            trackers: [
                SloTracker::new(slos.interactive),
                SloTracker::new(slos.batch),
                SloTracker::new(slos.background),
            ],
        }
    }

    /// Record one completed request against its class's objective.
    pub fn record(&mut self, class: TrafficClass, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        self.trackers[class.slot()].record(ttft_s, tbt_s, e2e_s);
    }

    pub fn tracker(&self, class: TrafficClass) -> &SloTracker {
        &self.trackers[class.slot()]
    }

    pub fn completed(&self) -> usize {
        self.trackers.iter().map(|t| t.completed()).sum()
    }

    /// Class-weighted SLO pressure: the worst weighted per-class signal.
    /// Interactive pressure passes through at full strength; batch and
    /// background are attenuated so latency-tolerant distress asks for
    /// admission priority, not megahertz.
    pub fn pressure(&self) -> f64 {
        TrafficClass::ALL
            .iter()
            .map(|&c| CLASS_PRESSURE_WEIGHTS[c.slot()] * self.tracker(c).pressure())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainment_counts_e2e_violations() {
        let mut t = SloTracker::new(Slo { ttft_p95_s: 1.0, tbt_p95_s: 0.1, e2e_p99_s: 2.0 });
        assert_eq!(t.attainment(), 1.0);
        for i in 0..10 {
            // Two of ten exceed the 2 s target.
            let e2e = if i < 8 { 1.0 } else { 3.0 };
            t.record(0.2, 0.02, e2e);
        }
        assert_eq!(t.completed(), 10);
        assert!((t.attainment() - 0.8).abs() < 1e-12);
        assert!(t.recent_violation_rate() > 0.15);
    }

    #[test]
    fn pressure_rises_with_violations_and_falls_with_slack() {
        let slo = Slo { ttft_p95_s: 1.0, tbt_p95_s: 0.1, e2e_p99_s: 2.0 };
        let mut slack = SloTracker::new(slo);
        for _ in 0..50 {
            slack.record(0.1, 0.01, 0.5);
        }
        assert!(slack.pressure() < 0.5, "slack pressure {}", slack.pressure());

        let mut hot = SloTracker::new(slo);
        for _ in 0..50 {
            hot.record(0.9, 0.09, 2.5); // violating e2e
        }
        assert!(hot.pressure() > 1.0, "hot pressure {}", hot.pressure());
    }

    #[test]
    fn pressure_is_quiet_during_warmup() {
        let mut t = SloTracker::new(Slo::interactive());
        assert_eq!(t.pressure(), 0.0);
        t.record(100.0, 100.0, 100.0); // one outlier, still warming up
        assert_eq!(t.pressure(), 0.0);
    }

    #[test]
    fn recent_window_recovers_after_a_burst() {
        let slo = Slo { ttft_p95_s: 10.0, tbt_p95_s: 10.0, e2e_p99_s: 2.0 };
        let mut t = SloTracker::new(slo);
        for _ in 0..10 {
            t.record(0.1, 0.01, 3.0); // burst of violations
        }
        assert!(t.pressure() > 1.5);
        for _ in 0..2 * RECENT_WINDOW {
            t.record(0.1, 0.01, 0.3); // burst clears
        }
        assert_eq!(t.recent_violation_rate(), 0.0);
        assert_eq!(t.completed(), 10 + 2 * RECENT_WINDOW);
    }

    #[test]
    fn class_slos_default_loosens_down_the_priority_ladder() {
        let c = ClassSlos::default();
        assert_eq!(c.for_class(TrafficClass::Interactive), Slo::interactive());
        assert_eq!(c.for_class(TrafficClass::Batch), Slo::relaxed());
        assert_eq!(c.for_class(TrafficClass::Background), Slo::background());
        assert!(c.interactive.ttft_p95_s < c.batch.ttft_p95_s);
        assert!(c.batch.ttft_p95_s < c.background.ttft_p95_s);
        assert!(c.interactive.e2e_p99_s < c.batch.e2e_p99_s);
        assert!(c.batch.e2e_p99_s < c.background.e2e_p99_s);
    }

    #[test]
    fn class_tracker_routes_records_to_the_right_class() {
        let mut t = ClassSloTracker::new(ClassSlos::default());
        t.record(TrafficClass::Interactive, 0.1, 0.01, 0.5);
        t.record(TrafficClass::Background, 20.0, 0.2, 90.0);
        t.record(TrafficClass::Background, 25.0, 0.2, 95.0);
        assert_eq!(t.tracker(TrafficClass::Interactive).completed(), 1);
        assert_eq!(t.tracker(TrafficClass::Batch).completed(), 0);
        assert_eq!(t.tracker(TrafficClass::Background).completed(), 2);
        assert_eq!(t.completed(), 3);
    }

    #[test]
    fn class_weighted_pressure_discounts_background_distress() {
        // The same latencies: violations for interactive, comfortably in
        // budget for background. The class-aware signal must be calm when
        // only background carries them, hot when interactive does.
        let mut bg_heavy = ClassSloTracker::new(ClassSlos::default());
        for _ in 0..40 {
            bg_heavy.record(TrafficClass::Background, 9.0, 0.09, 12.0);
        }
        let mut int_heavy = ClassSloTracker::new(ClassSlos::default());
        for _ in 0..40 {
            int_heavy.record(TrafficClass::Interactive, 9.0, 0.09, 12.0);
        }
        assert!(bg_heavy.pressure() < 0.2, "bg pressure {}", bg_heavy.pressure());
        assert!(int_heavy.pressure() > 1.0, "int pressure {}", int_heavy.pressure());
        // Even a violating background stream is attenuated below the
        // equivalent interactive distress.
        let mut bg_violating = ClassSloTracker::new(ClassSlos::default());
        for _ in 0..40 {
            bg_violating.record(TrafficClass::Background, 100.0, 1.0, 300.0);
        }
        assert!(bg_violating.pressure() < int_heavy.pressure());
        assert!(bg_violating.pressure() > 0.0);
    }

    #[test]
    fn ttft_p99_is_monotone_with_p95() {
        let mut t = SloTracker::new(Slo::interactive());
        for i in 1..=200 {
            t.record(i as f64 / 100.0, 0.01, 1.0);
        }
        assert!(t.ttft_p99() >= t.ttft_p95());
    }

    #[test]
    fn streaming_percentiles_are_exposed() {
        let mut t = SloTracker::new(Slo::interactive());
        for i in 1..=100 {
            let x = i as f64 / 100.0;
            t.record(x, x / 10.0, x * 2.0);
        }
        assert!(t.ttft_p95() > t.e2e_p50() / 2.0 * 0.5); // sanity: populated
        assert!(t.e2e_p99() <= 2.0 + 1e-9);
        assert!(t.e2e_p50() < t.e2e_p95() && t.e2e_p95() <= t.e2e_p99());
        assert!(t.tbt_p95() < 0.11);
    }
}
