//! Arrival-process generators: timestamped request streams over the
//! workload corpus.
//!
//! The paper's replay harness measures closed-world batches; a serving
//! system sees an *arrival process*. Four generators cover the shapes a
//! production trace exhibits: homogeneous Poisson (steady load), a
//! two-state MMPP (bursts), a sinusoidal diurnal ramp, and replay of a
//! recorded timestamp trace. All draw query indices and inter-arrival
//! randomness from an explicit seed, so every serving experiment replays
//! exactly.

use crate::workload::ReplaySuite;
use crate::Rng;

/// One timestamped request: when it arrives and which corpus query it is.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Arrival time on the simulated clock, seconds.
    pub t_s: f64,
    /// Index into the suite's query/feature arrays.
    pub query_idx: usize,
}

/// Exponential inter-arrival draw at `rate` events/second.
#[inline]
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() / rate
}

/// The supported arrival processes.
#[derive(Debug, Clone)]
pub enum TrafficPattern {
    /// Homogeneous Poisson process at `rps` requests/second.
    Poisson { rps: f64 },
    /// Two-state Markov-modulated Poisson process: calm periods at
    /// `base_rps` alternating with bursts at `burst_rps`; dwell times in
    /// each state are exponential with mean `mean_dwell_s`.
    Bursty { base_rps: f64, burst_rps: f64, mean_dwell_s: f64 },
    /// Sinusoidal diurnal ramp: the instantaneous rate swings between
    /// `min_rps` and `max_rps` with period `period_s` (thinning sampler).
    Diurnal { min_rps: f64, max_rps: f64, period_s: f64 },
    /// Replay a recorded, non-decreasing timestamp trace; cycled with the
    /// trace's span if more arrivals are requested than it holds.
    Replay { timestamps: Vec<f64> },
}

impl TrafficPattern {
    pub fn label(&self) -> String {
        match self {
            TrafficPattern::Poisson { rps } => format!("poisson@{rps}rps"),
            TrafficPattern::Bursty { base_rps, burst_rps, .. } => {
                format!("bursty[{base_rps}/{burst_rps}rps]")
            }
            TrafficPattern::Diurnal { min_rps, max_rps, .. } => {
                format!("diurnal[{min_rps}-{max_rps}rps]")
            }
            TrafficPattern::Replay { timestamps } => {
                format!("replay[{} events]", timestamps.len())
            }
        }
    }

    /// Generate `n` arrivals drawing query indices uniformly from the whole
    /// suite.
    pub fn generate(&self, suite: &ReplaySuite, n: usize, seed: u64) -> Vec<Arrival> {
        let pool: Vec<usize> = (0..suite.len()).collect();
        self.generate_from(&pool, n, seed)
    }

    /// Generate `n` arrivals drawing query indices uniformly from `pool`
    /// (e.g. only the generation datasets for a decode-heavy scenario).
    pub fn generate_from(&self, pool: &[usize], n: usize, seed: u64) -> Vec<Arrival> {
        assert!(!pool.is_empty(), "traffic needs a non-empty query pool");
        let mut rng = crate::rng(seed);
        let times = self.timestamps(n, &mut rng);
        times
            .into_iter()
            .map(|t_s| Arrival { t_s, query_idx: pool[rng.gen_range(0, pool.len())] })
            .collect()
    }

    fn timestamps(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            TrafficPattern::Poisson { rps } => {
                assert!(rps > 0.0);
                let mut t = 0.0;
                for _ in 0..n {
                    t += exp_gap(rng, rps);
                    out.push(t);
                }
            }
            TrafficPattern::Bursty { base_rps, burst_rps, mean_dwell_s } => {
                assert!(base_rps > 0.0 && burst_rps > 0.0 && mean_dwell_s > 0.0);
                let mut t = 0.0;
                let mut burst = false;
                let mut state_end = exp_gap(rng, 1.0 / mean_dwell_s);
                while out.len() < n {
                    let rate = if burst { burst_rps } else { base_rps };
                    let gap = exp_gap(rng, rate);
                    if t + gap > state_end {
                        // Memoryless: jump to the state boundary, flip, and
                        // redraw the gap under the new state's rate.
                        t = state_end;
                        burst = !burst;
                        state_end = t + exp_gap(rng, 1.0 / mean_dwell_s);
                        continue;
                    }
                    t += gap;
                    out.push(t);
                }
            }
            TrafficPattern::Diurnal { min_rps, max_rps, period_s } => {
                assert!(min_rps > 0.0 && max_rps >= min_rps && period_s > 0.0);
                // Lewis–Shedler thinning with λ_max as the majorant; the
                // rate trough sits at t = 0 (cold start, like a new region).
                let rate_at = |t: f64| {
                    let phase = std::f64::consts::TAU * t / period_s;
                    min_rps + (max_rps - min_rps) * 0.5 * (1.0 - phase.cos())
                };
                let mut t = 0.0;
                while out.len() < n {
                    t += exp_gap(rng, max_rps);
                    if rng.gen_f64() < rate_at(t) / max_rps {
                        out.push(t);
                    }
                }
            }
            TrafficPattern::Replay { ref timestamps } => {
                assert!(!timestamps.is_empty(), "replay trace is empty");
                assert!(
                    timestamps.windows(2).all(|w| w[0] <= w[1]),
                    "replay trace must be non-decreasing"
                );
                // Rebase to t = 0: production traces carry wall-clock
                // offsets, and serving the offset as idle time would
                // swamp every energy comparison.
                let t0 = timestamps[0];
                let last = timestamps.last().unwrap() - t0;
                // Cycle period: trace span plus one mean inter-arrival gap.
                let span = last + last / timestamps.len() as f64;
                for i in 0..n {
                    let cycle = (i / timestamps.len()) as f64;
                    out.push(timestamps[i % timestamps.len()] - t0 + cycle * span);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dataset;

    fn suite() -> ReplaySuite {
        ReplaySuite::quick(3, 10)
    }

    #[test]
    fn arrivals_are_deterministic_sorted_and_in_pool() {
        let s = suite();
        let pool = s.dataset_indices(Dataset::NarrativeQa);
        for pattern in [
            TrafficPattern::Poisson { rps: 5.0 },
            TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 20.0, mean_dwell_s: 1.0 },
            TrafficPattern::Diurnal { min_rps: 1.0, max_rps: 10.0, period_s: 10.0 },
            TrafficPattern::Replay { timestamps: vec![0.0, 0.5, 0.6, 2.0] },
        ] {
            let a = pattern.generate_from(&pool, 200, 9);
            let b = pattern.generate_from(&pool, 200, 9);
            assert_eq!(a, b, "{}", pattern.label());
            assert_eq!(a.len(), 200);
            assert!(
                a.windows(2).all(|w| w[0].t_s <= w[1].t_s),
                "{} not sorted",
                pattern.label()
            );
            assert!(a.iter().all(|x| pool.contains(&x.query_idx)));
            assert!(a[0].t_s >= 0.0);
        }
    }

    #[test]
    fn poisson_hits_the_requested_rate() {
        let s = suite();
        let a = TrafficPattern::Poisson { rps: 8.0 }.generate(&s, 4000, 1);
        let rate = a.len() as f64 / a.last().unwrap().t_s;
        assert!((rate - 8.0).abs() / 8.0 < 0.1, "rate {rate:.2}");
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Coefficient of variation of inter-arrival gaps: 1 for Poisson,
        // substantially above 1 for an MMPP with well-separated rates.
        let s = suite();
        let cv = |arr: &[Arrival]| {
            let gaps: Vec<f64> = arr.windows(2).map(|w| w[1].t_s - w[0].t_s).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m).powi(2)).sum::<f64>() / gaps.len() as f64;
            v.sqrt() / m
        };
        let pois = TrafficPattern::Poisson { rps: 5.0 }.generate(&s, 3000, 2);
        let burst = TrafficPattern::Bursty { base_rps: 1.0, burst_rps: 25.0, mean_dwell_s: 2.0 }
            .generate(&s, 3000, 2);
        assert!(cv(&burst) > cv(&pois) * 1.3, "cv {} vs {}", cv(&burst), cv(&pois));
    }

    #[test]
    fn diurnal_peaks_midperiod() {
        let s = suite();
        let period = 20.0;
        let a = TrafficPattern::Diurnal { min_rps: 0.5, max_rps: 10.0, period_s: period }
            .generate(&s, 2000, 4);
        // Arrivals in the peak half of each cycle (quarter..three-quarter)
        // must dominate the trough half.
        let (mut peak, mut trough) = (0usize, 0usize);
        for x in &a {
            let phase = (x.t_s / period).fract();
            if (0.25..0.75).contains(&phase) {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(peak > 2 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn replay_cycles_beyond_the_trace() {
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![0.1, 0.4, 1.0] };
        let a = tr.generate(&s, 7, 5);
        assert_eq!(a.len(), 7);
        // First cycle reproduces the trace rebased to t = 0.
        assert!((a[0].t_s - 0.0).abs() < 1e-12);
        assert!((a[1].t_s - 0.3).abs() < 1e-12);
        assert!((a[2].t_s - 0.9).abs() < 1e-12);
        // Later cycles are offset copies, still sorted.
        assert!(a[3].t_s > a[2].t_s);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn replay_rebases_wall_clock_offsets() {
        // A production trace with an un-rebased clock must not inject the
        // offset as leading idle time.
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![3600.0, 3600.5, 3601.0] };
        let a = tr.generate(&s, 6, 5);
        assert!((a[0].t_s - 0.0).abs() < 1e-12);
        assert!((a[2].t_s - 1.0).abs() < 1e-12);
        // Cycle period = span (1.0) + mean gap (1/3): no huge dead gaps.
        assert!((a[3].t_s - (1.0 + 1.0 / 3.0)).abs() < 1e-9);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    #[should_panic(expected = "non-empty query pool")]
    fn empty_pool_panics() {
        TrafficPattern::Poisson { rps: 1.0 }.generate_from(&[], 5, 0);
    }

    // The replay-trace contract, pinned: an out-of-order trace is
    // *rejected* (loudly, at generation time — not silently sorted, which
    // would hide a corrupted production trace), while duplicate
    // timestamps are legal (real traces batch arrivals on coarse clocks)
    // and replay deterministically in trace order.

    #[test]
    #[should_panic(expected = "replay trace must be non-decreasing")]
    fn replay_rejects_unsorted_traces() {
        let s = suite();
        TrafficPattern::Replay { timestamps: vec![0.0, 2.0, 1.0] }.generate(&s, 3, 0);
    }

    #[test]
    fn replay_accepts_duplicate_timestamps_deterministically() {
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![0.0, 0.5, 0.5, 0.5, 1.0] };
        let a = tr.generate(&s, 10, 11);
        let b = tr.generate(&s, 10, 11);
        assert_eq!(a, b);
        // Duplicates survive as simultaneous arrivals, in trace order.
        assert_eq!(a[1].t_s, 0.5);
        assert_eq!(a[2].t_s, 0.5);
        assert_eq!(a[3].t_s, 0.5);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }
}
