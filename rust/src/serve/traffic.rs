//! Arrival-process generators: timestamped request streams over the
//! workload corpus.
//!
//! The paper's replay harness measures closed-world batches; a serving
//! system sees an *arrival process*. Four generators cover the shapes a
//! production trace exhibits: homogeneous Poisson (steady load), a
//! two-state MMPP (bursts), a sinusoidal diurnal ramp, and replay of a
//! recorded timestamp trace. All draw query indices and inter-arrival
//! randomness from an explicit seed, so every serving experiment replays
//! exactly.

use crate::workload::{Dataset, ReplaySuite};
use crate::Rng;

/// The serving class a request belongs to. Classes carry different latency
/// budgets (see [`crate::serve::ClassSlos`]) and different admission
/// priority: the governor can only harvest decode's frequency slack when it
/// knows *which* requests tolerate latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TrafficClass {
    /// Chat-style traffic: tight TTFT/e2e budgets, highest priority.
    Interactive,
    /// Throughput-oriented batch jobs: relaxed budgets, mid priority.
    Batch,
    /// Best-effort offline work: loose budgets, lowest priority (protected
    /// from starvation only by admission aging).
    Background,
}

impl TrafficClass {
    pub const ALL: [TrafficClass; 3] =
        [TrafficClass::Interactive, TrafficClass::Batch, TrafficClass::Background];

    pub fn label(self) -> &'static str {
        match self {
            TrafficClass::Interactive => "interactive",
            TrafficClass::Batch => "batch",
            TrafficClass::Background => "background",
        }
    }

    /// Strict admission priority: higher wins the queue head.
    pub fn priority(self) -> usize {
        match self {
            TrafficClass::Interactive => 2,
            TrafficClass::Batch => 1,
            TrafficClass::Background => 0,
        }
    }

    /// Dense array index, in `ALL` order.
    pub fn slot(self) -> usize {
        self as usize
    }
}

/// One timestamped request: when it arrives and which corpus query it is.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Arrival time on the simulated clock, seconds.
    pub t_s: f64,
    /// Index into the suite's query/feature arrays.
    pub query_idx: usize,
    /// Serving class; single-class generators tag everything Interactive,
    /// which reproduces the pre-class behavior exactly.
    pub class: TrafficClass,
}

impl Arrival {
    /// An Interactive-class arrival — the single-class default.
    pub fn at(t_s: f64, query_idx: usize) -> Arrival {
        Arrival { t_s, query_idx, class: TrafficClass::Interactive }
    }
}

/// Exponential inter-arrival draw at `rate` events/second.
#[inline]
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() / rate
}

/// The supported arrival processes.
#[derive(Debug, Clone)]
pub enum TrafficPattern {
    /// Homogeneous Poisson process at `rps` requests/second.
    Poisson { rps: f64 },
    /// Two-state Markov-modulated Poisson process: calm periods at
    /// `base_rps` alternating with bursts at `burst_rps`; dwell times in
    /// each state are exponential with mean `mean_dwell_s`.
    Bursty { base_rps: f64, burst_rps: f64, mean_dwell_s: f64 },
    /// Sinusoidal diurnal ramp: the instantaneous rate swings between
    /// `min_rps` and `max_rps` with period `period_s` (thinning sampler).
    Diurnal { min_rps: f64, max_rps: f64, period_s: f64 },
    /// Replay a recorded, non-decreasing timestamp trace; cycled with the
    /// trace's span if more arrivals are requested than it holds.
    Replay { timestamps: Vec<f64> },
    /// Superposition of per-class streams (see [`ClassMix`]): each class is
    /// a Poisson process modulated by one *shared* burst envelope —
    /// real bursts are correlated across classes — with heavy-tailed
    /// log-normal output-length targets mapped onto its corpus pool.
    MixedClasses { mix: ClassMix },
}

impl TrafficPattern {
    pub fn label(&self) -> String {
        match self {
            TrafficPattern::Poisson { rps } => format!("poisson@{rps}rps"),
            TrafficPattern::Bursty { base_rps, burst_rps, .. } => {
                format!("bursty[{base_rps}/{burst_rps}rps]")
            }
            TrafficPattern::Diurnal { min_rps, max_rps, .. } => {
                format!("diurnal[{min_rps}-{max_rps}rps]")
            }
            TrafficPattern::Replay { timestamps } => {
                let span = match (timestamps.first(), timestamps.last()) {
                    (Some(a), Some(b)) => b - a,
                    _ => 0.0,
                };
                format!("replay[{} events/{span:.1}s]", timestamps.len())
            }
            TrafficPattern::MixedClasses { mix } => format!(
                "mixed[i{}/b{}/g{}rps]",
                mix.interactive.rps, mix.batch.rps, mix.background.rps
            ),
        }
    }

    /// Generate `n` arrivals drawing query indices uniformly from the whole
    /// suite (mixed-class traffic instead draws per-class corpus pools).
    pub fn generate(&self, suite: &ReplaySuite, n: usize, seed: u64) -> Vec<Arrival> {
        if let TrafficPattern::MixedClasses { mix } = self {
            return mix.generate(suite, n, seed);
        }
        let pool: Vec<usize> = (0..suite.len()).collect();
        self.generate_from(&pool, n, seed)
    }

    /// Generate `n` arrivals drawing query indices uniformly from `pool`
    /// (e.g. only the generation datasets for a decode-heavy scenario).
    /// Single-class generators tag everything [`TrafficClass::Interactive`].
    pub fn generate_from(&self, pool: &[usize], n: usize, seed: u64) -> Vec<Arrival> {
        assert!(
            !matches!(self, TrafficPattern::MixedClasses { .. }),
            "mixed-class traffic draws per-class corpus pools; use generate(suite, ..)"
        );
        assert!(!pool.is_empty(), "traffic needs a non-empty query pool");
        let mut rng = crate::rng(seed);
        let times = self.timestamps(n, &mut rng);
        times
            .into_iter()
            .map(|t_s| Arrival {
                t_s,
                query_idx: pool[rng.gen_range(0, pool.len())],
                class: TrafficClass::Interactive,
            })
            .collect()
    }

    fn timestamps(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            TrafficPattern::Poisson { rps } => {
                assert!(rps > 0.0);
                let mut t = 0.0;
                for _ in 0..n {
                    t += exp_gap(rng, rps);
                    out.push(t);
                }
            }
            TrafficPattern::Bursty { base_rps, burst_rps, mean_dwell_s } => {
                assert!(base_rps > 0.0 && burst_rps > 0.0 && mean_dwell_s > 0.0);
                let mut t = 0.0;
                let mut burst = false;
                let mut state_end = exp_gap(rng, 1.0 / mean_dwell_s);
                while out.len() < n {
                    let rate = if burst { burst_rps } else { base_rps };
                    let gap = exp_gap(rng, rate);
                    if t + gap > state_end {
                        // Memoryless: jump to the state boundary, flip, and
                        // redraw the gap under the new state's rate.
                        t = state_end;
                        burst = !burst;
                        state_end = t + exp_gap(rng, 1.0 / mean_dwell_s);
                        continue;
                    }
                    t += gap;
                    out.push(t);
                }
            }
            TrafficPattern::Diurnal { min_rps, max_rps, period_s } => {
                assert!(min_rps > 0.0 && max_rps >= min_rps && period_s > 0.0);
                // Lewis–Shedler thinning with λ_max as the majorant; the
                // rate trough sits at t = 0 (cold start, like a new region).
                let rate_at = |t: f64| {
                    let phase = std::f64::consts::TAU * t / period_s;
                    min_rps + (max_rps - min_rps) * 0.5 * (1.0 - phase.cos())
                };
                let mut t = 0.0;
                while out.len() < n {
                    t += exp_gap(rng, max_rps);
                    if rng.gen_f64() < rate_at(t) / max_rps {
                        out.push(t);
                    }
                }
            }
            TrafficPattern::Replay { ref timestamps } => {
                assert!(!timestamps.is_empty(), "replay trace is empty");
                assert!(
                    timestamps.iter().all(|t| t.is_finite()),
                    "replay trace timestamps must be finite"
                );
                assert!(
                    timestamps.windows(2).all(|w| w[0] <= w[1]),
                    "replay trace must be non-decreasing"
                );
                // Rebase to t = 0: production traces carry wall-clock
                // offsets, and serving the offset as idle time would
                // swamp every energy comparison.
                let t0 = timestamps[0];
                let last = timestamps.last().unwrap() - t0;
                // Cycle period: trace span plus one mean inter-arrival gap.
                let span = last + last / timestamps.len() as f64;
                for i in 0..n {
                    let cycle = (i / timestamps.len()) as f64;
                    out.push(timestamps[i % timestamps.len()] - t0 + cycle * span);
                }
            }
            // generate_from rejects MixedClasses before reaching here.
            TrafficPattern::MixedClasses { .. } => unreachable!(),
        }
        out
    }
}

/// One class's load knobs in a [`ClassMix`]: its mean request rate and the
/// log-normal parameters of its output-length target. A heavy-tailed
/// `exp(mu + sigma·N(0,1))` token target is drawn per request and mapped to
/// the nearest-output-length query in the class's corpus pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassLoad {
    /// Mean arrival rate, requests/second (0 disables the class).
    pub rps: f64,
    /// Mean of ln(output tokens).
    pub ln_out_mu: f64,
    /// Std-dev of ln(output tokens); larger = heavier tail.
    pub ln_out_sigma: f64,
}

/// The mixed-class synthetic trace generator: three per-class Poisson
/// streams modulated by a *shared* two-state burst envelope (bursts in real
/// traffic are correlated across classes — a product launch lifts chat and
/// batch pipelines together), merged into one time-sorted stream.
///
/// Corpus mix per class: Interactive draws BoolQ + TruthfulQA (short
/// prompts, quick answers), Batch draws HellaSwag + NarrativeQA, and
/// Background draws NarrativeQA only (long-form generation).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassMix {
    pub interactive: ClassLoad,
    pub batch: ClassLoad,
    pub background: ClassLoad,
    /// Rate multiplier all classes share while the envelope is bursting.
    pub burst_mult: f64,
    /// Mean dwell time in each envelope state, seconds.
    pub mean_dwell_s: f64,
}

impl Default for ClassMix {
    /// An interactive-minority mix: most of the token volume is
    /// latency-tolerant, which is exactly the regime where class-aware
    /// governance pays (the paper's decode slack is harvestable).
    fn default() -> ClassMix {
        ClassMix {
            interactive: ClassLoad { rps: 2.0, ln_out_mu: 3.2, ln_out_sigma: 0.7 },
            batch: ClassLoad { rps: 1.5, ln_out_mu: 4.4, ln_out_sigma: 0.5 },
            background: ClassLoad { rps: 1.0, ln_out_mu: 4.6, ln_out_sigma: 0.4 },
            burst_mult: 4.0,
            mean_dwell_s: 15.0,
        }
    }
}

impl ClassMix {
    pub fn load(&self, c: TrafficClass) -> ClassLoad {
        match c {
            TrafficClass::Interactive => self.interactive,
            TrafficClass::Batch => self.batch,
            TrafficClass::Background => self.background,
        }
    }

    /// A class's corpus pool over `suite`; falls back to the whole suite if
    /// the preferred datasets are absent (degenerate test suites).
    pub fn class_pool(suite: &ReplaySuite, c: TrafficClass) -> Vec<usize> {
        let datasets: &[Dataset] = match c {
            TrafficClass::Interactive => &[Dataset::BoolQ, Dataset::TruthfulQa],
            TrafficClass::Batch => &[Dataset::HellaSwag, Dataset::NarrativeQa],
            TrafficClass::Background => &[Dataset::NarrativeQa],
        };
        let pool: Vec<usize> = (0..suite.len())
            .filter(|&i| datasets.contains(&suite.queries[i].dataset))
            .collect();
        if pool.is_empty() {
            (0..suite.len()).collect()
        } else {
            pool
        }
    }

    /// Generate `n` arrivals: per-class counts proportional to rate shares,
    /// each class thinned against the shared burst envelope, merged sorted
    /// by arrival time. Fully deterministic in `seed`.
    pub fn generate(&self, suite: &ReplaySuite, n: usize, seed: u64) -> Vec<Arrival> {
        assert!(!suite.is_empty(), "traffic needs a non-empty suite");
        assert!(self.burst_mult >= 1.0, "burst_mult must be >= 1");
        assert!(self.mean_dwell_s > 0.0, "mean_dwell_s must be > 0");
        let total_rps: f64 = TrafficClass::ALL.iter().map(|&c| self.load(c).rps).sum();
        assert!(total_rps > 0.0, "mixed-class traffic needs a positive total rate");

        // Per-class request counts: floors of the rate shares, remainder
        // dealt in class order so the counts always sum to n.
        let mut counts = [0usize; 3];
        for (i, &c) in TrafficClass::ALL.iter().enumerate() {
            counts[i] = (n as f64 * self.load(c).rps / total_rps) as usize;
        }
        let mut short = n - counts.iter().sum::<usize>();
        for slot in counts.iter_mut() {
            if short == 0 {
                break;
            }
            *slot += 1;
            short -= 1;
        }

        // The shared envelope draws from its own stream so every class sees
        // the same burst boundaries regardless of per-class counts.
        let mut envelope = BurstEnvelope::new(seed ^ 0xB157_ECE1, self.mean_dwell_s);
        let mut merged: Vec<Arrival> = Vec::with_capacity(n);
        for (i, &class) in TrafficClass::ALL.iter().enumerate() {
            let load = self.load(class);
            if counts[i] == 0 || load.rps <= 0.0 {
                continue;
            }
            assert!(load.ln_out_sigma >= 0.0, "ln_out_sigma must be >= 0");
            let pool = Self::class_pool(suite, class);
            // Independent per-class stream: one class's count never
            // perturbs another class's draws.
            let mut rng = crate::rng(seed.wrapping_add((i as u64 + 1) * 0x9E37_79B9));
            let lam_max = load.rps * self.burst_mult;
            let mut t = 0.0;
            for _ in 0..counts[i] {
                // Lewis–Shedler thinning against the shared envelope.
                loop {
                    t += exp_gap(&mut rng, lam_max);
                    let mult = if envelope.is_burst(t) { self.burst_mult } else { 1.0 };
                    if rng.gen_f64() < mult / self.burst_mult {
                        break;
                    }
                }
                let target = (load.ln_out_mu + load.ln_out_sigma * rng.normal()).exp();
                let query_idx = pool
                    .iter()
                    .copied()
                    .min_by(|&a, &b| {
                        let da = (suite.queries[a].output_tokens as f64 - target).abs();
                        let db = (suite.queries[b].output_tokens as f64 - target).abs();
                        da.total_cmp(&db).then(a.cmp(&b))
                    })
                    .unwrap();
                merged.push(Arrival { t_s: t, query_idx, class });
            }
        }
        // Stable sort on time alone: per-class streams are already sorted
        // and deterministic, so ties (if any) resolve in class order.
        merged.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
        merged
    }
}

/// The two-state burst envelope shared by every class in a [`ClassMix`]:
/// calm/burst segments with exponential dwell, extended lazily from a
/// dedicated RNG stream so segment boundaries depend only on the seed.
struct BurstEnvelope {
    rng: Rng,
    mean_dwell_s: f64,
    /// End time of each segment; segment `i` bursts iff `i` is odd.
    ends: Vec<f64>,
}

impl BurstEnvelope {
    fn new(seed: u64, mean_dwell_s: f64) -> BurstEnvelope {
        BurstEnvelope { rng: crate::rng(seed), mean_dwell_s, ends: Vec::new() }
    }

    fn is_burst(&mut self, t: f64) -> bool {
        while self.ends.last().copied().unwrap_or(0.0) <= t {
            let start = self.ends.last().copied().unwrap_or(0.0);
            self.ends.push(start + exp_gap(&mut self.rng, 1.0 / self.mean_dwell_s));
        }
        let seg = self.ends.partition_point(|&end| end <= t);
        seg % 2 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dataset;

    fn suite() -> ReplaySuite {
        ReplaySuite::quick(3, 10)
    }

    #[test]
    fn arrivals_are_deterministic_sorted_and_in_pool() {
        let s = suite();
        let pool = s.dataset_indices(Dataset::NarrativeQa);
        for pattern in [
            TrafficPattern::Poisson { rps: 5.0 },
            TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 20.0, mean_dwell_s: 1.0 },
            TrafficPattern::Diurnal { min_rps: 1.0, max_rps: 10.0, period_s: 10.0 },
            TrafficPattern::Replay { timestamps: vec![0.0, 0.5, 0.6, 2.0] },
        ] {
            let a = pattern.generate_from(&pool, 200, 9);
            let b = pattern.generate_from(&pool, 200, 9);
            assert_eq!(a, b, "{}", pattern.label());
            assert_eq!(a.len(), 200);
            assert!(
                a.windows(2).all(|w| w[0].t_s <= w[1].t_s),
                "{} not sorted",
                pattern.label()
            );
            assert!(a.iter().all(|x| pool.contains(&x.query_idx)));
            assert!(a[0].t_s >= 0.0);
        }
    }

    #[test]
    fn poisson_hits_the_requested_rate() {
        let s = suite();
        let a = TrafficPattern::Poisson { rps: 8.0 }.generate(&s, 4000, 1);
        let rate = a.len() as f64 / a.last().unwrap().t_s;
        assert!((rate - 8.0).abs() / 8.0 < 0.1, "rate {rate:.2}");
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Coefficient of variation of inter-arrival gaps: 1 for Poisson,
        // substantially above 1 for an MMPP with well-separated rates.
        let s = suite();
        let cv = |arr: &[Arrival]| {
            let gaps: Vec<f64> = arr.windows(2).map(|w| w[1].t_s - w[0].t_s).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m).powi(2)).sum::<f64>() / gaps.len() as f64;
            v.sqrt() / m
        };
        let pois = TrafficPattern::Poisson { rps: 5.0 }.generate(&s, 3000, 2);
        let burst = TrafficPattern::Bursty { base_rps: 1.0, burst_rps: 25.0, mean_dwell_s: 2.0 }
            .generate(&s, 3000, 2);
        assert!(cv(&burst) > cv(&pois) * 1.3, "cv {} vs {}", cv(&burst), cv(&pois));
    }

    #[test]
    fn diurnal_peaks_midperiod() {
        let s = suite();
        let period = 20.0;
        let a = TrafficPattern::Diurnal { min_rps: 0.5, max_rps: 10.0, period_s: period }
            .generate(&s, 2000, 4);
        // Arrivals in the peak half of each cycle (quarter..three-quarter)
        // must dominate the trough half.
        let (mut peak, mut trough) = (0usize, 0usize);
        for x in &a {
            let phase = (x.t_s / period).fract();
            if (0.25..0.75).contains(&phase) {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(peak > 2 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn replay_cycles_beyond_the_trace() {
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![0.1, 0.4, 1.0] };
        let a = tr.generate(&s, 7, 5);
        assert_eq!(a.len(), 7);
        // First cycle reproduces the trace rebased to t = 0.
        assert!((a[0].t_s - 0.0).abs() < 1e-12);
        assert!((a[1].t_s - 0.3).abs() < 1e-12);
        assert!((a[2].t_s - 0.9).abs() < 1e-12);
        // Later cycles are offset copies, still sorted.
        assert!(a[3].t_s > a[2].t_s);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn replay_rebases_wall_clock_offsets() {
        // A production trace with an un-rebased clock must not inject the
        // offset as leading idle time.
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![3600.0, 3600.5, 3601.0] };
        let a = tr.generate(&s, 6, 5);
        assert!((a[0].t_s - 0.0).abs() < 1e-12);
        assert!((a[2].t_s - 1.0).abs() < 1e-12);
        // Cycle period = span (1.0) + mean gap (1/3): no huge dead gaps.
        assert!((a[3].t_s - (1.0 + 1.0 / 3.0)).abs() < 1e-9);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    #[should_panic(expected = "non-empty query pool")]
    fn empty_pool_panics() {
        TrafficPattern::Poisson { rps: 1.0 }.generate_from(&[], 5, 0);
    }

    // The replay-trace contract, pinned: an out-of-order trace is
    // *rejected* (loudly, at generation time — not silently sorted, which
    // would hide a corrupted production trace), while duplicate
    // timestamps are legal (real traces batch arrivals on coarse clocks)
    // and replay deterministically in trace order.

    #[test]
    #[should_panic(expected = "replay trace must be non-decreasing")]
    fn replay_rejects_unsorted_traces() {
        let s = suite();
        TrafficPattern::Replay { timestamps: vec![0.0, 2.0, 1.0] }.generate(&s, 3, 0);
    }

    #[test]
    #[should_panic(expected = "timestamps must be finite")]
    fn replay_rejects_non_finite_timestamps() {
        let s = suite();
        TrafficPattern::Replay { timestamps: vec![0.0, f64::NAN, 2.0] }.generate(&s, 3, 0);
    }

    #[test]
    #[should_panic(expected = "timestamps must be finite")]
    fn replay_rejects_infinite_timestamps() {
        let s = suite();
        TrafficPattern::Replay { timestamps: vec![0.0, 1.0, f64::INFINITY] }.generate(&s, 3, 0);
    }

    #[test]
    fn replay_label_carries_the_trace_span() {
        let tr = TrafficPattern::Replay { timestamps: vec![10.0, 11.0, 12.5] };
        assert_eq!(tr.label(), "replay[3 events/2.5s]");
    }

    #[test]
    fn single_class_generators_tag_interactive() {
        let s = suite();
        let a = TrafficPattern::Poisson { rps: 5.0 }.generate(&s, 50, 7);
        assert!(a.iter().all(|x| x.class == TrafficClass::Interactive));
        assert_eq!(Arrival::at(1.5, 3), Arrival {
            t_s: 1.5,
            query_idx: 3,
            class: TrafficClass::Interactive
        });
    }

    #[test]
    fn class_priorities_are_strict() {
        assert!(TrafficClass::Interactive.priority() > TrafficClass::Batch.priority());
        assert!(TrafficClass::Batch.priority() > TrafficClass::Background.priority());
        assert_eq!(TrafficClass::ALL.len(), 3);
        assert_eq!(TrafficClass::Background.label(), "background");
    }

    #[test]
    fn mixed_classes_merge_sorted_and_deterministic() {
        let s = suite();
        let tr = TrafficPattern::MixedClasses { mix: ClassMix::default() };
        let a = tr.generate(&s, 120, 13);
        let b = tr.generate(&s, 120, 13);
        assert_eq!(a, b);
        assert_eq!(a.len(), 120);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s), "not sorted");
        assert!(a.iter().all(|x| x.t_s.is_finite() && x.t_s >= 0.0));
        for c in TrafficClass::ALL {
            assert!(a.iter().any(|x| x.class == c), "no {} arrivals", c.label());
        }
    }

    #[test]
    fn mixed_classes_respect_corpus_pools_and_rate_shares() {
        let s = suite();
        let mix = ClassMix::default();
        let a = mix.generate(&s, 200, 21);
        for x in &a {
            let pool = ClassMix::class_pool(&s, x.class);
            assert!(pool.contains(&x.query_idx), "{} outside pool", x.class.label());
        }
        // Rate shares 2.0/1.5/1.0 over n=200: floors 88/66/44 sum to 198,
        // the 2-request remainder is dealt in class order.
        let count = |c| a.iter().filter(|x| x.class == c).count();
        assert_eq!(count(TrafficClass::Interactive), 89);
        assert_eq!(count(TrafficClass::Batch), 67);
        assert_eq!(count(TrafficClass::Background), 44);
        // Background never draws classification queries.
        assert!(a
            .iter()
            .filter(|x| x.class == TrafficClass::Background)
            .all(|x| s.queries[x.query_idx].output_tokens > 0));
    }

    #[test]
    fn mixed_classes_output_lengths_track_the_lognormal_knobs() {
        let s = ReplaySuite::quick(5, 40);
        // Interactive aims short, background aims long: the realized mean
        // output budgets must be ordered accordingly.
        let a = ClassMix::default().generate(&s, 300, 3);
        let mean_out = |c: TrafficClass| {
            let xs: Vec<f64> = a
                .iter()
                .filter(|x| x.class == c)
                .map(|x| s.queries[x.query_idx].output_tokens as f64)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            mean_out(TrafficClass::Interactive) < mean_out(TrafficClass::Background),
            "interactive {} vs background {}",
            mean_out(TrafficClass::Interactive),
            mean_out(TrafficClass::Background)
        );
    }

    #[test]
    fn mixed_classes_bursts_are_correlated_across_classes() {
        // The envelope is shared, so when one class bursts they all do:
        // per-window arrival counts of any two classes must be positively
        // correlated (independent streams would sit near zero).
        let s = suite();
        let mix = ClassMix { burst_mult: 10.0, mean_dwell_s: 5.0, ..ClassMix::default() };
        let a = mix.generate(&s, 2000, 17);
        let horizon = a.last().unwrap().t_s;
        let window = 2.0;
        let bins = (horizon / window) as usize + 1;
        let counts = |c: TrafficClass| {
            let mut v = vec![0.0f64; bins];
            for x in a.iter().filter(|x| x.class == c) {
                v[(x.t_s / window) as usize] += 1.0;
            }
            v
        };
        let pearson = |xs: &[f64], ys: &[f64]| {
            let n = xs.len() as f64;
            let (mx, my) =
                (xs.iter().sum::<f64>() / n, ys.iter().sum::<f64>() / n);
            let cov: f64 =
                xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / n;
            let (vx, vy) = (
                xs.iter().map(|x| (x - mx).powi(2)).sum::<f64>() / n,
                ys.iter().map(|y| (y - my).powi(2)).sum::<f64>() / n,
            );
            cov / (vx.sqrt() * vy.sqrt())
        };
        let (i, b, g) = (
            counts(TrafficClass::Interactive),
            counts(TrafficClass::Batch),
            counts(TrafficClass::Background),
        );
        assert!(pearson(&i, &b) > 0.2, "interactive/batch corr {}", pearson(&i, &b));
        assert!(pearson(&i, &g) > 0.2, "interactive/background corr {}", pearson(&i, &g));
    }

    #[test]
    #[should_panic(expected = "per-class corpus pools")]
    fn mixed_classes_reject_generate_from() {
        TrafficPattern::MixedClasses { mix: ClassMix::default() }.generate_from(&[0, 1], 5, 0);
    }

    #[test]
    fn replay_accepts_duplicate_timestamps_deterministically() {
        let s = suite();
        let tr = TrafficPattern::Replay { timestamps: vec![0.0, 0.5, 0.5, 0.5, 1.0] };
        let a = tr.generate(&s, 10, 11);
        let b = tr.generate(&s, 10, 11);
        assert_eq!(a, b);
        // Duplicates survive as simultaneous arrivals, in trace order.
        assert_eq!(a[1].t_s, 0.5);
        assert_eq!(a[2].t_s, 0.5);
        assert_eq!(a[3].t_s, 0.5);
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }
}
