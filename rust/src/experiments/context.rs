//! Shared experiment state: the replay suite, quality matrix, and a
//! memoised DVFS sweep store so tables/figures that read the same cells
//! (XI, XII, XIII, F3, F4, F5) measure once.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::Result;

use crate::config::model::{model_for_tier, ModelTier};
use crate::config::{ExperimentConfig, FreqMHz, GpuSpec};
use crate::coordinator::DvfsPolicy;
use crate::engine::{ReplayEngine, ReplayMetrics};
use crate::quality::{QualityMatrix, QualityModel};
use crate::workload::{Dataset, ReplaySuite};

/// Key of one sweep cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CellKey {
    pub tier: ModelTier,
    pub batch: usize,
    pub freq: FreqMHz,
    /// None = full suite (all datasets pooled, as Table XI's rows).
    pub dataset: Option<Dataset>,
}

/// Shared, lazily-populated experiment context.
pub struct Context {
    pub cfg: ExperimentConfig,
    pub gpu: GpuSpec,
    pub suite: ReplaySuite,
    pub quality_model: QualityModel,
    pub quality: QualityMatrix,
    cells: RefCell<HashMap<CellKey, ReplayMetrics>>,
}

impl Context {
    /// Build with the paper's full scale (3,817 queries).
    pub fn paper(seed: u64) -> Self {
        Self::with_suite(ExperimentConfig::default(), ReplaySuite::paper_scale(seed))
    }

    /// Reduced-scale context for tests/benches; same pipeline.
    pub fn quick(seed: u64, queries_per_dataset: usize) -> Self {
        let mut cfg = ExperimentConfig::quick();
        cfg.queries_per_dataset = queries_per_dataset;
        Self::with_suite(cfg, ReplaySuite::quick(seed, queries_per_dataset))
    }

    fn with_suite(cfg: ExperimentConfig, suite: ReplaySuite) -> Self {
        let qm = QualityModel::new();
        let quality = QualityMatrix::build(&suite, &qm);
        Context {
            cfg,
            gpu: GpuSpec::rtx_pro_6000(),
            suite,
            quality_model: qm,
            quality,
            cells: RefCell::new(HashMap::new()),
        }
    }

    /// Measure (or recall) one sweep cell.
    pub fn cell(&self, key: CellKey) -> Result<ReplayMetrics> {
        if let Some(m) = self.cells.borrow().get(&key) {
            return Ok(m.clone());
        }
        let engine = ReplayEngine::new(self.gpu.clone(), model_for_tier(key.tier));
        let idx: Vec<usize> = match key.dataset {
            Some(d) => self.suite.dataset_indices(d),
            None => (0..self.suite.len()).collect(),
        };
        let m = engine.run(&self.suite, &idx, key.batch, &DvfsPolicy::Static(key.freq))?;
        self.cells.borrow_mut().insert(key, m.clone());
        Ok(m)
    }

    /// Baseline frequency (2842 MHz) cell.
    pub fn baseline_cell(&self, tier: ModelTier, batch: usize, dataset: Option<Dataset>) -> Result<ReplayMetrics> {
        self.cell(CellKey { tier, batch, freq: self.gpu.f_max_mhz, dataset })
    }

    /// Phase-aware run (not memoised — used by the case study only).
    pub fn phase_aware(&self, tier: ModelTier, batch: usize) -> Result<ReplayMetrics> {
        let engine = ReplayEngine::new(self.gpu.clone(), model_for_tier(tier));
        let idx: Vec<usize> = (0..self.suite.len()).collect();
        engine.run(
            &self.suite,
            &idx,
            batch,
            &DvfsPolicy::paper_phase_aware(&self.gpu),
        )
    }

    pub fn cached_cells(&self) -> usize {
        self.cells.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_are_memoised() {
        let ctx = Context::quick(3, 6);
        let k = CellKey { tier: ModelTier::B1, batch: 1, freq: 2842, dataset: Some(Dataset::TruthfulQa) };
        let a = ctx.cell(k).unwrap();
        assert_eq!(ctx.cached_cells(), 1);
        let b = ctx.cell(k).unwrap();
        assert_eq!(ctx.cached_cells(), 1);
        assert_eq!(a.energy_j, b.energy_j);
    }

    #[test]
    fn full_suite_cell_pools_datasets() {
        let ctx = Context::quick(4, 5);
        let m = ctx.baseline_cell(ModelTier::B1, 1, None).unwrap();
        assert_eq!(m.queries, ctx.suite.len());
    }
}
