//! Tables VII–X and XV: quality by scale, feature–quality correlations,
//! scaling patterns, rule validation, and the routing strategy map.

use anyhow::Result;

use crate::config::ModelTier;
use crate::coordinator::router::Router;
use crate::quality::labels::pattern_shares;
use crate::quality::{classify_patterns, ScalingPattern};
use crate::stats::pearson;
use crate::workload::Dataset;

use super::context::Context;
use super::report::{f3, pct0, r2, Report};

/// Table VII: quality scores by model and dataset.
pub fn table7(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-07",
        "Quality scores by model and dataset (accuracy / ROUGE-L)",
        &["Dataset", "1B", "3B", "8B", "14B", "32B", "Avg"],
    );
    let mut model_avgs = vec![0.0; 5];
    for d in [
        Dataset::BoolQ,
        Dataset::HellaSwag,
        Dataset::TruthfulQa,
        Dataset::NarrativeQa,
    ] {
        let idx = ctx.suite.dataset_indices(d);
        let mut cells = vec![d.label().to_string()];
        let mut sum = 0.0;
        for t in ModelTier::ALL {
            let m = ctx.quality.mean_raw_over(t, &idx);
            model_avgs[t.index()] += m / 4.0;
            sum += m;
            cells.push(f3(m));
        }
        cells.push(f3(sum / 5.0));
        r.row(cells);
    }
    let mut avg_row = vec!["Model Avg".to_string()];
    for a in &model_avgs {
        avg_row.push(f3(*a));
    }
    avg_row.push(f3(model_avgs.iter().sum::<f64>() / 5.0));
    r.row(avg_row);
    r.note("paper model avgs: 0.423 / 0.514 / 0.559 / 0.583 / 0.596");
    Ok(r)
}

/// Table VIII: feature–quality correlations by model size.
pub fn table8(ctx: &Context) -> Result<Report> {
    let n = ctx.suite.len();
    let mut r = Report::new(
        "table-08",
        "Feature-quality correlations by model size",
        &["Feature", "1B", "3B", "8B", "14B", "32B"],
    );
    let feats: [(&str, Box<dyn Fn(usize) -> f64>); 3] = [
        ("Entity Density", Box::new(|i| ctx.suite.features[i].entity_density)),
        ("Causal Question", Box::new(|i| ctx.suite.features[i].causal_question)),
        ("Token Entropy", Box::new(|i| ctx.suite.features[i].token_entropy)),
    ];
    for (name, f) in feats {
        let xs: Vec<f64> = (0..n).map(|i| f(i)).collect();
        let mut cells = vec![name.to_string()];
        for t in ModelTier::ALL {
            // Correlate with dataset-normalized quality, pooled (paper).
            let q: Vec<f64> = (0..n).map(|i| ctx.quality.norm[t.index()][i]).collect();
            cells.push(r2(pearson(&xs, &q)));
        }
        r.row(cells);
    }
    r.note("paper: entity -0.20..-0.32 (negative, strengthening); causal negative; entropy positive, growing with size");
    Ok(r)
}

/// Table IX: query scaling patterns across model sizes.
pub fn table9(ctx: &Context) -> Result<Report> {
    let patterns = classify_patterns(&ctx.quality);
    let shares = pattern_shares(&patterns);
    let paper = [44.5, 15.5, 32.6, 7.4];
    let mut r = Report::new(
        "table-09",
        "Query scaling patterns across model sizes",
        &["Pattern", "%", "Paper %", "Mean entity", "Mean causal", "Mean entropy"],
    );
    for (k, p) in ScalingPattern::ALL.iter().enumerate() {
        let idx: Vec<usize> = (0..ctx.suite.len())
            .filter(|&i| patterns[i] == *p)
            .collect();
        let mean = |f: &dyn Fn(usize) -> f64| {
            if idx.is_empty() {
                f64::NAN
            } else {
                idx.iter().map(|&i| f(i)).sum::<f64>() / idx.len() as f64
            }
        };
        r.row(vec![
            p.label().to_string(),
            pct0(shares[k] * 100.0),
            pct0(paper[k]),
            f3(mean(&|i| ctx.suite.features[i].entity_density)),
            f3(mean(&|i| ctx.suite.features[i].causal_question)),
            f3(mean(&|i| ctx.suite.features[i].token_entropy)),
        ]);
    }
    r.note("paper profiles: AlwaysEasy entity 0.17, AlwaysHard entity 0.27");
    Ok(r)
}

/// Table X: rule-based classification validation (easy/hard quality gap).
pub fn table10(ctx: &Context) -> Result<Report> {
    let easy_idx: Vec<usize> = (0..ctx.suite.len())
        .filter(|&i| Router::is_easy_rule(&ctx.suite.features[i]))
        .collect();
    let hard_idx: Vec<usize> = (0..ctx.suite.len())
        .filter(|&i| !Router::is_easy_rule(&ctx.suite.features[i]))
        .collect();
    let mut r = Report::new(
        "table-10",
        "Classification validation: quality by difficulty category",
        &["Model", "Easy", "Hard", "Gap", "Valid?"],
    );
    let mut gaps = Vec::new();
    for t in ModelTier::ALL {
        // Validation uses dataset-normalized quality (comparable scales).
        let m = |idx: &[usize]| {
            idx.iter()
                .map(|&i| ctx.quality.norm[t.index()][i])
                .sum::<f64>()
                / idx.len().max(1) as f64
        };
        let e = m(&easy_idx);
        let h = m(&hard_idx);
        gaps.push(e - h);
        r.row(vec![
            format!("tier-{}", t.label()),
            f3(e),
            f3(h),
            format!("{:+.3}", e - h),
            if e > h { "yes" } else { "NO" }.to_string(),
        ]);
    }
    let avg: f64 = gaps.iter().sum::<f64>() / gaps.len() as f64;
    r.row(vec![
        "Average".to_string(),
        String::new(),
        String::new(),
        format!("{avg:+.3}"),
        if gaps.iter().all(|g| *g > 0.0) { "yes" } else { "NO" }.to_string(),
    ]);
    r.note(format!(
        "rule split: {} easy / {} hard (paper: 50.8%/49.2%); paper avg gap +0.256",
        easy_idx.len(),
        hard_idx.len()
    ));
    Ok(r)
}

/// Table XV: routing strategy based on scaling patterns.
pub fn table15(ctx: &Context) -> Result<Report> {
    let patterns = classify_patterns(&ctx.quality);
    let shares = pattern_shares(&patterns);
    let strategy = [
        (ScalingPattern::AlwaysEasy, "1-3B", "Similar quality across sizes"),
        (ScalingPattern::ScalingHelps, "8B+", "Quality improves with scale"),
        (ScalingPattern::AlwaysHard, "1-3B", "Limited benefit from scaling"),
        (ScalingPattern::Inconsistent, "8B", "Architecture-dependent"),
    ];
    let mut r = Report::new(
        "table-15",
        "Routing strategy based on scaling patterns",
        &["Pattern", "%", "Model", "Rationale"],
    );
    for (p, model, why) in strategy {
        let k = ScalingPattern::ALL.iter().position(|x| *x == p).unwrap();
        r.row(vec![
            p.label().to_string(),
            pct0(shares[k] * 100.0),
            model.to_string(),
            why.to_string(),
        ]);
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(103, 200)
    }

    #[test]
    fn table7_model_scaling_is_monotoneish() {
        let c = ctx();
        let r = table7(&c).unwrap();
        let avg_row = r.rows.last().unwrap();
        let avgs: Vec<f64> = avg_row[1..6].iter().map(|s| s.parse().unwrap()).collect();
        // Model averages grow with scale (paper: 0.423 → 0.596).
        assert!(avgs[4] > avgs[0] + 0.10, "{avgs:?}");
        assert!((avgs[0] - 0.423).abs() < 0.07, "{avgs:?}");
        assert!((avgs[4] - 0.596).abs() < 0.07, "{avgs:?}");
    }

    #[test]
    fn table8_entity_negative_all_sizes() {
        let c = ctx();
        let r = table8(&c).unwrap();
        let entity: Vec<f64> = r.rows[0][1..].iter().map(|s| s.parse().unwrap()).collect();
        for (i, e) in entity.iter().enumerate() {
            assert!((-0.55..=-0.08).contains(e), "entity corr tier {i}: {e}");
        }
        let causal: Vec<f64> = r.rows[1][1..].iter().map(|s| s.parse().unwrap()).collect();
        assert!(causal.iter().all(|c| *c < 0.0), "{causal:?}");
    }

    #[test]
    fn table10_every_tier_validates() {
        let c = ctx();
        let r = table10(&c).unwrap();
        for row in &r.rows {
            assert_eq!(row[4], "yes", "row {row:?}");
        }
    }

    #[test]
    fn table15_shares_sum_to_one() {
        let c = ctx();
        let r = table15(&c).unwrap();
        let total: f64 = r
            .rows
            .iter()
            .map(|row| row[1].trim_end_matches('%').parse::<f64>().unwrap())
            .sum();
        assert!((total - 100.0).abs() < 0.5, "{total}");
    }
}
