//! Experiment output: paper-format ASCII tables plus CSV export.

use std::fmt::Write as _;
use std::path::Path;

/// One regenerated table/figure.
#[derive(Debug, Clone)]
pub struct Report {
    /// Experiment id, e.g. "table-11" or "fig-4".
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (paper-vs-measured commentary, bands).
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: Vec<S>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch in {}", self.id);
        self.rows.push(cells.into_iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    /// Render as a fixed-width ASCII table.
    pub fn ascii(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} — {} ==", self.id.to_uppercase(), self.title);
        let mut header = String::new();
        for (w, c) in widths.iter().zip(&self.columns) {
            let _ = write!(header, "| {:<w$} ", c, w = w);
        }
        let _ = writeln!(out, "{header}|");
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let mut line = String::new();
            for (w, cell) in widths.iter().zip(row) {
                let _ = write!(line, "| {:<w$} ", cell, w = w);
            }
            let _ = writeln!(out, "{line}|");
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    /// CSV form (quoting cells containing separators).
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.columns.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV to `<dir>/<id>.csv`.
    pub fn write_csv(&self, dir: impl AsRef<Path>) -> std::io::Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }
}

/// Format helpers used across experiments.
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x)
}

pub fn pct0(x: f64) -> String {
    format!("{:.1}%", x)
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn r2(x: f64) -> String {
    format!("{x:+.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_and_csv_render() {
        let mut r = Report::new("table-0", "demo", &["a", "b"]);
        r.row(vec!["x", "1"]);
        r.row(vec!["long cell", "2,3"]);
        r.note("a note");
        let a = r.ascii();
        assert!(a.contains("TABLE-0"));
        assert!(a.contains("long cell"));
        assert!(a.contains("note: a note"));
        let c = r.csv();
        assert!(c.starts_with("a,b\n"));
        assert!(c.contains("\"2,3\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Report::new("t", "t", &["a", "b"]).row(vec!["only one"]);
    }

    #[test]
    fn csv_written_to_disk() {
        let mut r = Report::new("table-test-io", "demo", &["x"]);
        r.row(vec!["1"]);
        let dir = std::env::temp_dir().join("ewatt-report-test");
        let p = r.write_csv(&dir).unwrap();
        assert!(p.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
