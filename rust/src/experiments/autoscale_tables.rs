//! Elastic fleet comparison: static peak provisioning vs autoscaling vs
//! autoscaling under replica failures, on diurnal traffic.
//!
//! The paper's Section VII upper bound fixes the fleet size; its diurnal/
//! bursty traffic discussion implies the opposite regime dominates real
//! bills — replicas idling off-peak burn idle power that per-token policy
//! cannot touch. This experiment runs the tradeoff end-to-end: a sinusoidal
//! diurnal arrival stream hits (a) a peak-provisioned static fleet, (b) the
//! same fleet under the reactive autoscaler (cold-start energy + warm-up
//! delay charged on every scale-up), and (c) the autoscaled fleet with a
//! seeded MTBF/MTTR crash/recovery process injected. Per-request energy
//! comes from the attribution ledger (cold starts amortized like idle), so
//! J/req reflects the full provisioning bill. Deterministic in
//! [`AUTOSCALE_SEED`].

use anyhow::Result;

use crate::config::ModelTier;
use crate::coordinator::DvfsPolicy;
use crate::fleet::{
    FailureConfig, FleetConfig, FleetOutcome, FleetSim, LeastLoaded, ReactiveConfig, ReplicaSpec,
    ReplicaState,
};
use crate::serve::TrafficPattern;

use super::context::Context;
use super::report::{pct0, Report};

/// Master seed for the diurnal arrival stream and the failure process.
pub const AUTOSCALE_SEED: u64 = 0xE1A57;

/// Requests simulated per deployment (spans ≈ two diurnal periods).
const REQUESTS: usize = 900;

/// Peak-provisioned replica count (the static baseline's fleet size and
/// the autoscaler's ceiling).
const N_PEAK: usize = 4;

/// Model tier every replica serves.
const TIER: ModelTier = ModelTier::B8;

/// The diurnal arrival process: deep troughs (where a static fleet idles)
/// and peaks sized to need most of the provisioned replicas.
pub fn diurnal() -> TrafficPattern {
    TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 8.0, period_s: 120.0 }
}

/// The reactive scaler tuning used across the elastic comparisons.
pub fn reactive() -> ReactiveConfig {
    ReactiveConfig { min_live: 1, max_live: N_PEAK, ..ReactiveConfig::default() }
}

/// The injected failure process (MTBF/MTTR per replica, seconds).
pub fn failures() -> FailureConfig {
    FailureConfig { mtbf_s: 60.0, mttr_s: 20.0, seed: AUTOSCALE_SEED ^ 0xFA11 }
}

/// The compared deployments: (name, fleet config). All share one model
/// tier, the governed DVFS band, and least-loaded routing, so the deltas
/// isolate the lifecycle policy.
pub fn deployments(ctx: &Context) -> Vec<(String, FleetConfig)> {
    let gov = DvfsPolicy::governed(&ctx.gpu);
    let live = ReplicaSpec::tiered(TIER, gov);
    let cold = ReplicaSpec { state: ReplicaState::Cold, ..live.clone() };
    let static_peak = FleetConfig::builder()
        .replicas(N_PEAK, live.clone())
        .build()
        .expect("static deployment config is valid");
    let elastic = || {
        FleetConfig::builder()
            .replica(live.clone())
            .replicas(N_PEAK - 1, cold.clone())
            .reactive(reactive())
    };
    let autoscaled = elastic().build().expect("autoscaled deployment config is valid");
    let autoscaled_failures = elastic()
        .failures(failures())
        .build()
        .expect("failure deployment config is valid");
    vec![
        (format!("static-{N_PEAK}"), static_peak),
        ("autoscaled".into(), autoscaled),
        ("autoscaled+failures".into(), autoscaled_failures),
    ]
}

/// Run one deployment on the shared diurnal stream.
pub fn run_deployment(ctx: &Context, cfg: FleetConfig) -> Result<FleetOutcome> {
    let arrivals = diurnal().generate(&ctx.suite, REQUESTS, AUTOSCALE_SEED);
    FleetSim::new(ctx.gpu.clone(), cfg).run(&ctx.suite, &arrivals, &mut LeastLoaded)
}

/// The comparison table: full-bill joules/request, tail latency, SLO
/// attainment, and lifecycle counters per deployment.
pub fn autoscale_table(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "autoscale",
        "Elastic fleet: static peak provisioning vs autoscaling vs failures",
        &[
            "Deployment", "Served", "Total (J)", "Idle (J)", "Cold (J)", "J/req",
            "vs static", "E2E p99 (s)", "SLO attain", "Up/Down", "Fail/Req", "Mean live",
        ],
    );
    let mut base_jreq = None;
    for (di, (name, cfg)) in deployments(ctx).into_iter().enumerate() {
        let o = run_deployment(ctx, cfg)?;
        // Guard the degenerate case explicitly: a zero-served cell would
        // render every attributed per-request column NaN.
        anyhow::ensure!(
            o.served == REQUESTS,
            "{name}: served {}/{REQUESTS} requests",
            o.served
        );
        let jreq = o.attributed_joules_per_request();
        let base = *base_jreq.get_or_insert(jreq);
        r.row(vec![
            name,
            o.served.to_string(),
            format!("{:.0}", o.total_j()),
            format!("{:.0}", o.idle_j),
            format!("{:.0}", o.coldstart_j),
            format!("{jreq:.1}"),
            if di == 0 { "-".to_string() } else { pct0(100.0 * (1.0 - jreq / base)) },
            format!("{:.2}", o.slo.e2e_p99()),
            pct0(100.0 * o.slo.attainment()),
            format!("{}/{}", o.lifecycle.scale_ups, o.lifecycle.scale_downs),
            format!("{}/{}", o.lifecycle.failures, o.lifecycle.requeued),
            format!("{:.2}", o.mean_live_replicas),
        ]);
    }
    r.note(format!(
        "{REQUESTS} requests over {} (≈2 periods); all deployments: {N_PEAK}x{} replicas, \
         governed DVFS, least-loaded routing; J/req is the full attributed bill \
         (prefill+decode+switch+idle+cold-start)",
        diurnal().label(),
        TIER.label(),
    ));
    r.note(format!(
        "autoscaled: reactive hysteresis (min 1, max {N_PEAK}), cold start {:.0} J + {:.0} s \
         warm-up; failures: MTBF {:.0} s, MTTR {:.0} s per replica, crashes requeue \
         in-flight work with original arrival timestamps",
        FleetConfig::default().cold_start.energy_j,
        FleetConfig::default().cold_start.warmup_s,
        failures().mtbf_s,
        failures().mttr_s,
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(127, 40)
    }

    #[test]
    fn table_has_all_cells_and_is_deterministic() {
        let c = ctx();
        let a = autoscale_table(&c).unwrap();
        assert_eq!(a.rows.len(), deployments(&c).len());
        let b = autoscale_table(&c).unwrap();
        assert_eq!(a.csv(), b.csv());
    }

    #[test]
    fn autoscaling_beats_static_peak_on_joules_per_request_within_slo() {
        // The PR's acceptance bar: the elastic fleet undercuts peak
        // provisioning on the full attributed bill while holding the p99
        // end-to-end SLO, cold starts included.
        let c = ctx();
        let mut deps = deployments(&c);
        let (_, auto_cfg) = deps.remove(1);
        let (_, static_cfg) = deps.remove(0);
        let slo = static_cfg.slo;
        let st = run_deployment(&c, static_cfg).unwrap();
        let au = run_deployment(&c, auto_cfg).unwrap();
        assert!(au.coldstart_j > 0.0, "autoscaled run never paid a cold start");
        assert!(au.lifecycle.scale_ups > 0 && au.lifecycle.scale_downs > 0);
        assert!(
            au.mean_live_replicas < st.mean_live_replicas,
            "autoscaling kept {} live on average vs static {}",
            au.mean_live_replicas,
            st.mean_live_replicas
        );
        assert!(
            au.attributed_joules_per_request() < st.attributed_joules_per_request(),
            "autoscaled {:.1} J/req vs static {:.1} J/req",
            au.attributed_joules_per_request(),
            st.attributed_joules_per_request()
        );
        for (name, o) in [("static", &st), ("autoscaled", &au)] {
            assert!(
                o.slo.e2e_p99() <= slo.e2e_p99_s,
                "{name}: p99 {:.2}s over the {:.1}s SLO",
                o.slo.e2e_p99(),
                slo.e2e_p99_s
            );
        }
    }

    #[test]
    fn failure_injection_conserves_energy_and_loses_nothing() {
        let c = ctx();
        let (_, cfg) = deployments(&c).remove(2);
        let o = run_deployment(&c, cfg).unwrap();
        assert_eq!(o.served, REQUESTS, "requests lost under failures");
        assert_eq!(o.slo.completed(), REQUESTS);
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e} under failure injection");
        // Each request is completed by exactly one replica.
        let mut counts = vec![0usize; REQUESTS];
        for r in &o.replicas {
            assert!(r.served <= REQUESTS);
        }
        for (req, &rep) in o.served_by.iter().enumerate() {
            assert!(rep < o.replicas.len(), "request {req} unserved");
            counts[req] += 1;
        }
        assert!(counts.iter().all(|&c| c == 1));
    }
}
