//! Ablations beyond the paper's tables — the design choices DESIGN.md calls
//! out, plus the paper's own threats-to-validity/future-work directions:
//!
//! - `batching`: static vs continuous batching under DVFS, with SLO
//!   accounting (the production dynamic the paper's offline setup excludes);
//! - `powercap`: frequency pinning vs a power-cap governor (related work
//!   [33]/[34] knob) at matched power budgets;
//! - `cluster`: multi-GPU data-parallel scaling (named future work);
//! - `sensitivity`: robustness of the headline 42% savings to ±30%
//!   perturbations of every major simulator constant — the check that the
//!   reproduction's conclusion is not an artifact of one calibrated number.

use anyhow::Result;

use crate::config::model::model_for_tier;
use crate::config::{GpuSpec, ModelTier};
use crate::coordinator::{Cluster, DvfsPolicy};
use crate::engine::{BatchingMode, OnlineConfig, OnlineSim, ReplayEngine};
use crate::gpu::power::frequency_for_cap;
use crate::perf::decode_step_cost;
use crate::perf::energy::pct_savings;
use crate::workload::Dataset;

use super::context::Context;
use super::report::{pct0, Report};

/// Static vs continuous batching × DVFS policy, under a Poisson load.
pub fn ablation_batching(ctx: &Context) -> Result<Report> {
    let model = model_for_tier(ModelTier::B8);
    let queries: Vec<&crate::workload::Query> = ctx
        .suite
        .dataset_indices(Dataset::TruthfulQa)
        .into_iter()
        .map(|i| &ctx.suite.queries[i])
        .collect();
    let mut r = Report::new(
        "ablation-batching",
        "Online serving: batching discipline x DVFS policy (Poisson 8 rps, SLO 2 s)",
        &["batching", "policy", "p50 (s)", "p95 (s)", "SLO viol.", "J/req", "qps"],
    );
    for batching in [BatchingMode::Static, BatchingMode::Continuous] {
        for policy in [
            DvfsPolicy::baseline(&ctx.gpu),
            DvfsPolicy::paper_phase_aware(&ctx.gpu),
        ] {
            let sim = OnlineSim::new(
                ctx.gpu.clone(),
                model.clone(),
                OnlineConfig {
                    arrival_rps: 8.0,
                    max_batch: 8,
                    batching,
                    policy,
                    slo_s: 2.0,
                    seed: ctx.cfg.seed,
                },
            );
            let m = sim.run(&queries)?;
            // Zero-served runs now report NaN rates instead of silent
            // zeros; a table cell must never be in that state.
            anyhow::ensure!(
                m.served == queries.len(),
                "{batching:?}/{}: served {}/{} requests",
                policy.label(),
                m.served,
                queries.len()
            );
            r.row(vec![
                format!("{batching:?}"),
                policy.label(),
                format!("{:.3}", m.percentile(50.0)),
                format!("{:.3}", m.percentile(95.0)),
                pct0(m.violation_rate() * 100.0),
                format!("{:.1}", m.joules_per_request()),
                format!("{:.2}", m.throughput_rps()),
            ]);
        }
    }
    r.note("expected shape: continuous <= static on p95; phase-aware cuts J/req ~35-45% in both disciplines");
    Ok(r)
}

/// Frequency pinning vs power-cap governor at matched budgets.
pub fn ablation_powercap(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "ablation-powercap",
        "Pinned frequency vs power-cap governor (decode-shaped work, B=1)",
        &["model", "cap (W)", "governor freq", "pinned-180 E down", "governor E down"],
    );
    let idx: Vec<usize> = (0..ctx.suite.len()).collect();
    for tier in [ModelTier::B3, ModelTier::B32] {
        let model = model_for_tier(tier);
        let engine = ReplayEngine::new(ctx.gpu.clone(), model.clone());
        let base = engine.run(&ctx.suite, &idx, 1, &DvfsPolicy::Static(ctx.gpu.f_max_mhz))?;
        let pinned = engine.run(&ctx.suite, &idx, 1, &DvfsPolicy::Static(180))?;
        for cap in [250.0, 350.0] {
            let c = decode_step_cost(&model, 1, 256);
            let f = frequency_for_cap(&ctx.gpu, &c, cap);
            let governed = engine.run(&ctx.suite, &idx, 1, &DvfsPolicy::Static(f))?;
            r.row(vec![
                tier.label().to_string(),
                format!("{cap:.0}"),
                format!("{f} MHz"),
                pct0(pct_savings(pinned.energy_j, base.energy_j)),
                pct0(pct_savings(governed.energy_j, base.energy_j)),
            ]);
        }
    }
    r.note("a decode-power cap of ~250 W selects the same low-frequency region as the paper's pinning");
    Ok(r)
}

/// Multi-GPU data-parallel scaling (future work of the paper).
pub fn ablation_cluster(ctx: &Context) -> Result<Report> {
    let model = model_for_tier(ModelTier::B8);
    let idx: Vec<usize> = (0..ctx.suite.len()).collect();
    let mut r = Report::new(
        "ablation-cluster",
        "Data-parallel replica scaling (8B, batch 4, phase-aware DVFS)",
        &["replicas", "makespan (s)", "speedup", "balance", "energy (J)", "qps"],
    );
    let mut base_makespan = 0.0;
    for n in [1usize, 2, 4, 8] {
        let c = Cluster::new(
            ctx.gpu.clone(),
            model.clone(),
            n,
            DvfsPolicy::paper_phase_aware(&ctx.gpu),
        );
        let m = c.run(&ctx.suite, &idx, 4)?;
        if n == 1 {
            base_makespan = m.makespan_s();
        }
        r.row(vec![
            n.to_string(),
            format!("{:.2}", m.makespan_s()),
            format!("{:.2}x", base_makespan / m.makespan_s()),
            format!("{:.2}", m.balance()),
            format!("{:.0}", m.energy_j),
            format!("{:.2}", m.throughput_qps()),
        ]);
    }
    r.note(
        "runs through the fleet engine: makespan scales with balance quality; energy \
         rises slightly with replica count (lower decode occupancy per replica)",
    );
    Ok(r)
}

/// Sensitivity of the headline result to the calibrated constants.
pub fn ablation_sensitivity(ctx: &Context) -> Result<Report> {
    let idx: Vec<usize> = (0..ctx.suite.len()).collect();
    let savings_with = |gpu: &GpuSpec| -> Result<f64> {
        let engine = ReplayEngine::new(gpu.clone(), model_for_tier(ModelTier::B8));
        let hi = engine.run(&ctx.suite, &idx, 1, &DvfsPolicy::Static(gpu.f_max_mhz))?;
        let lo = engine.run(&ctx.suite, &idx, 1, &DvfsPolicy::Static(180))?;
        Ok(pct_savings(lo.energy_j, hi.energy_j))
    };
    let mut r = Report::new(
        "ablation-sensitivity",
        "Headline 42% savings under ±30% perturbation of simulator constants (8B, B=1)",
        &["perturbation", "E down", "within 30-55% band?"],
    );
    let base = savings_with(&ctx.gpu)?;
    r.row(vec!["calibrated".to_string(), pct0(base), "yes".into()]);
    type Perturb = (&'static str, fn(&mut GpuSpec));
    let perturbations: [Perturb; 8] = [
        ("mem_bw -30%", |g| g.mem_bw_bytes *= 0.7),
        ("mem_bw +30%", |g| g.mem_bw_bytes *= 1.3),
        ("p_sm -30%", |g| g.p_sm_w *= 0.7),
        ("p_sm +30%", |g| g.p_sm_w *= 1.3),
        ("kappa -30%", |g| g.kappa_mem_activity *= 0.7),
        ("kappa +30%", |g| g.kappa_mem_activity = (g.kappa_mem_activity * 1.3).min(1.0)),
        ("host overhead -30%", |g| {
            g.t_framework_s *= 0.7;
            g.t_launch_s *= 0.7;
            g.t_host_per_seq_s *= 0.7;
        }),
        ("host overhead +30%", |g| {
            g.t_framework_s *= 1.3;
            g.t_launch_s *= 1.3;
            g.t_host_per_seq_s *= 1.3;
        }),
    ];
    for (name, f) in perturbations {
        let mut g = ctx.gpu.clone();
        f(&mut g);
        let s = savings_with(&g)?;
        r.row(vec![
            name.to_string(),
            pct0(s),
            if (30.0..=55.0).contains(&s) { "yes" } else { "NO" }.to_string(),
        ]);
    }
    r.note("the decode-insensitivity conclusion must not hinge on any single calibrated value");
    Ok(r)
}

/// Run one ablation by name.
pub fn run_ablation(ctx: &Context, name: &str) -> Result<Report> {
    match name {
        "batching" => ablation_batching(ctx),
        "powercap" => ablation_powercap(ctx),
        "cluster" => ablation_cluster(ctx),
        "sensitivity" => ablation_sensitivity(ctx),
        other => anyhow::bail!(
            "unknown ablation {other:?} (have: batching, powercap, cluster, sensitivity)"
        ),
    }
}

pub const ALL_ABLATIONS: [&str; 4] = ["batching", "powercap", "cluster", "sensitivity"];

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(211, 40)
    }

    #[test]
    fn batching_ablation_shape() {
        let r = ablation_batching(&ctx()).unwrap();
        assert_eq!(r.rows.len(), 4);
        // Phase-aware rows use less energy than their baseline sibling.
        let jreq = |i: usize| -> f64 { r.rows[i][5].parse().unwrap() };
        assert!(jreq(1) < jreq(0), "static: phase-aware should save energy");
        assert!(jreq(3) < jreq(2), "continuous: phase-aware should save energy");
    }

    #[test]
    fn sensitivity_all_in_band() {
        let r = ablation_sensitivity(&ctx()).unwrap();
        for row in &r.rows {
            assert_eq!(row[2], "yes", "perturbation broke the band: {row:?}");
        }
    }

    #[test]
    fn cluster_ablation_scales() {
        let r = ablation_cluster(&ctx()).unwrap();
        let speedup: f64 = r.rows.last().unwrap()[2].trim_end_matches('x').parse().unwrap();
        assert!(speedup > 3.0, "8-replica speedup {speedup}");
    }

    #[test]
    fn powercap_matches_pinning_region() {
        let r = ablation_powercap(&ctx()).unwrap();
        for row in &r.rows {
            let gov: f64 = row[4].trim_end_matches('%').parse().unwrap();
            assert!(gov > 10.0, "governor saves energy: {row:?}");
            let cap: f64 = row[1].parse().unwrap();
            if cap <= 250.0 {
                // A tight cap lands in the paper's low-frequency region.
                assert!(gov > 25.0, "tight cap should save >25%: {row:?}");
            }
        }
    }

    #[test]
    fn unknown_ablation_errors() {
        assert!(run_ablation(&ctx(), "nope").is_err());
    }
}
