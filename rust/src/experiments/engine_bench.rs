//! `ewatt bench` — the engine hot-path perf-regression harness.
//!
//! Times the shared continuous-batching engine on its headline hot path
//! (a 16-replica fleet under round-robin Poisson traffic, a million
//! arrivals by default) twice: once with the indexed event queue
//! ([`StepSelector::Indexed`], the production path) and once with the
//! reference linear scan ([`StepSelector::LinearReference`], the oracle
//! the property tests pin the queue against). Both runs serve the exact
//! same seeded arrival stream, so the ratio isolates the step-selection
//! machinery from the simulation physics.
//!
//! Results append to a tracked trajectory file (`BENCH_engine.json` at
//! the repo root, format `{"entries":[...],"format":1}`) keyed on the
//! benchmark configuration (replicas × arrivals × seed). `--check`
//! additionally gates against the last blessed entry for the same
//! configuration: the indexed mean may not exceed [`REGRESSION_BUDGET`]×
//! the blessed wall time. Every run also asserts the indexed path beats
//! the linear reference by at least `--min-speedup` (default
//! [`DEFAULT_MIN_SPEEDUP`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{ensure, Context as _, Result};

use crate::config::{GpuSpec, ModelTier};
use crate::coordinator::DvfsPolicy;
use crate::fleet::{FleetConfig, FleetSim, ReplicaSpec, RoundRobin, StepSelector};
use crate::serve::{Arrival, TrafficPattern};
use crate::util::bench::fmt_dur;
use crate::util::json::JsonValue;
use crate::workload::ReplaySuite;

/// `--check` budget: the indexed mean may grow to at most this multiple of
/// the last blessed wall time for the same configuration before the gate
/// fails (25% headroom for runner noise; real regressions are larger).
pub const REGRESSION_BUDGET: f64 = 1.25;

/// Default floor on indexed-vs-linear speedup at headline scale.
pub const DEFAULT_MIN_SPEEDUP: f64 = 3.0;

/// Most recent entries kept per trajectory file.
const MAX_ENTRIES: usize = 50;

/// One `ewatt bench` invocation's knobs (CLI flags map 1:1).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Fleet size of the headline configuration (`--replicas`).
    pub replicas: usize,
    /// Arrival-stream length (`--arrivals`).
    pub arrivals: usize,
    /// Master seed for the suite and arrival stream (`--seed`).
    pub seed: u64,
    /// Full runs averaged per selector (`--iters`).
    pub iters: usize,
    /// Gate against the blessed trajectory instead of just appending
    /// (`--check`).
    pub check: bool,
    /// Required indexed-vs-linear speedup (`--min-speedup`).
    pub min_speedup: f64,
    /// Trajectory file (`--json`), repo-root `BENCH_engine.json` by default.
    pub path: PathBuf,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            replicas: 16,
            arrivals: 1_000_000,
            seed: 0xB37C,
            iters: 1,
            check: false,
            min_speedup: DEFAULT_MIN_SPEEDUP,
            path: PathBuf::from("BENCH_engine.json"),
        }
    }
}

/// Run the harness: measure both selectors, enforce the speedup floor and
/// (under `--check`) the regression budget, then append to the trajectory.
pub fn run(opts: &BenchOptions) -> Result<()> {
    ensure!(opts.replicas >= 1, "need at least one replica");
    ensure!(opts.arrivals >= 1, "need at least one arrival");
    ensure!(opts.iters >= 1, "need at least one iteration");

    let gpu = GpuSpec::rtx_pro_6000();
    let suite = ReplaySuite::quick(opts.seed ^ 0x51, 48);
    // Load scales with the fleet so bigger fleets stay busy rather than
    // stretching the simulated horizon.
    let pattern = TrafficPattern::Poisson { rps: 8.0 * opts.replicas as f64 };
    let arrivals = pattern.generate(&suite, opts.arrivals, opts.seed);
    let cfg = FleetConfig::builder()
        .replicas(
            opts.replicas,
            ReplicaSpec::tiered(ModelTier::B3, DvfsPolicy::Static(gpu.f_max_mhz)),
        )
        .build()?;
    let sim = FleetSim::new(gpu, cfg);

    eprintln!(
        "engine bench: {} replicas x {} arrivals (seed {:#x}, {} iter/selector) ...",
        opts.replicas,
        opts.arrivals,
        opts.seed,
        opts.iters
    );
    let indexed = measure(&sim, &suite, &arrivals, StepSelector::Indexed, opts.iters)?;
    let linear = measure(&sim, &suite, &arrivals, StepSelector::LinearReference, opts.iters)?;
    let speedup = linear.as_secs_f64() / indexed.as_secs_f64();
    println!("indexed queue   : {}", fmt_dur(indexed));
    println!("linear reference: {}", fmt_dur(linear));
    println!("speedup         : {speedup:.2}x (floor {:.1}x)", opts.min_speedup);

    let mut entries = load(&opts.path)?;
    let baseline = last_matching(&entries, opts);
    if opts.check {
        match baseline {
            Some(prev_ms) => {
                let cur_ms = 1e3 * indexed.as_secs_f64();
                let budget_ms = prev_ms * REGRESSION_BUDGET;
                ensure!(
                    cur_ms <= budget_ms,
                    "hot-path regression: indexed mean {cur_ms:.1} ms vs blessed \
                     {prev_ms:.1} ms (budget {budget_ms:.1} ms = {REGRESSION_BUDGET}x)"
                );
                println!("regression gate : {cur_ms:.1} ms within {budget_ms:.1} ms budget");
            }
            None => {
                // A fresh checkout ships `{"entries":[],"format":1}` — the
                // first --check run blesses rather than fails, but the
                // speedup floor below is raised to the headline default so
                // a bless run can never waive the indexed-vs-linear bar.
                let why = if entries.is_empty() {
                    "no baseline entries in"
                } else {
                    "no baseline entry matches this configuration in"
                };
                eprintln!("{why} {} — blessing this run as the baseline", opts.path.display());
            }
        }
    }
    let floor = effective_floor(opts.check, baseline.is_some(), opts.min_speedup);
    if floor > opts.min_speedup {
        eprintln!("speedup floor raised to {floor:.1}x (--check bless run cannot waive it)");
    }
    ensure!(
        speedup >= floor,
        "indexed selector is only {speedup:.2}x faster than the linear reference \
         (need >= {floor:.1}x)"
    );

    entries.push(entry(opts, indexed, linear, speedup));
    if entries.len() > MAX_ENTRIES {
        let drop = entries.len() - MAX_ENTRIES;
        entries.drain(..drop);
    }
    save(&opts.path, &entries)?;
    println!("recorded entry in {}", opts.path.display());
    Ok(())
}

/// The speedup floor actually enforced. `--min-speedup` is honored
/// verbatim except on a `--check` run with no blessed baseline: there the
/// regression budget cannot gate anything, so the indexed-vs-linear floor
/// is raised to at least [`DEFAULT_MIN_SPEEDUP`] — otherwise a bless run
/// with a lowered floor would record a trajectory no gate ever checked.
fn effective_floor(check: bool, has_baseline: bool, min_speedup: f64) -> f64 {
    if check && !has_baseline {
        min_speedup.max(DEFAULT_MIN_SPEEDUP)
    } else {
        min_speedup
    }
}

/// Mean wall time of `iters` full runs under one selector.
fn measure(
    sim: &FleetSim,
    suite: &ReplaySuite,
    arrivals: &[Arrival],
    selector: StepSelector,
    iters: usize,
) -> Result<Duration> {
    let mut total = Duration::ZERO;
    let mut served = 0usize;
    for _ in 0..iters {
        let mut router = RoundRobin::default();
        let t0 = Instant::now();
        let o = sim.run_with_selector(suite, arrivals, &mut router, selector)?;
        total += t0.elapsed();
        served += o.served;
    }
    ensure!(served == iters * arrivals.len(), "bench run dropped requests");
    Ok(total / iters as u32)
}

/// Seeds are recorded as hex strings so 64-bit values round-trip exactly
/// through the f64-backed JSON number type.
fn seed_key(seed: u64) -> String {
    format!("{seed:#x}")
}

fn entry(opts: &BenchOptions, indexed: Duration, linear: Duration, speedup: f64) -> JsonValue {
    let unix_s = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut m = BTreeMap::new();
    m.insert("replicas".to_string(), JsonValue::Number(opts.replicas as f64));
    m.insert("arrivals".to_string(), JsonValue::Number(opts.arrivals as f64));
    m.insert("seed".to_string(), JsonValue::String(seed_key(opts.seed)));
    m.insert("iters".to_string(), JsonValue::Number(opts.iters as f64));
    m.insert(
        "indexed_ms".to_string(),
        JsonValue::Number(1e3 * indexed.as_secs_f64()),
    );
    m.insert(
        "linear_ms".to_string(),
        JsonValue::Number(1e3 * linear.as_secs_f64()),
    );
    m.insert("speedup".to_string(), JsonValue::Number(speedup));
    m.insert("unix_s".to_string(), JsonValue::Number(unix_s as f64));
    JsonValue::Object(m)
}

/// Last blessed indexed wall time (ms) for this exact configuration.
fn last_matching(entries: &[JsonValue], opts: &BenchOptions) -> Option<f64> {
    let seed = seed_key(opts.seed);
    entries.iter().rev().find_map(|e| {
        let same = e.get("replicas").and_then(JsonValue::as_usize) == Some(opts.replicas)
            && e.get("arrivals").and_then(JsonValue::as_usize) == Some(opts.arrivals)
            && e.get("seed").and_then(JsonValue::as_str) == Some(seed.as_str());
        if same {
            e.get("indexed_ms").and_then(JsonValue::as_f64)
        } else {
            None
        }
    })
}

fn load(path: &Path) -> Result<Vec<JsonValue>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let doc = JsonValue::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    ensure!(
        doc.get("format").and_then(JsonValue::as_usize) == Some(1),
        "{}: unsupported trajectory format",
        path.display()
    );
    Ok(doc
        .get("entries")
        .and_then(JsonValue::as_array)
        .map(<[JsonValue]>::to_vec)
        .unwrap_or_default())
}

fn save(path: &Path, entries: &[JsonValue]) -> Result<()> {
    let mut m = BTreeMap::new();
    m.insert("format".to_string(), JsonValue::Number(1.0));
    m.insert("entries".to_string(), JsonValue::Array(entries.to_vec()));
    let text = JsonValue::Object(m).to_string() + "\n";
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(path: PathBuf, check: bool) -> BenchOptions {
        BenchOptions {
            replicas: 2,
            arrivals: 40,
            seed: 0x7E57,
            iters: 1,
            check,
            // At toy scale queue overhead can exceed the scan savings; the
            // smoke test exercises the harness, not the headline ratio.
            min_speedup: 0.0,
            path,
        }
    }

    #[test]
    fn blesses_then_gates_a_trajectory() {
        let path = std::env::temp_dir().join(format!("ewatt_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);

        run(&tiny(path.clone(), false)).unwrap();
        let first = load(&path).unwrap();
        assert_eq!(first.len(), 1);
        assert!(first[0].get("indexed_ms").and_then(JsonValue::as_f64).unwrap() > 0.0);
        assert_eq!(
            first[0].get("seed").and_then(JsonValue::as_str),
            Some("0x7e57")
        );

        // Re-bless with a huge wall time so the --check pass/fail outcomes
        // below are timing-proof on any machine.
        let opts = tiny(path.clone(), false);
        let slow = entry(&opts, Duration::from_secs(3600), Duration::from_secs(7200), 2.0);
        save(&path, &[slow]).unwrap();
        run(&tiny(path.clone(), true)).unwrap();
        assert_eq!(load(&path).unwrap().len(), 2);

        // A blessed entry no real run can beat must trip the gate.
        let fast = entry(&opts, Duration::from_nanos(1), Duration::from_nanos(4), 4.0);
        save(&path, &[fast]).unwrap();
        assert!(run(&tiny(path.clone(), true)).is_err());

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn check_without_a_baseline_raises_the_floor_to_the_headline_default() {
        // The regression that made `--check` vacuous: an empty committed
        // trajectory meant neither gate could fire. A bless run must now
        // hold the headline speedup floor even if `--min-speedup` lowered
        // it; with a baseline (or outside --check) the flag is honored.
        assert_eq!(effective_floor(true, false, 0.0), DEFAULT_MIN_SPEEDUP);
        assert_eq!(effective_floor(true, false, 5.0), 5.0);
        assert_eq!(effective_floor(true, true, 0.0), 0.0);
        assert_eq!(effective_floor(false, false, 0.0), 0.0);
    }

    #[test]
    fn check_on_empty_committed_trajectory_blesses_or_fails_the_floor_only() {
        // The repo ships an empty trajectory; `--check` on it must never
        // fail on the *missing entry*. The only admissible failure is the
        // (raised) speedup floor — at toy scale the ratio is machine-
        // dependent, so both outcomes are legal but each is pinned.
        let path =
            std::env::temp_dir().join(format!("ewatt_bench_empty_{}.json", std::process::id()));
        std::fs::write(&path, "{\"entries\":[],\"format\":1}\n").unwrap();
        assert_eq!(load(&path).unwrap().len(), 0, "empty trajectory must load as zero entries");
        match run(&tiny(path.clone(), true)) {
            Ok(()) => {
                assert_eq!(load(&path).unwrap().len(), 1, "the blessed run must be recorded");
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("faster than the linear reference"),
                    "only the speedup floor may fail a baseline-less --check run, got: {msg}"
                );
                assert_eq!(load(&path).unwrap().len(), 0, "a floored run must not bless");
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn committed_trajectory_file_is_loadable() {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_engine.json");
        load(&path).unwrap_or_else(|e| panic!("committed {} must parse: {e}", path.display()));
    }

    #[test]
    fn matching_is_keyed_on_configuration() {
        let opts = tiny(PathBuf::from("unused.json"), false);
        let e = entry(&opts, Duration::from_millis(10), Duration::from_millis(40), 4.0);
        assert_eq!(last_matching(&[e.clone()], &opts), Some(10.0));
        let other = BenchOptions { replicas: 3, ..opts };
        assert_eq!(last_matching(&[e], &other), None);
    }
}
