//! SLO-aware serving comparison: `Static`, `PhaseAware`, and `Governed`
//! policies under traffic.
//!
//! The paper's Section VII-C combines routing and DVFS *offline* as an
//! upper bound; this experiment re-runs the DVFS half as an online,
//! closed-loop result — a traffic-driven serving loop where the governed
//! policy must hold tail-latency SLOs while it chases the phase-aware
//! profile's energy. Every number derives from [`SLO_SEED`], so the table
//! is bit-identical across runs.

use anyhow::Result;

use crate::config::model::model_for_tier;
use crate::config::ModelTier;
use crate::coordinator::DvfsPolicy;
use crate::serve::{ServeSim, ServeSimConfig, TrafficPattern};
use crate::workload::Dataset;

use super::context::Context;
use super::report::{pct0, Report};

/// Master seed for arrival streams (fixed: the table is deterministic).
pub const SLO_SEED: u64 = 0x510_CAFE;

/// Requests simulated per (scenario, policy) cell.
const REQUESTS: usize = 120;

/// The serving tier under test (the paper's mid-size 8B workhorse).
const TIER: ModelTier = ModelTier::B8;

/// Traffic scenarios: steady, bursty, and diurnal — calibrated around the
/// simulated testbed's ≈8 req/s continuous-batching capacity for 8B.
pub fn scenarios() -> Vec<(&'static str, TrafficPattern)> {
    vec![
        ("steady", TrafficPattern::Poisson { rps: 3.0 }),
        // Bursts push toward (not past) the ≈5.5 req/s continuous-batching
        // capacity; sustained overload would breach the SLO under *every*
        // policy and measure nothing about the controller.
        (
            "bursty",
            TrafficPattern::Bursty { base_rps: 1.5, burst_rps: 7.0, mean_dwell_s: 3.0 },
        ),
        (
            "diurnal",
            TrafficPattern::Diurnal { min_rps: 0.5, max_rps: 6.0, period_s: 30.0 },
        ),
    ]
}

/// Policies compared in every scenario.
pub fn policies(ctx: &Context) -> Vec<DvfsPolicy> {
    vec![
        DvfsPolicy::baseline(&ctx.gpu),
        DvfsPolicy::paper_phase_aware(&ctx.gpu),
        DvfsPolicy::governed(&ctx.gpu),
    ]
}

/// Generation-task query pool (decode-heavy, the serving-relevant mix).
fn generation_pool(ctx: &Context) -> Vec<usize> {
    let mut pool = ctx.suite.dataset_indices(Dataset::TruthfulQa);
    pool.extend(ctx.suite.dataset_indices(Dataset::NarrativeQa));
    pool
}

/// The comparison table: energy, tails, attainment, and controller
/// activity per (scenario, policy).
pub fn slo_table(ctx: &Context) -> Result<Report> {
    let sim = ServeSim::new(ctx.gpu.clone(), model_for_tier(TIER), ServeSimConfig::default());
    let pool = generation_pool(ctx);
    let mut r = Report::new(
        "slo-serve",
        "SLO-aware serving: energy vs tail latency across traffic scenarios",
        &[
            "Scenario", "Policy", "Energy (J)", "J/req", "vs static", "TTFT p95 (ms)",
            "E2E p99 (s)", "SLO attain", "Switches", "Mean dec MHz",
        ],
    );
    for (si, (name, pattern)) in scenarios().into_iter().enumerate() {
        let arrivals = pattern.generate_from(&pool, REQUESTS, SLO_SEED ^ (si as u64) << 8);
        let mut base_energy = None;
        for policy in policies(ctx) {
            let o = sim.run(&ctx.suite, &arrivals, &policy)?;
            // A zero-served cell would make every per-request column NaN;
            // that is a broken scenario, not a reportable row.
            anyhow::ensure!(
                o.served == arrivals.len(),
                "{name}/{}: served {}/{} requests",
                policy.label(),
                o.served,
                arrivals.len()
            );
            let base = *base_energy.get_or_insert(o.energy_j);
            r.row(vec![
                name.to_string(),
                policy.label(),
                format!("{:.1}", o.energy_j),
                format!("{:.2}", o.joules_per_request()),
                if o.energy_j == base {
                    "-".to_string()
                } else {
                    pct0(100.0 * (1.0 - o.energy_j / base))
                },
                format!("{:.0}", 1e3 * o.slo.ttft_p95()),
                format!("{:.2}", o.slo.e2e_p99()),
                pct0(100.0 * o.slo.attainment()),
                o.freq_switches.to_string(),
                format!("{:.0}", o.mean_decode_freq_mhz),
            ]);
        }
    }
    r.note(format!(
        "{REQUESTS} requests/cell, {} tier, SLO: ttft p95 ≤ {:.1}s, tbt p95 ≤ {:.0}ms, e2e p99 ≤ {:.1}s",
        TIER.label(),
        sim.cfg.slo.ttft_p95_s,
        1e3 * sim.cfg.slo.tbt_p95_s,
        sim.cfg.slo.e2e_p99_s
    ));
    r.note(
        "energy and 'vs static' are active (prefill+decode+switch; idle draw is \
         policy-independent); J/req is attributed total (active + amortized idle) over served, \
         identical to summing the per-request attribution ledger"
            .to_string(),
    );
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(113, 40)
    }

    #[test]
    fn table_has_all_cells_and_is_deterministic() {
        let c = ctx();
        let a = slo_table(&c).unwrap();
        assert_eq!(a.rows.len(), scenarios().len() * policies(&c).len());
        let b = slo_table(&c).unwrap();
        assert_eq!(a.csv(), b.csv());
    }

    #[test]
    fn governed_meets_the_acceptance_bar_in_every_scenario() {
        // ≥25% energy savings vs Static(f_max) with p99 e2e inside the SLO —
        // the online version of the paper's upper-bound case study.
        let c = ctx();
        let sim = ServeSim::new(
            c.gpu.clone(),
            model_for_tier(TIER),
            ServeSimConfig::default(),
        );
        let pool = generation_pool(&c);
        for (si, (name, pattern)) in scenarios().into_iter().enumerate() {
            let arrivals = pattern.generate_from(&pool, REQUESTS, SLO_SEED ^ (si as u64) << 8);
            let base = sim.run(&c.suite, &arrivals, &DvfsPolicy::baseline(&c.gpu)).unwrap();
            let gov = sim.run(&c.suite, &arrivals, &DvfsPolicy::governed(&c.gpu)).unwrap();
            let savings = 1.0 - gov.energy_j / base.energy_j;
            assert!(savings >= 0.25, "{name}: savings {savings:.3}");
            assert!(
                gov.slo.e2e_p99() <= sim.cfg.slo.e2e_p99_s,
                "{name}: p99 {:.2}s breaches the SLO",
                gov.slo.e2e_p99()
            );
            assert!(gov.slo.attainment() >= 0.95, "{name}: attainment {:.3}", gov.slo.attainment());
        }
    }
}
