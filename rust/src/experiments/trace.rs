//! `ewatt trace` — replay a named scenario with tracing attached and
//! leave auditable evidence behind.
//!
//! One invocation runs the scenario from
//! [`crate::experiments::scenarios`] with a [`Recorder`] sink and a
//! fixed-cadence heartbeat [`TimelineSampler`] attached, then:
//!
//! 1. writes `traces.jsonl` (schema-versioned header + one span per
//!    line, byte-deterministic under the scenario's fixed seed),
//! 2. writes `timeline.jsonl` (one per-replica gauge row per heartbeat
//!    boundary, same byte-determinism contract),
//! 3. re-reads and validates both files it just wrote,
//! 4. replays the evidence through the [`crate::obs::alerts`] rule
//!    engine (SLO burn rate, frequency flapping, queue growth, ledger
//!    conservation) and records the firings in the manifest,
//! 5. writes `manifest.json` with the config digest and an energy rollup
//!    recomputed from the trace and cross-checked against the
//!    [`crate::fleet::EnergyLedger`] totals to ≤ 1e-6,
//! 6. renders a per-request waterfall, the top-K energy hogs, and the
//!    metrics-registry dump to stdout.
//!
//! The rendering is derived *from the trace file's span stream*, not
//! from engine internals — what you read is what the artifact proves.
//! Two artifact directories produced this way are exactly what
//! `ewatt diff` ([`crate::obs::diff`]) consumes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context as _, Result};

use crate::config::GpuSpec;
use crate::experiments::scenarios::{self, Scenario};
use crate::fleet::FleetOutcome;
use crate::obs::{
    evaluate_alerts, fnv1a_64, timeline_header, trace_header, validate_timeline_jsonl,
    validate_trace_jsonl, write_timeline_jsonl, write_trace_jsonl, AlertConfig, AlertFiring,
    MetricsRegistry, Recorder, RunManifest, Span, SpanEvent, TimelineSampler, DEFAULT_CADENCE_S,
};
use crate::util::cli::Args;
use crate::util::json::JsonValue;

/// Waterfall bar width, characters.
const BAR_COLS: usize = 48;

/// Everything one `ewatt trace` invocation produced.
pub struct TraceRun {
    pub outcome: FleetOutcome,
    pub spans: Vec<Span>,
    pub trace_path: PathBuf,
    pub timeline_path: PathBuf,
    pub manifest_path: PathBuf,
    /// Worst relative error of the manifest's energy rollup cross-check.
    pub max_rel_err: f64,
    /// Alert firings from replaying the run's evidence (also recorded in
    /// the manifest). Empty on the clean golden scenarios.
    pub alerts: Vec<AlertFiring>,
    /// The human-readable report (waterfall + hogs + metrics).
    pub rendered: String,
}

/// CLI entry point: `ewatt trace <scenario> [--out DIR] [--top K]
/// [--limit N] [--cadence S]`.
pub fn run_cli(args: &Args) -> Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let Some(name) = args.positional.first() else {
        let names: Vec<&str> = scenarios::all(&gpu).iter().map(|s| s.name).collect();
        bail!(
            "usage: ewatt trace <scenario> [--out DIR] [--top K] [--limit N] [--cadence S]\n\
             scenarios: {}",
            names.join(", ")
        );
    };
    let out_dir = match args.get("out") {
        Some(dir) => PathBuf::from(dir),
        None => PathBuf::from("target/trace").join(name),
    };
    let top = args.get_usize("top", 10);
    let limit = args.get_usize("limit", 24);
    let cadence_s = args.get_f64("cadence", DEFAULT_CADENCE_S);
    let run = execute(&gpu, name, &out_dir, top, limit, cadence_s)?;
    println!("{}", run.rendered);
    if run.alerts.is_empty() {
        println!("alerts:   none");
    } else {
        for a in &run.alerts {
            println!("ALERT [{}] t={:.2}s: {}", a.rule.label(), a.t_s, a.message);
        }
    }
    println!("trace:    {}", run.trace_path.display());
    println!("timeline: {}", run.timeline_path.display());
    println!("manifest: {}", run.manifest_path.display());
    Ok(())
}

/// Run one observed replay (trace + heartbeat) and write all three
/// artifacts into `out_dir`.
pub fn execute(
    gpu: &GpuSpec,
    name: &str,
    out_dir: &Path,
    top: usize,
    limit: usize,
    cadence_s: f64,
) -> Result<TraceRun> {
    let sc = scenarios::by_name(gpu, name)?;
    let suite = Scenario::suite();
    let mut rec = Recorder::default();
    let mut sampler = TimelineSampler::new(cadence_s);
    let outcome = sc.run_observed(gpu, &suite, &mut rec, &mut sampler)?;

    let canonical = sc.canonical();
    let digest = format!("{:#018x}", fnv1a_64(canonical.as_bytes()));
    let header = trace_header(&format!("trace/{}", sc.name), sc.seed, &digest);
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let trace_path = out_dir.join("traces.jsonl");
    write_trace_jsonl(&trace_path, &header, &rec.spans)?;

    let tl_header = timeline_header(&format!("trace/{}", sc.name), sc.seed, cadence_s);
    let timeline_path = out_dir.join("timeline.jsonl");
    write_timeline_jsonl(&timeline_path, &tl_header, &sampler.rows)?;

    // Validate the artifacts we just wrote, not the in-memory streams:
    // the files are the evidence.
    let body = std::fs::read_to_string(&trace_path)
        .with_context(|| format!("reading back {}", trace_path.display()))?;
    let parsed = validate_trace_jsonl(&body)
        .with_context(|| format!("{} failed validation", trace_path.display()))?;
    ensure!(
        parsed == rec.spans.len(),
        "trace file carries {parsed} spans, run emitted {}",
        rec.spans.len()
    );
    let tl_body = std::fs::read_to_string(&timeline_path)
        .with_context(|| format!("reading back {}", timeline_path.display()))?;
    let tl_rows = validate_timeline_jsonl(&tl_body)
        .with_context(|| format!("{} failed validation", timeline_path.display()))?;
    ensure!(
        tl_rows == sampler.rows.len(),
        "timeline file carries {tl_rows} rows, sampler emitted {}",
        sampler.rows.len()
    );

    // Replay the evidence through the alert rules. The clean golden
    // scenarios fire nothing (pinned by rust/tests/obs_trace.rs); a dirty
    // run carries its firings in the manifest. A class-aware scenario is
    // judged against its own per-class SLOs — its Background completions
    // are slow by design, not burn.
    let alert_cfg = AlertConfig {
        class_slos: sc.cfg.classes.as_ref().map(|c| c.slos),
        ..AlertConfig::default()
    };
    let alerts =
        evaluate_alerts(&rec.spans, &sampler.rows, &sc.cfg.slo, outcome.total_j(), &alert_cfg);

    let mut manifest = RunManifest::new(&format!("trace {}", sc.name), sc.seed);
    manifest.set("scenario", JsonValue::String(sc.name.to_string()));
    manifest.set_config_digest(&canonical);
    manifest.set_outcome(&outcome);
    let max_rel_err = manifest.set_energy_rollup(&outcome, &rec.spans)?;
    manifest.set_alerts(&alerts);
    let mut tf = BTreeMap::new();
    tf.insert("file".to_string(), JsonValue::String("traces.jsonl".to_string()));
    tf.insert("spans".to_string(), JsonValue::Number(rec.spans.len() as f64));
    manifest.set("trace", JsonValue::Object(tf));
    let mut tlf = BTreeMap::new();
    tlf.insert("file".to_string(), JsonValue::String("timeline.jsonl".to_string()));
    tlf.insert("rows".to_string(), JsonValue::Number(sampler.rows.len() as f64));
    tlf.insert("cadence_s".to_string(), JsonValue::Number(cadence_s));
    manifest.set("timeline", JsonValue::Object(tlf));
    let manifest_path = manifest.write(out_dir, "manifest.json")?;

    let rendered = render(&sc, &outcome, &rec.spans, top, limit, max_rel_err);
    Ok(TraceRun {
        outcome,
        spans: rec.spans,
        trace_path,
        timeline_path,
        manifest_path,
        max_rel_err,
        alerts,
        rendered,
    })
}

/// The full human-readable report, derived from the span stream alone.
fn render(
    sc: &Scenario,
    outcome: &FleetOutcome,
    spans: &[Span],
    top: usize,
    limit: usize,
    max_rel_err: f64,
) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "scenario {}: served {} / makespan {:.2} s / {:.0} J total ({:.2} J/req) / \
         {} spans / rollup err {max_rel_err:.1e}\n\n",
        sc.name,
        outcome.served,
        outcome.makespan_s,
        outcome.total_j(),
        outcome.total_j() / (outcome.served.max(1) as f64),
        spans.len(),
    ));
    out.push_str(&render_waterfall(outcome, spans, limit));
    out.push('\n');
    out.push_str(&render_hogs(outcome, spans, top));
    out.push('\n');
    let mut reg = MetricsRegistry::new();
    for s in spans {
        reg.observe(s);
    }
    out.push_str(&reg.render());
    out
}

/// Per-request waterfall: `·` while queued/waiting, `█` while on a
/// replica, one row per request in arrival order.
fn render_waterfall(outcome: &FleetOutcome, spans: &[Span], limit: usize) -> String {
    let n = outcome.joules.len();
    let mut queued = vec![f64::NAN; n];
    let mut admitted = vec![f64::NAN; n];
    let mut served = vec![f64::NAN; n];
    let mut tokens = vec![0usize; n];
    for s in spans {
        match &s.event {
            SpanEvent::Queued { req, .. } => queued[*req] = s.t_s,
            SpanEvent::Admitted { req, .. } => {
                // Keep the *first* admission: crash-requeued requests are
                // shown from their original wait onward.
                if admitted[*req].is_nan() {
                    admitted[*req] = s.t_s;
                }
            }
            SpanEvent::Served { req, tokens: tok, .. } => {
                served[*req] = s.t_s;
                tokens[*req] = *tok;
            }
            _ => {}
        }
    }
    let span_s = outcome.makespan_s.max(1e-9);
    let col = |t: f64| (((t / span_s) * BAR_COLS as f64) as usize).min(BAR_COLS - 1);
    let rows = n.min(limit);
    let mut out = String::new();
    out.push_str(&format!(
        "waterfall (first {rows} of {n} requests, {BAR_COLS} cols = makespan):\n"
    ));
    for req in 0..rows {
        let (q, a, s) = (queued[req], admitted[req].max(queued[req]), served[req]);
        let mut bar = vec![' '; BAR_COLS];
        if q.is_finite() && s.is_finite() {
            for c in bar.iter_mut().take(col(a)).skip(col(q)) {
                *c = '·';
            }
            for c in bar.iter_mut().take(col(s) + 1).skip(col(a)) {
                *c = '█';
            }
        }
        out.push_str(&format!(
            "  req {req:4} rep {} |{}| q {q:7.2}s  s {s:7.2}s  {:3} tok  {:8.2} J\n",
            outcome.served_by[req],
            bar.into_iter().collect::<String>(),
            tokens[req],
            outcome.joules[req],
        ));
    }
    if n > rows {
        out.push_str(&format!("  … {} more requests (raise --limit to show them)\n", n - rows));
    }
    out
}

/// Top-K requests by attributed total energy, from the
/// `request_summary` spans.
fn render_hogs(outcome: &FleetOutcome, spans: &[Span], top: usize) -> String {
    let mut hogs: Vec<(usize, usize, &crate::fleet::attribution::PhaseEnergy)> = spans
        .iter()
        .filter_map(|s| match &s.event {
            SpanEvent::RequestSummary { req, replica, energy, .. } => {
                Some((*req, *replica, energy))
            }
            _ => None,
        })
        .collect();
    hogs.sort_by(|a, b| b.2.total_j().total_cmp(&a.2.total_j()).then(a.0.cmp(&b.0)));
    let k = hogs.len().min(top);
    let mut out = String::new();
    out.push_str(&format!("top {k} energy hogs (of {} requests):\n", hogs.len()));
    out.push_str("   req  rep  prefill_j   decode_j  overhead_j    total_j  share\n");
    let fleet_j = outcome.total_j().max(1e-12);
    for &(req, rep, e) in hogs.iter().take(k) {
        let overhead = e.switch_j + e.idle_j + e.coldstart_j;
        out.push_str(&format!(
            "  {req:4}  {rep:3}  {:9.2}  {:9.2}  {:10.2}  {:9.2}  {:4.1}%\n",
            e.prefill_j,
            e.decode_j,
            overhead,
            e.total_j(),
            100.0 * e.total_j() / fleet_j,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ewatt-trace-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn execute_writes_validated_artifacts_and_renders() {
        let gpu = GpuSpec::rtx_pro_6000();
        let dir = tmp_dir("exec");
        let run = execute(&gpu, "poisson-1rep-static", &dir, 5, 8, 0.5).unwrap();
        assert!(run.max_rel_err <= 1e-6);
        assert_eq!(run.outcome.served, 48);
        assert!(!run.spans.is_empty());
        // All three artifacts exist and the manifest names them.
        let manifest = std::fs::read_to_string(&run.manifest_path).unwrap();
        let m = JsonValue::parse(manifest.trim_end()).unwrap();
        assert_eq!(m.get("scenario").and_then(JsonValue::as_str), Some("poisson-1rep-static"));
        assert_eq!(
            m.get("trace").and_then(|t| t.get("file")).and_then(JsonValue::as_str),
            Some("traces.jsonl")
        );
        assert_eq!(
            m.get("timeline").and_then(|t| t.get("file")).and_then(JsonValue::as_str),
            Some("timeline.jsonl")
        );
        assert_eq!(
            m.get("outcome").and_then(|o| o.get("served")).and_then(JsonValue::as_usize),
            Some(48)
        );
        // The clean golden scenario fires no alerts, and the manifest
        // records that auditable zero.
        assert!(run.alerts.is_empty(), "{:?}", run.alerts);
        assert_eq!(
            m.get("alerts").and_then(|a| a.get("count")).and_then(JsonValue::as_usize),
            Some(0)
        );
        // The timeline covers the makespan at the requested cadence.
        let tl = std::fs::read_to_string(&run.timeline_path).unwrap();
        let rows = crate::obs::validate_timeline_jsonl(&tl).unwrap();
        assert_eq!(rows, (run.outcome.makespan_s / 0.5) as usize + 1);
        // The report shows the truncation notice (limit 8 < 48 requests)
        // and the hog table.
        assert!(run.rendered.contains("… 40 more requests"));
        assert!(run.rendered.contains("top 5 energy hogs"));
        assert!(run.rendered.contains("counters:"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_seed_reruns_are_byte_identical() {
        let gpu = GpuSpec::rtx_pro_6000();
        let (d1, d2) = (tmp_dir("rep1"), tmp_dir("rep2"));
        let a = execute(&gpu, "poisson-1rep-governed", &d1, 3, 4, 0.5).unwrap();
        let b = execute(&gpu, "poisson-1rep-governed", &d2, 3, 4, 0.5).unwrap();
        let t1 = std::fs::read(&a.trace_path).unwrap();
        let t2 = std::fs::read(&b.trace_path).unwrap();
        assert_eq!(t1, t2, "traces.jsonl must be byte-identical across same-seed runs");
        let tl1 = std::fs::read(&a.timeline_path).unwrap();
        let tl2 = std::fs::read(&b.timeline_path).unwrap();
        assert_eq!(tl1, tl2, "timeline.jsonl must be byte-identical across same-seed runs");
        let m1 = std::fs::read(&a.manifest_path).unwrap();
        let m2 = std::fs::read(&b.manifest_path).unwrap();
        assert_eq!(m1, m2, "manifests must be byte-identical across same-seed runs");
        assert_eq!(a.rendered, b.rendered);
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn self_diff_of_one_run_is_all_zero() {
        // The acceptance smoke test: `ewatt diff` of a run against itself
        // reports exact-zero deltas and no alerts on either side.
        let gpu = GpuSpec::rtx_pro_6000();
        let (d1, d2) = (tmp_dir("selfa"), tmp_dir("selfb"));
        let a = execute(&gpu, "poisson-1rep-static", &d1, 3, 4, 0.5).unwrap();
        let b = execute(&gpu, "poisson-1rep-static", &d2, 3, 4, 0.5).unwrap();
        assert!(a.alerts.is_empty() && b.alerts.is_empty());
        let report = crate::obs::diff::execute(&d1, &d2).unwrap();
        assert_eq!(report.d_j_per_req(), 0.0);
        assert_eq!(report.total_abs_delta, 0.0);
        assert_eq!(report.a.alerts, 0);
        assert_eq!(report.b.alerts, 0);
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn governed_vs_static_diff_attributes_saving_to_decode() {
        // The paper's comparison, end to end through the artifacts: the
        // governed run saves J/req over the static pin under identical
        // traffic, and the diff attributes that saving to decode-phase
        // frequency reduction. CI runs the same pair with
        // `--min-decode-share 0.8`; this test pins a softer floor so the
        // library invariant survives tuning noise.
        let gpu = GpuSpec::rtx_pro_6000();
        let (d1, d2) = (tmp_dir("stat"), tmp_dir("gov"));
        execute(&gpu, "poisson-1rep-static", &d1, 3, 4, 0.5).unwrap();
        execute(&gpu, "poisson-1rep-governed", &d2, 3, 4, 0.5).unwrap();
        let report = crate::obs::diff::execute(&d1, &d2).unwrap();
        assert!(
            report.d_j_per_req() < 0.0,
            "governed must save energy per request: Δ = {}",
            report.d_j_per_req()
        );
        assert!(
            report.decode_share > 0.5,
            "decode phase must dominate the attribution, got {:.3}",
            report.decode_share
        );
        // The static pin decodes in exactly one frequency regime; the
        // governed run must have decoded below it to save that energy.
        assert_eq!(report.a.decode_by_freq.len(), 1, "{:?}", report.a.decode_by_freq);
        assert!(
            report.b.decode_by_freq.keys().min() < report.a.decode_by_freq.keys().min(),
            "governed regimes {:?} never dipped below static {:?}",
            report.b.decode_by_freq,
            report.a.decode_by_freq
        );
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn unknown_scenario_lists_the_registry() {
        let gpu = GpuSpec::rtx_pro_6000();
        let err = execute(&gpu, "no-such-scenario", &tmp_dir("bad"), 1, 1, 0.5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("diurnal-elastic-failures"), "{err}");
    }
}
