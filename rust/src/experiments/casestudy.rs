//! Tables XVI–XVIII: the workload-aware + phase-aware case study
//! (Section VII).

use anyhow::Result;

use crate::config::model::model_for_tier;
use crate::config::ModelTier;
use crate::coordinator::{DvfsPolicy, Router, Scheduler};
use crate::perf::energy::{pct_change, pct_savings};
use crate::quality::{classify_patterns, ScalingPattern};
use crate::quality::labels::pattern_shares;
use crate::workload::Dataset;

use super::context::{CellKey, Context};
use super::report::{pct, pct0, Report};

/// Table XVI: phase-aware DVFS energy savings by model
/// (prefill @2842, decode @180 vs everything @2842).
pub fn table16(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-16",
        "Phase-aware DVFS energy savings by model",
        &["Model", "Baseline (J/q)", "Phase-aware (J/q)", "Savings", "Latency"],
    );
    let paper = [
        ("42.3%", "+5.6%"),
        ("39.9%", "+4.2%"),
        ("42.2%", "+2.5%"),
        ("41.0%", "+1.3%"),
        ("44.0%", "+0.3%"),
    ];
    let mut savings_acc = Vec::new();
    for (tier, (pe, pl)) in ModelTier::ALL.into_iter().zip(paper) {
        let base = ctx.baseline_cell(tier, 1, None)?;
        let pa = ctx.phase_aware(tier, 1)?;
        let s = pct_savings(pa.energy_j, base.energy_j);
        let l = pct_change(pa.latency_s, base.latency_s);
        savings_acc.push(s);
        r.row(vec![
            model_for_tier(tier).name,
            format!("{:.2}", base.energy_per_query()),
            format!("{:.2}", pa.energy_per_query()),
            format!("{} (paper {pe})", pct0(s)),
            format!("{} (paper {pl})", pct(l)),
        ]);
    }
    r.note(format!(
        "average savings {:.1}% (paper 41.9%)",
        savings_acc.iter().sum::<f64>() / savings_acc.len() as f64
    ));
    Ok(r)
}

/// Routing plan per scaling pattern (Table XV → XVII).
fn pattern_plan() -> [(ScalingPattern, ModelTier); 4] {
    [
        (ScalingPattern::AlwaysEasy, ModelTier::B3),
        (ScalingPattern::ScalingHelps, ModelTier::B14),
        (ScalingPattern::AlwaysHard, ModelTier::B3),
        (ScalingPattern::Inconsistent, ModelTier::B8),
    ]
}

/// Table XVII: estimated combined savings (routing + phase-aware DVFS) vs
/// always-32B at max frequency.
pub fn table17(ctx: &Context) -> Result<Report> {
    let patterns = classify_patterns(&ctx.quality);
    let shares = pattern_shares(&patterns);
    let base32 = ctx.baseline_cell(ModelTier::B32, 1, None)?;
    let base_jpq = base32.energy_per_query();

    let mut r = Report::new(
        "table-17",
        "Estimated combined energy savings (routing + phase-aware DVFS)",
        &["Category", "%", "Model", "Freq", "Est. savings", "Paper"],
    );
    let paper = ["88%", "77%", "88%", "83%"];
    let mut weighted = 0.0;
    for ((p, tier), pe) in pattern_plan().into_iter().zip(paper) {
        let k = ScalingPattern::ALL.iter().position(|x| *x == p).unwrap();
        let pa = ctx.phase_aware(tier, 1)?;
        let s = pct_savings(pa.energy_per_query(), base_jpq);
        weighted += shares[k] * s;
        r.row(vec![
            p.label().to_string(),
            pct0(shares[k] * 100.0),
            tier.label().to_string(),
            "180 MHz (decode)".to_string(),
            pct0(s),
            pe.to_string(),
        ]);
    }
    r.row(vec![
        "Weighted Average".to_string(),
        String::new(),
        String::new(),
        String::new(),
        pct0(weighted),
        "87%".to_string(),
    ]);
    Ok(r)
}

/// Quality of a strategy: mean classification accuracy over BoolQ +
/// HellaSwag on the serving tier (the paper's quality yardstick, VII-C1).
fn classification_quality(ctx: &Context, tier: ModelTier) -> f64 {
    let mut acc = 0.0;
    for d in [Dataset::BoolQ, Dataset::HellaSwag] {
        let idx = ctx.suite.dataset_indices(d);
        acc += ctx.quality.mean_raw_over(tier, &idx) / 2.0;
    }
    acc
}

/// Table XVIII: energy-quality tradeoff across strategies.
pub fn table18(ctx: &Context) -> Result<Report> {
    let base = ctx.baseline_cell(ModelTier::B32, 1, None)?;
    let dvfs_only = ctx.cell(CellKey {
        tier: ModelTier::B32,
        batch: 1,
        freq: 180,
        dataset: None,
    })?;
    let routing_only = ctx.baseline_cell(ModelTier::B3, 1, None)?;
    let combined = ctx.phase_aware(ModelTier::B3, 1)?;

    let q32 = classification_quality(ctx, ModelTier::B32);
    let q3 = classification_quality(ctx, ModelTier::B3);

    let mut r = Report::new(
        "table-18",
        "Energy-quality tradeoff across strategies",
        &["Strategy", "Energy (J/q)", "Quality", "Savings", "Paper savings"],
    );
    let jpq = |m: &crate::engine::ReplayMetrics| m.energy_per_query();
    let rows: [(&str, f64, f64, &str); 4] = [
        ("Baseline (32B, 2842 MHz)", jpq(&base), q32, "-"),
        ("DVFS only (32B, 180 MHz)", jpq(&dvfs_only), q32, "44%"),
        ("Routing only (3B, 2842 MHz)", jpq(&routing_only), q3, "80%"),
        ("Combined (3B, 180 MHz)", jpq(&combined), q3, "88%"),
    ];
    let base_jpq = jpq(&base);
    for (name, e, q, p) in rows {
        r.row(vec![
            name.to_string(),
            format!("{e:.2}"),
            pct0(q * 100.0),
            if name.starts_with("Baseline") {
                "-".to_string()
            } else {
                pct0(pct_savings(e, base_jpq))
            },
            p.to_string(),
        ]);
    }
    r.note("paper qualities: 83.8% (32B) vs 77.0% (3B) on BoolQ+HellaSwag");
    Ok(r)
}

/// The live scheduler run backing the combined strategy (sanity cross-check
/// for Table XVII/XVIII — routed replay rather than share-weighted algebra).
pub fn scheduler_crosscheck(ctx: &Context) -> Result<Report> {
    let base = Scheduler::new(
        ctx.gpu.clone(),
        Router::with_tiers(ModelTier::B32, ModelTier::B32),
        DvfsPolicy::baseline(&ctx.gpu),
        1,
    )
    .run(&ctx.suite)?;
    let combined = Scheduler::new(
        ctx.gpu.clone(),
        Router::paper_default(),
        DvfsPolicy::paper_phase_aware(&ctx.gpu),
        1,
    )
    .run(&ctx.suite)?;
    let mut r = Report::new(
        "table-17b",
        "Scheduler cross-check: routed phase-aware replay vs 32B baseline",
        &["Config", "Energy (J)", "Savings"],
    );
    r.row(vec![
        "32B @ 2842".to_string(),
        format!("{:.1}", base.total_energy_j),
        "-".to_string(),
    ]);
    r.row(vec![
        "routed + phase-aware".to_string(),
        format!("{:.1}", combined.total_energy_j),
        pct0(pct_savings(combined.total_energy_j, base.total_energy_j)),
    ]);
    for (tier, n) in &combined.routed {
        r.note(format!("routed {n} queries to {}", tier.label()));
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(109, 150)
    }

    #[test]
    fn table16_savings_in_band() {
        let c = ctx();
        let r = table16(&c).unwrap();
        for row in &r.rows {
            let s: f64 = row[3]
                .split_whitespace()
                .next()
                .unwrap()
                .trim_end_matches('%')
                .parse()
                .unwrap();
            assert!((30.0..=50.0).contains(&s), "savings out of band: {row:?}");
        }
    }

    #[test]
    fn table17_weighted_average_in_band() {
        let c = ctx();
        let r = table17(&c).unwrap();
        let w: f64 = r.rows.last().unwrap()[4]
            .trim_end_matches('%')
            .parse()
            .unwrap();
        // Paper: ~87%; band 75–95%.
        assert!((75.0..=95.0).contains(&w), "weighted savings {w}");
    }

    #[test]
    fn table18_strategy_ordering() {
        let c = ctx();
        let r = table18(&c).unwrap();
        let e: Vec<f64> = r.rows.iter().map(|row| row[1].parse().unwrap()).collect();
        // combined < routing < dvfs < baseline.
        assert!(e[3] < e[2] && e[2] < e[1] && e[1] < e[0], "{e:?}");
        let q: Vec<f64> = r
            .rows
            .iter()
            .map(|row| row[2].trim_end_matches('%').parse().unwrap())
            .collect();
        // DVFS preserves quality; routing trades it.
        assert_eq!(q[0], q[1]);
        assert!(q[2] < q[0]);
    }
}
