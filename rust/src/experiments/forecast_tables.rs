//! Predictive vs reactive autoscaling on seasonal traffic, with KV-state
//! migration under failures.
//!
//! The reactive scaler (`ewatt autoscale`) chases load: every diurnal
//! ramp queues requests until backlog crosses a watermark, then pays a
//! cold start at the worst possible moment; every trough burns idle
//! joules until the slack watermarks finally clear. The forecasting
//! scaler schedules the same capacity *ahead* of the wave — warm-ups
//! land before the ramp, drains land before the trough — so the same
//! fleet serves the same arrivals with both a shorter queueing tail and
//! a smaller full bill. This experiment pins that double win as a hard
//! gate: the table errors out if predictive ever fails to beat reactive
//! on p99 queue wait **and** attributed J/req.
//!
//! The third deployment reruns the forecast fleet under a seeded
//! MTBF/MTTR crash process with checkpoint/handoff migration enabled:
//! in-flight sequences are checkpointed off dying replicas, replayed on
//! live ones (billed to the `migration_j` ledger phase), and the table
//! enforces energy conservation to ≤ 1e-6 on the churned run.
//! Deterministic in [`FORECAST_SEED`].

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::ModelTier;
use crate::coordinator::DvfsPolicy;
use crate::fleet::{
    FailureConfig, FleetConfig, FleetOutcome, FleetSim, ForecastConfig, LeastLoaded,
    MigrationPolicy, ReactiveConfig, ReplicaSpec, ReplicaState,
};
use crate::obs::{Recorder, Span, SpanEvent};
use crate::serve::TrafficPattern;

use super::context::Context;
use super::report::{pct0, Report};

/// Master seed for the diurnal arrival stream and the failure process.
pub const FORECAST_SEED: u64 = 0xF0CA57;

/// Requests simulated per deployment (spans ≈ 7 diurnal periods, so the
/// periodogram's two-cycle learning window covers a minority of the run).
const REQUESTS: usize = 1400;

/// Peak replica count (both scalers' ceiling).
const N_PEAK: usize = 4;

/// Model tier every replica serves.
const TIER: ModelTier = ModelTier::B8;

/// The seasonal arrival process: a fast diurnal cycle with deep troughs,
/// where late drains burn idle and late warm-ups queue the ramp.
pub fn diurnal() -> TrafficPattern {
    TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 6.0, period_s: 60.0 }
}

/// The reactive comparator (same tuning family as `ewatt autoscale`).
pub fn reactive() -> ReactiveConfig {
    ReactiveConfig { min_live: 1, max_live: N_PEAK, ..ReactiveConfig::default() }
}

/// The forecasting scaler under test. The lead time covers the
/// cold-start warm-up with one bin of margin, and the candidate-period
/// grid brackets the true cycle.
pub fn forecast() -> ForecastConfig {
    ForecastConfig {
        min_live: 1,
        max_live: N_PEAK,
        warmup_s: 12.0,
        periods_s: vec![30.0, 60.0, 90.0],
        rate_per_replica: 1.8,
        cooldown_s: 5.0,
        ..ForecastConfig::default()
    }
}

/// The injected failure process for the migration deployment.
pub fn failures() -> FailureConfig {
    FailureConfig { mtbf_s: 90.0, mttr_s: 20.0, seed: FORECAST_SEED ^ 0xFA11 }
}

/// The compared deployments. All share the fleet shape (1 live +
/// `N_PEAK - 1` cold), one model tier, the governed DVFS band, and
/// least-loaded routing, so the deltas isolate the scaling discipline.
pub fn deployments(ctx: &Context) -> Vec<(String, FleetConfig)> {
    let gov = DvfsPolicy::governed(&ctx.gpu);
    let live = ReplicaSpec::tiered(TIER, gov);
    let cold = ReplicaSpec { state: ReplicaState::Cold, ..live.clone() };
    let fleet = || FleetConfig::builder().replica(live.clone()).replicas(N_PEAK - 1, cold.clone());
    let reactive_cfg = elastic().reactive(reactive()).build().expect("reactive config is valid");
    let forecast_cfg = elastic().forecast(forecast()).build().expect("forecast config is valid");
    let churned = elastic()
        .forecast(forecast())
        .failures(failures())
        .migration(MigrationPolicy::default())
        .build()
        .expect("migration config is valid");
    vec![
        ("reactive".into(), reactive_cfg),
        ("forecast".into(), forecast_cfg),
        ("forecast+failures+migration".into(), churned),
    ]
}

/// Run one deployment on the shared diurnal stream, traced (tracing is
/// an observer: physics is bit-identical to the untraced run).
pub fn run_deployment(ctx: &Context, cfg: FleetConfig) -> Result<(FleetOutcome, Vec<Span>)> {
    let arrivals = diurnal().generate(&ctx.suite, REQUESTS, FORECAST_SEED);
    let mut rec = Recorder::default();
    let outcome = FleetSim::new(ctx.gpu.clone(), cfg)
        .run_traced(&ctx.suite, &arrivals, &mut LeastLoaded, &mut rec)?;
    Ok((outcome, rec.spans))
}

/// Per-request queue wait: first admission minus arrival, read off the
/// span stream (a crash before first admission extends the wait, exactly
/// as the request experienced it).
pub fn queue_waits(spans: &[Span]) -> Vec<f64> {
    let mut queued: BTreeMap<usize, f64> = BTreeMap::new();
    let mut admitted: BTreeMap<usize, f64> = BTreeMap::new();
    for s in spans {
        match s.event {
            SpanEvent::Queued { req, .. } => {
                queued.entry(req).or_insert(s.t_s);
            }
            SpanEvent::Admitted { req, .. } => {
                admitted.entry(req).or_insert(s.t_s);
            }
            _ => {}
        }
    }
    queued
        .iter()
        .filter_map(|(req, &t_q)| admitted.get(req).map(|&t_a| (t_a - t_q).max(0.0)))
        .collect()
}

/// The p99 of a sample by sorted rank (empty samples report 0).
pub fn p99(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 * 0.99).ceil() as usize).clamp(1, v.len()) - 1;
    v[idx]
}

/// The comparison table, with the PR's acceptance bar enforced inline:
/// predictive must beat reactive on p99 queue wait AND attributed J/req,
/// and the churned migration run must conserve energy to ≤ 1e-6.
pub fn forecast_table(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "forecast",
        "Predictive vs reactive autoscaling on diurnal traffic (+ migration under failures)",
        &[
            "Deployment", "Served", "Total (J)", "Idle (J)", "Cold (J)", "Migr (J)", "J/req",
            "Queue p99 (s)", "E2E p99 (s)", "SLO attain", "Up/Down", "Fail/Mig/Res", "Mean live",
        ],
    );
    let mut measured: Vec<(String, FleetOutcome, f64)> = Vec::new();
    for (name, cfg) in deployments(ctx) {
        let (o, spans) = run_deployment(ctx, cfg)?;
        ensure!(o.served == REQUESTS, "{name}: served {}/{REQUESTS} requests", o.served);
        let waits = queue_waits(&spans);
        ensure!(waits.len() == REQUESTS, "{name}: {} of {REQUESTS} queue waits", waits.len());
        let qp99 = p99(&waits);
        r.row(vec![
            name.clone(),
            o.served.to_string(),
            format!("{:.0}", o.total_j()),
            format!("{:.0}", o.idle_j),
            format!("{:.0}", o.coldstart_j),
            format!("{:.0}", o.migration_j),
            format!("{:.1}", o.attributed_joules_per_request()),
            format!("{qp99:.2}"),
            format!("{:.2}", o.slo.e2e_p99()),
            pct0(100.0 * o.slo.attainment()),
            format!("{}/{}", o.lifecycle.scale_ups, o.lifecycle.scale_downs),
            format!(
                "{}/{}/{}",
                o.lifecycle.failures,
                o.migration.drained + o.migration.crash_recovered,
                o.migration.resumed
            ),
            format!("{:.2}", o.mean_live_replicas),
        ]);
        measured.push((name, o, qp99));
    }

    // Hard gate 1: the predictive scaler's double win over reactive.
    let (_, reactive_o, reactive_q) = &measured[0];
    let (_, forecast_o, forecast_q) = &measured[1];
    ensure!(
        forecast_q < reactive_q,
        "forecast p99 queue wait {forecast_q:.3} s does not beat reactive {reactive_q:.3} s"
    );
    ensure!(
        forecast_o.attributed_joules_per_request() < reactive_o.attributed_joules_per_request(),
        "forecast {:.1} J/req does not beat reactive {:.1} J/req",
        forecast_o.attributed_joules_per_request(),
        reactive_o.attributed_joules_per_request()
    );

    // Hard gate 2: the churned run migrated work and conserved energy.
    let (_, churned, _) = &measured[2];
    ensure!(churned.lifecycle.failures > 0, "failure process injected no crashes");
    let carried = churned.migration.drained + churned.migration.crash_recovered;
    ensure!(carried > 0, "no in-flight work was ever checkpointed under churn");
    ensure!(
        churned.migration.resumed == carried,
        "{} checkpoints evacuated but {} resumed",
        carried,
        churned.migration.resumed
    );
    let attributed: f64 = churned.joules.iter().sum();
    let rel = (attributed - churned.total_j()).abs() / churned.total_j();
    ensure!(rel <= 1e-6, "migration run conservation off by {rel:e} (> 1e-6)");

    r.note(format!(
        "{REQUESTS} requests over {} (≈7 periods); all deployments: 1 live + {} cold {} \
         replicas, governed DVFS, least-loaded routing; queue p99 is first-admission minus \
         arrival from the span stream; J/req is the full attributed bill",
        diurnal().label(),
        N_PEAK - 1,
        TIER.label(),
    ));
    r.note(format!(
        "forecast: {} s lead over a {} s warm-up, periodogram over {:?} s candidates; \
         reactive: backlog/pressure hysteresis (min 1, max {N_PEAK}); migration row adds MTBF \
         {:.0} s / MTTR {:.0} s crashes with checkpoint-every-{} handoff (replay billed to \
         migration_j, conservation enforced at 1e-6)",
        forecast().warmup_s,
        FleetConfig::default().cold_start.warmup_s,
        forecast().periods_s,
        failures().mtbf_s,
        failures().mttr_s,
        MigrationPolicy::default().checkpoint_every_tokens,
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(127, 40)
    }

    #[test]
    fn table_has_all_cells_enforces_the_gates_and_is_deterministic() {
        let c = ctx();
        let a = forecast_table(&c).unwrap();
        assert_eq!(a.rows.len(), deployments(&c).len());
        let b = forecast_table(&c).unwrap();
        assert_eq!(a.csv(), b.csv());
    }

    #[test]
    fn predictive_beats_reactive_on_tail_queueing_and_energy() {
        // The PR's acceptance bar, asserted directly (the table also
        // enforces it, but this failure message names the numbers).
        let c = ctx();
        let mut deps = deployments(&c);
        let (_, forecast_cfg) = deps.remove(1);
        let (_, reactive_cfg) = deps.remove(0);
        let (re, re_spans) = run_deployment(&c, reactive_cfg).unwrap();
        let (fo, fo_spans) = run_deployment(&c, forecast_cfg).unwrap();
        assert!(fo.lifecycle.scale_ups > 0 && fo.lifecycle.scale_downs > 0);
        assert!(fo.coldstart_j > 0.0, "forecast run never paid a cold start");
        let (re_q, fo_q) = (p99(&queue_waits(&re_spans)), p99(&queue_waits(&fo_spans)));
        assert!(fo_q < re_q, "forecast p99 queue wait {fo_q:.3} s vs reactive {re_q:.3} s");
        assert!(
            fo.attributed_joules_per_request() < re.attributed_joules_per_request(),
            "forecast {:.1} J/req vs reactive {:.1} J/req",
            fo.attributed_joules_per_request(),
            re.attributed_joules_per_request()
        );
    }

    #[test]
    fn migration_under_failures_conserves_energy_and_loses_nothing() {
        let c = ctx();
        let (_, cfg) = deployments(&c).remove(2);
        let (o, spans) = run_deployment(&c, cfg).unwrap();
        assert_eq!(o.served, REQUESTS, "requests lost under churn");
        assert!(o.lifecycle.failures > 0, "no crashes injected");
        let carried = o.migration.drained + o.migration.crash_recovered;
        assert!(carried > 0, "nothing checkpointed under churn");
        assert_eq!(o.migration.resumed, carried, "handoffs not exactly-once");
        assert!(o.migration_j > 0.0, "replay energy never billed");
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel <= 1e-6, "conservation off by {rel:e} under migration churn");
        // The span stream tells the same story as the counters.
        let migrated =
            spans.iter().filter(|s| matches!(s.event, SpanEvent::Migrated { .. })).count();
        let resumed = spans.iter().filter(|s| matches!(s.event, SpanEvent::Resumed { .. })).count();
        assert_eq!(migrated, carried, "migrated spans disagree with the counters");
        assert_eq!(resumed, o.migration.resumed, "resumed spans disagree with the counters");
    }

    #[test]
    fn queue_wait_helpers_are_exact_on_a_synthetic_stream() {
        use crate::serve::TrafficClass;
        let mut spans = Vec::new();
        for req in 0..4usize {
            spans.push(Span {
                t_s: req as f64,
                event: SpanEvent::Queued { req, query_idx: 0, class: TrafficClass::Interactive },
            });
            spans.push(Span {
                t_s: req as f64 + (req + 1) as f64,
                event: SpanEvent::Admitted { req, replica: 0 },
            });
            // A second admission (post-crash) must not shadow the first.
            spans.push(Span { t_s: 100.0, event: SpanEvent::Admitted { req, replica: 1 } });
        }
        let waits = queue_waits(&spans);
        assert_eq!(waits, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p99(&waits), 4.0);
        assert_eq!(p99(&[]), 0.0);
    }
}
