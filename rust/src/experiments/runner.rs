//! Experiment dispatch: `ewatt table <n>` / `ewatt figure <n>` / `ewatt all`.

use anyhow::{bail, Result};

use super::casestudy;
use super::context::Context;
use super::dvfs_tables;
use super::figures;
use super::quality_tables;
use super::report::Report;
use super::slo_tables;
use super::workload_tables;

/// All experiment ids in paper order.
pub const ALL_TABLES: [u32; 16] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16];
pub const ALL_FIGURES: [u32; 6] = [2, 3, 4, 5, 6, 7];

/// Run one table by paper number.
pub fn run_table(ctx: &Context, n: u32) -> Result<Vec<Report>> {
    Ok(match n {
        1 => vec![workload_tables::table1(ctx)?],
        2 => vec![workload_tables::table2(ctx)?],
        3 => vec![workload_tables::table3(ctx)?],
        4 => vec![workload_tables::table4(ctx)?],
        5 => vec![workload_tables::table5(ctx)?],
        6 => vec![workload_tables::table6(ctx)?],
        7 => vec![quality_tables::table7(ctx)?],
        8 => vec![quality_tables::table8(ctx)?],
        9 => vec![quality_tables::table9(ctx)?],
        10 => vec![quality_tables::table10(ctx)?],
        11 => vec![dvfs_tables::table11(ctx)?],
        12 => vec![dvfs_tables::table12(ctx)?],
        13 => vec![dvfs_tables::table13(ctx)?],
        14 => vec![dvfs_tables::table14(ctx)?],
        15 => vec![quality_tables::table15(ctx)?],
        16 => vec![casestudy::table16(ctx)?],
        17 => vec![casestudy::table17(ctx)?, casestudy::scheduler_crosscheck(ctx)?],
        18 => vec![casestudy::table18(ctx)?],
        other => bail!("no table {other} in the paper (I–XVIII)"),
    })
}

/// Run one figure by paper number.
pub fn run_figure(ctx: &Context, n: u32) -> Result<Vec<Report>> {
    Ok(match n {
        2 => vec![figures::fig2(ctx)?],
        3 => vec![figures::fig3(ctx)?],
        4 => vec![figures::fig4(ctx)?],
        5 => vec![figures::fig5(ctx)?],
        6 => vec![figures::fig6(ctx)?],
        7 => vec![figures::fig7(ctx)?],
        other => bail!("no figure {other} in the paper (2–7)"),
    })
}

/// Run everything (tables I–XVIII, figures 2–7, then the serve-layer
/// SLO comparison).
pub fn run_all(ctx: &Context) -> Result<Vec<Report>> {
    let mut out = Vec::new();
    for n in 1..=18u32 {
        out.extend(run_table(ctx, n)?);
    }
    for n in ALL_FIGURES {
        out.extend(run_figure(ctx, n)?);
    }
    out.push(slo_tables::slo_table(ctx)?);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_ids_error() {
        let ctx = Context::quick(9, 4);
        assert!(run_table(&ctx, 19).is_err());
        assert!(run_figure(&ctx, 1).is_err());
        assert!(run_figure(&ctx, 8).is_err());
    }

    #[test]
    fn cheap_tables_run_on_tiny_context() {
        let ctx = Context::quick(9, 6);
        for n in [1u32, 2, 3, 4, 15] {
            let reports = run_table(&ctx, n).unwrap();
            assert!(!reports.is_empty());
            assert!(!reports[0].rows.is_empty());
        }
    }
}
