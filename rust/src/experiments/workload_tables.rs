//! Tables I–VI: setup summary and workload characterization (Section V).

use anyhow::Result;

use crate::config::model::paper_models;
use crate::quality::easy_hard_labels;
use crate::stats::{cross_validate_accuracy, pearson};
use crate::workload::Dataset;

use super::context::Context;
use super::report::{f2, f3, pct0, r2, Report};

/// Table I: models and datasets used in the evaluation.
pub fn table1(_ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-01",
        "Models and datasets used in evaluation",
        &["Model", "Params", "Arch", "Layers", "d_model", "d_ff", "KV heads"],
    );
    for m in paper_models() {
        r.row(vec![
            m.name.clone(),
            format!("{:.1}B", m.param_count() as f64 / 1e9),
            "Decoder-only".into(),
            m.n_layers.to_string(),
            m.d_model.to_string(),
            m.d_ff.to_string(),
            m.n_kv_heads.to_string(),
        ]);
    }
    r.note("Datasets: BoolQ/HellaSwag (classification, log-likelihood), TruthfulQA/NarrativeQA (generation, ≤100 tokens).");
    Ok(r)
}

/// Paper's Table II values for the comparison column.
const TABLE2_PAPER: [(Dataset, f64, f64, f64, f64); 4] = [
    (Dataset::TruthfulQa, 12.6, 5.7, 5.0, 52.0),
    (Dataset::BoolQ, 102.9, 46.0, 24.0, 294.0),
    (Dataset::HellaSwag, 163.8, 56.0, 49.0, 265.0),
    (Dataset::NarrativeQa, 339.1, 34.3, 208.0, 396.0),
];

/// Table II: input length statistics (tokens).
pub fn table2(ctx: &Context) -> Result<Report> {
    let stats = ctx.suite.length_stats();
    let mut r = Report::new(
        "table-02",
        "Input length statistics (tokens) — measured vs paper",
        &["Dataset", "Mean", "Std", "Min", "Max", "Range", "Paper mean"],
    );
    for (d, pmean, _pstd, _pmin, _pmax) in TABLE2_PAPER {
        let s = stats.iter().find(|s| s.dataset == d).unwrap();
        r.row(vec![
            d.label().to_string(),
            f2(s.tokens.mean),
            f2(s.tokens.std),
            format!("{:.0}", s.tokens.min),
            format!("{:.0}", s.tokens.max),
            format!("{:.1}x", s.tokens.range_ratio()),
            f2(pmean),
        ]);
    }
    let means: Vec<f64> = TABLE2_PAPER
        .iter()
        .map(|(d, ..)| stats.iter().find(|s| s.dataset == *d).unwrap().tokens.mean)
        .collect();
    r.note(format!(
        "mean-length span {:.1}x across datasets (paper: 26.9x)",
        means.iter().cloned().fold(f64::MIN, f64::max)
            / means.iter().cloned().fold(f64::MAX, f64::min)
    ));
    Ok(r)
}

/// Table III: input complexity features by dataset (means).
pub fn table3(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-03",
        "Input complexity features by dataset (mean values)",
        &["Feature", "BoolQ", "HellaSwag", "TruthfulQA", "NarrativeQA"],
    );
    let order = [
        Dataset::BoolQ,
        Dataset::HellaSwag,
        Dataset::TruthfulQa,
        Dataset::NarrativeQa,
    ];
    let feat_row = |name: &str, f: &dyn Fn(&crate::features::FeatureVector) -> f64| {
        let mut cells = vec![name.to_string()];
        for d in order {
            cells.push(f3(ctx.suite.feature_mean(d, f)));
        }
        cells
    };
    let rows = vec![
        feat_row("Complexity Score", &|f| f.complexity_score),
        feat_row("Reasoning Complexity", &|f| f.reasoning_complexity),
        feat_row("Entity Density", &|f| f.entity_density),
        feat_row("Token Entropy", &|f| f.token_entropy),
        feat_row("Causal Questions (%)", &|f| f.causal_question * 100.0),
    ];
    for row in rows {
        r.row(row);
    }
    r.note("paper row targets: entity 0.20/0.12/0.34/0.18; causal 2.4/4.4/10.2/33.6%; entropy 5.82/6.31/3.50/7.16");
    Ok(r)
}

/// Table IV: causal question distribution by dataset.
pub fn table4(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-04",
        "Causal question distribution by dataset",
        &["Dataset", "Causal questions (%)", "Paper (%)", "Dominant query type"],
    );
    let dominant = [
        (Dataset::BoolQ, 2.4, "Factual verification"),
        (Dataset::HellaSwag, 4.4, "Sequence prediction"),
        (Dataset::TruthfulQa, 10.2, "Factual and causal"),
        (Dataset::NarrativeQa, 33.6, "Comprehension and causal"),
    ];
    for (d, paper, kind) in dominant {
        r.row(vec![
            d.label().to_string(),
            pct0(ctx.suite.feature_mean(d, |f| f.causal_question) * 100.0),
            pct0(paper),
            kind.to_string(),
        ]);
    }
    Ok(r)
}

/// Table V: feature independence from input length.
pub fn table5(ctx: &Context) -> Result<Report> {
    let n = ctx.suite.len();
    let length: Vec<f64> = (0..n)
        .map(|i| ctx.suite.features[i].input_length as f64)
        .collect();
    let quality: Vec<f64> = (0..n).map(|i| ctx.quality.mean_norm(i)).collect();

    let mut r = Report::new(
        "table-05",
        "Feature independence from input length",
        &["Feature", "Corr. with length", "Paper", "Independent?"],
    );
    let feats: [(&str, Box<dyn Fn(usize) -> f64>, f64); 5] = [
        ("Entity Density", Box::new(|i| ctx.suite.features[i].entity_density), -0.44),
        ("Causal Question Score", Box::new(|i| ctx.suite.features[i].causal_question), 0.31),
        ("Reasoning Complexity", Box::new(|i| ctx.suite.features[i].reasoning_complexity), 0.19),
        ("Token Entropy", Box::new(|i| ctx.suite.features[i].token_entropy), 0.88),
        ("Complexity Score", Box::new(|i| ctx.suite.features[i].complexity_score), 0.16),
    ];
    for (name, f, paper) in feats {
        let xs: Vec<f64> = (0..n).map(|i| f(i)).collect();
        let c = pearson(&xs, &length);
        r.row(vec![
            name.to_string(),
            r2(c),
            r2(paper),
            if c.abs() < 0.5 { "yes" } else { "no" }.to_string(),
        ]);
    }
    let lq = pearson(&length, &quality);
    r.row(vec![
        "Length -> Quality".to_string(),
        r2(lq),
        "+0.00".to_string(),
        "(near zero)".to_string(),
    ]);
    Ok(r)
}

/// Table VI: feature-ablation difficulty-classification accuracy
/// (LR, C=1.0, 5-fold stratified CV — the paper's exact protocol).
pub fn table6(ctx: &Context) -> Result<Report> {
    let labels = easy_hard_labels(&ctx.suite, &ctx.quality);
    let hard: Vec<bool> = labels.iter().map(|&e| !e).collect();
    let n = ctx.suite.len();

    // Length-only baseline: threshold at 150 tokens (paper's heuristic).
    let len_correct = (0..n)
        .filter(|&i| (ctx.suite.features[i].input_length > 150) == hard[i])
        .count() as f64
        / n as f64;

    let fset = |take: &dyn Fn(usize) -> Vec<f64>| -> Vec<Vec<f64>> {
        (0..n).map(|i| take(i)).collect()
    };
    let mut rng = crate::rng(ctx.cfg.seed ^ 0x7ab1e6);
    let mut cv = |x: &[Vec<f64>]| cross_validate_accuracy(x, &hard, 5, 1.0, &mut rng);

    let len_entity = cv(&fset(&|i| {
        let f = &ctx.suite.features[i];
        vec![f.input_length as f64, f.entity_density]
    }));
    let len_entity_causal = cv(&fset(&|i| {
        let f = &ctx.suite.features[i];
        vec![f.input_length as f64, f.entity_density, f.causal_question]
    }));
    let features_only = cv(&fset(&|i| ctx.suite.features[i].semantic_array().to_vec()));

    let mut r = Report::new(
        "table-06",
        "Feature ablation: difficulty classification accuracy (5-fold CV)",
        &["Feature set", "Accuracy", "Paper"],
    );
    r.row(vec!["Length only (>150 tokens)".to_string(), pct0(len_correct * 100.0), "51.1%".into()]);
    r.row(vec!["+ Entity density".to_string(), pct0(len_entity * 100.0), "66.6%".into()]);
    r.row(vec!["+ Causal question score".to_string(), pct0(len_entity_causal * 100.0), "68.4%".into()]);
    r.row(vec!["Features only (no length)".to_string(), pct0(features_only * 100.0), "68.6%".into()]);
    r.note("semantic features must beat the length baseline by >= 10 pp (calibration band)");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(101, 150)
    }

    #[test]
    fn table2_reproduces_length_ordering_and_scale() {
        let c = ctx();
        let r = table2(&c).unwrap();
        assert_eq!(r.rows.len(), 4);
        // Means within ±15% of the paper's (calibration band).
        for (row, (_, pmean, ..)) in r.rows.iter().zip(TABLE2_PAPER) {
            let measured: f64 = row[1].parse().unwrap();
            assert!(
                (measured - pmean).abs() / pmean < 0.15,
                "{}: measured {measured} vs paper {pmean}",
                row[0]
            );
        }
    }

    #[test]
    fn table5_shows_length_independence() {
        let c = ctx();
        let r = table5(&c).unwrap();
        // Entity/causal/reasoning/complexity independent; entropy not.
        let get = |name: &str| {
            r.rows
                .iter()
                .find(|row| row[0] == name)
                .unwrap_or_else(|| panic!("{name} missing"))
                .clone()
        };
        assert_eq!(get("Entity Density")[3], "yes");
        assert_eq!(get("Causal Question Score")[3], "yes");
        assert_eq!(get("Token Entropy")[3], "no");
        let lq: f64 = get("Length -> Quality")[1].parse().unwrap();
        assert!(lq.abs() < 0.15, "length-quality corr {lq}");
    }

    #[test]
    fn table6_semantics_beat_length() {
        let c = ctx();
        let r = table6(&c).unwrap();
        let acc = |i: usize| -> f64 {
            r.rows[i][1].trim_end_matches('%').parse().unwrap()
        };
        let baseline = acc(0);
        let semantic = acc(3);
        assert!((40.0..=62.0).contains(&baseline), "length baseline {baseline}");
        assert!(semantic >= baseline + 8.0, "semantic {semantic} vs baseline {baseline}");
    }

    #[test]
    fn table1_echoes_specs() {
        let r = table1(&ctx()).unwrap();
        assert_eq!(r.rows.len(), 5);
        assert!(r.ascii().contains("Qwen2.5-32B"));
    }
}
