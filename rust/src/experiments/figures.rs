//! Figures 2–7 as data-series reports (CSV is the canonical artifact; the
//! ASCII rendering includes the series so the shape is visible in-terminal).

use anyhow::Result;

use crate::config::model::model_for_tier;
use crate::config::ModelTier;
use crate::coordinator::router::Router;
use crate::gpu::GpuSim;
use crate::perf::energy::{pct_change, pct_savings};
use crate::perf::{decode_step_cost, prefill_cost};
use crate::stats::pearson;
use crate::workload::Dataset;

use super::context::{CellKey, Context};
use super::report::{f3, pct, pct0, Report};

/// Figure 2: input length vs quality scatter (r ≈ 0).
pub fn fig2(ctx: &Context) -> Result<Report> {
    let n = ctx.suite.len();
    let mut r = Report::new(
        "fig-02",
        "Input length vs quality score (scatter)",
        &["query", "input_tokens", "mean_norm_quality", "easy"],
    );
    let length: Vec<f64> = (0..n)
        .map(|i| ctx.suite.features[i].input_length as f64)
        .collect();
    let quality: Vec<f64> = (0..n).map(|i| ctx.quality.mean_norm(i)).collect();
    for i in 0..n {
        r.row(vec![
            i.to_string(),
            format!("{:.0}", length[i]),
            f3(quality[i]),
            (quality[i] > 0.5).to_string(),
        ]);
    }
    r.note(format!(
        "pearson r = {:+.3} (paper: +0.002 — length cannot predict difficulty)",
        pearson(&length, &quality)
    ));
    Ok(r)
}

/// Figure 3: energy per generated token vs GPU frequency.
pub fn fig3(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "fig-03",
        "Energy per generated token across GPU frequencies",
        &["freq_mhz", "1B (J/tok)", "3B", "8B", "14B", "32B"],
    );
    for &f in &ctx.gpu.freq_levels_mhz {
        let mut cells = vec![f.to_string()];
        for tier in ModelTier::ALL {
            // Generation datasets only (tokens are produced there).
            let m = ctx.cell(CellKey { tier, batch: 1, freq: f, dataset: Some(Dataset::NarrativeQa) })?;
            cells.push(format!("{:.4}", m.energy_per_token()));
        }
        r.row(cells);
    }
    r.note("monotone decreasing with frequency (memory-bound decode)");
    Ok(r)
}

/// Figure 4: the frequency cliff — savings vs frequency per model.
pub fn fig4(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "fig-04",
        "Frequency cliff: energy savings vs SM frequency (B=1, full mix)",
        &["freq_mhz", "1B", "3B", "8B", "14B", "32B"],
    );
    for &f in &ctx.gpu.freq_levels_mhz {
        let mut cells = vec![f.to_string()];
        for tier in ModelTier::ALL {
            let base = ctx.baseline_cell(tier, 1, None)?;
            let m = ctx.cell(CellKey { tier, batch: 1, freq: f, dataset: None })?;
            cells.push(pct0(pct_savings(m.energy_j, base.energy_j)));
        }
        r.row(cells);
    }
    r.note("savings plateau below ~1000 MHz; all models 40-45% in the plateau (paper Fig. 4)");
    Ok(r)
}

/// Figure 5: batch-size effect on savings and latency penalty.
pub fn fig5(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "fig-05",
        "Effect of batch size on DVFS effectiveness (180 vs 2842 MHz)",
        &["batch", "avg E down", "avg L delta"],
    );
    for &b in &ctx.cfg.batch_sizes {
        let mut e_acc = 0.0;
        let mut l_acc = 0.0;
        for tier in ModelTier::ALL {
            let hi = ctx.baseline_cell(tier, b, None)?;
            let lo = ctx.cell(CellKey { tier, batch: b, freq: 180, dataset: None })?;
            e_acc += pct_savings(lo.energy_j, hi.energy_j) / 5.0;
            l_acc += pct_change(lo.latency_s, hi.latency_s) / 5.0;
        }
        r.row(vec![b.to_string(), pct0(e_acc), pct(l_acc)]);
    }
    r.note("paper: savings 41.9/42.4/43.6%, latency +2.8/+2.1/+1.1%");
    Ok(r)
}

/// Figure 6: phase-aware frequency profile over one generation request —
/// (time, freq, power) trace.
pub fn fig6(ctx: &Context) -> Result<Report> {
    let tier = ModelTier::B8;
    let model = model_for_tier(tier);
    let seq = 336; // NarrativeQA-scale prompt
    let steps = 32;
    let mut r = Report::new(
        "fig-06",
        "Phase-aware frequency profile during one inference",
        &["t_start_s", "phase", "freq_mhz", "power_w", "duration_s"],
    );
    let mut t = 0.0;
    let pre_sim = GpuSim::new(ctx.gpu.clone(), ctx.gpu.f_max_mhz);
    let pre = pre_sim.execute(&prefill_cost(&model, 1, seq));
    r.row(vec![
        format!("{t:.4}"),
        "prefill".into(),
        ctx.gpu.f_max_mhz.to_string(),
        format!("{:.0}", pre.mean_power_w),
        format!("{:.4}", pre.latency_s),
    ]);
    t += pre.latency_s;
    let sw = ctx.gpu.f_switch_overhead_s;
    r.row(vec![
        format!("{t:.4}"),
        "dvfs-switch".into(),
        "180".into(),
        format!("{:.0}", ctx.gpu.p_idle_w),
        format!("{sw:.4}"),
    ]);
    t += sw;
    let dec_sim = GpuSim::new(ctx.gpu.clone(), 180);
    for s in 0..steps {
        let d = dec_sim.execute(&decode_step_cost(&model, 1, seq + s));
        if s < 3 || s == steps - 1 {
            r.row(vec![
                format!("{t:.4}"),
                format!("decode[{s}]"),
                "180".into(),
                format!("{:.0}", d.mean_power_w),
                format!("{:.4}", d.latency_s),
            ]);
        }
        t += d.latency_s;
    }
    r.note("high-frequency prefill, low-frequency decode; transition at prefill completion (paper Fig. 6)");
    Ok(r)
}

/// Figure 7: energy-quality Pareto frontier of the four strategies.
pub fn fig7(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "fig-07",
        "Energy-quality Pareto frontier",
        &["strategy", "energy_j_per_query", "quality"],
    );
    let quality = |tier: ModelTier| {
        let mut acc = 0.0;
        for d in [Dataset::BoolQ, Dataset::HellaSwag] {
            let idx = ctx.suite.dataset_indices(d);
            acc += ctx.quality.mean_raw_over(tier, &idx) / 2.0;
        }
        acc
    };
    let strategies: [(&str, ModelTier, bool); 4] = [
        ("baseline-32B@2842", ModelTier::B32, false),
        ("dvfs-32B@180", ModelTier::B32, true),
        ("routing-3B@2842", ModelTier::B3, false),
        ("combined-3B@180", ModelTier::B3, true),
    ];
    for (name, tier, low) in strategies {
        let m = if low {
            ctx.cell(CellKey { tier, batch: 1, freq: 180, dataset: None })?
        } else {
            ctx.baseline_cell(tier, 1, None)?
        };
        r.row(vec![
            name.to_string(),
            format!("{:.2}", m.energy_per_query()),
            f3(quality(tier)),
        ]);
    }
    r.note("DVFS moves left at equal quality ('free'); routing trades quality for energy (paper Fig. 7)");
    let _ = Router::is_easy_rule; // routing rule referenced by the figure caption
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(113, 24)
    }

    #[test]
    fn fig2_near_zero_correlation() {
        let c = ctx();
        let r = fig2(&c).unwrap();
        let note = &r.notes[0];
        let val: f64 = note
            .split('=')
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(val.abs() < 0.2, "length-quality corr {val}");
    }

    #[test]
    fn fig3_energy_per_token_monotone_in_freq() {
        let c = ctx();
        let r = fig3(&c).unwrap();
        for col in 1..=5 {
            let series: Vec<f64> = r.rows.iter().map(|row| row[col].parse().unwrap()).collect();
            for w in series.windows(2) {
                assert!(w[0] <= w[1] * 1.001, "J/tok not monotone: {series:?}");
            }
        }
    }

    #[test]
    fn fig4_cliff_shape() {
        let c = ctx();
        let r = fig4(&c).unwrap();
        // At 960 MHz, most of the 180 MHz savings are already realized.
        let row960 = r.rows.iter().find(|row| row[0] == "960").unwrap();
        let row180 = r.rows.iter().find(|row| row[0] == "180").unwrap();
        for col in 1..=5 {
            let s960: f64 = row960[col].trim_end_matches('%').parse().unwrap();
            let s180: f64 = row180[col].trim_end_matches('%').parse().unwrap();
            assert!(s960 > 0.75 * s180, "no plateau: {s960} vs {s180}");
        }
    }

    #[test]
    fn fig6_trace_is_contiguous() {
        let c = ctx();
        let r = fig6(&c).unwrap();
        assert!(r.rows.len() >= 5);
        assert_eq!(r.rows[0][1], "prefill");
        assert_eq!(r.rows[1][1], "dvfs-switch");
        // Prefill at max freq, decode at 180.
        assert_eq!(r.rows[0][2], "2842");
        assert_eq!(r.rows[2][2], "180");
        // Decode power far below prefill power.
        let p_pre: f64 = r.rows[0][3].parse().unwrap();
        let p_dec: f64 = r.rows[2][3].parse().unwrap();
        assert!(p_dec < 0.75 * p_pre, "{p_dec} vs {p_pre}");
    }

    #[test]
    fn fig7_pareto_relationships() {
        // Larger context: strategy quality gaps need enough classification
        // samples to separate from Bernoulli noise.
        let c = Context::quick(113, 150);
        let r = fig7(&c).unwrap();
        let e: Vec<f64> = r.rows.iter().map(|row| row[1].parse().unwrap()).collect();
        let q: Vec<f64> = r.rows.iter().map(|row| row[2].parse().unwrap()).collect();
        assert!(e[1] < e[0] && (q[1] - q[0]).abs() < 1e-9); // dvfs: free energy
        assert!(e[2] < e[1] && q[2] < q[0]); // routing: cheaper, lower quality
        assert!(e[3] < e[2]); // combined cheapest
    }
}
