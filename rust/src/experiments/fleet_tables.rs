//! Fleet serving comparison: monolithic-large vs routed heterogeneous
//! deployments × static vs governed DVFS, online.
//!
//! Section VII of the paper multiplies the savings of workload-aware model
//! selection (Table XV) and phase-aware DVFS (Table XI) *offline*, as an
//! upper bound. This experiment runs the combination as a closed loop: a
//! mixed-difficulty arrival stream hits a four-replica fleet through a
//! live router while each replica's governor chases its own SLO pressure.
//! Per-request energy comes from the attribution ledger, so the table can
//! report joules/request as a distribution (mean and p99), not a ratio of
//! aggregates. Deterministic in [`FLEET_SEED`].

use anyhow::Result;

use crate::config::ModelTier;
use crate::coordinator::DvfsPolicy;
use crate::fleet::{
    DifficultyTiered, EnergyAware, FleetConfig, FleetRouter, FleetSim, LeastLoaded, ReplicaSpec,
};
use crate::quality::QualityModel;
use crate::serve::TrafficPattern;

use super::context::Context;
use super::report::{pct0, Report};

/// Master seed for the fleet arrival streams.
pub const FLEET_SEED: u64 = 0xF1EE7;

/// Requests simulated per (scenario, deployment) cell.
const REQUESTS: usize = 160;

/// Small/large tiers of the routed deployments (the paper's Table XV
/// routing condensed to two tiers, as in `coordinator::Router`).
const SMALL: ModelTier = ModelTier::B3;
const LARGE: ModelTier = ModelTier::B14;

/// Replicas per deployment (monolithic: 4 large; routed: 2 small + 2 large).
const N_LARGE_ONLY: usize = 4;
const N_SPLIT: usize = 2;

/// Traffic scenarios, calibrated under the four-replica fleet's capacity so
/// the comparison measures policy, not collapse.
pub fn scenarios() -> Vec<(&'static str, TrafficPattern)> {
    vec![
        ("steady", TrafficPattern::Poisson { rps: 6.0 }),
        (
            "bursty",
            TrafficPattern::Bursty { base_rps: 3.0, burst_rps: 10.0, mean_dwell_s: 3.0 },
        ),
    ]
}

/// The compared deployments: (name, fleet config, router).
pub fn deployments(ctx: &Context) -> Vec<(String, FleetConfig, Box<dyn FleetRouter>)> {
    let stat = DvfsPolicy::baseline(&ctx.gpu);
    let gov = DvfsPolicy::governed(&ctx.gpu);
    let mono = |p| {
        FleetConfig::builder()
            .replicas(N_LARGE_ONLY, ReplicaSpec::tiered(LARGE, p))
            .build()
            .expect("monolithic deployment config is valid")
    };
    let split = |p| {
        FleetConfig::builder()
            .replicas(N_SPLIT, ReplicaSpec::tiered(SMALL, p))
            .replicas(N_SPLIT, ReplicaSpec::tiered(LARGE, p))
            .build()
            .expect("routed deployment config is valid")
    };
    let ll = || Box::new(LeastLoaded) as Box<dyn FleetRouter>;
    vec![
        ("monolithic-14B·static".into(), mono(stat), ll()),
        ("monolithic-14B·governed".into(), mono(gov), ll()),
        ("routed-3B/14B·static".into(), split(stat), Box::new(DifficultyTiered::default())),
        ("routed-3B/14B·governed".into(), split(gov), Box::new(DifficultyTiered::default())),
        ("energy-routed·governed".into(), split(gov), Box::new(EnergyAware::default())),
    ]
}

/// The comparison table: attributed joules/request (mean + p99), tail
/// latency, SLO attainment, and served quality per deployment.
pub fn fleet_table(ctx: &Context) -> Result<Report> {
    let qm = QualityModel::new();
    let mut r = Report::new(
        "fleet-serve",
        "Heterogeneous fleet: routing x DVFS co-design under traffic",
        &[
            "Scenario", "Deployment", "Router", "Energy (J)", "J/req", "J/req p99",
            "vs mono-static", "E2E p99 (s)", "SLO attain", "Quality", "Switches",
        ],
    );
    for (si, (scenario, pattern)) in scenarios().into_iter().enumerate() {
        let arrivals = pattern.generate(&ctx.suite, REQUESTS, FLEET_SEED ^ ((si as u64) << 8));
        let mut base_jreq = None;
        for (di, (name, cfg, mut router)) in deployments(ctx).into_iter().enumerate() {
            let sim = FleetSim::new(ctx.gpu.clone(), cfg);
            let label = router.label();
            let o = sim.run(&ctx.suite, &arrivals, router.as_mut())?;
            // Guard the degenerate case explicitly: a zero-served cell
            // would render every attributed per-request column NaN.
            anyhow::ensure!(
                o.served == arrivals.len(),
                "{scenario}/{name}: served {}/{} requests",
                o.served,
                arrivals.len()
            );
            // Quality of what was actually served: each request sampled on
            // the tier of the replica that *completed* it (identical to
            // first-routed here, but robust if failure injection is ever
            // enabled in these deployments).
            let quality: f64 = arrivals
                .iter()
                .enumerate()
                .map(|(i, a)| {
                    let tier = o.replicas[o.served_by[i]].tier;
                    let q = &ctx.suite.queries[a.query_idx];
                    qm.sample(q, &ctx.suite.features[a.query_idx], tier)
                })
                .sum::<f64>()
                / arrivals.len() as f64;
            let jreq = o.attributed_joules_per_request();
            let base = *base_jreq.get_or_insert(jreq);
            r.row(vec![
                scenario.to_string(),
                name,
                label,
                format!("{:.0}", o.total_j()),
                format!("{jreq:.1}"),
                format!("{:.1}", o.attributed_joules_per_request_quantile(0.99)),
                if di == 0 { "-".to_string() } else { pct0(100.0 * (1.0 - jreq / base)) },
                format!("{:.2}", o.slo.e2e_p99()),
                pct0(100.0 * o.slo.attainment()),
                format!("{quality:.3}"),
                o.freq_switches.to_string(),
            ]);
        }
    }
    r.note(format!(
        "{REQUESTS} requests/cell over the full dataset mix; 4 replicas per deployment; \
         J/req is per-request attributed energy (prefill+decode+switch+idle)"
    ));
    r.note(
        "monolithic = 4x14B least-loaded; routed = 2x3B + 2x14B difficulty-tiered; \
         energy-routed = same fleet, joules/token-aware routing",
    );
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(127, 40)
    }

    #[test]
    fn table_has_all_cells_and_is_deterministic() {
        let c = ctx();
        let a = fleet_table(&c).unwrap();
        assert_eq!(a.rows.len(), scenarios().len() * deployments(&c).len());
        let b = fleet_table(&c).unwrap();
        assert_eq!(a.csv(), b.csv());
    }

    #[test]
    fn routed_governed_beats_monolithic_static_within_slo() {
        // The PR's acceptance bar, per scenario: lower attributed J/req at
        // equal (within-target) p99 SLO attainment.
        let c = ctx();
        for (si, (scenario, pattern)) in scenarios().into_iter().enumerate() {
            let arrivals =
                pattern.generate(&c.suite, REQUESTS, FLEET_SEED ^ ((si as u64) << 8));
            let mut deps = deployments(&c);
            let (_, mono_cfg, mut mono_router) = deps.remove(0);
            let (_, routed_cfg, mut routed_router) = deps.remove(2); // routed-governed
            let slo = mono_cfg.slo;
            let mono = FleetSim::new(c.gpu.clone(), mono_cfg)
                .run(&c.suite, &arrivals, mono_router.as_mut())
                .unwrap();
            let routed = FleetSim::new(c.gpu.clone(), routed_cfg)
                .run(&c.suite, &arrivals, routed_router.as_mut())
                .unwrap();
            assert!(
                routed.attributed_joules_per_request() < mono.attributed_joules_per_request(),
                "{scenario}: routed {:.1} J/req vs mono {:.1} J/req",
                routed.attributed_joules_per_request(),
                mono.attributed_joules_per_request()
            );
            for (name, o) in [("mono", &mono), ("routed", &routed)] {
                assert!(
                    o.slo.e2e_p99() <= slo.e2e_p99_s,
                    "{scenario}/{name}: p99 {:.2}s over the {:.1}s SLO",
                    o.slo.e2e_p99(),
                    slo.e2e_p99_s
                );
            }
        }
    }
}
