//! `ewatt lab` — the mixed-workload headline table.
//!
//! One synthetic mixed-class trace (the [`ClassMix`] generator: per-class
//! corpus mixes, heavy-tailed log-normal output lengths, correlated
//! cross-class bursts) is served twice through the *same* governed fleet:
//!
//! - **class-blind**: no [`ClassPolicy`] attached — FIFO admission,
//!   least-loaded routing, every completion measured against the single
//!   interactive SLO. Latency-tolerant distress during bursts pins the
//!   governor high for everyone.
//! - **class-aware**: [`ClassPolicy`] attached — strict-priority admission
//!   with starvation aging, class-reserved KV headroom, class-aware
//!   routing, and a class-weighted pressure signal, so only *interactive*
//!   distress lifts the frequency.
//!
//! The table attributes J/req and tail latency per class, and
//! [`LabReport::check`] asserts the headline: class-aware governance
//! strictly lowers Batch and Background J/req while Interactive p95 TTFT
//! and p99 e2e stay within the interactive budgets, with per-class energy
//! summing back to the fleet ledger to ≤ 1e-6. With `--out`, the arrival
//! stream is serialized to `prompts.jsonl` (LF-only, byte-deterministic)
//! so the exact trace travels with the result.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context as _, Result};

use crate::config::{GpuSpec, ModelTier};
use crate::coordinator::DvfsPolicy;
use crate::fleet::{
    ClassAware, ClassPolicy, FleetConfig, FleetOutcome, FleetRouter, FleetSim, LeastLoaded,
    ReplicaSpec,
};
use crate::obs::export::{num, obj, text, uint};
use crate::obs::{Recorder, Span, SpanEvent};
use crate::serve::slo::ClassSlos;
use crate::serve::traffic::{Arrival, ClassMix, TrafficClass};
use crate::stats::exact_quantile;
use crate::util::cli::Args;
use crate::workload::ReplaySuite;

/// Default request count (two bursty dwell cycles at the default mix).
pub const DEFAULT_REQUESTS: usize = 96;
/// Default arrival seed.
pub const DEFAULT_SEED: u64 = 0x1AB0;

/// One class's measured row under one governance mode.
#[derive(Debug, Clone, Copy)]
pub struct ClassStats {
    pub requests: usize,
    /// Σ attributed joules over the class's requests (exact bills).
    pub total_j: f64,
    pub ttft_p95_s: f64,
    pub e2e_p99_s: f64,
}

impl ClassStats {
    pub fn j_per_req(&self) -> f64 {
        self.total_j / self.requests.max(1) as f64
    }
}

/// One serving run of the lab trace, reduced to per-class evidence.
#[derive(Debug)]
pub struct LabRun {
    pub outcome: FleetOutcome,
    /// Per-class rows in [`TrafficClass::ALL`] order.
    pub by_class: [ClassStats; 3],
    /// Relative error of Σ per-class joules vs the fleet ledger total.
    pub conservation_rel_err: f64,
}

/// The full two-run comparison `ewatt lab` prints and asserts.
#[derive(Debug)]
pub struct LabReport {
    pub blind: LabRun,
    pub aware: LabRun,
    /// The budgets the class-aware run is judged against.
    pub slos: ClassSlos,
    pub arrivals: Vec<Arrival>,
    pub seed: u64,
}

/// Reduce one traced run to per-class J/req and exact tail latencies.
/// Energy comes from the finalize-time bills ([`FleetOutcome::joules`]),
/// grouped by each arrival's class; latency comes from the `served` spans.
fn summarize(arrivals: &[Arrival], outcome: FleetOutcome, spans: &[Span]) -> LabRun {
    let zero = ClassStats { requests: 0, total_j: 0.0, ttft_p95_s: f64::NAN, e2e_p99_s: f64::NAN };
    let mut by_class = [zero; 3];
    for (req, a) in arrivals.iter().enumerate() {
        let s = &mut by_class[a.class.slot()];
        s.requests += 1;
        s.total_j += outcome.joules[req];
    }
    let mut ttft: [Vec<f64>; 3] = Default::default();
    let mut e2e: [Vec<f64>; 3] = Default::default();
    for s in spans {
        if let SpanEvent::Served { class, ttft_s, e2e_s, .. } = s.event {
            ttft[class.slot()].push(ttft_s);
            e2e[class.slot()].push(e2e_s);
        }
    }
    for (i, s) in by_class.iter_mut().enumerate() {
        s.ttft_p95_s = exact_quantile(&ttft[i], 0.95);
        s.e2e_p99_s = exact_quantile(&e2e[i], 0.99);
    }
    let class_sum: f64 = by_class.iter().map(|s| s.total_j).sum();
    let total = outcome.total_j();
    let conservation_rel_err = (class_sum - total).abs() / total.max(f64::MIN_POSITIVE);
    LabRun { outcome, by_class, conservation_rel_err }
}

/// Serve the lab trace once. `classes == None` is the class-blind
/// baseline (least-loaded routing, FIFO admission); `Some` attaches the
/// policy and the class-aware router.
fn run_one(
    gpu: &GpuSpec,
    suite: &ReplaySuite,
    arrivals: &[Arrival],
    classes: Option<ClassPolicy>,
) -> Result<LabRun> {
    let gov = DvfsPolicy::governed(gpu);
    let mut router: Box<dyn FleetRouter> = match &classes {
        Some(_) => Box::new(ClassAware::default()),
        None => Box::new(LeastLoaded),
    };
    let mut builder = FleetConfig::builder().replicas(2, ReplicaSpec::tiered(ModelTier::B8, gov));
    if let Some(policy) = classes {
        builder = builder.classes(policy);
    }
    let cfg = builder.build()?;
    let mut rec = Recorder::default();
    let outcome = FleetSim::new(gpu.clone(), cfg)
        .run_traced(suite, arrivals, router.as_mut(), &mut rec)
        .context("workload lab run")?;
    Ok(summarize(arrivals, outcome, &rec.spans))
}

/// The workload every lab invocation replays (same fixture as the golden
/// scenarios, so lab results and scenario traces are comparable).
pub fn lab_suite() -> ReplaySuite {
    ReplaySuite::quick(17, 24)
}

/// Run the two-sided comparison on one mixed-class trace.
pub fn execute(gpu: &GpuSpec, requests: usize, seed: u64) -> Result<LabReport> {
    let suite = lab_suite();
    let arrivals = ClassMix::default().generate(&suite, requests, seed);
    let policy = ClassPolicy::default();
    let slos = policy.slos;
    let blind = run_one(gpu, &suite, &arrivals, None)?;
    let aware = run_one(gpu, &suite, &arrivals, Some(policy))?;
    Ok(LabReport { blind, aware, slos, arrivals, seed })
}

impl LabReport {
    /// The headline bar, as a hard assertion: class-aware admission +
    /// governance must strictly lower Batch and Background J/req vs the
    /// class-blind governed baseline while Interactive stays within its
    /// own budgets, and both runs' class partitions must conserve energy.
    pub fn check(&self) -> Result<()> {
        for (label, run) in [("class-blind", &self.blind), ("class-aware", &self.aware)] {
            ensure!(
                run.conservation_rel_err <= 1e-6,
                "{label}: per-class bills sum off the ledger by {:.3e}",
                run.conservation_rel_err
            );
            for c in TrafficClass::ALL {
                ensure!(
                    run.by_class[c.slot()].requests > 0,
                    "{label}: trace carries no {} requests",
                    c.label()
                );
            }
        }
        for c in [TrafficClass::Batch, TrafficClass::Background] {
            let (b, a) = (&self.blind.by_class[c.slot()], &self.aware.by_class[c.slot()]);
            ensure!(
                a.j_per_req() < b.j_per_req(),
                "class-aware must lower {} J/req: blind {:.2}, aware {:.2}",
                c.label(),
                b.j_per_req(),
                a.j_per_req()
            );
        }
        let i = &self.aware.by_class[TrafficClass::Interactive.slot()];
        let budget = self.slos.interactive;
        ensure!(
            i.ttft_p95_s <= budget.ttft_p95_s,
            "interactive p95 TTFT {:.3} s blew the {:.3} s budget",
            i.ttft_p95_s,
            budget.ttft_p95_s
        );
        ensure!(
            i.e2e_p99_s <= budget.e2e_p99_s,
            "interactive p99 e2e {:.3} s blew the {:.3} s budget",
            i.e2e_p99_s,
            budget.e2e_p99_s
        );
        Ok(())
    }

    /// Render the per-class comparison table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "workload lab: {} mixed-class requests (seed {:#x}), same governed fleet twice",
            self.arrivals.len(),
            self.seed
        );
        let _ = writeln!(
            out,
            "{:12} {:>4} {:>14} {:>14} {:>8} {:>18} {:>18}",
            "class", "n", "blind J/req", "aware J/req", "ΔJ/req", "aware ttft p95", "aware e2e p99"
        );
        for c in TrafficClass::ALL {
            let b = &self.blind.by_class[c.slot()];
            let a = &self.aware.by_class[c.slot()];
            let slo = self.slos.for_class(c);
            let _ = writeln!(
                out,
                "{:12} {:>4} {:>14.2} {:>14.2} {:>8.2} {:>10.3}s ≤{:5.1} {:>10.3}s ≤{:5.1}",
                c.label(),
                a.requests,
                b.j_per_req(),
                a.j_per_req(),
                a.j_per_req() - b.j_per_req(),
                a.ttft_p95_s,
                slo.ttft_p95_s,
                a.e2e_p99_s,
                slo.e2e_p99_s
            );
        }
        let (bj, aj) = (self.blind.outcome.total_j(), self.aware.outcome.total_j());
        let _ = writeln!(
            out,
            "fleet: blind {:.0} J / {:.2} s makespan — aware {:.0} J / {:.2} s makespan",
            bj,
            self.blind.outcome.makespan_s,
            aj,
            self.aware.outcome.makespan_s
        );
        let _ = writeln!(
            out,
            "per-class conservation vs ledger: blind {:.1e}, aware {:.1e}",
            self.blind.conservation_rel_err, self.aware.conservation_rel_err
        );
        out
    }
}

/// The lab trace as `prompts.jsonl`: one LF-terminated line per request
/// (`t_s`, `class`, `query_idx`, `dataset`, `output_tokens`), in arrival
/// order. Byte-deterministic under a fixed seed.
pub fn prompts_jsonl(suite: &ReplaySuite, arrivals: &[Arrival]) -> String {
    let mut out = String::new();
    for a in arrivals {
        let q = &suite.queries[a.query_idx];
        let line = obj(vec![
            ("t_s", num(a.t_s)),
            ("class", text(a.class.label())),
            ("query_idx", uint(a.query_idx)),
            ("dataset", text(q.dataset.label())),
            ("output_tokens", uint(q.output_tokens)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// `ewatt lab [--requests N] [--seed S] [--out DIR]`: print the table,
/// optionally write `prompts.jsonl`, then enforce [`LabReport::check`].
pub fn run_cli(args: &Args) -> Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let requests = args.get_usize("requests", DEFAULT_REQUESTS);
    let seed = args.get_u64("seed", DEFAULT_SEED);
    let report = execute(&gpu, requests, seed)?;
    print!("{}", report.render());
    if let Some(dir) = args.get("out") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join("prompts.jsonl");
        write_prompts(&path, &report)?;
        println!("wrote {}", path.display());
    }
    report.check()?;
    println!("lab bar holds: class-aware beats class-blind on Batch/Background J/req");
    Ok(())
}

fn write_prompts(path: &Path, report: &LabReport) -> Result<()> {
    let body = prompts_jsonl(&lab_suite(), &report.arrivals);
    std::fs::write(path, body).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_aware_governance_beats_class_blind_on_the_mixed_trace() {
        // The PR's headline, pinned at the default lab configuration.
        let gpu = GpuSpec::rtx_pro_6000();
        let report = execute(&gpu, DEFAULT_REQUESTS, DEFAULT_SEED).unwrap();
        report.check().unwrap();
        // Both runs served the full trace.
        assert_eq!(report.blind.outcome.served, DEFAULT_REQUESTS);
        assert_eq!(report.aware.outcome.served, DEFAULT_REQUESTS);
        // The table renders every class row.
        let table = report.render();
        for c in TrafficClass::ALL {
            assert!(table.contains(c.label()), "{table}");
        }
    }

    #[test]
    fn prompts_jsonl_is_deterministic_lf_only_and_complete() {
        let suite = lab_suite();
        let arrivals = ClassMix::default().generate(&suite, 40, DEFAULT_SEED);
        let a = prompts_jsonl(&suite, &arrivals);
        let b = prompts_jsonl(&suite, &arrivals);
        assert_eq!(a, b);
        assert!(!a.contains('\r'), "prompts.jsonl must be LF-only");
        assert_eq!(a.lines().count(), 40);
        // Every line round-trips as JSON carrying the class tag.
        for (line, arr) in a.lines().zip(&arrivals) {
            let v = crate::util::json::JsonValue::parse(line).unwrap();
            assert_eq!(
                v.get("class").and_then(crate::util::json::JsonValue::as_str),
                Some(arr.class.label())
            );
        }
    }
}
