//! Tables XI–XIV: the DVFS characterization (Section VI).

use anyhow::Result;

use crate::config::ModelTier;
use crate::perf::energy::{pct_change, pct_savings};
use crate::perf::edp;
use crate::workload::Dataset;

use super::context::{CellKey, Context};
use super::report::{pct, pct0, Report};

/// Table XI: DVFS at 180 MHz vs baseline (2842 MHz), 5 models × 3 batches.
pub fn table11(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-11",
        "DVFS results at 180 MHz vs baseline 2842 MHz",
        &["Model", "B", "E down", "L delta", "Pre delta", "Dec delta", "Pre%", "Dec%"],
    );
    let mut batch_acc: Vec<(usize, Vec<f64>, Vec<f64>)> = ctx
        .cfg
        .batch_sizes
        .iter()
        .map(|&b| (b, Vec::new(), Vec::new()))
        .collect();
    for tier in ModelTier::ALL {
        for &b in &ctx.cfg.batch_sizes {
            let hi = ctx.baseline_cell(tier, b, None)?;
            let lo = ctx.cell(CellKey { tier, batch: b, freq: ctx.gpu.f_min_mhz(), dataset: None })?;
            let e_down = pct_savings(lo.energy_j, hi.energy_j);
            let l_delta = pct_change(lo.latency_s, hi.latency_s);
            let pre_delta = pct_change(lo.prefill_s, hi.prefill_s);
            let dec_delta = if hi.decode_s > 0.0 {
                pct_change(lo.decode_s, hi.decode_s)
            } else {
                0.0
            };
            r.row(vec![
                format!("Llama/Qwen-{}", tier.label()),
                b.to_string(),
                pct0(e_down),
                pct(l_delta),
                pct(pre_delta),
                pct(dec_delta),
                pct0(100.0 * hi.prefill_s / hi.latency_s),
                pct0(100.0 * hi.decode_s / hi.latency_s),
            ]);
            let acc = batch_acc.iter_mut().find(|(bb, ..)| *bb == b).unwrap();
            acc.1.push(e_down);
            acc.2.push(l_delta);
        }
    }
    for (b, es, ls) in batch_acc {
        r.row(vec![
            format!("Avg B={b}"),
            b.to_string(),
            pct0(es.iter().sum::<f64>() / es.len() as f64),
            pct(ls.iter().sum::<f64>() / ls.len() as f64),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
    }
    r.note("paper: E down 39.9-44.2% every cell; dec delta within ±1%; pre delta falls with size and batch");
    Ok(r)
}

/// Table XII: EDP-optimal frequency by model and batch size.
pub fn table12(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-12",
        "Optimal EDP frequency by model and batch (vs 2842 MHz)",
        &["Model", "B", "Freq (MHz)", "E down", "L delta"],
    );
    for tier in ModelTier::ALL {
        for &b in &ctx.cfg.batch_sizes {
            let base = ctx.baseline_cell(tier, b, None)?;
            let base_edp = edp(base.energy_j, base.latency_s);
            let mut best = (ctx.gpu.f_max_mhz, base_edp, 0.0, 0.0);
            for &f in &ctx.gpu.freq_levels_mhz {
                let m = ctx.cell(CellKey { tier, batch: b, freq: f, dataset: None })?;
                let e = edp(m.energy_j, m.latency_s);
                if e < best.1 {
                    best = (
                        f,
                        e,
                        pct_savings(m.energy_j, base.energy_j),
                        pct_change(m.latency_s, base.latency_s),
                    );
                }
            }
            r.row(vec![
                format!("Llama/Qwen-{}", tier.label()),
                b.to_string(),
                best.0.to_string(),
                pct0(best.2),
                pct(best.3),
            ]);
        }
    }
    r.note("paper: optimum ~960 MHz at B=1 for all models; 42-63% savings");
    Ok(r)
}

/// Table XIII: DVFS effectiveness by output length and model size (B=1,
/// 180 MHz vs baseline).
pub fn table13(ctx: &Context) -> Result<Report> {
    let mut r = Report::new(
        "table-13",
        "DVFS effectiveness by output length and model size (180 MHz, B=1)",
        &["Slice", "E down", "L up", "Paper E down", "Paper L up"],
    );
    // Per dataset (averaged over models), as the left half of the table.
    let paper_ds = [
        (Dataset::BoolQ, "41.5%", "+7.5%"),
        (Dataset::HellaSwag, "42.6%", "+4.1%"),
        (Dataset::TruthfulQa, "42.9%", "+0.9%"),
        (Dataset::NarrativeQa, "43.1%", "+0.9%"),
    ];
    for (d, pe, pl) in paper_ds {
        let mut e_acc = 0.0;
        let mut l_acc = 0.0;
        for tier in ModelTier::ALL {
            let hi = ctx.baseline_cell(tier, 1, Some(d))?;
            let lo = ctx.cell(CellKey { tier, batch: 1, freq: 180, dataset: Some(d) })?;
            e_acc += pct_savings(lo.energy_j, hi.energy_j) / 5.0;
            l_acc += pct_change(lo.latency_s, hi.latency_s) / 5.0;
        }
        r.row(vec![
            format!("{} ({})", d.label(), if d.task() == crate::workload::TaskKind::Classification { "LL" } else { "gen" }),
            pct0(e_acc),
            pct(l_acc),
            pe.to_string(),
            pl.to_string(),
        ]);
    }
    // Per size group (full suite).
    let groups: [(&str, &[ModelTier], &str); 3] = [
        ("Small (1-3B)", &[ModelTier::B1, ModelTier::B3], "+4.8%"),
        ("Medium (8B)", &[ModelTier::B8], "+2.5%"),
        ("Large (14-32B)", &[ModelTier::B14, ModelTier::B32], "+0.6%"),
    ];
    for (name, tiers, pl) in groups {
        let mut e_acc = 0.0;
        let mut l_acc = 0.0;
        for &tier in tiers {
            let hi = ctx.baseline_cell(tier, 1, None)?;
            let lo = ctx.cell(CellKey { tier, batch: 1, freq: 180, dataset: None })?;
            e_acc += pct_savings(lo.energy_j, hi.energy_j) / tiers.len() as f64;
            l_acc += pct_change(lo.latency_s, hi.latency_s) / tiers.len() as f64;
        }
        r.row(vec![name.to_string(), pct0(e_acc), pct(l_acc), "41-44%".into(), pl.to_string()]);
    }
    Ok(r)
}

/// Table XIV: summary of phase-level DVFS effects.
pub fn table14(ctx: &Context) -> Result<Report> {
    // Derived from the same cells as XI/XII.
    let mut e_all = Vec::new();
    let mut l_all = Vec::new();
    let mut dec_share = Vec::new();
    for tier in ModelTier::ALL {
        let hi = ctx.baseline_cell(tier, 1, None)?;
        let lo = ctx.cell(CellKey { tier, batch: 1, freq: 180, dataset: None })?;
        e_all.push(pct_savings(lo.energy_j, hi.energy_j));
        l_all.push(pct_change(lo.latency_s, hi.latency_s));
        dec_share.push(100.0 * hi.decode_s / hi.latency_s);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let min = |v: &[f64]| v.iter().cloned().fold(f64::MAX, f64::min);
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);

    let mut r = Report::new(
        "table-14",
        "Summary of phase-level DVFS effects (B=1)",
        &["Aspect", "Observation", "Paper"],
    );
    r.row(vec![
        "Energy savings @180MHz".to_string(),
        format!("{:.1}-{:.1}% (avg {:.1}%)", min(&e_all), max(&e_all), mean(&e_all)),
        "40-44% (avg 42%)".to_string(),
    ]);
    r.row(vec![
        "Latency change".to_string(),
        format!("{:+.1}..{:+.1}%", min(&l_all), max(&l_all)),
        "+1-3%".to_string(),
    ]);
    r.row(vec![
        "Decode time fraction".to_string(),
        format!("{:.0}-{:.0}%", min(&dec_share), max(&dec_share)),
        "77-91%".to_string(),
    ]);
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::quick(107, 24)
    }

    #[test]
    fn table11_bands_hold() {
        let c = ctx();
        let r = table11(&c).unwrap();
        // 15 model×batch rows + 3 averages.
        assert_eq!(r.rows.len(), 18);
        for row in &r.rows[..15] {
            let e: f64 = row[2].trim_end_matches('%').parse().unwrap();
            assert!((30.0..=55.0).contains(&e), "E down out of band: {row:?}");
            let dec: f64 = row[5].trim_start_matches('+').trim_end_matches('%').parse().unwrap();
            assert!(dec.abs() < 2.0, "decode delta out of band: {row:?}");
        }
    }

    #[test]
    fn table12_optimum_below_fmax() {
        let c = ctx();
        let r = table12(&c).unwrap();
        for row in &r.rows {
            let f: u32 = row[2].parse().unwrap();
            assert!(f < 2842, "EDP optimum should be below fmax: {row:?}");
            let e: f64 = row[3].trim_end_matches('%').parse().unwrap();
            assert!(e > 25.0, "optimum saves energy: {row:?}");
        }
    }

    #[test]
    fn table13_long_output_has_small_latency_penalty() {
        let c = ctx();
        let r = table13(&c).unwrap();
        let get = |name: &str| -> f64 {
            r.rows
                .iter()
                .find(|row| row[0].starts_with(name))
                .unwrap()[2]
                .trim_start_matches('+')
                .trim_end_matches('%')
                .parse()
                .unwrap()
        };
        // Generation datasets (decode-dominated) see much smaller latency
        // penalties than the classification (prefill-only) datasets.
        assert!(get("NarrativeQA") < get("BoolQ"));
        assert!(get("TruthfulQA") < get("HellaSwag"));
        assert!(get("NarrativeQA") < 2.0);
        // Size groups: penalty falls with size.
        assert!(get("Large") <= get("Small"));
    }
}
