//! Experiment harness: one regenerator per paper table (I–XVIII) and figure
//! (2–7), sharing a memoised measurement context. See DESIGN.md §6 for the
//! per-experiment acceptance bands; `rust/tests/calibration.rs` asserts them.

pub mod ablations;
pub mod autoscale_tables;
pub mod casestudy;
pub mod context;
pub mod dvfs_tables;
pub mod engine_bench;
pub mod figures;
pub mod fleet_tables;
pub mod forecast_tables;
pub mod quality_tables;
pub mod report;
pub mod runner;
pub mod scenarios;
pub mod slo_tables;
pub mod trace;
pub mod workload_lab;
pub mod workload_tables;

pub use context::Context;
pub use report::Report;
pub use runner::{run_all, run_figure, run_table};
