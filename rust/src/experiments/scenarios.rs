//! The named scenario registry.
//!
//! Eight seeded serving scenarios spanning the stack — traffic shapes
//! (Poisson / bursty / diurnal / mixed-class) × fleets (one-replica,
//! mixed-tier, elastic, failing, migrating) × policies (static /
//! governed / class-aware). They were born as
//! fixtures of the golden-trace regression suite
//! (`rust/tests/scenarios.rs`, which still pins them against
//! `scenarios.snap`); they live in the library so `ewatt trace` can
//! replay any of them by name with a [`TraceSink`] attached. The configs
//! here are **pinned**: changing one invalidates the blessed snapshot and
//! must be re-blessed deliberately.

use anyhow::{Context as _, Result};

use crate::config::{GpuSpec, ModelTier};
use crate::coordinator::DvfsPolicy;
use crate::fleet::{
    ClassAware, ClassPolicy, DifficultyTiered, EnergyAware, FailureConfig, FleetConfig,
    FleetOutcome, FleetRouter, FleetSim, LeastLoaded, MigrationPolicy, ReactiveConfig, ReplicaSpec,
    ReplicaState, RoundRobin,
};
use crate::obs::{TimelineSampler, TraceSink};
use crate::serve::traffic::{Arrival, ClassMix};
use crate::serve::TrafficPattern;
use crate::workload::ReplaySuite;

/// One pinned scenario: name, fleet, router factory, traffic, request
/// count, arrival seed.
pub struct Scenario {
    pub name: &'static str,
    pub cfg: FleetConfig,
    pub router: fn() -> Box<dyn FleetRouter>,
    pub pattern: TrafficPattern,
    pub requests: usize,
    pub seed: u64,
}

impl Scenario {
    /// The workload every scenario replays (seed and size are part of the
    /// pinned fixture).
    pub fn suite() -> ReplaySuite {
        ReplaySuite::quick(17, 24)
    }

    /// The scenario's seeded arrival stream.
    pub fn arrivals(&self, suite: &ReplaySuite) -> Vec<Arrival> {
        self.pattern.generate(suite, self.requests, self.seed)
    }

    /// Replay the scenario (untraced).
    pub fn run(&self, gpu: &GpuSpec, suite: &ReplaySuite) -> Result<FleetOutcome> {
        let arrivals = self.arrivals(suite);
        let mut router = (self.router)();
        FleetSim::new(gpu.clone(), self.cfg.clone())
            .run(suite, &arrivals, router.as_mut())
            .with_context(|| format!("scenario {}", self.name))
    }

    /// Replay the scenario with a [`TraceSink`] attached. Physics is
    /// bit-identical to [`Scenario::run`].
    pub fn run_traced(
        &self,
        gpu: &GpuSpec,
        suite: &ReplaySuite,
        sink: &mut dyn TraceSink,
    ) -> Result<FleetOutcome> {
        let arrivals = self.arrivals(suite);
        let mut router = (self.router)();
        FleetSim::new(gpu.clone(), self.cfg.clone())
            .run_traced(suite, &arrivals, router.as_mut(), sink)
            .with_context(|| format!("scenario {}", self.name))
    }

    /// Replay the scenario with both a [`TraceSink`] and a heartbeat
    /// [`TimelineSampler`] attached. Physics is bit-identical to
    /// [`Scenario::run`] (pinned by `rust/tests/obs_trace.rs`).
    pub fn run_observed(
        &self,
        gpu: &GpuSpec,
        suite: &ReplaySuite,
        sink: &mut dyn TraceSink,
        timeline: &mut TimelineSampler,
    ) -> Result<FleetOutcome> {
        let arrivals = self.arrivals(suite);
        let mut router = (self.router)();
        FleetSim::new(gpu.clone(), self.cfg.clone())
            .run_observed(suite, &arrivals, router.as_mut(), sink, timeline)
            .with_context(|| format!("scenario {}", self.name))
    }

    /// Canonical text of everything that determines this scenario's
    /// outcome — the input to the manifest's config digest. Two runs with
    /// equal canonical text are replays of the same experiment.
    pub fn canonical(&self) -> String {
        format!(
            "scenario={}\ncfg={:?}\nrouter={}\npattern={:?}\nrequests={}\nseed={:#x}\n\
             suite=ReplaySuite::quick(17,24)\n",
            self.name,
            self.cfg,
            (self.router)().label(),
            self.pattern,
            self.requests,
            self.seed,
        )
    }
}

/// Every pinned scenario, in snapshot order.
pub fn all(gpu: &GpuSpec) -> Vec<Scenario> {
    let gov = DvfsPolicy::governed(gpu);
    let stat = DvfsPolicy::Static(gpu.f_max_mhz);
    let tiered = |n: usize, tier, p| {
        FleetConfig::builder().replicas(n, ReplicaSpec::tiered(tier, p)).build().unwrap()
    };
    let mixed = |p| {
        FleetConfig::builder()
            .replicas(2, ReplicaSpec::tiered(ModelTier::B3, p))
            .replicas(2, ReplicaSpec::tiered(ModelTier::B14, p))
            .build()
            .unwrap()
    };
    let elastic = |failures: Option<FailureConfig>| {
        let live = ReplicaSpec::tiered(ModelTier::B8, gov);
        let cold = ReplicaSpec { state: ReplicaState::Cold, ..live.clone() };
        let mut b = FleetConfig::builder()
            .replica(live)
            .replicas(2, cold)
            .reactive(ReactiveConfig { min_live: 1, max_live: 3, ..ReactiveConfig::default() });
        if let Some(f) = failures {
            b = b.failures(f);
        }
        b.build().unwrap()
    };
    vec![
        Scenario {
            name: "poisson-1rep-static",
            cfg: tiered(1, ModelTier::B8, stat),
            router: || Box::new(RoundRobin::default()),
            pattern: TrafficPattern::Poisson { rps: 1.5 },
            requests: 48,
            seed: 0x5CE1,
        },
        Scenario {
            name: "poisson-1rep-governed",
            cfg: tiered(1, ModelTier::B8, gov),
            router: || Box::new(RoundRobin::default()),
            pattern: TrafficPattern::Poisson { rps: 1.5 },
            requests: 48,
            seed: 0x5CE1,
        },
        Scenario {
            name: "bursty-tiered-governed-difficulty",
            cfg: mixed(gov),
            router: || Box::new(DifficultyTiered::default()),
            pattern: TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 8.0, mean_dwell_s: 3.0 },
            requests: 72,
            seed: 0x5CE2,
        },
        Scenario {
            name: "bursty-tiered-static-energy-aware",
            cfg: mixed(stat),
            router: || Box::new(EnergyAware::default()),
            pattern: TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 8.0, mean_dwell_s: 3.0 },
            requests: 72,
            seed: 0x5CE2,
        },
        Scenario {
            name: "diurnal-elastic-autoscaled",
            cfg: elastic(None),
            router: || Box::new(LeastLoaded),
            pattern: TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 4.0, period_s: 90.0 },
            requests: 160,
            seed: 0x5CE3,
        },
        Scenario {
            name: "diurnal-elastic-failures",
            cfg: elastic(Some(FailureConfig { mtbf_s: 60.0, mttr_s: 15.0, seed: 0xFA11 })),
            router: || Box::new(LeastLoaded),
            pattern: TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 4.0, period_s: 90.0 },
            requests: 160,
            seed: 0x5CE3,
        },
        Scenario {
            name: "mixed-class-aware",
            cfg: FleetConfig::builder()
                .replicas(2, ReplicaSpec::tiered(ModelTier::B8, gov))
                .classes(ClassPolicy::default())
                .build()
                .unwrap(),
            router: || Box::new(ClassAware::default()),
            pattern: TrafficPattern::MixedClasses { mix: ClassMix::default() },
            requests: 48,
            seed: 0x5CE4,
        },
        Scenario {
            name: "diurnal-elastic-migration",
            cfg: {
                let live = ReplicaSpec::tiered(ModelTier::B8, gov);
                let cold = ReplicaSpec { state: ReplicaState::Cold, ..live.clone() };
                FleetConfig::builder()
                    .replica(live)
                    .replicas(2, cold)
                    .reactive(ReactiveConfig {
                        min_live: 1,
                        max_live: 3,
                        ..ReactiveConfig::default()
                    })
                    .failures(FailureConfig { mtbf_s: 60.0, mttr_s: 15.0, seed: 0xFA11 })
                    .migration(MigrationPolicy::default())
                    .build()
                    .unwrap()
            },
            router: || Box::new(LeastLoaded),
            pattern: TrafficPattern::Diurnal { min_rps: 0.3, max_rps: 4.0, period_s: 90.0 },
            requests: 160,
            seed: 0x5CE3,
        },
    ]
}

/// Look one scenario up by name; the error lists what exists.
pub fn by_name(gpu: &GpuSpec, name: &str) -> Result<Scenario> {
    let names: Vec<&str> = all(gpu).iter().map(|s| s.name).collect();
    all(gpu)
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown scenario {name:?} — available: {}", names.join(", ")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let gpu = GpuSpec::rtx_pro_6000();
        let scenarios = all(&gpu);
        assert_eq!(scenarios.len(), 8);
        for (i, a) in scenarios.iter().enumerate() {
            for b in &scenarios[i + 1..] {
                assert_ne!(a.name, b.name);
            }
            assert_eq!(by_name(&gpu, a.name).unwrap().name, a.name);
        }
        let err = by_name(&gpu, "nope").unwrap_err().to_string();
        assert!(err.contains("poisson-1rep-static"), "error must list scenarios: {err}");
    }

    #[test]
    fn canonical_text_distinguishes_scenarios_and_is_stable() {
        let gpu = GpuSpec::rtx_pro_6000();
        let scenarios = all(&gpu);
        let texts: Vec<String> = scenarios.iter().map(Scenario::canonical).collect();
        for (i, a) in texts.iter().enumerate() {
            assert_eq!(a, &all(&gpu)[i].canonical(), "canonical text must be deterministic");
            for b in &texts[i + 1..] {
                assert_ne!(a, b);
            }
        }
        assert!(texts[0].contains("seed=0x5ce1"));
    }
}
