//! # ewatt — energy/performance characterization of LLM inference under GPU DVFS
//!
//! Reproduction of *"Characterizing LLM Inference Energy-Performance Tradeoffs
//! across Workloads and GPU Scaling"* (Maliakel, Ilager, Brandic — CS.LG 2025)
//! as a three-layer Rust + JAX + Pallas framework.
//!
//! The crate is organized bottom-up (see DESIGN.md §4):
//!
//! - substrates: [`text`], [`features`], [`stats`], [`workload`], [`quality`]
//! - hardware model: [`gpu`] (DVFS/power/telemetry simulator), [`perf`]
//!   (roofline + host-overhead phase cost model)
//! - execution: [`engine`] (two-phase inference engine), [`runtime`]
//!   (PJRT loader/executor for the AOT artifacts)
//! - the paper's pipeline: [`coordinator`] (router + phase-aware DVFS
//!   policies) and [`experiments`] (every table/figure regenerator)
//! - serving under traffic: [`serve`] (arrival processes, SLO tracking,
//!   and the closed-loop DVFS governor driving the event-driven serving
//!   simulator — the online version of the paper's Section VII case study)
//! - fleet serving: [`fleet`] (heterogeneous governed replica fleets with
//!   difficulty- and energy-aware routing, and per-request energy
//!   attribution — Section VII's routing × DVFS co-design run closed-loop)
//! - observability: [`obs`] (deterministic request-span tracing, metrics
//!   registry, and auditable `traces.jsonl` + manifest exporters)

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod features;
pub mod fleet;
pub mod gpu;
pub mod obs;
pub mod perf;
pub mod quality;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod text;
pub mod util;
pub mod workload;

/// Canonical deterministic RNG used across the crate (replayable studies).
pub type Rng = util::rng::Rng;

/// Build a seeded [`Rng`]; every experiment derives all randomness from an
/// explicit seed so runs are exactly reproducible.
pub fn rng(seed: u64) -> Rng {
    util::rng::Rng::seed_from_u64(seed)
}
