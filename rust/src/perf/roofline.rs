//! Roofline + host-overhead timing at a given SM frequency.

use crate::config::{FreqMHz, GpuSpec};

use super::costmodel::PhaseCost;

/// Timing decomposition of one phase step at one frequency.
#[derive(Debug, Clone, Copy)]
pub struct PhaseBreakdown {
    /// CPU-side time (launches + framework), frequency-independent.
    pub t_host: f64,
    /// GPU busy time.
    pub t_gpu: f64,
    /// Fraction of GPU time the compute pipeline is the constraint.
    pub u_comp: f64,
    /// Fraction of GPU time memory bandwidth is utilized.
    pub u_mem: f64,
    /// Clock-sensitivity exponent used (diagnostic).
    pub eta: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.t_host + self.t_gpu
    }
}

/// Occupancy-scaled clock-sensitivity exponent (DESIGN.md §5): small
/// work-shapes are DRAM-latency-bound and respond sub-linearly to SM clock.
pub fn eta(gpu: &GpuSpec, cost: &PhaseCost) -> f64 {
    let parallelism = (cost.rows * cost.width).max(1.0);
    (gpu.clock_sens_coeff / parallelism.powf(gpu.clock_sens_pow)).min(1.0)
}

/// Time one phase step at SM frequency `f`.
pub fn phase_time(gpu: &GpuSpec, cost: &PhaseCost, f: FreqMHz) -> PhaseBreakdown {
    let t_host = gpu.t_framework_s
        + cost.n_layers as f64 * gpu.kernels_per_layer * gpu.t_launch_s
        + cost.batch as f64 * gpu.t_host_per_seq_s;
    let t_mem = cost.mem_bytes / gpu.mem_bw_bytes;
    let t_comp_fmax = cost.flops / gpu.peak_flops_fp16;
    let e = eta(gpu, cost);
    let ratio = gpu.f_max_mhz as f64 / f as f64;
    let t_comp = t_comp_fmax * ratio.powf(e);
    let t_gpu = t_comp.max(t_mem);
    PhaseBreakdown {
        t_host,
        t_gpu,
        u_comp: (t_comp / t_gpu).min(1.0),
        u_mem: (t_mem / t_gpu).min(1.0),
        eta: e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::perf::costmodel::{decode_step_cost, prefill_cost};

    fn gpu() -> GpuSpec {
        GpuSpec::rtx_pro_6000()
    }

    #[test]
    fn decode_latency_is_frequency_insensitive() {
        // The paper's core observation (Table XI: decode Δ within ±1%).
        let g = gpu();
        for tier in ModelTier::ALL {
            let m = model_for_tier(tier);
            for batch in [1usize, 4, 8] {
                let c = decode_step_cost(&m, batch, 128);
                let hi = phase_time(&g, &c, g.f_max_mhz).total();
                let lo = phase_time(&g, &c, g.f_min_mhz()).total();
                let delta = (lo - hi) / hi;
                assert!(
                    delta.abs() < 0.02,
                    "{} b{batch}: decode Δ {delta:+.3}",
                    m.name
                );
            }
        }
    }

    #[test]
    fn prefill_slows_at_min_frequency_and_less_for_big_models() {
        let g = gpu();
        let mut prev_delta = f64::INFINITY;
        for tier in ModelTier::ALL {
            let m = model_for_tier(tier);
            let c = prefill_cost(&m, 1, 100);
            let hi = phase_time(&g, &c, g.f_max_mhz).total();
            let lo = phase_time(&g, &c, 180).total();
            let delta = (lo - hi) / hi;
            assert!(
                delta > 0.005 && delta < 0.80,
                "{}: prefill Δ {delta:+.3} out of band",
                m.name
            );
            assert!(
                delta < prev_delta,
                "{}: prefill sensitivity should fall with size",
                m.name
            );
            prev_delta = delta;
        }
    }

    #[test]
    fn prefill_sensitivity_falls_with_batch() {
        let g = gpu();
        let m = model_for_tier(ModelTier::B1);
        let delta = |b: usize| {
            let c = prefill_cost(&m, b, 100);
            let hi = phase_time(&g, &c, g.f_max_mhz).total();
            let lo = phase_time(&g, &c, 180).total();
            (lo - hi) / hi
        };
        assert!(delta(8) < delta(4));
        assert!(delta(4) < delta(1));
    }

    #[test]
    fn latency_is_monotone_nonincreasing_in_frequency() {
        let g = gpu();
        let m = model_for_tier(ModelTier::B3);
        let c = prefill_cost(&m, 1, 200);
        let mut prev = f64::INFINITY;
        for &f in &g.freq_levels_mhz {
            let t = phase_time(&g, &c, f).total();
            assert!(t <= prev * 1.0000001, "t({f}) = {t} > t(prev) = {prev}");
            prev = t;
        }
    }

    #[test]
    fn eta_decreases_with_parallelism() {
        let g = gpu();
        let m1 = model_for_tier(ModelTier::B1);
        let small = decode_step_cost(&m1, 1, 64);
        let big = prefill_cost(&m1, 8, 512);
        assert!(eta(&g, &small) > eta(&g, &big));
        assert!(eta(&g, &small) <= 1.0);
        assert!(eta(&g, &big) > 0.0);
    }

    #[test]
    fn utilizations_are_fractions() {
        let g = gpu();
        let m = model_for_tier(ModelTier::B14);
        for c in [prefill_cost(&m, 4, 300), decode_step_cost(&m, 4, 300)] {
            for &f in &g.freq_levels_mhz {
                let b = phase_time(&g, &c, f);
                assert!(b.u_comp > 0.0 && b.u_comp <= 1.0);
                assert!(b.u_mem > 0.0 && b.u_mem <= 1.0);
                assert!(b.u_comp == 1.0 || b.u_mem == 1.0); // one binds
            }
        }
    }
}
