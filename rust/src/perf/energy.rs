//! Energy metrics (Section IV-D): per-request joules and the energy-delay
//! product used to find the frequency sweet spot (Table XII).

/// Energy-delay product: EDP = energy × latency.
pub fn edp(energy_j: f64, latency_s: f64) -> f64 {
    energy_j * latency_s
}

/// Percent change of `new` vs `baseline` (positive = increase).
pub fn pct_change(new: f64, baseline: f64) -> f64 {
    100.0 * (new - baseline) / baseline
}

/// Percent reduction of `new` vs `baseline` (positive = savings).
pub fn pct_savings(new: f64, baseline: f64) -> f64 {
    100.0 * (baseline - new) / baseline
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edp_is_product() {
        assert_eq!(edp(2.0, 3.0), 6.0);
    }

    #[test]
    fn pct_helpers() {
        assert!((pct_change(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((pct_savings(58.0, 100.0) - 42.0).abs() < 1e-12);
    }
}
