//! FLOP/byte accounting per inference phase for decoder-only transformers.

use crate::config::ModelSpec;

/// Work description of one GPU phase step (prefill pass or one decode step).
#[derive(Debug, Clone, Copy)]
pub struct PhaseCost {
    /// Total floating-point operations.
    pub flops: f64,
    /// Total HBM traffic in bytes (weights + KV cache + activations).
    pub mem_bytes: f64,
    /// Rows of work in flight (batch × tokens processed this step) — the
    /// occupancy driver for the clock-sensitivity model.
    pub rows: f64,
    /// Effective model width √(d_model·d_ff) — occupancy's second axis
    /// (the FFN GEMMs dominate per-layer work, so wider FFNs parallelize
    /// further and reduce clock sensitivity; cf. Qwen2.5-32B's 27k d_ff).
    pub width: f64,
    /// Layer count (drives host launch overhead).
    pub n_layers: usize,
    /// Sequences in the batch (drives per-row host overhead).
    pub batch: usize,
}

impl PhaseCost {
    /// Arithmetic intensity in FLOP/byte.
    pub fn intensity(&self) -> f64 {
        self.flops / self.mem_bytes
    }
}

/// Prefill: process `seq` prompt tokens for each of `batch` sequences.
///
/// FLOPs: 2·params per token (GEMMs) plus quadratic attention
/// (2·2·L·H·Dh·seq² per sequence). Memory: weights once, plus KV written,
/// plus activations.
pub fn prefill_cost(m: &ModelSpec, batch: usize, seq: usize) -> PhaseCost {
    let params = m.param_count() as f64;
    let tokens = (batch * seq) as f64;
    let attn_flops = 4.0
        * m.n_layers as f64
        * m.n_heads as f64
        * m.head_dim() as f64
        * (seq * seq) as f64
        * batch as f64;
    let flops = 2.0 * params * tokens + attn_flops;

    let weight_bytes = m.weight_footprint_bytes() as f64;
    let kv_write = tokens * m.kv_bytes_per_token() as f64;
    // Activations: read+write d_model per token per layer, few passes.
    let act_bytes = 6.0 * tokens * (m.d_model * m.n_layers * m.weight_bytes) as f64;
    PhaseCost {
        flops,
        mem_bytes: weight_bytes + kv_write + act_bytes,
        rows: tokens,
        width: ((m.d_model * m.d_ff) as f64).sqrt(),
        n_layers: m.n_layers,
        batch,
    }
}

/// One decode step: generate one token per sequence with `ctx` tokens of
/// context already in the KV cache.
///
/// FLOPs: 2·params per sequence plus attention over the cache. Memory:
/// weights once (shared across the batch), KV cache read per sequence,
/// one KV entry written per sequence.
pub fn decode_step_cost(m: &ModelSpec, batch: usize, ctx: usize) -> PhaseCost {
    let params = m.param_count() as f64;
    let b = batch as f64;
    let attn_flops = 4.0
        * m.n_layers as f64
        * m.n_heads as f64
        * m.head_dim() as f64
        * ctx as f64
        * b;
    let flops = 2.0 * params * b + attn_flops;

    let weight_bytes = m.weight_footprint_bytes() as f64;
    let kv_read = b * ctx as f64 * m.kv_bytes_per_token() as f64;
    let kv_write = b * m.kv_bytes_per_token() as f64;
    let act_bytes = 6.0 * b * (m.d_model * m.n_layers * m.weight_bytes) as f64;
    PhaseCost {
        flops,
        mem_bytes: weight_bytes + kv_read + kv_write + act_bytes,
        rows: b,
        width: ((m.d_model * m.d_ff) as f64).sqrt(),
        n_layers: m.n_layers,
        batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};

    #[test]
    fn decode_intensity_is_low_prefill_high() {
        let m = model_for_tier(ModelTier::B8);
        let d = decode_step_cost(&m, 1, 256);
        let p = prefill_cost(&m, 1, 256);
        // Decode ~2 FLOP/byte (memory-bound); prefill ~hundreds.
        assert!(d.intensity() < 4.0, "decode AI {}", d.intensity());
        assert!(p.intensity() > 50.0, "prefill AI {}", p.intensity());
    }

    #[test]
    fn batching_amortizes_decode_weight_traffic() {
        let m = model_for_tier(ModelTier::B1);
        let b1 = decode_step_cost(&m, 1, 128);
        let b8 = decode_step_cost(&m, 8, 128);
        // 8× flops but far less than 8× bytes (weights shared).
        assert!((b8.flops / b1.flops - 8.0).abs() < 0.01);
        assert!(b8.mem_bytes / b1.mem_bytes < 2.0);
        assert!(b8.intensity() > 4.0 * b1.intensity());
    }

    #[test]
    fn prefill_flops_scale_with_seq_quadratically_in_attention() {
        let m = model_for_tier(ModelTier::B1);
        let short = prefill_cost(&m, 1, 64);
        let long = prefill_cost(&m, 1, 512);
        // Linear term dominates at these lengths, but attention grows 64×.
        assert!(long.flops > 8.0 * short.flops);
        assert!(long.flops < 12.0 * short.flops);
    }

    #[test]
    fn decode_cost_grows_with_context() {
        let m = model_for_tier(ModelTier::B8);
        let early = decode_step_cost(&m, 1, 16);
        let late = decode_step_cost(&m, 1, 1024);
        assert!(late.mem_bytes > early.mem_bytes);
        assert!(late.flops > early.flops);
    }

    #[test]
    fn weights_dominate_decode_bytes_at_small_ctx() {
        let m = model_for_tier(ModelTier::B32);
        let c = decode_step_cost(&m, 1, 64);
        let weights = m.weight_footprint_bytes() as f64;
        assert!(c.mem_bytes < 1.1 * weights);
        assert!(c.mem_bytes >= weights);
    }
}
