//! Performance model: per-phase FLOP/byte accounting for the paper's
//! transformer architectures, and the roofline + host-overhead timing model
//! that turns those counts into latency at a given SM frequency.
//!
//! The model (DESIGN.md §5):
//!
//! ```text
//! t_phase(f) = T_host + max(T_comp(f_max) · (f_max/f)^η, T_mem)
//!   T_host = t_framework + n_layers · kernels_per_layer · t_launch
//!   T_mem  = bytes / BW                      (memory clock is not scaled)
//!   T_comp = flops / peak(f_max)
//!   η      = min(1, coeff / (rows · width)^pow)   — occupancy-scaled
//! ```
//!
//! Decode (per-token flops ≈ 2·params, bytes ≈ weights + KV) is memory-bound
//! at every supported frequency, so its latency is ~f-independent — the
//! paper's central observation *emerges* from the counts rather than being
//! hard-coded. Prefill is compute-heavier and mildly frequency-sensitive,
//! with sensitivity falling as batch and model size grow (Table XI).

pub mod costmodel;
pub mod energy;
pub mod roofline;

pub use costmodel::{decode_step_cost, prefill_cost, PhaseCost};
pub use energy::edp;
pub use roofline::{phase_time, PhaseBreakdown};
