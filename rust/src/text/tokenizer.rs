//! Deterministic word/subword tokenizer.
//!
//! Substitutes the HF model tokenizers: words are split on whitespace,
//! punctuation is its own token, and long words are broken into ≤6-char
//! subword pieces — which makes token counts track BPE counts closely enough
//! for the length statistics the study reports (Table II tolerances are
//! asserted in tests).

/// One token of a query.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Token {
    /// Normalized (lowercased) text of the token.
    pub text: String,
    /// Surface form as it appeared.
    pub surface: String,
    /// True if the token is a punctuation mark.
    pub is_punct: bool,
    /// True if the surface form begins with an uppercase letter.
    pub capitalized: bool,
    /// True if this token starts a sentence.
    pub sentence_start: bool,
}

const MAX_PIECE: usize = 10;

/// Full tokenization: subword pieces plus punctuation tokens.
pub fn tokenize(text: &str) -> Vec<Token> {
    let mut out = Vec::new();
    let mut sentence_start = true;
    for raw in text.split_whitespace() {
        // Split leading/trailing punctuation off the word core.
        let chars: Vec<char> = raw.chars().collect();
        let start = chars.iter().position(|c| c.is_alphanumeric());
        let Some(start) = start else {
            for c in chars {
                out.push(punct_token(c, sentence_start));
            }
            continue;
        };
        let end = chars.iter().rposition(|c| c.is_alphanumeric()).unwrap();
        for &c in &chars[..start] {
            out.push(punct_token(c, sentence_start));
        }
        let core: String = chars[start..=end].iter().collect();
        let capitalized = core.chars().next().is_some_and(|c| c.is_uppercase());
        // Subword split for long words.
        let lower = core.to_lowercase();
        let pieces = split_pieces(&lower);
        let n = pieces.len();
        for (i, piece) in pieces.into_iter().enumerate() {
            out.push(Token {
                text: piece.clone(),
                surface: if n == 1 { core.clone() } else { piece },
                is_punct: false,
                capitalized: capitalized && i == 0,
                sentence_start: sentence_start && i == 0,
            });
        }
        sentence_start = false;
        for &c in &chars[end + 1..] {
            let ends_sentence = matches!(c, '.' | '!' | '?');
            out.push(punct_token(c, false));
            if ends_sentence {
                sentence_start = true;
            }
        }
    }
    out
}

fn punct_token(c: char, sentence_start: bool) -> Token {
    Token {
        text: c.to_string(),
        surface: c.to_string(),
        is_punct: true,
        capitalized: false,
        sentence_start,
    }
}

fn split_pieces(word: &str) -> Vec<String> {
    if word.chars().count() <= MAX_PIECE {
        return vec![word.to_string()];
    }
    let chars: Vec<char> = word.chars().collect();
    chars
        .chunks(MAX_PIECE)
        .map(|c| c.iter().collect())
        .collect()
}

/// Token count without materializing tokens — allocation-free fast path for
/// the feature extractor (identical to `tokenize(text).len()` by
/// construction; property-tested).
pub fn token_count(text: &str) -> usize {
    let mut n = 0usize;
    for raw in text.split_whitespace() {
        let chars_total = raw.chars().count();
        let mut core = 0usize;
        let mut leading_punct = 0usize;
        let mut seen_alnum = false;
        let mut trailing_punct = 0usize;
        for c in raw.chars() {
            if c.is_alphanumeric() {
                seen_alnum = true;
                core += 1 + trailing_punct; // interior punct counts as core span
                trailing_punct = 0;
            } else if seen_alnum {
                trailing_punct += 1;
            } else {
                leading_punct += 1;
            }
        }
        if !seen_alnum {
            n += chars_total; // punctuation-only blob
            continue;
        }
        n += leading_punct + trailing_punct + core.div_ceil(MAX_PIECE);
    }
    n
}

/// Word-level tokens only (no punctuation, no subword split) — what the
/// linguistic feature extractors operate on.
pub fn word_tokens(text: &str) -> Vec<Token> {
    let mut out = Vec::new();
    let mut sentence_start = true;
    for raw in text.split_whitespace() {
        let core: String = raw.chars().filter(|c| c.is_alphanumeric()).collect();
        if core.is_empty() {
            continue;
        }
        let capitalized = raw
            .chars()
            .find(|c| c.is_alphanumeric())
            .is_some_and(|c| c.is_uppercase());
        out.push(Token {
            text: core.to_lowercase(),
            surface: core.clone(),
            is_punct: false,
            capitalized,
            sentence_start,
        });
        sentence_start = raw.ends_with(['.', '!', '?']);
    }
    out
}

/// Number of sentences (terminator-delimited; at least 1 for non-empty text).
pub fn sentence_count(text: &str) -> usize {
    let n = text
        .chars()
        .filter(|c| matches!(c, '.' | '!' | '?'))
        .count();
    if n == 0 && !text.trim().is_empty() {
        1
    } else {
        n.max(usize::from(!text.trim().is_empty()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_words_and_punct() {
        let toks = tokenize("Why did Rome fall?");
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["why", "did", "rome", "fall", "?"]);
        assert!(toks[0].sentence_start);
        assert!(toks[2].capitalized);
        assert!(toks[4].is_punct);
    }

    #[test]
    fn long_words_become_subword_pieces() {
        let toks = tokenize("incomprehensibility");
        assert_eq!(toks.len(), 2); // incompreh ensibility (10 + 9 chars)
        assert!(toks.iter().all(|t| !t.is_punct));
        let joined: String = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(joined, "incomprehensibility");
    }

    #[test]
    fn sentence_boundaries_tracked() {
        let toks = word_tokens("She left. He stayed.");
        assert!(toks[0].sentence_start);
        assert!(!toks[1].sentence_start);
        assert!(toks[2].sentence_start);
        assert_eq!(sentence_count("She left. He stayed."), 2);
        assert_eq!(sentence_count("no terminator"), 1);
        assert_eq!(sentence_count(""), 0);
    }

    #[test]
    fn empty_and_punct_only() {
        assert!(tokenize("").is_empty());
        let toks = tokenize("...");
        assert_eq!(toks.len(), 3);
        assert!(toks.iter().all(|t| t.is_punct));
        assert!(word_tokens("...").is_empty());
    }

    #[test]
    fn token_count_tracks_word_count_plus_subwords() {
        let text = "the quick brown fox jumped over the lazy dog";
        assert_eq!(tokenize(text).len(), 9);
    }
}
