//! Text substrate: tokenization, lexicon NER, discourse-marker lexicons and
//! ROUGE-L — the pieces the paper gets from HF tokenizers, spaCy and
//! `rouge_score`, rebuilt natively (DESIGN.md §3).

pub mod markers;
pub mod ner;
pub mod rouge;
pub mod tokenizer;
pub mod vocab;

pub use ner::{EntityKind, NamedEntityRecognizer};
pub use rouge::rouge_l;
pub use tokenizer::{tokenize, word_tokens, Token};
