//! Discourse-marker lexicons: causal question words and reasoning markers
//! (Section V-C of the paper).

use super::tokenizer::{word_tokens, Token};

/// Causal question words — the paper's Causal Question Score numerator.
pub const CAUSAL_QUESTION_WORDS: &[&str] = &["why", "how", "explain", "justify", "prove"];

/// Causal / comparison discourse markers — the Reasoning Complexity numerator.
pub const REASONING_MARKERS: &[&str] = &[
    "because", "therefore", "however", "although", "consequently", "thus",
    "hence", "since", "whereas", "despite", "unless", "moreover",
    "furthermore", "nevertheless", "if", "then",
];

/// Does the question open with (or contain) a causal question word?
pub fn is_causal_question(text: &str) -> bool {
    is_causal_question_tokens(&word_tokens(text))
}

/// Token-level variant — lets callers that already tokenized (the feature
/// extractor hot path) avoid re-tokenizing.
pub fn is_causal_question_tokens(tokens: &[Token]) -> bool {
    tokens
        .iter()
        .any(|t| CAUSAL_QUESTION_WORDS.contains(&t.text.as_str()))
}

/// Density of reasoning markers per word (0–1).
pub fn reasoning_marker_density(text: &str) -> f64 {
    reasoning_marker_density_tokens(&word_tokens(text))
}

/// Token-level variant (see [`is_causal_question_tokens`]).
pub fn reasoning_marker_density_tokens(tokens: &[Token]) -> f64 {
    if tokens.is_empty() {
        return 0.0;
    }
    let hits = tokens
        .iter()
        .filter(|t| REASONING_MARKERS.contains(&t.text.as_str()))
        .count();
    hits as f64 / tokens.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_detection() {
        assert!(is_causal_question("Why did the empire fall?"));
        assert!(is_causal_question("Can you explain the result?"));
        assert!(!is_causal_question("Is the sky blue?"));
        assert!(!is_causal_question(""));
    }

    #[test]
    fn reasoning_density() {
        assert_eq!(reasoning_marker_density(""), 0.0);
        let d = reasoning_marker_density("it failed because the bridge collapsed");
        assert!((d - 1.0 / 6.0).abs() < 1e-9);
        assert_eq!(reasoning_marker_density("plain words only here"), 0.0);
    }

    #[test]
    fn lexicons_are_lowercase() {
        for w in CAUSAL_QUESTION_WORDS.iter().chain(REASONING_MARKERS) {
            assert_eq!(*w, w.to_lowercase());
        }
    }
}
