//! Word inventories: a Zipf-weighted common-English vocabulary plus the
//! entity gazetteer shared by the NER and the synthetic corpus generators.
//!
//! The generators draw entities from exactly the lists the recognizer knows
//! (plus heuristic-only surface forms), so measured entity *density* on
//! synthetic corpora is faithful to the injection rate — the property the
//! paper's workload characterization depends on.

/// Function words / stopwords (high-frequency head of the distribution).
pub const FUNCTION_WORDS: &[&str] = &[
    "the", "of", "and", "a", "to", "in", "is", "was", "that", "it", "he",
    "she", "for", "on", "are", "as", "with", "his", "her", "they", "at",
    "be", "this", "have", "from", "or", "one", "had", "by", "but", "not",
    "what", "all", "were", "we", "when", "your", "can", "said", "there",
    "an", "which", "do", "their", "if", "will", "each", "about", "them",
    "then", "many", "some", "would", "other", "into", "has", "more", "two",
    "like", "him", "time", "no", "could", "its", "only", "new", "these",
    "may", "did", "over", "such", "who", "most", "her", "also", "after",
];

/// Content nouns (mid-frequency).
pub const NOUNS: &[&str] = &[
    "story", "house", "river", "mountain", "war", "child", "family",
    "history", "water", "music", "power", "school", "night", "city",
    "letter", "question", "answer", "reason", "moment", "village", "book",
    "garden", "window", "journey", "winter", "summer", "morning", "door",
    "road", "forest", "memory", "silence", "voice", "shadow", "dream",
    "castle", "soldier", "doctor", "teacher", "farmer", "sailor", "market",
    "church", "island", "valley", "storm", "fire", "stone", "bridge",
    "horse", "ship", "train", "engine", "machine", "factory", "science",
    "theory", "evidence", "result", "effect", "cause", "process", "system",
    "species", "animal", "plant", "ocean", "climate", "planet", "energy",
    "disease", "medicine", "brain", "body", "heart", "blood", "cell",
    "language", "culture", "law", "court", "government", "election",
    "money", "trade", "industry", "empire", "kingdom", "revolution",
    "treaty", "battle", "army", "weapon", "victory", "defeat", "border",
];

/// Content verbs.
pub const VERBS: &[&str] = &[
    "walked", "returned", "discovered", "explained", "believed", "decided",
    "remembered", "followed", "carried", "watched", "listened", "answered",
    "asked", "wondered", "traveled", "arrived", "departed", "continued",
    "finished", "started", "built", "destroyed", "created", "found",
    "lost", "wrote", "read", "spoke", "whispered", "shouted", "promised",
    "refused", "accepted", "offered", "received", "developed", "caused",
    "produced", "increased", "decreased", "changed", "remained", "became",
    "happened", "occurred", "appeared", "vanished", "escaped", "survived",
];

/// Content adjectives/adverbs.
pub const MODIFIERS: &[&str] = &[
    "old", "young", "small", "large", "ancient", "modern", "quiet", "loud",
    "dark", "bright", "cold", "warm", "distant", "nearby", "famous",
    "forgotten", "important", "strange", "familiar", "sudden", "gradual",
    "slowly", "quickly", "carefully", "finally", "eventually", "certainly",
    "probably", "rarely", "often", "deep", "shallow", "heavy", "light",
    "early", "late", "empty", "crowded", "silent", "golden", "broken",
];

/// PERSON gazetteer (given + family names, used capitalized).
pub const PERSONS: &[&str] = &[
    "Eleanor", "Marcus", "Sofia", "Dmitri", "Amara", "Hiroshi", "Ingrid",
    "Rafael", "Nadia", "Tobias", "Yusuf", "Clara", "Viktor", "Leila",
    "Edmund", "Beatrice", "Johann", "Mariana", "Chen", "Priya", "Oskar",
    "Helena", "Darwin", "Newton", "Einstein", "Curie", "Tesla", "Lincoln",
    "Napoleon", "Cleopatra", "Galileo", "Mozart", "Shakespeare", "Austen",
    "Dickens", "Tolstoy", "Hemingway", "Orwell", "Twain", "Bronte",
];

/// ORG gazetteer.
pub const ORGS: &[&str] = &[
    "Parliament", "Congress", "Senate", "NASA", "UNESCO", "Interpol",
    "Oxford", "Cambridge", "Harvard", "Stanford", "Berkeley", "Sorbonne",
    "Admiralty", "Treasury", "Vatican", "Kremlin", "Pentagon", "Reuters",
    "Lloyds", "Medici", "Habsburg", "Romanov", "Tudor", "Stuart",
];

/// GPE (geo-political entity) gazetteer.
pub const GPES: &[&str] = &[
    "France", "England", "Russia", "Japan", "Egypt", "Brazil", "India",
    "China", "Persia", "Rome", "Athens", "Vienna", "Prague", "Lisbon",
    "Madrid", "Berlin", "Moscow", "Kyoto", "Cairo", "Istanbul", "Venice",
    "Florence", "Geneva", "Amsterdam", "Dublin", "Edinburgh", "Warsaw",
    "Budapest", "Stockholm", "Copenhagen", "Norway", "Sweden", "Poland",
    "Austria", "Hungary", "Greece", "Turkey", "Mexico", "Canada", "Peru",
];

/// LOC (physical location) gazetteer.
pub const LOCS: &[&str] = &[
    "Danube", "Nile", "Amazon", "Everest", "Sahara", "Alps", "Andes",
    "Pacific", "Atlantic", "Mediterranean", "Baltic", "Thames", "Seine",
    "Volga", "Rhine", "Himalayas", "Arctic", "Antarctica", "Kilimanjaro",
    "Serengeti", "Yangtze", "Mississippi", "Rockies", "Pyrenees",
];

/// Flattened gazetteer size (used by tests and density math).
pub fn gazetteer_len() -> usize {
    PERSONS.len() + ORGS.len() + GPES.len() + LOCS.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lists_nonempty_and_lowercase_where_expected() {
        for w in FUNCTION_WORDS.iter().chain(NOUNS).chain(VERBS).chain(MODIFIERS) {
            assert!(!w.is_empty());
            assert!(w.chars().next().unwrap().is_lowercase(), "{w}");
        }
        for w in PERSONS.iter().chain(ORGS).chain(GPES).chain(LOCS) {
            assert!(w.chars().next().unwrap().is_uppercase(), "{w}");
        }
    }

    #[test]
    fn gazetteer_has_no_duplicates_across_kinds() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for w in PERSONS.iter().chain(ORGS).chain(GPES).chain(LOCS) {
            assert!(seen.insert(*w), "duplicate gazetteer entry {w}");
        }
        assert_eq!(seen.len(), gazetteer_len());
    }
}
