//! ROUGE-L — the paper's quality metric for generation tasks (Section IV-D).
//!
//! Standard formulation: LCS-based F-measure between hypothesis and
//! reference word sequences (β = 1.2 per the original ROUGE paper; the
//! common `rouge_score` default uses pure F1 — we expose both).

use super::tokenizer::word_tokens;

/// Longest common subsequence length between two word sequences.
fn lcs_len(a: &[String], b: &[String]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    // Rolling 1-D DP (O(len(b)) memory).
    let mut prev = vec![0usize; b.len() + 1];
    let mut curr = vec![0usize; b.len() + 1];
    for ai in a {
        for (j, bj) in b.iter().enumerate() {
            curr[j + 1] = if ai == bj {
                prev[j] + 1
            } else {
                prev[j + 1].max(curr[j])
            };
        }
        std::mem::swap(&mut prev, &mut curr);
    }
    prev[b.len()]
}

/// ROUGE-L scores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RougeL {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

/// Compute ROUGE-L between a hypothesis and a reference text.
pub fn rouge_l(hypothesis: &str, reference: &str) -> RougeL {
    let h: Vec<String> = word_tokens(hypothesis).into_iter().map(|t| t.text).collect();
    let r: Vec<String> = word_tokens(reference).into_iter().map(|t| t.text).collect();
    if h.is_empty() || r.is_empty() {
        return RougeL { precision: 0.0, recall: 0.0, f1: 0.0 };
    }
    let lcs = lcs_len(&h, &r) as f64;
    let precision = lcs / h.len() as f64;
    let recall = lcs / r.len() as f64;
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    RougeL { precision, recall, f1 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_texts_score_one() {
        let s = rouge_l("the cat sat on the mat", "the cat sat on the mat");
        assert!((s.f1 - 1.0).abs() < 1e-12);
        assert!((s.precision - 1.0).abs() < 1e-12);
        assert!((s.recall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_texts_score_zero() {
        let s = rouge_l("alpha beta gamma", "delta epsilon zeta");
        assert_eq!(s.f1, 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rouge_l("", "reference words").f1, 0.0);
        assert_eq!(rouge_l("hypothesis words", "").f1, 0.0);
    }

    #[test]
    fn known_value() {
        // hyp: "the cat sat", ref: "the cat lay on the mat"
        // LCS = "the cat" (2); P = 2/3, R = 2/6, F1 = 2·(2/3)(1/3)/(2/3+1/3) = 4/9.
        let s = rouge_l("the cat sat", "the cat lay on the mat");
        assert!((s.precision - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.recall - 2.0 / 6.0).abs() < 1e-12);
        assert!((s.f1 - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn subsequence_not_substring() {
        // LCS tolerates gaps: "a b c" vs "a x b y c" → LCS 3.
        let s = rouge_l("alpha beta gamma", "alpha xray beta yankee gamma");
        assert!((s.recall - 3.0 / 5.0).abs() < 1e-12);
        assert!((s.precision - 1.0).abs() < 1e-12);
    }

    #[test]
    fn case_insensitive() {
        let s = rouge_l("The Cat", "the cat");
        assert!((s.f1 - 1.0).abs() < 1e-12);
    }
}
