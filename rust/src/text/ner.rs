//! Lexicon + heuristic named-entity recognizer.
//!
//! Substitutes spaCy's `en_core_web_sm` (PERSON/ORG/GPE/LOC — the four types
//! the paper counts for entity density). Recognition is gazetteer lookup
//! plus a capitalization heuristic for non-sentence-initial capitalized
//! words, mirroring how a small statistical NER behaves on clean text.

use std::collections::HashMap;

use super::tokenizer::{word_tokens, Token};
use super::vocab;

/// Entity types counted by the paper's entity-density feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EntityKind {
    Person,
    Org,
    Gpe,
    Loc,
}

/// A recognized entity span (single-token spans; the synthetic corpora
/// inject single-token entities).
#[derive(Debug, Clone)]
pub struct Entity {
    pub surface: String,
    pub kind: EntityKind,
}

/// Gazetteer-backed recognizer.
pub struct NamedEntityRecognizer {
    lexicon: HashMap<&'static str, EntityKind>,
}

impl Default for NamedEntityRecognizer {
    fn default() -> Self {
        Self::new()
    }
}

impl NamedEntityRecognizer {
    pub fn new() -> Self {
        let mut lexicon = HashMap::new();
        for w in vocab::PERSONS {
            lexicon.insert(*w, EntityKind::Person);
        }
        for w in vocab::ORGS {
            lexicon.insert(*w, EntityKind::Org);
        }
        for w in vocab::GPES {
            lexicon.insert(*w, EntityKind::Gpe);
        }
        for w in vocab::LOCS {
            lexicon.insert(*w, EntityKind::Loc);
        }
        NamedEntityRecognizer { lexicon }
    }

    /// Recognize entities among pre-tokenized words.
    pub fn recognize_tokens(&self, tokens: &[Token]) -> Vec<Entity> {
        let mut out = Vec::new();
        for tok in tokens {
            if tok.is_punct {
                continue;
            }
            if let Some(&kind) = self.lexicon.get(tok.surface.as_str()) {
                out.push(Entity {
                    surface: tok.surface.clone(),
                    kind,
                });
            } else if tok.capitalized && !tok.sentence_start {
                // Unknown capitalized mid-sentence word: heuristic PERSON,
                // like a small statistical model's fallback.
                out.push(Entity {
                    surface: tok.surface.clone(),
                    kind: EntityKind::Person,
                });
            }
        }
        out
    }

    /// Recognize entities in raw text.
    pub fn recognize(&self, text: &str) -> Vec<Entity> {
        self.recognize_tokens(&word_tokens(text))
    }

    /// Entity density: named-entity tokens / total word tokens (the paper's
    /// definition, Section V-C).
    pub fn entity_density(&self, text: &str) -> f64 {
        let tokens = word_tokens(text);
        if tokens.is_empty() {
            return 0.0;
        }
        self.recognize_tokens(&tokens).len() as f64 / tokens.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_gazetteer_entities() {
        let ner = NamedEntityRecognizer::new();
        let ents = ner.recognize("Napoleon marched toward Moscow along the Volga");
        let kinds: Vec<EntityKind> = ents.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![EntityKind::Person, EntityKind::Gpe, EntityKind::Loc]
        );
    }

    #[test]
    fn sentence_initial_capitalization_not_heuristic_entity() {
        let ner = NamedEntityRecognizer::new();
        // "Strange" is capitalized only because it starts the sentence.
        assert!(ner.recognize("Strange things happened").is_empty());
        // Mid-sentence unknown capitalized word → heuristic PERSON.
        let ents = ner.recognize("the ship Zanzibar sailed");
        assert_eq!(ents.len(), 1);
        assert_eq!(ents[0].kind, EntityKind::Person);
    }

    #[test]
    fn density_bounds() {
        let ner = NamedEntityRecognizer::new();
        assert_eq!(ner.entity_density(""), 0.0);
        let d = ner.entity_density("Napoleon met Cleopatra in Cairo");
        assert!(d > 0.0 && d <= 1.0);
        assert!((d - 3.0 / 5.0).abs() < 1e-9);
    }

    #[test]
    fn gazetteer_lookup_is_sentence_position_independent() {
        let ner = NamedEntityRecognizer::new();
        let ents = ner.recognize("Napoleon won");
        assert_eq!(ents.len(), 1); // known entity recognized even at start
    }
}
