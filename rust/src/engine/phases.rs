//! Batch execution on the simulated GPU: one prefill pass + decode loop,
//! with per-phase instrumentation.

use anyhow::Result;

use crate::config::ModelSpec;
use crate::gpu::{GpuSim, PhaseResult};
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::tokenizer::token_count;
use crate::workload::Query;

use super::kvcache::KvCacheManager;

/// Instrumented result of one batch (prefill/decode split — the paper's
/// phase-level measurement).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchMetrics {
    pub prefill: PhaseResult,
    pub decode: PhaseResult,
    pub batch: usize,
    /// Prompt length the batch ran at (max over rows — padding semantics).
    pub seq: usize,
    /// Total generated tokens across rows.
    pub tokens_out: usize,
    /// Decode steps executed (max over rows).
    pub decode_steps: usize,
}

impl BatchMetrics {
    pub fn latency_s(&self) -> f64 {
        self.prefill.latency_s + self.decode.latency_s
    }

    pub fn energy_j(&self) -> f64 {
        self.prefill.energy_j + self.decode.energy_j
    }

    /// Fraction of time spent in decode (Table XI's Dec% column).
    pub fn decode_share(&self) -> f64 {
        if self.latency_s() == 0.0 {
            0.0
        } else {
            self.decode.latency_s / self.latency_s()
        }
    }
}

/// Execute one dataset-homogeneous batch on the simulated GPU.
///
/// Classification queries (output budget 0) run log-likelihood mode:
/// `n_options` prefill passes and no decode (Section IV-C). Generation
/// queries decode until every row hits its budget (shorter rows pad, as an
/// offline replay harness does).
pub fn simulate_batch(
    model: &ModelSpec,
    gpu: &GpuSim,
    queries: &[&Query],
    kv: &mut KvCacheManager,
) -> Result<BatchMetrics> {
    assert!(!queries.is_empty());
    let batch = queries.len();
    let seq = queries
        .iter()
        .map(|q| token_count(&q.text).max(1))
        .max()
        .unwrap();
    let steps = queries.iter().map(|q| q.output_tokens).max().unwrap();

    for q in queries {
        kv.admit(q.id, seq)?;
    }

    let mut prefill = PhaseResult::default();
    // Log-likelihood mode scores each answer option with its own forward
    // pass; generation does a single prefill.
    let passes = if steps == 0 {
        queries[0].dataset.n_options()
    } else {
        1
    };
    let pcost = prefill_cost(model, batch, seq);
    for _ in 0..passes {
        prefill.add(&gpu.execute(&pcost));
    }

    let mut decode = PhaseResult::default();
    for s in 0..steps {
        let ctx = seq + s;
        let dcost = decode_step_cost(model, batch, ctx);
        decode.add(&gpu.execute(&dcost));
        for q in queries {
            if s < q.output_tokens {
                kv.extend(q.id)?;
            }
        }
    }

    for q in queries {
        kv.release(q.id);
    }

    Ok(BatchMetrics {
        prefill,
        decode,
        batch,
        seq,
        tokens_out: queries.iter().map(|q| q.output_tokens).sum(),
        decode_steps: steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::config::GpuSpec;
    use crate::workload::{Dataset, ReplaySuite};

    fn setup() -> (ReplaySuite, GpuSim) {
        (
            ReplaySuite::quick(7, 20),
            GpuSim::new(GpuSpec::rtx_pro_6000(), 2842),
        )
    }

    fn batch_of<'a>(suite: &'a ReplaySuite, d: Dataset, n: usize) -> Vec<&'a Query> {
        suite
            .dataset_indices(d)
            .into_iter()
            .take(n)
            .map(|i| &suite.queries[i])
            .collect()
    }

    #[test]
    fn generation_batches_are_decode_dominated() {
        let (suite, gpu) = setup();
        let m = model_for_tier(ModelTier::B8);
        let mut kv = KvCacheManager::new(&gpu.spec, &m);
        let qs = batch_of(&suite, Dataset::NarrativeQa, 1);
        let b = simulate_batch(&m, &gpu, &qs, &mut kv).unwrap();
        // Paper: decode is 77–91% of time.
        assert!(b.decode_share() > 0.70, "decode share {}", b.decode_share());
        assert!(b.tokens_out >= 80);
        assert_eq!(kv.active_seqs(), 0); // all released
    }

    #[test]
    fn classification_runs_loglikelihood_only() {
        let (suite, gpu) = setup();
        let m = model_for_tier(ModelTier::B1);
        let mut kv = KvCacheManager::new(&gpu.spec, &m);
        let qs = batch_of(&suite, Dataset::BoolQ, 4);
        let b = simulate_batch(&m, &gpu, &qs, &mut kv).unwrap();
        assert_eq!(b.tokens_out, 0);
        assert_eq!(b.decode_steps, 0);
        assert_eq!(b.decode.latency_s, 0.0);
        assert!(b.prefill.latency_s > 0.0);
    }

    #[test]
    fn energy_and_latency_accumulate_over_steps() {
        let (suite, gpu) = setup();
        let m = model_for_tier(ModelTier::B1);
        let mut kv = KvCacheManager::new(&gpu.spec, &m);
        let qs = batch_of(&suite, Dataset::TruthfulQa, 2);
        let b = simulate_batch(&m, &gpu, &qs, &mut kv).unwrap();
        assert!(b.decode.latency_s > b.prefill.latency_s);
        assert!(b.energy_j() > 0.0);
        assert!((b.latency_s() - (b.prefill.latency_s + b.decode.latency_s)).abs() < 1e-12);
    }
}
