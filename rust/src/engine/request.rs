//! Per-request measurement records.

use crate::config::ModelTier;
use crate::workload::Dataset;

/// Measured outcome of one query's inference.
#[derive(Debug, Clone, Copy)]
pub struct QueryMetrics {
    pub query_idx: usize,
    pub dataset: Dataset,
    pub tier: ModelTier,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Attributed energy, joules (batch energy split evenly across rows).
    pub energy_j: f64,
    /// Prefill portion of latency.
    pub prefill_s: f64,
    /// Decode portion of latency.
    pub decode_s: f64,
    /// Tokens generated (0 for log-likelihood classification).
    pub tokens_out: usize,
    pub input_tokens: usize,
}

/// Outcome of one served request on the real PJRT path.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub query_idx: usize,
    /// Generated text (tiny-LM detokenized).
    pub text: String,
    pub tokens_out: usize,
    /// Wall-clock latency of the real execution, seconds.
    pub wall_latency_s: f64,
    /// Simulated-GPU energy attributed to this request, joules.
    pub sim_energy_j: f64,
    /// ROUGE-L F1 vs. the query's reference.
    pub rouge_l: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_are_plain_data() {
        let m = QueryMetrics {
            query_idx: 0,
            dataset: Dataset::BoolQ,
            tier: ModelTier::B1,
            latency_s: 0.1,
            energy_j: 1.0,
            prefill_s: 0.02,
            decode_s: 0.08,
            tokens_out: 0,
            input_tokens: 100,
        };
        assert!(m.prefill_s + m.decode_s <= m.latency_s + 1e-12);
    }
}
