//! Replay engine: run a full suite (or a dataset slice) against one
//! (model, frequency-policy, batch-size) configuration — the inner loop of
//! every DVFS experiment in Section VI.

use anyhow::Result;

use crate::config::{FreqMHz, GpuSpec, ModelSpec};
use crate::coordinator::dvfs_policy::DvfsPolicy;
use crate::gpu::GpuSim;
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::tokenizer::token_count;
use crate::workload::{Dataset, Query, ReplaySuite};

use super::batcher::Batcher;
use super::kvcache::KvCacheManager;
use super::request::QueryMetrics;

/// Aggregate metrics of one replay run.
#[derive(Debug, Clone, Default)]
pub struct ReplayMetrics {
    pub energy_j: f64,
    pub latency_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub prefill_j: f64,
    pub decode_j: f64,
    pub tokens_out: usize,
    pub queries: usize,
    pub per_query: Vec<QueryMetrics>,
}

impl ReplayMetrics {
    pub fn decode_share(&self) -> f64 {
        if self.latency_s == 0.0 {
            0.0
        } else {
            self.decode_s / self.latency_s
        }
    }

    /// Mean energy per replayed query. `NaN` for an empty run — the old
    /// `queries.max(1)` guard silently reported 0 J/query instead of
    /// signaling the degenerate case.
    pub fn energy_per_query(&self) -> f64 {
        if self.queries == 0 {
            return f64::NAN;
        }
        self.energy_j / self.queries as f64
    }

    /// Mean energy per generated token. `NaN` when the replay produced no
    /// tokens (e.g. a classification-only slice) — previously the whole
    /// run's energy was attributed to one phantom token.
    pub fn energy_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            return f64::NAN;
        }
        self.energy_j / self.tokens_out as f64
    }
}

/// The replay engine: owns the GPU spec and model under test.
pub struct ReplayEngine {
    pub gpu_spec: GpuSpec,
    pub model: ModelSpec,
}

impl ReplayEngine {
    pub fn new(gpu_spec: GpuSpec, model: ModelSpec) -> Self {
        ReplayEngine { gpu_spec, model }
    }

    /// Run `indices` of `suite` at `batch` size under a DVFS policy.
    ///
    /// The policy picks the SM set point per phase; phase-aware policies pay
    /// the switch overhead twice per generation batch (up + down, Fig. 6).
    pub fn run(
        &self,
        suite: &ReplaySuite,
        indices: &[usize],
        batch: usize,
        policy: &DvfsPolicy,
    ) -> Result<ReplayMetrics> {
        let mut kv = KvCacheManager::new(&self.gpu_spec, &self.model);
        let mut out = ReplayMetrics::default();
        let batcher = Batcher::new(batch);
        for group in batcher.batches(&suite.queries, indices) {
            let queries: Vec<&Query> = group.iter().map(|&i| &suite.queries[i]).collect();
            let m = self.run_batch(&queries, policy, &mut kv)?;
            // Attribute batch totals evenly across rows (offline replay).
            let n = queries.len() as f64;
            for (&qi, q) in group.iter().zip(&queries) {
                out.per_query.push(QueryMetrics {
                    query_idx: qi,
                    dataset: q.dataset,
                    tier: self.model.tier,
                    latency_s: m.latency_s,
                    energy_j: m.energy_j / n,
                    prefill_s: m.prefill_s,
                    decode_s: m.decode_s,
                    tokens_out: q.output_tokens,
                    input_tokens: token_count(&q.text),
                });
            }
            out.energy_j += m.energy_j;
            out.latency_s += m.latency_s;
            out.prefill_s += m.prefill_s;
            out.decode_s += m.decode_s;
            out.prefill_j += m.prefill_j;
            out.decode_j += m.decode_j;
            out.tokens_out += m.tokens_out;
            out.queries += queries.len();
        }
        Ok(out)
    }

    fn run_batch(
        &self,
        queries: &[&Query],
        policy: &DvfsPolicy,
        kv: &mut KvCacheManager,
    ) -> Result<BatchTotals> {
        let batch = queries.len();
        let seq = queries
            .iter()
            .map(|q| token_count(&q.text).max(1))
            .max()
            .unwrap();
        let steps = queries.iter().map(|q| q.output_tokens).max().unwrap();
        for q in queries {
            kv.admit(q.id, seq)?;
        }

        let mut totals = BatchTotals::default();

        // --- prefill at the policy's prefill set point ---
        let f_pre = policy.prefill_freq(&self.gpu_spec);
        let gpu_pre = GpuSim::new(self.gpu_spec.clone(), f_pre);
        let passes = if steps == 0 {
            queries[0].dataset.n_options()
        } else {
            1
        };
        let pcost = prefill_cost(&self.model, batch, seq);
        for _ in 0..passes {
            let r = gpu_pre.execute(&pcost);
            totals.prefill_s += r.latency_s;
            totals.prefill_j += r.energy_j;
        }

        // --- decode at the policy's decode set point ---
        let f_dec = policy.decode_freq(&self.gpu_spec);
        if steps > 0 {
            if f_dec != f_pre {
                // Switch down and (after the batch) back up; idle power
                // during the transition (Figure 6's frequency profile).
                let sw = 2.0 * self.gpu_spec.f_switch_overhead_s;
                totals.decode_s += sw;
                totals.decode_j += sw * self.gpu_spec.p_idle_w;
            }
            let gpu_dec = GpuSim::new(self.gpu_spec.clone(), f_dec);
            for s in 0..steps {
                let dcost = decode_step_cost(&self.model, batch, seq + s);
                let r = gpu_dec.execute(&dcost);
                totals.decode_s += r.latency_s;
                totals.decode_j += r.energy_j;
                for q in queries {
                    if s < q.output_tokens {
                        kv.extend(q.id)?;
                    }
                }
            }
        }

        for q in queries {
            kv.release(q.id);
        }
        totals.latency_s = totals.prefill_s + totals.decode_s;
        totals.energy_j = totals.prefill_j + totals.decode_j;
        totals.tokens_out = queries.iter().map(|q| q.output_tokens).sum();
        Ok(totals)
    }

    /// Convenience: run one dataset at a static frequency.
    pub fn run_dataset_static(
        &self,
        suite: &ReplaySuite,
        dataset: Dataset,
        batch: usize,
        freq: FreqMHz,
    ) -> Result<ReplayMetrics> {
        let idx = suite.dataset_indices(dataset);
        self.run(suite, &idx, batch, &DvfsPolicy::Static(freq))
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct BatchTotals {
    energy_j: f64,
    latency_s: f64,
    prefill_s: f64,
    decode_s: f64,
    prefill_j: f64,
    decode_j: f64,
    tokens_out: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};

    fn engine(tier: ModelTier) -> ReplayEngine {
        ReplayEngine::new(GpuSpec::rtx_pro_6000(), model_for_tier(tier))
    }

    #[test]
    fn dvfs_headline_numbers_hold_on_replay() {
        // Mini Table XI: ~40% energy savings, small latency penalty.
        let suite = ReplaySuite::quick(11, 12);
        let idx: Vec<usize> = (0..suite.len()).collect();
        let e = engine(ModelTier::B8);
        let hi = e.run(&suite, &idx, 1, &DvfsPolicy::Static(2842)).unwrap();
        let lo = e.run(&suite, &idx, 1, &DvfsPolicy::Static(180)).unwrap();
        let savings = 1.0 - lo.energy_j / hi.energy_j;
        let lat = (lo.latency_s - hi.latency_s) / hi.latency_s;
        assert!(savings > 0.30 && savings < 0.52, "savings {savings:.3}");
        assert!(lat < 0.10, "latency Δ {lat:+.3}");
        assert_eq!(hi.queries, suite.len());
        assert_eq!(hi.per_query.len(), suite.len());
    }

    #[test]
    fn empty_replay_reports_nan_not_zero() {
        let m = ReplayMetrics::default();
        assert!(m.energy_per_query().is_nan());
        assert!(m.energy_per_token().is_nan());
    }

    #[test]
    fn decode_dominates_generation_replay() {
        let suite = ReplaySuite::quick(13, 10);
        let e = engine(ModelTier::B3);
        let m = e
            .run_dataset_static(&suite, Dataset::NarrativeQa, 1, 2842)
            .unwrap();
        assert!(m.decode_share() > 0.70, "decode share {}", m.decode_share());
        assert!(m.tokens_out > 0);
    }

    #[test]
    fn phase_aware_policy_saves_energy_with_tiny_latency_cost() {
        // The case-study policy (Section VII-B): high-freq prefill,
        // low-freq decode.
        let suite = ReplaySuite::quick(17, 10);
        let e = engine(ModelTier::B14);
        let idx = suite.dataset_indices(Dataset::TruthfulQa);
        let base = e.run(&suite, &idx, 1, &DvfsPolicy::Static(2842)).unwrap();
        let pa = e
            .run(
                &suite,
                &idx,
                1,
                &DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
            )
            .unwrap();
        let savings = 1.0 - pa.energy_j / base.energy_j;
        let lat = (pa.latency_s - base.latency_s) / base.latency_s;
        assert!(savings > 0.30, "savings {savings:.3}");
        assert!(lat.abs() < 0.05, "latency Δ {lat:+.3}");
        // And prefill stayed at full speed.
        assert!((pa.prefill_s - base.prefill_s).abs() / base.prefill_s < 0.01);
    }

    #[test]
    fn batching_reduces_latency_penalty() {
        // Table XI: LΔ falls from b1 to b8. The paper's row averages pool
        // all four datasets (classification prefill passes amortize
        // strongly with batch), so the test uses the full mix too.
        let suite = ReplaySuite::quick(19, 16);
        let e = engine(ModelTier::B1);
        let idx: Vec<usize> = (0..suite.len()).collect();
        let delta = |b: usize| {
            let hi = e.run(&suite, &idx, b, &DvfsPolicy::Static(2842)).unwrap();
            let lo = e.run(&suite, &idx, b, &DvfsPolicy::Static(180)).unwrap();
            (lo.latency_s - hi.latency_s) / hi.latency_s
        };
        assert!(delta(8) <= delta(1) + 1e-9);
    }
}
