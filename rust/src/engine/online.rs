//! Online serving simulation — the production dynamics the paper's offline
//! replay deliberately excludes ("threats to validity": continuous batching,
//! arrival processes, SLOs). Extension feature, exercised by the
//! `ewatt ablation batching` experiment.
//!
//! Event-driven: Poisson arrivals, a FIFO queue, one simulated device, and
//! two batching disciplines:
//!
//! - [`BatchingMode::Static`]: the classical replay discipline — collect up
//!   to `max_batch` requests, run prefill + the full decode to completion,
//!   then pick up the next batch.
//! - [`BatchingMode::Continuous`]: iteration-level scheduling (Orca/vLLM):
//!   new requests join the running batch at decode-step boundaries (paying
//!   their prefill), finished sequences leave immediately.

use anyhow::Result;

use crate::config::{GpuSpec, ModelSpec};
use crate::coordinator::dvfs_policy::DvfsPolicy;
use crate::gpu::GpuSim;
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::tokenizer::token_count;
use crate::workload::Query;
use crate::Rng;

/// Batching discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchingMode {
    Static,
    Continuous,
}

/// Online workload + serving configuration.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Mean arrival rate, requests/second (Poisson).
    pub arrival_rps: f64,
    pub max_batch: usize,
    pub batching: BatchingMode,
    pub policy: DvfsPolicy,
    /// Latency SLO for violation accounting, seconds.
    pub slo_s: f64,
    pub seed: u64,
}

/// Result of one online run.
#[derive(Debug, Clone, Default)]
pub struct OnlineMetrics {
    pub served: usize,
    pub energy_j: f64,
    /// Simulated wall-clock time at which the last request finished.
    pub makespan_s: f64,
    pub latencies_s: Vec<f64>,
    pub slo_violations: usize,
}

impl OnlineMetrics {
    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.latencies_s.clone();
        // total_cmp: a NaN sample sorts last instead of panicking.
        xs.sort_by(f64::total_cmp);
        xs[((xs.len() as f64 - 1.0) * p / 100.0).round() as usize]
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }

    /// SLO violations per served request. `NaN` when the run served
    /// nothing — a degenerate case callers must handle explicitly, not a
    /// silent 0% violation rate.
    pub fn violation_rate(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.slo_violations as f64 / self.served as f64
    }

    /// Mean energy per served request. `NaN` when nothing was served
    /// (the old `served.max(1)` guard reported the whole run's energy as
    /// one request's bill).
    pub fn joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.energy_j / self.served as f64
    }
}

struct Seq {
    arrival_s: f64,
    input_tokens: usize,
    remaining: usize,
    ctx: usize,
}

/// The online simulator.
pub struct OnlineSim {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub cfg: OnlineConfig,
}

impl OnlineSim {
    pub fn new(gpu: GpuSpec, model: ModelSpec, cfg: OnlineConfig) -> Self {
        OnlineSim { gpu, model, cfg }
    }

    /// Serve `queries` arriving as a Poisson stream.
    pub fn run(&self, queries: &[&Query]) -> Result<OnlineMetrics> {
        let mut rng = Rng::seed_from_u64(self.cfg.seed);
        // Pre-draw arrival times.
        let mut t = 0.0;
        let mut arrivals: Vec<(f64, &Query)> = Vec::with_capacity(queries.len());
        for q in queries {
            t += -(1.0 - rng.gen_f64()).ln() / self.cfg.arrival_rps;
            arrivals.push((t, q));
        }
        match self.cfg.batching {
            BatchingMode::Static => self.run_static(&arrivals),
            BatchingMode::Continuous => self.run_continuous(&arrivals),
        }
    }

    fn sims(&self) -> (GpuSim, GpuSim) {
        (
            GpuSim::new(self.gpu.clone(), self.cfg.policy.prefill_freq(&self.gpu)),
            GpuSim::new(self.gpu.clone(), self.cfg.policy.decode_freq(&self.gpu)),
        )
    }

    fn run_static(&self, arrivals: &[(f64, &Query)]) -> Result<OnlineMetrics> {
        let (pre_sim, dec_sim) = self.sims();
        let mut m = OnlineMetrics::default();
        let mut now = 0.0f64;
        let mut i = 0usize;
        while i < arrivals.len() {
            // Wait for at least one request, then take up to max_batch of
            // the requests already queued.
            now = now.max(arrivals[i].0);
            let mut batch = Vec::new();
            while i < arrivals.len()
                && batch.len() < self.cfg.max_batch
                && arrivals[i].0 <= now
            {
                batch.push(arrivals[i]);
                i += 1;
            }
            let seq = batch
                .iter()
                .map(|(_, q)| token_count(&q.text).max(1))
                .max()
                .unwrap();
            let steps = batch
                .iter()
                .map(|(_, q)| q.output_tokens.max(1))
                .max()
                .unwrap();
            let pre = pre_sim.execute(&prefill_cost(&self.model, batch.len(), seq));
            now += pre.latency_s;
            m.energy_j += pre.energy_j;
            for s in 0..steps {
                let r = dec_sim.execute(&decode_step_cost(&self.model, batch.len(), seq + s));
                now += r.latency_s;
                m.energy_j += r.energy_j;
            }
            for (arr, _q) in &batch {
                let lat = now - arr;
                if lat > self.cfg.slo_s {
                    m.slo_violations += 1;
                }
                m.latencies_s.push(lat);
                m.served += 1;
            }
        }
        m.makespan_s = now;
        Ok(m)
    }

    fn run_continuous(&self, arrivals: &[(f64, &Query)]) -> Result<OnlineMetrics> {
        let (pre_sim, dec_sim) = self.sims();
        let mut m = OnlineMetrics::default();
        let mut now = 0.0f64;
        let mut i = 0usize;
        let mut active: Vec<Seq> = Vec::new();
        while i < arrivals.len() || !active.is_empty() {
            // Admit arrivals at the step boundary (iteration-level).
            if active.is_empty() && i < arrivals.len() {
                now = now.max(arrivals[i].0);
            }
            while i < arrivals.len()
                && active.len() < self.cfg.max_batch
                && arrivals[i].0 <= now
            {
                let (arr, q) = arrivals[i];
                i += 1;
                let input = token_count(&q.text).max(1);
                // Joining request pays its prefill (batch-1 insertion, as
                // chunked-prefill engines do at step boundaries).
                let pre = pre_sim.execute(&prefill_cost(&self.model, 1, input));
                now += pre.latency_s;
                m.energy_j += pre.energy_j;
                active.push(Seq {
                    arrival_s: arr,
                    input_tokens: input,
                    remaining: q.output_tokens.max(1),
                    ctx: input,
                });
            }
            if active.is_empty() {
                continue;
            }
            // One decode step for the whole running batch.
            let ctx = active.iter().map(|s| s.ctx).max().unwrap();
            let r = dec_sim.execute(&decode_step_cost(&self.model, active.len(), ctx));
            now += r.latency_s;
            m.energy_j += r.energy_j;
            for s in active.iter_mut() {
                s.remaining -= 1;
                s.ctx += 1;
            }
            // Retire finished sequences.
            active.retain(|s| {
                if s.remaining == 0 {
                    let lat = now - s.arrival_s;
                    if lat > self.cfg.slo_s {
                        m.slo_violations += 1;
                    }
                    m.latencies_s.push(lat);
                    m.served += 1;
                    let _ = s.input_tokens;
                    false
                } else {
                    true
                }
            });
        }
        m.makespan_s = now;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::workload::{Dataset, ReplaySuite};

    fn setup(batching: BatchingMode, rps: f64) -> (ReplaySuite, OnlineSim) {
        let suite = ReplaySuite::quick(31, 20);
        let sim = OnlineSim::new(
            GpuSpec::rtx_pro_6000(),
            model_for_tier(ModelTier::B8),
            OnlineConfig {
                arrival_rps: rps,
                max_batch: 8,
                batching,
                policy: DvfsPolicy::Static(2842),
                slo_s: 2.0,
                seed: 9,
            },
        );
        (suite, sim)
    }

    fn gen_queries(suite: &ReplaySuite) -> Vec<&Query> {
        suite
            .dataset_indices(Dataset::TruthfulQa)
            .into_iter()
            .map(|i| &suite.queries[i])
            .collect()
    }

    #[test]
    fn serves_every_request_and_accounts_energy() {
        for mode in [BatchingMode::Static, BatchingMode::Continuous] {
            let (suite, sim) = setup(mode, 5.0);
            let qs = gen_queries(&suite);
            let m = sim.run(&qs).unwrap();
            assert_eq!(m.served, qs.len(), "{mode:?}");
            assert_eq!(m.latencies_s.len(), qs.len());
            assert!(m.energy_j > 0.0);
            assert!(m.makespan_s > 0.0);
            assert!(m.latencies_s.iter().all(|&l| l > 0.0));
        }
    }

    #[test]
    fn continuous_batching_cuts_tail_latency_under_load() {
        // The vLLM/Orca claim: at high load, iteration-level scheduling
        // stops short requests from queueing behind full static batches.
        let (suite, sim_s) = setup(BatchingMode::Static, 12.0);
        let (_, sim_c) = setup(BatchingMode::Continuous, 12.0);
        let qs = gen_queries(&suite);
        let st = sim_s.run(&qs).unwrap();
        let ct = sim_c.run(&qs).unwrap();
        assert!(
            ct.percentile(95.0) < st.percentile(95.0) * 1.05,
            "continuous p95 {:.3}s vs static {:.3}s",
            ct.percentile(95.0),
            st.percentile(95.0)
        );
    }

    #[test]
    fn low_frequency_decode_preserves_online_throughput() {
        // The paper's DVFS claim transfers to the online setting.
        let (suite, mut sim) = setup(BatchingMode::Continuous, 6.0);
        let qs = gen_queries(&suite);
        let hi = sim.run(&qs).unwrap();
        sim.cfg.policy = DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 };
        let lo = sim.run(&qs).unwrap();
        let savings = 1.0 - lo.energy_j / hi.energy_j;
        let thr = lo.throughput_rps() / hi.throughput_rps();
        assert!(savings > 0.30, "online savings {savings:.3}");
        assert!(thr > 0.95, "throughput ratio {thr:.3}");
    }

    #[test]
    fn percentile_survives_a_nan_latency_sample() {
        // Regression: `partial_cmp().unwrap()` panicked on NaN; total_cmp
        // sorts NaN after every finite latency instead.
        let mut m = OnlineMetrics::default();
        m.latencies_s.extend([0.3, f64::NAN, 0.1, 0.2]);
        m.served = 4;
        assert_eq!(m.percentile(0.0), 0.1);
        assert!(m.percentile(100.0).is_nan());
    }

    #[test]
    fn zero_served_metrics_are_nan_not_silent() {
        let m = OnlineMetrics::default();
        assert!(m.violation_rate().is_nan());
        assert!(m.joules_per_request().is_nan());
    }

    #[test]
    fn slo_accounting_counts_violations() {
        let (suite, mut sim) = setup(BatchingMode::Static, 50.0);
        sim.cfg.slo_s = 0.001; // impossible SLO
        let qs = gen_queries(&suite);
        let m = sim.run(&qs).unwrap();
        assert_eq!(m.slo_violations, m.served);
        assert!((m.violation_rate() - 1.0).abs() < 1e-12);
    }
}
