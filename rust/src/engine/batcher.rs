//! Replay batcher: groups same-dataset queries into fixed-size batches
//! (the paper's offline setup runs each dataset at batch sizes 1/4/8).

use crate::workload::{Dataset, Query};

/// Fixed-size, dataset-homogeneous batching over a replay set.
pub struct Batcher {
    batch_size: usize,
}

impl Batcher {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size >= 1, "batch size must be >= 1");
        Batcher { batch_size }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Partition query indices into dataset-homogeneous batches, preserving
    /// arrival order within each dataset. The final batch of a dataset may
    /// be smaller than `batch_size`.
    pub fn batches(&self, queries: &[Query], indices: &[usize]) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for d in Dataset::ALL {
            let mut cur = Vec::with_capacity(self.batch_size);
            for &i in indices.iter().filter(|&&i| queries[i].dataset == d) {
                cur.push(i);
                if cur.len() == self.batch_size {
                    out.push(std::mem::take(&mut cur));
                }
            }
            if !cur.is_empty() {
                out.push(cur);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ReplaySuite;

    #[test]
    fn batches_are_homogeneous_and_cover_all() {
        let suite = ReplaySuite::quick(3, 10);
        let all: Vec<usize> = (0..suite.len()).collect();
        let b = Batcher::new(4);
        let batches = b.batches(&suite.queries, &all);
        let mut seen: Vec<usize> = batches.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, all);
        for batch in &batches {
            assert!(batch.len() <= 4 && !batch.is_empty());
            let d = suite.queries[batch[0]].dataset;
            assert!(batch.iter().all(|&i| suite.queries[i].dataset == d));
        }
        // 10 queries per dataset at batch 4 → 3 batches each (4+4+2).
        assert_eq!(batches.len(), 12);
    }

    #[test]
    fn batch_one_is_one_query_each() {
        let suite = ReplaySuite::quick(4, 5);
        let all: Vec<usize> = (0..suite.len()).collect();
        let batches = Batcher::new(1).batches(&suite.queries, &all);
        assert_eq!(batches.len(), suite.len());
    }

    #[test]
    #[should_panic(expected = "batch size")]
    fn zero_batch_panics() {
        Batcher::new(0);
    }
}
