//! KV-cache capacity manager for the simulated device.
//!
//! Tracks cache residency against the GPU memory left after weights, the
//! accounting a serving engine needs before admitting a batch (the paper's
//! Section II-B: the growing KV cache is the decode phase's memory driver).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::{GpuSpec, ModelSpec};

/// Tracks allocated KV bytes per active sequence.
pub struct KvCacheManager {
    capacity_bytes: u64,
    kv_bytes_per_token: u64,
    used_bytes: u64,
    seqs: HashMap<u64, u64>, // seq id -> allocated tokens
    peak_bytes: u64,
}

impl KvCacheManager {
    /// Budget = device memory − weights − activation headroom (5%).
    pub fn new(gpu: &GpuSpec, model: &ModelSpec) -> Self {
        let headroom = gpu.mem_capacity_bytes / 20;
        let capacity = gpu
            .mem_capacity_bytes
            .saturating_sub(model.weight_footprint_bytes())
            .saturating_sub(headroom);
        KvCacheManager {
            capacity_bytes: capacity,
            kv_bytes_per_token: model.kv_bytes_per_token() as u64,
            used_bytes: 0,
            seqs: HashMap::new(),
            peak_bytes: 0,
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    /// Admit a sequence with `tokens` of prompt context.
    pub fn admit(&mut self, seq_id: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let need = tokens as u64 * self.kv_bytes_per_token;
        if self.used_bytes + need > self.capacity_bytes {
            bail!(
                "KV cache OOM admitting seq {seq_id}: need {need} B, \
                 used {}/{} B",
                self.used_bytes,
                self.capacity_bytes
            );
        }
        self.used_bytes += need;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.seqs.insert(seq_id, tokens as u64);
        Ok(())
    }

    /// Extend a sequence by one generated token.
    pub fn extend(&mut self, seq_id: u64) -> Result<()> {
        let Some(tokens) = self.seqs.get_mut(&seq_id) else {
            bail!("sequence {seq_id} not admitted");
        };
        if self.used_bytes + self.kv_bytes_per_token > self.capacity_bytes {
            bail!("KV cache OOM extending seq {seq_id}");
        }
        *tokens += 1;
        self.used_bytes += self.kv_bytes_per_token;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        Ok(())
    }

    /// Release a finished sequence.
    pub fn release(&mut self, seq_id: u64) {
        if let Some(tokens) = self.seqs.remove(&seq_id) {
            self.used_bytes -= tokens * self.kv_bytes_per_token;
        }
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};

    fn mgr(tier: ModelTier) -> KvCacheManager {
        KvCacheManager::new(&GpuSpec::rtx_pro_6000(), &model_for_tier(tier))
    }

    #[test]
    fn admit_extend_release_accounting() {
        let mut m = mgr(ModelTier::B8);
        m.admit(1, 100).unwrap();
        let per_tok = 131_072u64;
        assert_eq!(m.used_bytes(), 100 * per_tok);
        m.extend(1).unwrap();
        assert_eq!(m.used_bytes(), 101 * per_tok);
        m.release(1);
        assert_eq!(m.used_bytes(), 0);
        assert_eq!(m.peak_bytes(), 101 * per_tok);
        assert_eq!(m.active_seqs(), 0);
    }

    #[test]
    fn double_admit_rejected() {
        let mut m = mgr(ModelTier::B1);
        m.admit(7, 10).unwrap();
        assert!(m.admit(7, 10).is_err());
    }

    #[test]
    fn extend_unknown_rejected() {
        let mut m = mgr(ModelTier::B1);
        assert!(m.extend(9).is_err());
    }

    #[test]
    fn oom_on_capacity_exhaustion() {
        let model = model_for_tier(ModelTier::B32);
        let mut m = KvCacheManager::new(&GpuSpec::rtx_pro_6000(), &model);
        // One enormous context that cannot fit the post-weights budget.
        let too_many =
            (m.capacity_bytes() / model.kv_bytes_per_token() as u64 + 1) as usize;
        assert!(m.admit(1, too_many).is_err());
        assert_eq!(m.used_bytes(), 0); // failed admit must not leak
        // Just inside the budget is fine.
        m.admit(2, too_many - 2).unwrap();
        assert!(m.extend(2).is_ok());
    }

    #[test]
    fn capacity_smaller_for_bigger_models() {
        assert!(mgr(ModelTier::B32).capacity_bytes() < mgr(ModelTier::B1).capacity_bytes());
    }
}
