//! Two-phase inference engine.
//!
//! Mirrors the paper's offline replay harness (Section IV): queries are
//! grouped into fixed-size batches, each batch runs a prefill pass followed
//! by an autoregressive decode loop, and every phase step is executed on the
//! simulated GPU ([`crate::gpu::GpuSim`]) with per-phase latency/energy
//! instrumentation — the `torch.cuda.synchronize()`-fenced measurement the
//! paper describes.
//!
//! The same engine structure also drives the *real* PJRT tiny-LM path in
//! [`crate::coordinator::server`] (the end-to-end example).

pub mod batcher;
pub mod online;
pub mod kvcache;
pub mod phases;
pub mod replay;
pub mod request;

pub use batcher::Batcher;
pub use online::{BatchingMode, OnlineConfig, OnlineMetrics, OnlineSim};
pub use kvcache::KvCacheManager;
pub use phases::{simulate_batch, BatchMetrics};
pub use replay::{ReplayEngine, ReplayMetrics};
pub use request::{QueryMetrics, RequestOutcome};
