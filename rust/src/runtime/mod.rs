//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + raw weights + manifest) and executes them on the PJRT CPU
//! client — the self-contained request path. Python never runs here.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes HloModuleProtos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).

pub mod artifact;
pub mod client;
pub mod tinylm;

pub use artifact::{Manifest, ProgramSpec, TensorSpec, TierArtifacts};
pub use client::RuntimeClient;
pub use tinylm::{DecodeState, TinyLm};
