//! Thin wrapper over the PJRT CPU client: compile HLO text, manage device
//! buffers. One client is shared by all loaded models.

use std::path::Path;

use anyhow::{Context, Result};

/// Shared PJRT client handle.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU PJRT client (the only backend in this environment;
    /// real deployments would select TPU/GPU plugins here).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it to an executable.
    pub fn compile_hlo_text(&self, path: impl AsRef<Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Upload an f32 host tensor.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer")
    }

    /// Upload a host literal (used by the tuple-output fallback path).
    pub fn upload_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .context("uploading literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().unwrap();
        assert!(c.platform().to_lowercase().contains("cpu") || !c.platform().is_empty());
    }

    #[test]
    fn uploads_round_trip() {
        let c = RuntimeClient::cpu().unwrap();
        let b = c.upload_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let bi = c.upload_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(bi.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn wrong_dims_rejected() {
        let c = RuntimeClient::cpu().unwrap();
        assert!(c.upload_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
