//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::JsonValue;

/// One tensor inside a tier's raw weight blob.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset into the weights file.
    pub offset: usize,
    pub nelems: usize,
}

/// One compiled program (phase × batch) of a tier.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: String,
    pub phase: String,
    pub batch: usize,
    /// Input signature: (shape, dtype) per flat argument.
    pub inputs: Vec<(Vec<usize>, String)>,
}

/// Architecture metadata of a tier (mirrors python's ModelConfig).
#[derive(Debug, Clone, Copy)]
pub struct TierConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

/// Everything the runtime needs for one tier.
#[derive(Debug, Clone)]
pub struct TierArtifacts {
    pub name: String,
    pub config: TierConfig,
    pub param_count: u64,
    pub weights_file: String,
    pub weights_bytes: usize,
    pub tensors: Vec<TensorSpec>,
    pub programs: BTreeMap<String, ProgramSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub prefill_seq: usize,
    pub tiers: BTreeMap<String, TierArtifacts>,
}

fn get<'a>(v: &'a JsonValue, key: &str) -> Result<&'a JsonValue> {
    v.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"))
}

fn get_usize(v: &JsonValue, key: &str) -> Result<usize> {
    get(v, key)?
        .as_usize()
        .ok_or_else(|| anyhow!("manifest key {key:?} is not a number"))
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = JsonValue::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let format = get_usize(&v, "format")?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let prefill_seq = get_usize(&v, "prefill_seq")?;
        let mut tiers = BTreeMap::new();
        for (name, tv) in get(&v, "tiers")?
            .as_object()
            .ok_or_else(|| anyhow!("tiers must be an object"))?
        {
            tiers.insert(name.clone(), parse_tier(name, tv)?);
        }
        Ok(Manifest { dir, prefill_seq, tiers })
    }

    pub fn tier(&self, name: &str) -> Result<&TierArtifacts> {
        self.tiers
            .get(name)
            .ok_or_else(|| anyhow!("tier {name:?} not in manifest (have: {:?})",
                self.tiers.keys().collect::<Vec<_>>()))
    }

    /// Read a tier's weight blob as little-endian f32s per tensor.
    pub fn load_weights(&self, tier: &TierArtifacts) -> Result<Vec<(TensorSpec, Vec<f32>)>> {
        let path = self.dir.join(&tier.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        if bytes.len() != tier.weights_bytes {
            bail!(
                "weight blob size mismatch: file {} bytes, manifest says {}",
                bytes.len(),
                tier.weights_bytes
            );
        }
        let mut out = Vec::with_capacity(tier.tensors.len());
        for t in &tier.tensors {
            let start = t.offset;
            let end = start + t.nelems * 4;
            if end > bytes.len() {
                bail!("tensor {} overruns weight blob", t.name);
            }
            let mut data = Vec::with_capacity(t.nelems);
            for c in bytes[start..end].chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            out.push((t.clone(), data));
        }
        Ok(out)
    }
}

fn parse_tier(name: &str, v: &JsonValue) -> Result<TierArtifacts> {
    let cfg = get(v, "config")?;
    let config = TierConfig {
        vocab: get_usize(cfg, "vocab")?,
        d_model: get_usize(cfg, "d_model")?,
        n_layers: get_usize(cfg, "n_layers")?,
        n_heads: get_usize(cfg, "n_heads")?,
        n_kv_heads: get_usize(cfg, "n_kv_heads")?,
        d_ff: get_usize(cfg, "d_ff")?,
        max_seq: get_usize(cfg, "max_seq")?,
        head_dim: get_usize(cfg, "head_dim")?,
    };
    let mut tensors = Vec::new();
    for tv in get(v, "tensors")?
        .as_array()
        .ok_or_else(|| anyhow!("tensors must be an array"))?
    {
        tensors.push(TensorSpec {
            name: get(tv, "name")?
                .as_str()
                .ok_or_else(|| anyhow!("tensor name"))?
                .to_string(),
            shape: get(tv, "shape")?
                .as_array()
                .ok_or_else(|| anyhow!("tensor shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
            offset: get_usize(tv, "offset")?,
            nelems: get_usize(tv, "nelems")?,
        });
    }
    let mut programs = BTreeMap::new();
    for (pname, pv) in get(v, "programs")?
        .as_object()
        .ok_or_else(|| anyhow!("programs must be an object"))?
    {
        let inputs = get(pv, "inputs")?
            .as_array()
            .ok_or_else(|| anyhow!("program inputs"))?
            .iter()
            .map(|iv| {
                let shape = iv
                    .get("shape")
                    .and_then(|s| s.as_array())
                    .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0)).collect())
                    .unwrap_or_default();
                let dtype = iv
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string();
                (shape, dtype)
            })
            .collect();
        programs.insert(
            pname.clone(),
            ProgramSpec {
                file: get(pv, "file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("program file"))?
                    .to_string(),
                phase: get(pv, "phase")?
                    .as_str()
                    .ok_or_else(|| anyhow!("program phase"))?
                    .to_string(),
                batch: get_usize(pv, "batch")?,
                inputs,
            },
        );
    }
    Ok(TierArtifacts {
        name: name.to_string(),
        config,
        param_count: get_usize(v, "param_count")? as u64,
        weights_file: get(v, "weights")?
            .as_str()
            .ok_or_else(|| anyhow!("weights file"))?
            .to_string(),
        weights_bytes: get_usize(v, "weights_bytes")?,
        tensors,
        programs,
    })
}

/// Default artifacts directory: `$EWATT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("EWATT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load(default_dir()).ok()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = manifest() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        assert_eq!(m.prefill_seq, 64);
        let t1 = m.tier("t1").unwrap();
        assert_eq!(t1.config.d_model, 64);
        assert_eq!(t1.tensors.len(), 11);
        assert!(t1.programs.contains_key("prefill_b1"));
        assert!(t1.programs.contains_key("decode_b1"));
    }

    #[test]
    fn weights_round_trip_sizes() {
        let Some(m) = manifest() else {
            return;
        };
        let t1 = m.tier("t1").unwrap().clone();
        let w = m.load_weights(&t1).unwrap();
        let total: usize = w.iter().map(|(_, d)| d.len() * 4).sum();
        assert_eq!(total, t1.weights_bytes);
        // embed is first and matches [vocab, d_model].
        assert_eq!(w[0].0.name, "embed");
        assert_eq!(w[0].0.shape, vec![t1.config.vocab, t1.config.d_model]);
        // Values are finite floats, not garbage.
        assert!(w[0].1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn missing_tier_is_error() {
        let Some(m) = manifest() else {
            return;
        };
        assert!(m.tier("t99").is_err());
    }
}
