//! The executable tiny-LM: weights + compiled prefill/decode programs.
//!
//! Hot-path design: weights live as device buffers uploaded once; the KV
//! cache stays on device between decode steps (`execute_b`) — only token ids
//! and logits cross the host boundary per step, mirroring how a production
//! engine would drive a PJRT device.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{Manifest, TierArtifacts, TierConfig};
use super::client::RuntimeClient;

/// On-device decode state (KV cache buffers + position).
pub struct DecodeState {
    pub k_cache: xla::PjRtBuffer,
    pub v_cache: xla::PjRtBuffer,
    /// Next position to write (== current valid cache length).
    pub pos: usize,
    pub batch: usize,
}

/// A loaded, executable model tier.
pub struct TinyLm {
    pub tier: String,
    pub config: TierConfig,
    pub param_count: u64,
    weights: Vec<xla::PjRtBuffer>,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    prefill_seq: usize,
}

impl TinyLm {
    /// Load one tier: upload weights, compile all its programs.
    pub fn load(client: &RuntimeClient, manifest: &Manifest, tier_name: &str) -> Result<Self> {
        let tier: &TierArtifacts = manifest.tier(tier_name)?;
        let host_weights = manifest.load_weights(tier)?;
        let mut weights = Vec::with_capacity(host_weights.len());
        for (spec, data) in &host_weights {
            weights.push(
                client
                    .upload_f32(data, &spec.shape)
                    .with_context(|| format!("uploading {}", spec.name))?,
            );
        }
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        for (name, prog) in &tier.programs {
            let exe = client.compile_hlo_text(manifest.dir.join(&prog.file))?;
            match prog.phase.as_str() {
                "prefill" => prefill.insert(prog.batch, exe),
                "decode" => decode.insert(prog.batch, exe),
                other => bail!("unknown phase {other:?} in program {name}"),
            };
        }
        Ok(TinyLm {
            tier: tier_name.to_string(),
            config: tier.config,
            param_count: tier.param_count,
            weights,
            prefill,
            decode,
            prefill_seq: manifest.prefill_seq,
        })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.decode.keys().cloned().collect()
    }

    pub fn prefill_seq(&self) -> usize {
        self.prefill_seq
    }

    /// Run prefill over `tokens` (row-major `[batch, prefill_seq]`, padded by
    /// the caller). Returns per-row last-position logits and the on-device
    /// decode state.
    pub fn prefill(
        &self,
        client: &RuntimeClient,
        tokens: &[i32],
        batch: usize,
    ) -> Result<(Vec<f32>, DecodeState)> {
        let exe = self
            .prefill
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill program for batch {batch}"))?;
        if tokens.len() != batch * self.prefill_seq {
            bail!(
                "prefill expects {}x{} tokens, got {}",
                batch,
                self.prefill_seq,
                tokens.len()
            );
        }
        let tok = client.upload_i32(tokens, &[batch, self.prefill_seq])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok);
        let mut out = exe.execute_b(&args).context("prefill execute")?;
        let (logits, k, v) = untuple3(client, &mut out, &self.cache_dims(batch))?;
        Ok((
            logits,
            DecodeState { k_cache: k, v_cache: v, pos: self.prefill_seq, batch },
        ))
    }

    /// KV-cache dims for a batch: [L, B, Hkv, max_seq, Dh].
    fn cache_dims(&self, batch: usize) -> Vec<usize> {
        vec![
            self.config.n_layers,
            batch,
            self.config.n_kv_heads,
            self.config.max_seq,
            self.config.head_dim,
        ]
    }

    /// One decode step: feed `tokens` (one per row), advance the cache.
    /// Returns logits `[batch, vocab]` flattened.
    pub fn decode_step(
        &self,
        client: &RuntimeClient,
        state: &mut DecodeState,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let batch = state.batch;
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode program for batch {batch}"))?;
        if tokens.len() != batch {
            bail!("decode expects {batch} tokens, got {}", tokens.len());
        }
        if state.pos >= self.config.max_seq {
            bail!("KV cache exhausted (pos {} >= max_seq {})", state.pos, self.config.max_seq);
        }
        let tok = client.upload_i32(tokens, &[batch])?;
        let pos = client.upload_i32(&[state.pos as i32], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&state.k_cache);
        args.push(&state.v_cache);
        args.push(&pos);
        let mut out = exe.execute_b(&args).context("decode execute")?;
        let (logits, k, v) = untuple3(client, &mut out, &self.cache_dims(batch))?;
        state.k_cache = k;
        state.v_cache = v;
        state.pos += 1;
        Ok(logits)
    }

    /// Greedy argmax over `[batch, vocab]` logits.
    pub fn argmax(&self, logits: &[f32], batch: usize) -> Vec<i32> {
        let v = self.config.vocab;
        (0..batch)
            .map(|b| {
                let row = &logits[b * v..(b + 1) * v];
                let mut best = 0usize;
                for (i, &x) in row.iter().enumerate() {
                    if x > row[best] {
                        best = i;
                    }
                }
                best as i32
            })
            .collect()
    }
}

/// Unpack a (logits, k_cache, v_cache) execution result.
///
/// jax lowering uses `return_tuple=True`. xla_extension 0.5.1's PJRT CPU
/// client does not set `untuple_result`, so the three outputs arrive as ONE
/// tuple buffer: decompose through a host literal and re-upload the caches.
/// (Newer plugins untuple — that path keeps everything on device.) The
/// round-trip is the known hot-path cost of this plugin version; measured in
/// `benches/engine_hotpath.rs` and discussed in EXPERIMENTS.md §Perf.
fn untuple3(
    client: &RuntimeClient,
    out: &mut Vec<Vec<xla::PjRtBuffer>>,
    cache_dims: &[usize],
) -> Result<(Vec<f32>, xla::PjRtBuffer, xla::PjRtBuffer)> {
    let replica = out.pop().ok_or_else(|| anyhow!("no execution outputs"))?;
    match replica.len() {
        3 => {
            let mut it = replica.into_iter();
            let logits = it.next().unwrap().to_literal_sync()?.to_vec::<f32>()?;
            Ok((logits, it.next().unwrap(), it.next().unwrap()))
        }
        1 => {
            let lit = replica[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != 3 {
                bail!("expected 3-tuple output, got {}", parts.len());
            }
            let mut it = parts.into_iter();
            let logits = it.next().unwrap().to_vec::<f32>()?;
            // NOTE: upload via the copying host-buffer path
            // (kImmutableOnlyDuringCall) — buffer_from_host_literal in
            // xla_extension 0.5.1 does not await the transfer, so the
            // literal could be freed mid-copy (observed segfault).
            let k_host = it.next().unwrap().to_vec::<f32>()?;
            let v_host = it.next().unwrap().to_vec::<f32>()?;
            let k = client.upload_f32(&k_host, cache_dims)?;
            let v = client.upload_f32(&v_host, cache_dims)?;
            Ok((logits, k, v))
        }
        n => bail!("unexpected output arity {n}"),
    }
}
