//! Quality surrogate and difficulty labelling (Section V).
//!
//! Substitutes running the five pretrained models against gold answers
//! (impossible offline — DESIGN.md §3): quality is modelled as a calibrated
//! function of the *published* per-dataset/per-model means (Table VII) and
//! the per-query semantic features the paper identifies as difficulty
//! drivers (entity density, causal-question score), plus a shared per-query
//! latent difficulty that correlates outcomes across model sizes — the
//! property that produces the paper's scaling patterns (Table IX).

pub mod labels;
pub mod surrogate;

pub use labels::{classify_patterns, easy_hard_labels, QualityMatrix, ScalingPattern};
pub use surrogate::QualityModel;
