//! Difficulty labels and scaling patterns (Sections V-D2 and V-E3).

use crate::config::ModelTier;
use crate::stats::minmax_normalize;
use crate::workload::{Dataset, ReplaySuite};

use super::surrogate::QualityModel;

/// Per-query × per-tier quality scores over a suite, plus dataset-normalized
/// variants (the paper min-max normalizes within each dataset so accuracy
/// and ROUGE-L live on comparable scales).
pub struct QualityMatrix {
    /// `raw[t][i]`: quality of query i on tier t.
    pub raw: Vec<Vec<f64>>,
    /// `norm[t][i]`: min-max normalized within the query's dataset.
    pub norm: Vec<Vec<f64>>,
}

impl QualityMatrix {
    /// Evaluate the surrogate over the whole suite.
    pub fn build(suite: &ReplaySuite, qm: &QualityModel) -> Self {
        let n = suite.len();
        let mut raw = vec![vec![0.0; n]; 5];
        for t in ModelTier::ALL {
            let row = &mut raw[t.index()];
            for i in 0..n {
                row[i] = qm.sample(&suite.queries[i], &suite.features[i], t);
            }
        }
        let mut norm = raw.clone();
        for t in 0..5 {
            for d in Dataset::ALL {
                let idx = suite.dataset_indices(d);
                let mut vals: Vec<f64> = idx.iter().map(|&i| norm[t][i]).collect();
                minmax_normalize(&mut vals);
                for (j, &i) in idx.iter().enumerate() {
                    norm[t][i] = vals[j];
                }
            }
        }
        QualityMatrix { raw, norm }
    }

    /// Normalized mean across tiers for query i.
    pub fn mean_norm(&self, i: usize) -> f64 {
        self.norm.iter().map(|row| row[i]).sum::<f64>() / 5.0
    }

    /// Mean raw quality of tier t over a set of query indices.
    pub fn mean_raw_over(&self, t: ModelTier, idx: &[usize]) -> f64 {
        if idx.is_empty() {
            return f64::NAN;
        }
        idx.iter().map(|&i| self.raw[t.index()][i]).sum::<f64>() / idx.len() as f64
    }
}

/// Binary easy/hard labels: easy ⇔ normalized mean quality across models
/// exceeds the dataset median (Section V-D2 — yields ≈ 49/51 split).
pub fn easy_hard_labels(suite: &ReplaySuite, qm: &QualityMatrix) -> Vec<bool> {
    let n = suite.len();
    // Classification outcomes are binary, so per-query means sit on a coarse
    // grid with mass exactly at the median; a deterministic sub-ULP jitter
    // breaks ties so the split stays ≈ balanced (the paper reports 49/51).
    let means: Vec<f64> = (0..n)
        .map(|i| {
            let jitter = (suite.queries[i].id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40) as f64
                * 1e-13;
            qm.mean_norm(i) + jitter
        })
        .collect();
    let mut easy = vec![false; n];
    for d in Dataset::ALL {
        let idx = suite.dataset_indices(d);
        let mut vals: Vec<f64> = idx.iter().map(|&i| means[i]).collect();
        // total_cmp: a NaN mean (empty matrix row) sorts last, not panics.
        vals.sort_by(f64::total_cmp);
        let median = vals[vals.len() / 2];
        for &i in &idx {
            easy[i] = means[i] > median;
        }
    }
    easy
}

/// The paper's four scaling patterns (Table IX).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScalingPattern {
    /// Easy for all five models — route to 1–3B.
    AlwaysEasy,
    /// Fails on small models, succeeds from 8B up — the routing win.
    ScalingHelps,
    /// Hard for every size — scaling wastes energy.
    AlwaysHard,
    /// Architecture-dependent behaviour.
    Inconsistent,
}

impl ScalingPattern {
    pub const ALL: [ScalingPattern; 4] = [
        ScalingPattern::AlwaysEasy,
        ScalingPattern::ScalingHelps,
        ScalingPattern::AlwaysHard,
        ScalingPattern::Inconsistent,
    ];

    pub fn label(self) -> &'static str {
        match self {
            ScalingPattern::AlwaysEasy => "Always Easy",
            ScalingPattern::ScalingHelps => "Scaling Helps",
            ScalingPattern::AlwaysHard => "Always Hard",
            ScalingPattern::Inconsistent => "Inconsistent",
        }
    }
}

/// Classify each query by per-tier success (normalized quality ≥ 0.5).
pub fn classify_patterns(qm: &QualityMatrix) -> Vec<ScalingPattern> {
    let n = qm.raw[0].len();
    (0..n)
        .map(|i| {
            let succ: Vec<bool> = (0..5).map(|t| qm.norm[t][i] >= 0.5).collect();
            // "Fail on small models but succeed on 8B+" (Section V-E3).
            let small_fail_any = !succ[0] || !succ[1];
            let large_ok = succ[2] && succ[3] && succ[4];
            if succ.iter().all(|&s| s) {
                ScalingPattern::AlwaysEasy
            } else if succ.iter().all(|&s| !s) {
                ScalingPattern::AlwaysHard
            } else if small_fail_any && large_ok {
                ScalingPattern::ScalingHelps
            } else {
                ScalingPattern::Inconsistent
            }
        })
        .collect()
}

/// Pattern shares in suite order of [`ScalingPattern::ALL`] (fractions).
pub fn pattern_shares(patterns: &[ScalingPattern]) -> [f64; 4] {
    let n = patterns.len().max(1) as f64;
    let mut out = [0.0; 4];
    for p in patterns {
        let k = ScalingPattern::ALL.iter().position(|x| x == p).unwrap();
        out[k] += 1.0;
    }
    out.iter_mut().for_each(|x| *x /= n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ReplaySuite;

    fn matrix(seed: u64, n: usize) -> (ReplaySuite, QualityMatrix) {
        let suite = ReplaySuite::quick(seed, n);
        let qm = QualityModel::new();
        let m = QualityMatrix::build(&suite, &qm);
        (suite, m)
    }

    #[test]
    fn easy_hard_split_is_roughly_balanced() {
        let (suite, m) = matrix(41, 300);
        let labels = easy_hard_labels(&suite, &m);
        let frac = labels.iter().filter(|&&e| e).count() as f64 / labels.len() as f64;
        // Paper: 49% easy / 51% hard.
        assert!((0.35..=0.65).contains(&frac), "easy fraction {frac}");
    }

    #[test]
    fn easy_queries_score_higher_on_every_tier() {
        // Table X: positive gap for all five models.
        let (suite, m) = matrix(43, 400);
        let labels = easy_hard_labels(&suite, &m);
        let easy_idx: Vec<usize> = (0..suite.len()).filter(|&i| labels[i]).collect();
        let hard_idx: Vec<usize> = (0..suite.len()).filter(|&i| !labels[i]).collect();
        for t in ModelTier::ALL {
            let gap = m.mean_raw_over(t, &easy_idx) - m.mean_raw_over(t, &hard_idx);
            assert!(gap > 0.05, "{}: easy-hard gap {gap:.3}", t.label());
        }
    }

    #[test]
    fn pattern_shares_match_table9_bands() {
        let (_suite, m) = matrix(47, 500);
        let patterns = classify_patterns(&m);
        let shares = pattern_shares(&patterns);
        // Table IX: 44.5 / 15.5 / 32.6 / 7.4 — generous ±10pp bands.
        assert!((0.30..=0.60).contains(&shares[0]), "AlwaysEasy {:.3}", shares[0]);
        assert!((0.05..=0.30).contains(&shares[1]), "ScalingHelps {:.3}", shares[1]);
        assert!((0.18..=0.45).contains(&shares[2]), "AlwaysHard {:.3}", shares[2]);
        assert!(shares[3] < 0.20, "Inconsistent {:.3}", shares[3]);
        let total: f64 = shares.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn always_easy_queries_have_lower_entity_density() {
        // Table IX's feature profile: Easy ⇒ entity 0.17 vs Hard ⇒ 0.27.
        let (suite, m) = matrix(53, 400);
        let patterns = classify_patterns(&m);
        let mean_entity = |p: ScalingPattern| {
            let idx: Vec<usize> = (0..suite.len())
                .filter(|&i| patterns[i] == p)
                .collect();
            idx.iter().map(|&i| suite.features[i].entity_density).sum::<f64>()
                / idx.len().max(1) as f64
        };
        assert!(mean_entity(ScalingPattern::AlwaysEasy) < mean_entity(ScalingPattern::AlwaysHard));
    }
}
