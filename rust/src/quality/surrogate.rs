//! The calibrated quality model.
//!
//! For query q (dataset d, features x) and model tier t:
//!
//! ```text
//! difficulty(q) = w_e·entity(q) + w_c·causal(q) + σ_u·u_q      u_q ~ N(0,1)
//! score_t(q)    = M[d][t] + s·(μ_d − difficulty(q)) + σ_ε·ε_qt
//! ```
//!
//! - `M[d][t]` is Table VII verbatim — the published calibration points.
//! - `u_q` is shared across tiers (same query, similar model families), so
//!   outcomes are strongly correlated across sizes; `ε_qt` is the small
//!   independent component (different tokenizers/architectures).
//! - Classification datasets emit Bernoulli-like {0,1} accuracy via a
//!   probit threshold chosen so the per-dataset mean equals `M[d][t]`;
//!   generation datasets emit clipped continuous ROUGE-L-like scores.

use crate::config::ModelTier;
use crate::features::FeatureVector;
use crate::stats::descriptive::probit;
use crate::workload::gen::profile;
use crate::workload::{Dataset, Query, TaskKind};

/// Table VII of the paper: quality by model and dataset (accuracy for
/// classification, ROUGE-L for generation).
pub const QUALITY_MEANS: [(Dataset, [f64; 5]); 4] = [
    (Dataset::BoolQ, [0.685, 0.785, 0.855, 0.785, 0.815]),
    (Dataset::HellaSwag, [0.640, 0.755, 0.805, 0.830, 0.860]),
    (Dataset::TruthfulQa, [0.208, 0.211, 0.207, 0.243, 0.252]),
    (Dataset::NarrativeQa, [0.161, 0.306, 0.368, 0.474, 0.455]),
];

/// Feature weights of the latent difficulty (entity density dominates —
/// Section V-F insight 2).
const W_ENTITY: f64 = 1.0;
const W_CAUSAL: f64 = 0.35;
/// Shared latent difficulty noise (correlates tiers).
const SIGMA_U: f64 = 0.11;
/// Independent per-(query, tier) noise.
const SIGMA_EPS: f64 = 0.045;
/// Difficulty → score sensitivity.
const SENS: f64 = 0.9;

/// Calibrated quality surrogate.
#[derive(Debug, Clone, Default)]
pub struct QualityModel;

impl QualityModel {
    pub fn new() -> Self {
        QualityModel
    }

    /// Published mean quality (Table VII).
    pub fn mean(&self, d: Dataset, t: ModelTier) -> f64 {
        QUALITY_MEANS
            .iter()
            .find(|(dd, _)| *dd == d)
            .map(|(_, row)| row[t.index()])
            .expect("all datasets present")
    }

    /// Noise-free semantic difficulty from features alone — the observable
    /// part of [`Self::difficulty`]. This is what an online router can see
    /// at request time (the latent noise is unknowable before serving), so
    /// the fleet layer's difficulty-tiered routing keys on it.
    pub fn feature_difficulty(x: &FeatureVector) -> f64 {
        W_ENTITY * x.entity_density + W_CAUSAL * x.causal_question
    }

    /// Latent difficulty of a query (higher = harder), centred near the
    /// dataset's feature profile.
    pub fn difficulty(&self, q: &Query, x: &FeatureVector) -> f64 {
        let u = latent_noise(q.id);
        Self::feature_difficulty(x) + SIGMA_U * u
    }

    /// Dataset-mean difficulty (for centring), from the generator profile.
    fn mean_difficulty(&self, d: Dataset) -> f64 {
        let p = profile(d);
        W_ENTITY * p.entity_rate + W_CAUSAL * p.causal_rate
    }

    /// Difficulty spread within a dataset (for the probit calibration).
    fn sigma_difficulty(&self, d: Dataset) -> f64 {
        let p = profile(d);
        // Entity density of an n-word query is a binomial proportion;
        // approximate its std from the mean query length.
        let n = p.mean_tokens.max(4.0);
        let var_entity = p.entity_rate * (1.0 - p.entity_rate) / n;
        let var_causal = p.causal_rate * (1.0 - p.causal_rate);
        (W_ENTITY * W_ENTITY * var_entity
            + W_CAUSAL * W_CAUSAL * var_causal
            + SIGMA_U * SIGMA_U)
            .sqrt()
    }

    /// Continuous expected score before task-specific emission.
    pub fn score(&self, q: &Query, x: &FeatureVector, t: ModelTier) -> f64 {
        let d = q.dataset;
        let eps = eps_noise(q.id, t);
        self.mean(d, t) + SENS * (self.mean_difficulty(d) - self.difficulty(q, x))
            + SIGMA_EPS * eps
    }

    /// Sampled per-query quality: {0,1} accuracy for classification,
    /// continuous ROUGE-L-like for generation. Deterministic in (query id,
    /// tier) — replays exactly.
    pub fn sample(&self, q: &Query, x: &FeatureVector, t: ModelTier) -> f64 {
        let d = q.dataset;
        match d.task() {
            TaskKind::Classification => {
                // Threshold the standardized score so that the dataset-level
                // accuracy equals M[d][t] by construction.
                let m = self.mean(d, t).clamp(0.02, 0.98);
                let sigma = (SENS * SENS * self.sigma_difficulty(d).powi(2)
                    + SIGMA_EPS * SIGMA_EPS)
                    .sqrt();
                let z = (self.score(q, x, t) - self.mean(d, t)) / sigma;
                if z > probit(1.0 - m) {
                    1.0
                } else {
                    0.0
                }
            }
            TaskKind::Generation => self.score(q, x, t).clamp(0.0, 1.0),
        }
    }
}

/// Deterministic standard-normal draw from the query id (shared latent).
fn latent_noise(id: u64) -> f64 {
    let mut r = crate::rng(id.wrapping_mul(0xD131_0BA6_98DF_B5AC));
    r.normal()
}

/// Deterministic independent noise per (query, tier).
fn eps_noise(id: u64, t: ModelTier) -> f64 {
    let mut r = crate::rng(id ^ (t.index() as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
    r.normal()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::FeatureExtractor;
    use crate::workload::ReplaySuite;

    #[test]
    fn dataset_means_match_table7() {
        // Means over a full-size suite must land on the published numbers.
        let suite = ReplaySuite::quick(17, 600);
        let qm = QualityModel::new();
        for (d, row) in QUALITY_MEANS {
            let idx = suite.dataset_indices(d);
            for t in ModelTier::ALL {
                let mean: f64 = idx
                    .iter()
                    .map(|&i| qm.sample(&suite.queries[i], &suite.features[i], t))
                    .sum::<f64>()
                    / idx.len() as f64;
                let target = row[t.index()];
                assert!(
                    (mean - target).abs() < 0.06,
                    "{} {}: mean {mean:.3} vs Table VII {target:.3}",
                    d.label(),
                    t.label()
                );
            }
        }
    }

    #[test]
    fn harder_features_lower_quality() {
        let qm = QualityModel::new();
        let fx = FeatureExtractor::new();
        let mut easy_q = crate::workload::gen::generate(Dataset::NarrativeQa, 1, 900_001, &mut crate::rng(5))
            .remove(0);
        easy_q.text = "Was the village quiet during winter mornings when snow covered the road?".into();
        let easy_f = fx.extract(&easy_q.text);
        let mut hard_q = easy_q.clone();
        hard_q.text = "Why did Napoleon and Cleopatra justify the Habsburg treaty in Vienna near the Danube?".into();
        let hard_f = fx.extract(&hard_q.text);
        for t in ModelTier::ALL {
            assert!(
                qm.score(&hard_q, &hard_f, t) < qm.score(&easy_q, &easy_f, t),
                "{}: entity/causal-dense query must score lower",
                t.label()
            );
        }
    }

    #[test]
    fn classification_outputs_binary_generation_continuous() {
        let suite = ReplaySuite::quick(23, 40);
        let qm = QualityModel::new();
        for (i, q) in suite.queries.iter().enumerate() {
            let v = qm.sample(q, &suite.features[i], ModelTier::B8);
            match q.dataset.task() {
                TaskKind::Classification => assert!(v == 0.0 || v == 1.0),
                TaskKind::Generation => assert!((0.0..=1.0).contains(&v)),
            }
        }
    }

    #[test]
    fn outcomes_correlate_across_tiers() {
        // The shared latent must make per-query outcomes agree far more
        // often than independence would allow — the mechanism behind the
        // paper's 44.5% "always easy" share.
        let suite = ReplaySuite::quick(31, 400);
        let qm = QualityModel::new();
        let idx = suite.dataset_indices(Dataset::BoolQ);
        let (mut agree, mut n) = (0usize, 0usize);
        for &i in &idx {
            let a = qm.sample(&suite.queries[i], &suite.features[i], ModelTier::B1);
            let b = qm.sample(&suite.queries[i], &suite.features[i], ModelTier::B32);
            if a == b {
                agree += 1;
            }
            n += 1;
        }
        let rate = agree as f64 / n as f64;
        // Independence would give ~0.685·0.815 + 0.315·0.185 ≈ 0.62.
        assert!(rate > 0.72, "cross-tier agreement {rate:.3}");
    }

    #[test]
    fn sampling_is_deterministic() {
        let suite = ReplaySuite::quick(37, 10);
        let qm = QualityModel::new();
        let a = qm.sample(&suite.queries[0], &suite.features[0], ModelTier::B14);
        let b = qm.sample(&suite.queries[0], &suite.features[0], ModelTier::B14);
        assert_eq!(a, b);
    }
}
