//! The simulated GPU: executes phase work at a pinned SM frequency and
//! reports latency plus NVML-sampled energy.

use crate::config::{FreqMHz, GpuSpec};
use crate::perf::costmodel::PhaseCost;
use crate::perf::roofline::phase_time;

use super::power::{active_power, idle_power};
use super::telemetry::{PowerSampler, PowerSegment};
use super::thermal::throttle;

/// Result of executing one phase step.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseResult {
    /// Wall-clock latency in seconds (host + GPU, incl. throttling).
    pub latency_s: f64,
    /// GPU busy time in seconds.
    pub gpu_time_s: f64,
    /// Energy in joules as the NVML-style sampler would report it.
    pub energy_j: f64,
    /// Mean power during the step, watts.
    pub mean_power_w: f64,
    /// True if the sustained-power cap throttled this step.
    pub throttled: bool,
}

impl PhaseResult {
    /// Accumulate another step into this aggregate.
    pub fn add(&mut self, other: &PhaseResult) {
        self.latency_s += other.latency_s;
        self.gpu_time_s += other.gpu_time_s;
        self.energy_j += other.energy_j;
        self.throttled |= other.throttled;
        self.mean_power_w = if self.latency_s > 0.0 {
            self.energy_j / self.latency_s
        } else {
            0.0
        };
    }
}

/// A GPU pinned at one SM frequency (the paper pins clocks per experiment
/// via `nvidia-smi -lgc`; the phase-aware policy switches between two
/// pinned points and pays `f_switch_overhead_s`).
#[derive(Debug, Clone)]
pub struct GpuSim {
    pub spec: GpuSpec,
    freq: FreqMHz,
}

impl GpuSim {
    pub fn new(spec: GpuSpec, freq: FreqMHz) -> Self {
        assert!(
            spec.supports(freq),
            "frequency {freq} MHz not in the supported ladder {:?}",
            spec.freq_levels_mhz
        );
        GpuSim { spec, freq }
    }

    pub fn freq(&self) -> FreqMHz {
        self.freq
    }

    /// Change the SM set point; returns the switch latency to account for.
    pub fn set_freq(&mut self, freq: FreqMHz) -> f64 {
        assert!(self.spec.supports(freq), "unsupported frequency {freq}");
        if freq == self.freq {
            0.0
        } else {
            self.freq = freq;
            self.spec.f_switch_overhead_s
        }
    }

    /// Execute one phase step: roofline timing → power → thermal throttle →
    /// NVML-sampled energy.
    pub fn execute(&self, cost: &PhaseCost) -> PhaseResult {
        let b = phase_time(&self.spec, cost, self.freq);
        let p_req = active_power(&self.spec, self.freq, b.u_comp, b.u_mem);
        let (stretch, p_eff) = throttle(&self.spec, p_req);
        let t_gpu = b.t_gpu * stretch;
        let trace = [
            PowerSegment { duration_s: b.t_host, power_w: idle_power(&self.spec) },
            PowerSegment { duration_s: t_gpu, power_w: p_eff },
        ];
        let (energy_j, _) = PowerSampler::new(&self.spec).measure(&trace);
        let latency_s = b.t_host + t_gpu;
        PhaseResult {
            latency_s,
            gpu_time_s: t_gpu,
            energy_j,
            mean_power_w: energy_j / latency_s,
            throttled: stretch > 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::perf::costmodel::{decode_step_cost, prefill_cost};

    fn sim(f: FreqMHz) -> GpuSim {
        GpuSim::new(GpuSpec::rtx_pro_6000(), f)
    }

    #[test]
    fn decode_energy_drops_substantially_at_min_freq() {
        // The headline result: ~42% savings with ~unchanged decode latency.
        let m = model_for_tier(ModelTier::B8);
        let c = decode_step_cost(&m, 1, 128);
        let hi = sim(2842).execute(&c);
        let lo = sim(180).execute(&c);
        let savings = 1.0 - lo.energy_j / hi.energy_j;
        let lat = (lo.latency_s - hi.latency_s) / hi.latency_s;
        assert!(savings > 0.30 && savings < 0.55, "savings {savings:.3}");
        assert!(lat.abs() < 0.02, "decode latency Δ {lat:+.3}");
    }

    #[test]
    fn energy_per_step_monotone_in_frequency_for_decode() {
        let m = model_for_tier(ModelTier::B3);
        let c = decode_step_cost(&m, 4, 200);
        let spec = GpuSpec::rtx_pro_6000();
        let mut prev = 0.0;
        for &f in &spec.freq_levels_mhz {
            let e = sim(f).execute(&c).energy_j;
            assert!(e > prev, "E({f}) = {e} not increasing");
            prev = e;
        }
    }

    #[test]
    fn heavy_prefill_throttles_at_fmax_only() {
        let m = model_for_tier(ModelTier::B32);
        let c = prefill_cost(&m, 8, 300);
        let hi = sim(2842).execute(&c);
        let mid = sim(960).execute(&c);
        assert!(hi.throttled, "32B batched prefill should exceed the cap at fmax");
        assert!(!mid.throttled);
        assert!(hi.mean_power_w <= GpuSpec::rtx_pro_6000().p_sustain_w + 1e-9);
    }

    #[test]
    fn set_freq_charges_switch_overhead_once() {
        let mut s = sim(2842);
        assert_eq!(s.set_freq(2842), 0.0);
        let d = s.set_freq(180);
        assert!(d > 0.0);
        assert_eq!(s.freq(), 180);
    }

    #[test]
    #[should_panic(expected = "not in the supported ladder")]
    fn unsupported_frequency_panics() {
        sim(1234);
    }

    #[test]
    fn aggregate_add() {
        let m = model_for_tier(ModelTier::B1);
        let c = decode_step_cost(&m, 1, 64);
        let r = sim(960).execute(&c);
        let mut agg = PhaseResult::default();
        agg.add(&r);
        agg.add(&r);
        assert!((agg.energy_j - 2.0 * r.energy_j).abs() < 1e-12);
        assert!((agg.latency_s - 2.0 * r.latency_s).abs() < 1e-15);
    }
}
