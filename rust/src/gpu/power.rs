//! GPU power model.
//!
//! `P(f) = P_idle + P_mem·u_mem + P_sm·act·(f/f_max)·(V(f)/V_max)²`
//!
//! - `u_mem`: memory-bandwidth utilization (memory clock is never scaled, so
//!   this term is frequency-independent — it is why energy savings saturate
//!   at ~42% instead of approaching 100%).
//! - `act`: SM clock-domain activity, `max(u_comp, κ·u_mem)` — even
//!   memory-bound kernels keep the SM domain toggling to move data, which is
//!   why decode burns SM power at high clocks *without* running faster
//!   (Section VI-C: "higher frequencies during decode increase energy
//!   consumption without providing measurable performance benefits").
//! - The `f·V²` dynamic-power term with the voltage floor below `f_v0`
//!   produces the frequency cliff of Figure 4.

use crate::config::{FreqMHz, GpuSpec};

/// Active power draw in watts at frequency `f` with the given utilizations.
pub fn active_power(gpu: &GpuSpec, f: FreqMHz, u_comp: f64, u_mem: f64) -> f64 {
    let act = u_comp.max(gpu.kappa_mem_activity * u_mem).clamp(0.0, 1.0);
    // Compute-bound phases still stream activations/weights through the
    // memory subsystem even when bandwidth is not the bottleneck.
    let u_mem_eff = u_mem.max(0.4 * u_comp).clamp(0.0, 1.0);
    let v_ratio = gpu.voltage(f) / gpu.v_max;
    let f_ratio = f as f64 / gpu.f_max_mhz as f64;
    gpu.p_idle_w + gpu.p_mem_w * u_mem_eff + gpu.p_sm_w * act * f_ratio * v_ratio * v_ratio
}

/// Idle (host-side work in flight, GPU waiting) power draw.
pub fn idle_power(gpu: &GpuSpec) -> f64 {
    gpu.p_idle_w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::rtx_pro_6000()
    }

    #[test]
    fn power_increases_with_frequency() {
        let g = gpu();
        let mut prev = 0.0;
        for &f in &g.freq_levels_mhz {
            let p = active_power(&g, f, 0.9, 0.9);
            assert!(p > prev, "P({f}) = {p} not increasing");
            prev = p;
        }
    }

    #[test]
    fn memory_bound_phase_still_burns_sm_power_at_fmax() {
        // Decode shape: tiny compute utilization, saturated memory.
        let g = gpu();
        let p = active_power(&g, g.f_max_mhz, 0.05, 1.0);
        // SM term must be substantial (act = κ·u_mem), not just idle+mem.
        assert!(p > g.p_idle_w + g.p_mem_w + 100.0, "P = {p}");
    }

    #[test]
    fn sm_dynamic_power_nearly_gone_by_960() {
        let g = gpu();
        let p960 = active_power(&g, 960, 0.05, 1.0);
        let p180 = active_power(&g, 180, 0.05, 1.0);
        let pmax = active_power(&g, g.f_max_mhz, 0.05, 1.0);
        // The cliff: most of the max→min saving is already realized at 960.
        let frac = (pmax - p960) / (pmax - p180);
        assert!(frac > 0.80, "cliff fraction {frac}");
    }

    #[test]
    fn bounds_are_respected() {
        let g = gpu();
        let p = active_power(&g, g.f_max_mhz, 1.0, 1.0);
        assert!(p <= g.p_idle_w + g.p_mem_w + g.p_sm_w + 1e-9);
        let p0 = active_power(&g, 180, 0.0, 0.0);
        assert!((p0 - g.p_idle_w).abs() < 1e-9);
        assert_eq!(idle_power(&g), g.p_idle_w);
    }
}

/// Power-cap governor (extension; cf. the paper's related work on power
/// limits [33], [34]): the highest supported frequency whose predicted
/// power for `cost`-shaped work stays within `cap_w`. Falls back to the
/// floor frequency if even that exceeds the cap.
pub fn frequency_for_cap(
    gpu: &GpuSpec,
    cost: &crate::perf::costmodel::PhaseCost,
    cap_w: f64,
) -> FreqMHz {
    let mut best = gpu.f_min_mhz();
    for &f in &gpu.freq_levels_mhz {
        let b = crate::perf::roofline::phase_time(gpu, cost, f);
        let p = active_power(gpu, f, b.u_comp, b.u_mem);
        if p <= cap_w && f > best {
            best = f;
        }
    }
    best
}

#[cfg(test)]
mod cap_tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};
    use crate::perf::costmodel::{decode_step_cost, prefill_cost};

    #[test]
    fn cap_picks_monotone_frequencies() {
        let g = GpuSpec::rtx_pro_6000();
        let m = model_for_tier(ModelTier::B8);
        let c = decode_step_cost(&m, 1, 256);
        let mut prev = 0;
        for cap in [200.0, 300.0, 400.0, 600.0] {
            let f = frequency_for_cap(&g, &c, cap);
            assert!(f >= prev, "cap {cap}: f {f} < prev {prev}");
            prev = f;
        }
        // A generous cap allows max frequency.
        assert_eq!(frequency_for_cap(&g, &c, 1000.0), g.f_max_mhz);
    }

    #[test]
    fn compute_heavy_prefill_needs_lower_freq_for_same_cap() {
        let g = GpuSpec::rtx_pro_6000();
        let m = model_for_tier(ModelTier::B32);
        let pre = prefill_cost(&m, 8, 300);
        let dec = decode_step_cost(&m, 1, 256);
        let cap = 350.0;
        assert!(frequency_for_cap(&g, &pre, cap) <= frequency_for_cap(&g, &dec, cap));
    }

    #[test]
    fn impossible_cap_falls_back_to_floor() {
        let g = GpuSpec::rtx_pro_6000();
        let m = model_for_tier(ModelTier::B8);
        let c = decode_step_cost(&m, 1, 256);
        assert_eq!(frequency_for_cap(&g, &c, 1.0), g.f_min_mhz());
    }
}
