//! NVML-style power telemetry.
//!
//! The paper measures GPU power by sampling NVML at 10 ms via `nvidia-smi`
//! and integrating to joules (Section IV-B). The simulator reproduces the
//! *measurement process*, not just the ground truth: the power trace is a
//! piecewise-constant signal, the sampler reads it on a fixed 10 ms grid,
//! and energy is trapezoidally integrated over the samples — including the
//! quantization error a real NVML pipeline has on short requests.

use std::collections::VecDeque;

use crate::config::GpuSpec;

/// One segment of the simulated power trace.
#[derive(Debug, Clone, Copy)]
pub struct PowerSegment {
    pub duration_s: f64,
    pub power_w: f64,
}

/// Fixed-period sampler over a piecewise-constant power trace.
pub struct PowerSampler {
    period_s: f64,
}

impl PowerSampler {
    pub fn new(gpu: &GpuSpec) -> Self {
        PowerSampler { period_s: gpu.telemetry_period_s }
    }

    pub fn with_period(period_s: f64) -> Self {
        PowerSampler { period_s }
    }

    /// Power at absolute time `t` within the trace.
    fn power_at(trace: &[PowerSegment], t: f64) -> f64 {
        let mut acc = 0.0;
        for seg in trace {
            acc += seg.duration_s;
            if t < acc {
                return seg.power_w;
            }
        }
        trace.last().map(|s| s.power_w).unwrap_or(0.0)
    }

    /// Sample the trace on the fixed grid and trapezoidally integrate.
    /// Returns (energy_joules, n_samples).
    ///
    /// Streaming implementation — no sample buffer. This sits inside every
    /// simulated phase step (millions of calls per sweep), so it is kept
    /// allocation-free; see EXPERIMENTS.md §Perf.
    pub fn measure(&self, trace: &[PowerSegment]) -> (f64, usize) {
        let total: f64 = trace.iter().map(|s| s.duration_s).sum();
        if total <= 0.0 {
            return (0.0, 0);
        }
        // Samples at t = 0, p, 2p, ..., and the trailing edge.
        let mut energy = 0.0;
        let mut prev_t = 0.0;
        let mut prev_p = Self::power_at(trace, 0.0);
        let mut n = 1usize;
        let mut t = self.period_s;
        while t < total {
            let p = Self::power_at(trace, t);
            energy += 0.5 * (prev_p + p) * (t - prev_t);
            prev_t = t;
            prev_p = p;
            n += 1;
            t += self.period_s;
        }
        let p_end = Self::power_at(trace, total - 1e-12);
        energy += 0.5 * (prev_p + p_end) * (total - prev_t);
        (energy, n + 1)
    }

    /// Exact integral (ground truth, for validating the sampler).
    pub fn exact(trace: &[PowerSegment]) -> f64 {
        trace.iter().map(|s| s.duration_s * s.power_w).sum()
    }
}

/// One executed-step sample in the sliding telemetry window.
#[derive(Debug, Clone, Copy)]
pub struct StepSample {
    /// Simulated time at which the step finished, seconds.
    pub t_end_s: f64,
    /// GPU-busy duration of the step, seconds.
    pub duration_s: f64,
    /// Sampled energy of the step, joules.
    pub energy_j: f64,
}

/// Sliding-horizon telemetry readout.
///
/// Closed-loop controllers (the serve layer's DVFS governor) need *recent*
/// power/utilization, not lifetime aggregates: a governor reacting to the
/// mean power of the whole run would never see a burst. The window retains
/// per-step samples whose end time lies within `horizon_s` of the newest
/// sample and reports windowed mean power, energy, and busy fraction.
#[derive(Debug, Clone)]
pub struct TelemetryWindow {
    horizon_s: f64,
    samples: VecDeque<StepSample>,
}

impl TelemetryWindow {
    pub fn new(horizon_s: f64) -> TelemetryWindow {
        assert!(horizon_s > 0.0, "telemetry horizon must be positive");
        TelemetryWindow { horizon_s, samples: VecDeque::new() }
    }

    pub fn horizon_s(&self) -> f64 {
        self.horizon_s
    }

    /// Record a finished step and evict samples that fell out of the window.
    /// `t_end_s` must be non-decreasing across calls.
    pub fn record(&mut self, t_end_s: f64, duration_s: f64, energy_j: f64) {
        self.samples.push_back(StepSample { t_end_s, duration_s, energy_j });
        let cutoff = t_end_s - self.horizon_s;
        while self.samples.front().is_some_and(|s| s.t_end_s < cutoff) {
            self.samples.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total sampled energy inside the window, joules.
    pub fn energy_j(&self) -> f64 {
        self.samples.iter().map(|s| s.energy_j).sum()
    }

    /// Total GPU-busy time inside the window, seconds.
    pub fn busy_s(&self) -> f64 {
        self.samples.iter().map(|s| s.duration_s).sum()
    }

    /// Mean power over the window's busy time, watts (0 when empty).
    pub fn mean_power_w(&self) -> f64 {
        let busy = self.busy_s();
        if busy <= 0.0 {
            0.0
        } else {
            self.energy_j() / busy
        }
    }

    /// Busy fraction of the horizon (clamped to [0, 1]).
    pub fn busy_fraction(&self) -> f64 {
        (self.busy_s() / self.horizon_s).min(1.0)
    }
}

#[cfg(test)]
mod window_tests {
    use super::*;

    #[test]
    fn evicts_by_horizon_and_reports_recent_power() {
        let mut w = TelemetryWindow::new(1.0);
        // Old samples at 300 W.
        w.record(0.1, 0.1, 30.0);
        w.record(0.2, 0.1, 30.0);
        assert_eq!(w.len(), 2);
        assert!((w.mean_power_w() - 300.0).abs() < 1e-9);
        // A sample 2 s later evicts both.
        w.record(2.2, 0.1, 10.0);
        assert_eq!(w.len(), 1);
        assert!((w.mean_power_w() - 100.0).abs() < 1e-9);
        assert!((w.energy_j() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sample_exactly_at_the_horizon_boundary_is_retained() {
        // Eviction is strict (`t_end_s < cutoff`): a sample whose end time
        // lands *exactly* horizon seconds before the newest sample is still
        // inside the window. One ulp past the horizon evicts it.
        let mut w = TelemetryWindow::new(1.0);
        w.record(1.0, 0.1, 5.0);
        w.record(2.0, 0.1, 7.0); // cutoff = 1.0 == first sample's t_end
        assert_eq!(w.len(), 2, "boundary sample must survive");
        assert!((w.energy_j() - 12.0).abs() < 1e-12);

        let just_past = f64::from_bits(2.0f64.to_bits() + 1);
        w.record(just_past, 0.1, 3.0); // cutoff now one ulp past 1.0
        assert_eq!(w.len(), 2, "one ulp past the horizon must evict");
        assert!((w.energy_j() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn busy_fraction_clamps_and_tracks_load() {
        let mut w = TelemetryWindow::new(1.0);
        assert_eq!(w.busy_fraction(), 0.0);
        w.record(0.5, 0.25, 50.0);
        assert!((w.busy_fraction() - 0.25).abs() < 1e-12);
        w.record(0.9, 0.9, 50.0);
        assert_eq!(w.busy_fraction(), 1.0); // clamped
    }

    #[test]
    fn empty_window_is_zero_not_nan() {
        let w = TelemetryWindow::new(0.5);
        assert!(w.is_empty());
        assert_eq!(w.mean_power_w(), 0.0);
        assert_eq!(w.energy_j(), 0.0);
        assert_eq!(w.busy_s(), 0.0);
    }

    #[test]
    #[should_panic(expected = "horizon must be positive")]
    fn rejects_nonpositive_horizon() {
        TelemetryWindow::new(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_power_integrates_exactly() {
        let s = PowerSampler::with_period(0.010);
        let trace = [PowerSegment { duration_s: 1.0, power_w: 300.0 }];
        let (e, n) = s.measure(&trace);
        assert!((e - 300.0).abs() < 1e-9, "{e}");
        assert!(n >= 100);
    }

    #[test]
    fn sampler_approaches_exact_as_period_shrinks() {
        let trace = [
            PowerSegment { duration_s: 0.013, power_w: 500.0 },
            PowerSegment { duration_s: 0.049, power_w: 250.0 },
            PowerSegment { duration_s: 0.008, power_w: 90.0 },
        ];
        let exact = PowerSampler::exact(&trace);
        let coarse = PowerSampler::with_period(0.010).measure(&trace).0;
        let fine = PowerSampler::with_period(0.0001).measure(&trace).0;
        assert!((fine - exact).abs() < (coarse - exact).abs() + 1e-12);
        assert!((fine - exact).abs() / exact < 0.01);
        // 10 ms sampling on a ~70 ms request: bounded but nonzero error,
        // like real NVML integration.
        assert!((coarse - exact).abs() / exact < 0.25);
    }

    #[test]
    fn empty_trace_is_zero() {
        let s = PowerSampler::with_period(0.010);
        assert_eq!(s.measure(&[]).0, 0.0);
    }

    #[test]
    fn multi_segment_total_duration_respected() {
        let s = PowerSampler::with_period(0.010);
        let trace = [
            PowerSegment { duration_s: 0.5, power_w: 100.0 },
            PowerSegment { duration_s: 0.5, power_w: 200.0 },
        ];
        let (e, _) = s.measure(&trace);
        assert!((e - 150.0).abs() < 2.0, "{e}");
    }
}
