//! GPU/DVFS simulator — the substitute for the paper's RTX PRO 6000
//! Blackwell testbed with NVML telemetry (DESIGN.md §3).
//!
//! The simulator has four layers:
//! - [`power`]: the P(f, utilization) model with a voltage floor below the
//!   ~960 MHz knee — the mechanism behind the paper's "frequency cliff",
//! - [`thermal`]: sustained-power cap with duty-cycle throttling (why the
//!   largest models run *faster* at 960 MHz than at 2842 MHz, Table XII),
//! - [`telemetry`]: NVML-style 10 ms power sampling and trapezoidal energy
//!   integration — energy is *measured* the way the paper measures it,
//! - [`sim`]: executes [`crate::perf::PhaseCost`] work at a pinned SM
//!   frequency, producing latency + sampled energy.

pub mod power;
pub mod sim;
pub mod telemetry;
pub mod thermal;

pub use sim::{GpuSim, PhaseResult};
pub use telemetry::{PowerSampler, StepSample, TelemetryWindow};
