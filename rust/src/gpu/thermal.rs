//! Sustained-power cap with duty-cycle throttling.
//!
//! Real boards cannot sustain peak transient power: above `p_sustain` the
//! clock duty-cycles and effective throughput drops proportionally. This is
//! the mechanism behind Table XII's *negative* latency deltas — at 2842 MHz
//! heavy phases exceed the sustained cap and stall, so mid-frequency set
//! points can be outright faster.

use crate::config::GpuSpec;

/// Throttle factor ≥ 1 applied to GPU busy time, and the capped power draw.
pub fn throttle(gpu: &GpuSpec, requested_power_w: f64) -> (f64, f64) {
    if requested_power_w <= gpu.p_sustain_w {
        (1.0, requested_power_w)
    } else {
        // Duty-cycling: the board delivers p_sustain; work stretches by the
        // deficit ratio.
        (requested_power_w / gpu.p_sustain_w, gpu.p_sustain_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn below_cap_is_identity() {
        let g = GpuSpec::rtx_pro_6000();
        let (t, p) = throttle(&g, 300.0);
        assert_eq!(t, 1.0);
        assert_eq!(p, 300.0);
    }

    #[test]
    fn above_cap_stretches_time_and_caps_power() {
        let g = GpuSpec::rtx_pro_6000();
        let (t, p) = throttle(&g, g.p_sustain_w * 1.2);
        assert!((t - 1.2).abs() < 1e-12);
        assert_eq!(p, g.p_sustain_w);
    }

    #[test]
    fn energy_is_conserved_under_throttling() {
        // time × power before == after (duty cycling trades time for power).
        let g = GpuSpec::rtx_pro_6000();
        let req = 550.0;
        let (t, p) = throttle(&g, req);
        assert!((t * p - req).abs() < 1e-9);
    }
}
