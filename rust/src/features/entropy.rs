//! Shannon token entropy (Section V-C: H = -Σ p_i log2 p_i over the token
//! frequency distribution of one query).

use std::collections::BTreeMap;

/// Entropy in bits of the empirical distribution of `tokens`.
pub fn token_entropy<S: AsRef<str>>(tokens: &[S]) -> f64 {
    if tokens.is_empty() {
        return 0.0;
    }
    // BTreeMap: deterministic iteration order ⇒ bit-identical sums
    // across runs and extractor instances.
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for t in tokens {
        *counts.entry(t.as_ref()).or_insert(0) += 1;
    }
    let n = tokens.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Unique-token ratio (a complexity-score component).
pub fn unique_ratio<S: AsRef<str>>(tokens: &[S]) -> f64 {
    if tokens.is_empty() {
        return 0.0;
    }
    let uniq: std::collections::HashSet<&str> =
        tokens.iter().map(|t| t.as_ref()).collect();
    uniq.len() as f64 / tokens.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distribution_maxes_entropy() {
        let toks = ["a", "b", "c", "d"];
        assert!((token_entropy(&toks) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn constant_sequence_zero_entropy() {
        let toks = ["x"; 10];
        assert_eq!(token_entropy(&toks), 0.0);
    }

    #[test]
    fn empty_is_zero() {
        let toks: [&str; 0] = [];
        assert_eq!(token_entropy(&toks), 0.0);
        assert_eq!(unique_ratio(&toks), 0.0);
    }

    #[test]
    fn entropy_bounded_by_log_n() {
        let toks = ["a", "b", "a", "c", "a", "b"];
        let h = token_entropy(&toks);
        assert!(h > 0.0 && h <= (toks.len() as f64).log2());
    }

    #[test]
    fn unique_ratio_values() {
        assert_eq!(unique_ratio(&["a", "b", "c"]), 1.0);
        assert_eq!(unique_ratio(&["a", "a"]), 0.5);
    }
}
