//! The paper's five-feature query characterization (Section V-C).

use crate::text::markers::{is_causal_question_tokens, reasoning_marker_density_tokens};
use crate::text::tokenizer::{sentence_count, token_count, word_tokens};
use crate::text::NamedEntityRecognizer;

use super::entropy::{token_entropy, unique_ratio};

/// Names in the canonical feature order (used by the ablation study and the
/// difficulty classifier).
pub const FEATURE_NAMES: [&str; 6] = [
    "input_length",
    "complexity_score",
    "reasoning_complexity",
    "entity_density",
    "token_entropy",
    "causal_question",
];

/// All features of one query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeatureVector {
    /// Token count (subword tokenizer) — the surface-level baseline feature.
    pub input_length: usize,
    /// Weighted combination of normalized entropy, unique-token ratio,
    /// entity density, and average sentence length (0–1).
    pub complexity_score: f64,
    /// Density of causal/comparison markers per word (0–1).
    pub reasoning_complexity: f64,
    /// Named-entity tokens / word tokens (0–1).
    pub entity_density: f64,
    /// Shannon entropy of the query's token distribution, bits.
    pub token_entropy: f64,
    /// 1.0 if the query contains a causal question word, else 0.0.
    pub causal_question: f64,
}

impl FeatureVector {
    /// Canonical dense representation, order = [`FEATURE_NAMES`].
    pub fn to_array(&self) -> [f64; 6] {
        [
            self.input_length as f64,
            self.complexity_score,
            self.reasoning_complexity,
            self.entity_density,
            self.token_entropy,
            self.causal_question,
        ]
    }

    /// Semantic features only (no length), order = FEATURE_NAMES[1..].
    pub fn semantic_array(&self) -> [f64; 5] {
        [
            self.complexity_score,
            self.reasoning_complexity,
            self.entity_density,
            self.token_entropy,
            self.causal_question,
        ]
    }
}

/// Stateless (post-construction) extractor; owns the NER lexicon.
pub struct FeatureExtractor {
    ner: NamedEntityRecognizer,
}

impl Default for FeatureExtractor {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureExtractor {
    pub fn new() -> Self {
        FeatureExtractor {
            ner: NamedEntityRecognizer::new(),
        }
    }

    /// Extract all five features (plus length) from a query text.
    ///
    /// One allocation-free subword count + one word-level tokenization;
    /// every downstream feature reuses the word tokens — this is the
    /// serving-path cost the paper calls "negligible", benchmarked in
    /// workload_features.rs.
    pub fn extract(&self, text: &str) -> FeatureVector {
        let input_length = token_count(text);
        let words = word_tokens(text);
        let word_texts: Vec<&str> = words.iter().map(|t| t.text.as_str()).collect();

        let entropy = token_entropy(&word_texts);
        let uniq = unique_ratio(&word_texts);
        let entity_density = if words.is_empty() {
            0.0
        } else {
            self.ner.recognize_tokens(&words).len() as f64 / words.len() as f64
        };
        let sentences = sentence_count(text).max(1);
        let avg_sentence_len = words.len() as f64 / sentences as f64;

        // Complexity score: weighted mix of normalized components
        // (Section V-C). Entropy normalized by a 8-bit ceiling, sentence
        // length by a 40-word ceiling.
        let complexity_score = if words.is_empty() {
            0.0
        } else {
            0.3 * (entropy / 8.0).min(1.0)
                + 0.25 * uniq
                + 0.25 * entity_density.min(1.0)
                + 0.2 * (avg_sentence_len / 40.0).min(1.0)
        };

        FeatureVector {
            input_length,
            complexity_score,
            reasoning_complexity: reasoning_marker_density_tokens(&words),
            entity_density,
            token_entropy: entropy,
            causal_question: if is_causal_question_tokens(&words) { 1.0 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_text_is_all_zero() {
        let fx = FeatureExtractor::new();
        let f = fx.extract("");
        assert_eq!(f.input_length, 0);
        assert_eq!(f.complexity_score, 0.0);
        assert_eq!(f.entity_density, 0.0);
        assert_eq!(f.causal_question, 0.0);
    }

    #[test]
    fn causal_question_flag() {
        let fx = FeatureExtractor::new();
        assert_eq!(fx.extract("Why did Rome fall?").causal_question, 1.0);
        assert_eq!(fx.extract("Is water wet?").causal_question, 0.0);
    }

    #[test]
    fn entity_density_reflects_entities() {
        let fx = FeatureExtractor::new();
        let dense = fx.extract("Napoleon met Cleopatra in Cairo near the Nile");
        let sparse = fx.extract("the old man walked along the quiet river");
        assert!(dense.entity_density > sparse.entity_density);
        assert!(dense.entity_density > 0.3);
        assert_eq!(sparse.entity_density, 0.0);
    }

    #[test]
    fn all_normalized_features_bounded() {
        let fx = FeatureExtractor::new();
        let f = fx.extract(
            "Why did the Habsburg empire collapse after the war because of \
             economic pressure? Explain how Vienna and Budapest diverged.",
        );
        assert!(f.complexity_score > 0.0 && f.complexity_score <= 1.0);
        assert!(f.reasoning_complexity >= 0.0 && f.reasoning_complexity <= 1.0);
        assert!(f.entity_density >= 0.0 && f.entity_density <= 1.0);
        assert!(f.token_entropy >= 0.0);
    }

    #[test]
    fn longer_diverse_text_has_higher_entropy() {
        let fx = FeatureExtractor::new();
        let short = fx.extract("is it true");
        let long = fx.extract(
            "the ancient mariner traveled across distant oceans carrying \
             forgotten letters toward unfamiliar harbors under golden skies",
        );
        assert!(long.token_entropy > short.token_entropy);
    }

    #[test]
    fn array_round_trip() {
        let fx = FeatureExtractor::new();
        let f = fx.extract("Why is the Danube long?");
        let a = f.to_array();
        assert_eq!(a.len(), FEATURE_NAMES.len());
        assert_eq!(a[0], f.input_length as f64);
        assert_eq!(a[5], 1.0);
        assert_eq!(f.semantic_array()[2], f.entity_density);
    }
}
