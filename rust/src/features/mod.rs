//! Workload characterization features (Section V of the paper).
//!
//! Five lightweight, interpretable features extracted from each query before
//! inference: complexity score, reasoning complexity, entity density, token
//! entropy, and the causal-question flag. All are O(tokens) — "negligible
//! runtime overhead" per the paper — and the extraction path is benchmarked
//! in `benches/workload_features.rs`.

pub mod entropy;
pub mod extract;

pub use extract::{FeatureExtractor, FeatureVector, FEATURE_NAMES};
