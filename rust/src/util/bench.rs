//! Tiny benchmark harness (criterion substitute) for `cargo bench` targets.
//!
//! Measures wall time over warmup + measured iterations, reports
//! mean / p50 / p95 per benchmark in a fixed-width table, and optionally
//! asserts a throughput floor (used by the perf regression gates).

use std::time::{Duration, Instant};

/// One benchmark's collected result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

/// Run `f` repeatedly and collect timing stats. `f` is invoked once per
/// iteration; return something cheap to keep the optimizer honest.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Pretty-print a block of results.
pub fn report(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!("{:<44} {:>8} {:>12} {:>12} {:>12}", "benchmark", "iters", "mean", "p50", "p95");
    for r in results {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_dur(r.mean),
            fmt_dur(r.p50),
            fmt_dur(r.p95)
        );
    }
}

/// Human-readable duration.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_ordered_percentiles() {
        let r = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(r.iters, 50);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() < 1_000_000); // a no-op is fast
    }

    #[test]
    fn formats_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
