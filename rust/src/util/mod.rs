//! Offline-build substitutes for common ecosystem crates (DESIGN.md §3):
//! this environment has no network registry, so the deterministic PRNG
//! (`rand`), JSON (`serde_json`), CLI parsing (`clap`), bench harness
//! (`criterion`) and parallel map (`rayon`) are implemented here, each a
//! small, tested, purpose-built replacement.

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod rng;

pub use json::JsonValue;
pub use rng::Rng;
