//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every experiment derives all randomness from explicit seeds so studies
//! replay exactly; statistical quality of xoshiro256++ is more than enough
//! for workload synthesis and fold shuffling.

/// Seeded pseudo-random generator (xoshiro256++).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Uniform usize in [lo, hi) — hi exclusive, must be > lo.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "gen_range: empty range {lo}..{hi}");
        // Lemire-style rejection-free enough for our small ranges.
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform usize in [lo, hi] — inclusive.
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo, hi + 1)
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..1000 {
            let v = r.gen_range(3, 10);
            assert!((3..10).contains(&v));
            let w = r.gen_range_inclusive(5, 7);
            assert!((5..=7).contains(&w));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn empty_range_panics() {
        Rng::seed_from_u64(0).gen_range(5, 5);
    }
}
