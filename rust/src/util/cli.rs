//! Minimal CLI argument parsing (clap substitute): subcommand + `--key value`
//! / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .is_some_and(|next| !next.starts_with("--"))
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// One subcommand line for [`usage`]: name, argument sketch, one-line
/// description.
pub struct CommandSpec {
    pub name: &'static str,
    pub args: &'static str,
    pub help: &'static str,
}

/// Render the full usage block: every subcommand on its own aligned line
/// with its one-line description, so an unknown subcommand tells the user
/// everything the binary can do.
pub fn usage(program: &str, common: &str, commands: &[CommandSpec]) -> String {
    let head = |c: &CommandSpec| {
        if c.args.is_empty() {
            c.name.to_string()
        } else {
            format!("{} {}", c.name, c.args)
        }
    };
    let width = commands.iter().map(|c| head(c).len()).max().unwrap_or(0);
    let mut out = format!("usage: {program} <command> [options]\n\ncommands:\n");
    for c in commands {
        out.push_str(&format!("  {:width$}  {}\n", head(c), c.help));
    }
    if !common.is_empty() {
        out.push_str(&format!("\ncommon options: {common}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("table 11 --seed 42 --out results.csv --quick");
        assert_eq!(a.subcommand.as_deref(), Some("table"));
        assert_eq!(a.positional, vec!["11"]);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("out"), Some("results.csv"));
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --n=10");
        assert_eq!(a.get_usize("n", 0), 10);
    }

    #[test]
    fn float_options() {
        let a = parse("trace x --cadence 0.25 --min-decode-share=0.8");
        assert_eq!(a.get_f64("cadence", 0.5), 0.25);
        assert_eq!(a.get_f64("min-decode-share", -1.0), 0.8);
        assert_eq!(a.get_f64("absent", 1.5), 1.5);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    #[should_panic(expected = "expects a number")]
    fn bad_number_panics() {
        parse("x --n abc").get_usize("n", 0);
    }

    #[test]
    fn usage_lists_every_command_with_aligned_help() {
        let commands = [
            CommandSpec { name: "run", args: "<name>", help: "run one thing" },
            CommandSpec { name: "longer-command", args: "", help: "do more" },
        ];
        let text = usage("tool", "--seed N", &commands);
        assert!(text.starts_with("usage: tool <command>"));
        assert!(text.contains("run <name>"));
        assert!(text.contains("longer-command"));
        assert!(text.contains("common options: --seed N"));
        // Descriptions line up: both help strings start in the same column.
        let col = |needle: &str| {
            text.lines().find(|l| l.contains(needle)).unwrap().find(needle).unwrap()
        };
        assert_eq!(col("run one thing"), col("do more"));
    }
}
