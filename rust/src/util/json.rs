//! Minimal JSON: a recursive-descent parser (for `artifacts/manifest.json`)
//! and a writer (for experiment CSV/JSON exports). Supports the full JSON
//! grammar except exotic number forms; strings handle the standard escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn parse(text: &str) -> Result<JsonValue, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                // Policy: JSON has no NaN/Infinity tokens. Non-finite
                // values (e.g. the documented NaN `joules_per_request` of
                // a zero-served run) serialize as `null` — a parseable
                // "no value" — instead of emitting `NaN`/`inf`, which no
                // JSON reader (including this module's parser) accepts.
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            JsonValue::String(s) => write_escaped(out, s),
            JsonValue::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(JsonValue::Number)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Array(out));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Object(out));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(JsonValue::parse("42").unwrap(), JsonValue::Number(42.0));
        assert_eq!(JsonValue::parse("-1.5e2").unwrap(), JsonValue::Number(-150.0));
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(
            JsonValue::parse(r#""a\nb""#).unwrap(),
            JsonValue::String("a\nb".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = JsonValue::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"k":[1,2.5,"x",true,null],"m":{"n":-3}}"#;
        let v = JsonValue::parse(src).unwrap();
        let re = JsonValue::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(JsonValue::Number(bad).to_string(), "null");
        }
        // A container holding one stays parseable end to end.
        let v = JsonValue::Array(vec![JsonValue::Number(1.0), JsonValue::Number(f64::NAN)]);
        let text = v.to_string();
        assert_eq!(text, "[1,null]");
        assert!(JsonValue::parse(&text).is_ok());
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("1 2").is_err());
        assert!(JsonValue::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = JsonValue::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":1,"tiers":{"t1":{"param_count":160000,
            "tensors":[{"name":"embed","shape":[512,64],"offset":0}]}}}"#;
        let v = JsonValue::parse(src).unwrap();
        let t = v.get("tiers").unwrap().get("t1").unwrap();
        assert_eq!(t.get("param_count").unwrap().as_usize(), Some(160000));
        let shape = t.get("tensors").unwrap().as_array().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }
}
