//! Scoped-thread parallel map (rayon substitute).
//!
//! Chunks the input across `min(available_parallelism, items)` worker
//! threads with `std::thread::scope`. Order-preserving.

/// Parallel map preserving input order.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n);
    if workers <= 1 || n < 16 {
        return items.iter().map(&f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot_chunk, item_chunk) in out.chunks_mut(chunk).zip(items.chunks(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (slot, item) in slot_chunk.iter_mut().zip(item_chunk) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_small() {
        let e: Vec<u32> = vec![];
        assert!(par_map(&e, |&x| x).is_empty());
        assert_eq!(par_map(&[5], |&x| x + 1), vec![6]);
    }

    #[test]
    fn actually_runs_closures_once_each() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let xs: Vec<usize> = (0..257).collect();
        let _ = par_map(&xs, |_| count.fetch_add(1, Ordering::SeqCst));
        assert_eq!(count.load(Ordering::SeqCst), 257);
    }
}
