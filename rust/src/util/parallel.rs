//! Scoped-thread parallel map (rayon substitute).
//!
//! Chunks the input across `min(available_parallelism, items)` worker
//! threads with `std::thread::scope`. Order-preserving.

/// Parallel map preserving input order.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n);
    if workers <= 1 || n < 16 {
        return items.iter().map(&f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot_chunk, item_chunk) in out.chunks_mut(chunk).zip(items.chunks(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (slot, item) in slot_chunk.iter_mut().zip(item_chunk) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

/// Parallel map over *mutable* items, preserving input order.
///
/// Each worker thread owns a disjoint chunk of `items` via `chunks_mut`, so
/// `f` gets exclusive `&mut` access to its item plus the item's global
/// index. Unlike [`par_map`] there is no internal small-`n` cutoff beyond
/// the trivial cases — callers gate on their own cost model (the fleet
/// engine only fans out when the steppable backlog is worth a thread).
pub fn par_map_mut<T, U, F>(items: &mut [T], f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n);
    if workers <= 1 || n < 2 {
        return items.iter_mut().enumerate().map(|(i, item)| f(i, item)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (c, (slot_chunk, item_chunk)) in
            out.chunks_mut(chunk).zip(items.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            let base = c * chunk;
            s.spawn(move || {
                for (j, (slot, item)) in slot_chunk.iter_mut().zip(item_chunk).enumerate() {
                    *slot = Some(f(base + j, item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_small() {
        let e: Vec<u32> = vec![];
        assert!(par_map(&e, |&x| x).is_empty());
        assert_eq!(par_map(&[5], |&x| x + 1), vec![6]);
    }

    #[test]
    fn actually_runs_closures_once_each() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let xs: Vec<usize> = (0..257).collect();
        let _ = par_map(&xs, |_| count.fetch_add(1, Ordering::SeqCst));
        assert_eq!(count.load(Ordering::SeqCst), 257);
    }

    #[test]
    fn mut_variant_mutates_in_place_with_global_indices() {
        let mut xs: Vec<usize> = vec![0; 1000];
        let doubled = par_map_mut(&mut xs, |i, x| {
            *x = i;
            i * 2
        });
        assert_eq!(xs, (0..1000).collect::<Vec<_>>());
        assert_eq!(doubled, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn mut_variant_empty_and_single() {
        let mut e: Vec<u32> = vec![];
        assert!(par_map_mut(&mut e, |_, &mut x| x).is_empty());
        let mut one = vec![5u32];
        assert_eq!(par_map_mut(&mut one, |_, x| *x + 1), vec![6]);
    }
}
