//! Workload-aware model router.
//!
//! Implements the paper's validated rule (Section V-E4): a query is *easy*
//! iff entity density < 0.20 and causal-question score < 0.05 — and the
//! routing table of Section VII-A (Table XV): easy → small tier, hard →
//! capacity where it pays. A trained logistic-regression router (the
//! Table VI classifier) is also provided for comparison/ablation.

use crate::config::ModelTier;
use crate::features::FeatureVector;
use crate::stats::{LogisticRegression, Standardizer};

/// Routing outcome for one query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoutingDecision {
    pub tier: ModelTier,
    pub easy: bool,
}

/// Rule thresholds from the paper (Section V-E4).
pub const ENTITY_THRESHOLD: f64 = 0.20;
pub const CAUSAL_THRESHOLD: f64 = 0.05;

/// The router: rule-based by default, optionally carrying a trained LR.
pub struct Router {
    pub easy_tier: ModelTier,
    pub hard_tier: ModelTier,
    learned: Option<(LogisticRegression, Standardizer)>,
}

impl Default for Router {
    fn default() -> Self {
        Self::paper_default()
    }
}

impl Router {
    /// Table XV mapping condensed to two tiers: easy → 3B; hard → 14B
    /// ("Scaling Helps" is the only class where capacity pays; Always-Hard
    /// queries gain little from 32B at 2.5× the energy of 14B).
    pub fn paper_default() -> Self {
        Router {
            easy_tier: ModelTier::B3,
            hard_tier: ModelTier::B14,
            learned: None,
        }
    }

    pub fn with_tiers(easy_tier: ModelTier, hard_tier: ModelTier) -> Self {
        Router { easy_tier, hard_tier, learned: None }
    }

    /// Attach a trained difficulty classifier (features → hard?) to replace
    /// the threshold rule.
    pub fn with_learned(mut self, lr: LogisticRegression, scaler: Standardizer) -> Self {
        self.learned = Some((lr, scaler));
        self
    }

    /// The paper's rule: easy ⇔ low entity density AND low causal score.
    pub fn is_easy_rule(f: &FeatureVector) -> bool {
        f.entity_density < ENTITY_THRESHOLD && f.causal_question < CAUSAL_THRESHOLD
    }

    /// Route one query by its features.
    pub fn route(&self, f: &FeatureVector) -> RoutingDecision {
        let easy = match &self.learned {
            None => Self::is_easy_rule(f),
            Some((lr, scaler)) => {
                // The classifier predicts "hard"; semantic features only.
                let x = scaler.transform(&f.semantic_array());
                !lr.predict(&x)
            }
        };
        RoutingDecision {
            tier: if easy { self.easy_tier } else { self.hard_tier },
            easy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::FeatureExtractor;
    use crate::workload::ReplaySuite;

    #[test]
    fn rule_matches_paper_examples() {
        let fx = FeatureExtractor::new();
        let easy = fx.extract("Was the road quiet during the long winter?");
        assert!(Router::is_easy_rule(&easy));
        let hard = fx.extract("Why did Napoleon retreat from Moscow across the Volga?");
        assert!(!Router::is_easy_rule(&hard));
    }

    #[test]
    fn route_picks_configured_tiers() {
        let r = Router::paper_default();
        let fx = FeatureExtractor::new();
        let d = r.route(&fx.extract("Was the garden small?"));
        assert_eq!(d.tier, ModelTier::B3);
        assert!(d.easy);
        let d = r.route(&fx.extract("Explain why Cleopatra allied with Rome against Persia?"));
        assert_eq!(d.tier, ModelTier::B14);
        assert!(!d.easy);
    }

    #[test]
    fn rule_split_is_roughly_balanced_on_suite() {
        // Paper: 406 easy / 394 hard (50.8% / 49.2%) on its 800-query
        // validation subset.
        let suite = ReplaySuite::quick(29, 250);
        let easy = suite
            .features
            .iter()
            .filter(|f| Router::is_easy_rule(f))
            .count() as f64
            / suite.len() as f64;
        assert!((0.30..=0.70).contains(&easy), "easy share {easy:.3}");
    }

    #[test]
    fn learned_router_overrides_rule() {
        // A degenerate LR that calls everything hard.
        let mut lr = LogisticRegression::new(1.0);
        lr.weights = vec![0.0; 5];
        lr.bias = 10.0;
        let scaler = Standardizer { means: vec![0.0; 5], stds: vec![1.0; 5] };
        let r = Router::paper_default().with_learned(lr, scaler);
        let fx = FeatureExtractor::new();
        let d = r.route(&fx.extract("Was the garden small?"));
        assert_eq!(d.tier, ModelTier::B14);
        assert!(!d.easy);
    }
}
