//! DVFS policies: static set points (the paper's sweep), the phase-aware
//! profile of Section VII-B / Figure 6 (high frequency during compute-bound
//! prefill, low frequency during memory-bound decode), and the closed-loop
//! `Governed` band driven online by the serve layer's SLO governor.

use crate::config::{FreqMHz, GpuSpec};

/// Inference phase, for per-phase frequency selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Frequency policy applied per inference batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DvfsPolicy {
    /// One pinned SM frequency for both phases (Section VI's sweeps).
    Static(FreqMHz),
    /// Phase-aware: prefill at one set point, decode at another; the engine
    /// charges the switch overhead (Figure 6).
    PhaseAware { prefill: FreqMHz, decode: FreqMHz },
    /// Closed-loop: a serve-layer governor steps the decode set point within
    /// `[floor, ceil]` against live SLO pressure (see `crate::serve`).
    /// Open-loop consumers (the offline replay engine) see the ceiling —
    /// the safe initial set point a cold governor starts from.
    Governed { floor: FreqMHz, ceil: FreqMHz },
}

impl DvfsPolicy {
    /// The paper's recommended profile: max-frequency prefill, min-frequency
    /// decode (Section VII-B).
    pub fn paper_phase_aware(gpu: &GpuSpec) -> Self {
        DvfsPolicy::PhaseAware { prefill: gpu.f_max_mhz, decode: gpu.f_min_mhz() }
    }

    /// Baseline: everything at max frequency.
    pub fn baseline(gpu: &GpuSpec) -> Self {
        DvfsPolicy::Static(gpu.f_max_mhz)
    }

    /// Closed-loop band over the full supported ladder.
    pub fn governed(gpu: &GpuSpec) -> Self {
        DvfsPolicy::Governed { floor: gpu.f_min_mhz(), ceil: gpu.f_max_mhz }
    }

    pub fn prefill_freq(&self, gpu: &GpuSpec) -> FreqMHz {
        let f = match self {
            DvfsPolicy::Static(f) => *f,
            DvfsPolicy::PhaseAware { prefill, .. } => *prefill,
            // Prefill is compute-bound and frequency-sensitive: run hot.
            DvfsPolicy::Governed { ceil, .. } => *ceil,
        };
        assert!(gpu.supports(f), "unsupported prefill frequency {f}");
        f
    }

    pub fn decode_freq(&self, gpu: &GpuSpec) -> FreqMHz {
        let f = match self {
            DvfsPolicy::Static(f) => *f,
            DvfsPolicy::PhaseAware { decode, .. } => *decode,
            // Open-loop view: the governor's cold-start set point.
            DvfsPolicy::Governed { ceil, .. } => *ceil,
        };
        assert!(gpu.supports(f), "unsupported decode frequency {f}");
        f
    }

    pub fn label(&self) -> String {
        match self {
            DvfsPolicy::Static(f) => format!("static@{f}MHz"),
            DvfsPolicy::PhaseAware { prefill, decode } => {
                format!("phase-aware[{prefill}/{decode}MHz]")
            }
            DvfsPolicy::Governed { floor, ceil } => {
                format!("governed[{floor}-{ceil}MHz]")
            }
        }
    }
}

/// Pluggable per-phase frequency selection — the open-loop face every
/// frequency source presents to an engine. [`DvfsPolicy`] implements it
/// directly; the serve layer's stateful governors implement the richer
/// `serve::FreqGovernor` trait and fall back to this view when cold.
pub trait FrequencyPolicy {
    /// The SM set point for one phase step.
    fn freq_for(&self, phase: Phase, gpu: &GpuSpec) -> FreqMHz;

    /// Human-readable policy name for reports.
    fn policy_label(&self) -> String;
}

impl FrequencyPolicy for DvfsPolicy {
    fn freq_for(&self, phase: Phase, gpu: &GpuSpec) -> FreqMHz {
        match phase {
            Phase::Prefill => self.prefill_freq(gpu),
            Phase::Decode => self.decode_freq(gpu),
        }
    }

    fn policy_label(&self) -> String {
        self.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profile_uses_extremes() {
        let g = GpuSpec::rtx_pro_6000();
        let p = DvfsPolicy::paper_phase_aware(&g);
        assert_eq!(p.prefill_freq(&g), 2842);
        assert_eq!(p.decode_freq(&g), 180);
        assert_eq!(DvfsPolicy::baseline(&g).decode_freq(&g), 2842);
    }

    #[test]
    #[should_panic(expected = "unsupported")]
    fn unsupported_set_point_panics() {
        let g = GpuSpec::rtx_pro_6000();
        DvfsPolicy::Static(777).prefill_freq(&g);
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(DvfsPolicy::Static(960).label(), "static@960MHz");
        assert!(DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 }
            .label()
            .contains("2842/180"));
        assert!(DvfsPolicy::Governed { floor: 180, ceil: 2842 }
            .label()
            .contains("180-2842"));
    }

    #[test]
    fn governed_band_spans_the_ladder_and_starts_at_ceiling() {
        let g = GpuSpec::rtx_pro_6000();
        let p = DvfsPolicy::governed(&g);
        assert_eq!(p, DvfsPolicy::Governed { floor: 180, ceil: 2842 });
        // Open-loop view: both phases at the ceiling until a governor runs.
        assert_eq!(p.prefill_freq(&g), 2842);
        assert_eq!(p.decode_freq(&g), 2842);
    }

    #[test]
    fn trait_view_matches_inherent_accessors() {
        let g = GpuSpec::rtx_pro_6000();
        for p in [
            DvfsPolicy::Static(960),
            DvfsPolicy::paper_phase_aware(&g),
            DvfsPolicy::governed(&g),
        ] {
            assert_eq!(p.freq_for(Phase::Prefill, &g), p.prefill_freq(&g));
            assert_eq!(p.freq_for(Phase::Decode, &g), p.decode_freq(&g));
            assert_eq!(p.policy_label(), p.label());
        }
    }
}
