//! DVFS policies: static set points (the paper's sweep) and the phase-aware
//! profile of Section VII-B / Figure 6 (high frequency during compute-bound
//! prefill, low frequency during memory-bound decode).

use crate::config::{FreqMHz, GpuSpec};

/// Frequency policy applied per inference batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DvfsPolicy {
    /// One pinned SM frequency for both phases (Section VI's sweeps).
    Static(FreqMHz),
    /// Phase-aware: prefill at one set point, decode at another; the engine
    /// charges the switch overhead (Figure 6).
    PhaseAware { prefill: FreqMHz, decode: FreqMHz },
}

impl DvfsPolicy {
    /// The paper's recommended profile: max-frequency prefill, min-frequency
    /// decode (Section VII-B).
    pub fn paper_phase_aware(gpu: &GpuSpec) -> Self {
        DvfsPolicy::PhaseAware { prefill: gpu.f_max_mhz, decode: gpu.f_min_mhz() }
    }

    /// Baseline: everything at max frequency.
    pub fn baseline(gpu: &GpuSpec) -> Self {
        DvfsPolicy::Static(gpu.f_max_mhz)
    }

    pub fn prefill_freq(&self, gpu: &GpuSpec) -> FreqMHz {
        let f = match self {
            DvfsPolicy::Static(f) => *f,
            DvfsPolicy::PhaseAware { prefill, .. } => *prefill,
        };
        assert!(gpu.supports(f), "unsupported prefill frequency {f}");
        f
    }

    pub fn decode_freq(&self, gpu: &GpuSpec) -> FreqMHz {
        let f = match self {
            DvfsPolicy::Static(f) => *f,
            DvfsPolicy::PhaseAware { decode, .. } => *decode,
        };
        assert!(gpu.supports(f), "unsupported decode frequency {f}");
        f
    }

    pub fn label(&self) -> String {
        match self {
            DvfsPolicy::Static(f) => format!("static@{f}MHz"),
            DvfsPolicy::PhaseAware { prefill, decode } => {
                format!("phase-aware[{prefill}/{decode}MHz]")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profile_uses_extremes() {
        let g = GpuSpec::rtx_pro_6000();
        let p = DvfsPolicy::paper_phase_aware(&g);
        assert_eq!(p.prefill_freq(&g), 2842);
        assert_eq!(p.decode_freq(&g), 180);
        assert_eq!(DvfsPolicy::baseline(&g).decode_freq(&g), 2842);
    }

    #[test]
    #[should_panic(expected = "unsupported")]
    fn unsupported_set_point_panics() {
        let g = GpuSpec::rtx_pro_6000();
        DvfsPolicy::Static(777).prefill_freq(&g);
    }

    #[test]
    fn labels_are_descriptive() {
        assert_eq!(DvfsPolicy::Static(960).label(), "static@960MHz");
        assert!(DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 }
            .label()
            .contains("2842/180"));
    }
}
