//! Serving metrics aggregation (throughput / latency percentiles / energy).

/// Aggregated serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    latencies_s: Vec<f64>,
    pub energy_j: f64,
    pub tokens_out: usize,
    pub requests: usize,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn record(&mut self, latency_s: f64, energy_j: f64, tokens: usize) {
        self.latencies_s.push(latency_s);
        self.energy_j += energy_j;
        self.tokens_out += tokens;
        self.requests += 1;
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.latencies_s.clone();
        // total_cmp: a NaN sample sorts last instead of panicking.
        xs.sort_by(f64::total_cmp);
        let idx = ((xs.len() as f64 - 1.0) * p / 100.0).round() as usize;
        xs[idx]
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.latencies_s.is_empty() {
            return f64::NAN;
        }
        self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::NAN;
        }
        self.requests as f64 / self.wall_s
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::NAN;
        }
        self.tokens_out as f64 / self.wall_s
    }

    /// Mean energy per generated token. `NaN` when no tokens were produced
    /// (matching the crate-wide convention: degenerate runs report `NaN`,
    /// never a silent zero).
    pub fn joules_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            return f64::NAN;
        }
        self.energy_j / self.tokens_out as f64
    }

    /// Mean energy per served request. `NaN` when nothing was served.
    pub fn joules_per_request(&self) -> f64 {
        if self.requests == 0 {
            return f64::NAN;
        }
        self.energy_j / self.requests as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_and_percentiles() {
        let mut m = ServeMetrics::default();
        for i in 1..=100 {
            m.record(i as f64, 2.0, 10);
        }
        m.wall_s = 50.0;
        assert_eq!(m.requests, 100);
        assert!((m.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((m.percentile(95.0) - 95.0).abs() <= 1.0);
        assert!((m.mean_latency_s() - 50.5).abs() < 1e-9);
        assert!((m.throughput_rps() - 2.0).abs() < 1e-9);
        assert!((m.tokens_per_s() - 20.0).abs() < 1e-9);
        assert!((m.joules_per_token() - 0.2).abs() < 1e-9);
        assert!((m.joules_per_request() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_survives_a_nan_latency_sample() {
        // A wall-clock glitch can hand the tracker a NaN latency; the
        // percentile readout must not panic mid-run (regression for the
        // old `partial_cmp().unwrap()` sort).
        let mut m = ServeMetrics::default();
        for l in [0.2, f64::NAN, 0.1] {
            m.record(l, 1.0, 1);
        }
        assert_eq!(m.percentile(0.0), 0.1);
        assert!(m.percentile(100.0).is_nan());
    }

    #[test]
    fn empty_metrics_are_nan_not_panic() {
        let m = ServeMetrics::default();
        assert!(m.percentile(50.0).is_nan());
        assert!(m.mean_latency_s().is_nan());
        assert!(m.throughput_rps().is_nan());
        assert!(m.joules_per_token().is_nan());
        assert!(m.joules_per_request().is_nan());
    }
}
