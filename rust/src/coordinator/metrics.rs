//! Serving metrics aggregation (throughput / latency percentiles / energy).

/// Aggregated serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    latencies_s: Vec<f64>,
    pub energy_j: f64,
    pub tokens_out: usize,
    pub requests: usize,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn record(&mut self, latency_s: f64, energy_j: f64, tokens: usize) {
        self.latencies_s.push(latency_s);
        self.energy_j += energy_j;
        self.tokens_out += tokens;
        self.requests += 1;
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.latencies_s.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() as f64 - 1.0) * p / 100.0).round() as usize;
        xs[idx]
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.latencies_s.is_empty() {
            return f64::NAN;
        }
        self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::NAN;
        }
        self.requests as f64 / self.wall_s
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::NAN;
        }
        self.tokens_out as f64 / self.wall_s
    }

    pub fn joules_per_token(&self) -> f64 {
        self.energy_j / self.tokens_out.max(1) as f64
    }

    pub fn joules_per_request(&self) -> f64 {
        self.energy_j / self.requests.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_and_percentiles() {
        let mut m = ServeMetrics::default();
        for i in 1..=100 {
            m.record(i as f64, 2.0, 10);
        }
        m.wall_s = 50.0;
        assert_eq!(m.requests, 100);
        assert!((m.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((m.percentile(95.0) - 95.0).abs() <= 1.0);
        assert!((m.mean_latency_s() - 50.5).abs() < 1e-9);
        assert!((m.throughput_rps() - 2.0).abs() < 1e-9);
        assert!((m.tokens_per_s() - 20.0).abs() < 1e-9);
        assert!((m.joules_per_token() - 0.2).abs() < 1e-9);
        assert!((m.joules_per_request() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_nan_not_panic() {
        let m = ServeMetrics::default();
        assert!(m.percentile(50.0).is_nan());
        assert!(m.mean_latency_s().is_nan());
        assert!(m.throughput_rps().is_nan());
    }
}
