//! Multi-GPU replica pool — the paper's named future-work direction
//! ("extensions to multi-GPU inference"). Extension feature, exercised by
//! `ewatt ablation cluster`.
//!
//! Since the fleet layer landed, `Cluster` is a thin offline facade over
//! [`crate::fleet::FleetSim`]: the replay workload arrives all at once
//! (t = 0), a least-loaded router stripes it across `n_replicas` identical
//! replicas, and each replica runs the single iteration-level batching
//! loop the whole codebase shares ([`crate::fleet::engine::drive`] — the
//! same core behind `serve::ServeSim`). Compared with the old
//! fixed-batch dispatcher this admits per-request (prefills at batch 1,
//! continuous decode batching), so splitting work across more replicas
//! lowers decode occupancy slightly and costs a bounded energy overhead —
//! the occupancy-fragmentation effect the cluster ablation now reports.

use anyhow::Result;

use crate::config::{GpuSpec, ModelSpec};
use crate::fleet::{FleetConfig, FleetSim, LeastLoaded, ReplicaSpec, ReplicaState};
use crate::serve::slo::Slo;
use crate::serve::traffic::Arrival;
use crate::workload::ReplaySuite;

use super::dvfs_policy::DvfsPolicy;

/// A pool of identical replicas under one DVFS policy.
pub struct Cluster {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub n_replicas: usize,
    pub policy: DvfsPolicy,
}

/// Cluster-level replay result.
#[derive(Debug, Clone, Default)]
pub struct ClusterMetrics {
    /// Per-replica busy time, seconds.
    pub replica_busy_s: Vec<f64>,
    pub energy_j: f64,
    pub queries: usize,
}

impl ClusterMetrics {
    /// Wall time = the busiest replica (replicas run concurrently).
    pub fn makespan_s(&self) -> f64 {
        self.replica_busy_s.iter().cloned().fold(0.0, f64::max)
    }

    /// Load-balance quality: mean busy / max busy (1.0 = perfect).
    pub fn balance(&self) -> f64 {
        let max = self.makespan_s();
        if max == 0.0 {
            return 1.0;
        }
        let mean: f64 =
            self.replica_busy_s.iter().sum::<f64>() / self.replica_busy_s.len() as f64;
        mean / max
    }

    pub fn throughput_qps(&self) -> f64 {
        self.queries as f64 / self.makespan_s().max(1e-12)
    }
}

impl Cluster {
    pub fn new(gpu: GpuSpec, model: ModelSpec, n_replicas: usize, policy: DvfsPolicy) -> Self {
        assert!(n_replicas >= 1);
        Cluster { gpu, model, n_replicas, policy }
    }

    /// Replay `indices` through the fleet engine: every query arrives at
    /// t = 0, replicas decode up to `max_batch` sequences concurrently,
    /// dispatch is least-loaded.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        indices: &[usize],
        max_batch: usize,
    ) -> Result<ClusterMetrics> {
        let cfg = FleetConfig {
            replicas: vec![
                ReplicaSpec {
                    model: self.model.clone(),
                    policy: self.policy,
                    state: ReplicaState::Live,
                };
                self.n_replicas
            ],
            max_batch,
            // Offline replay: latency objectives are not under test.
            slo: Slo::relaxed(),
            ..FleetConfig::default()
        };
        let fleet = FleetSim::new(self.gpu.clone(), cfg);
        let arrivals: Vec<Arrival> = indices.iter().map(|&i| Arrival::at(0.0, i)).collect();
        let out = fleet.run(suite, &arrivals, &mut LeastLoaded)?;
        Ok(ClusterMetrics {
            replica_busy_s: out.replicas.iter().map(|r| r.busy_s).collect(),
            energy_j: out.energy_j,
            queries: out.served,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};

    fn run_with(n: usize) -> ClusterMetrics {
        let suite = ReplaySuite::quick(41, 12);
        let idx: Vec<usize> = (0..suite.len()).collect();
        Cluster::new(
            GpuSpec::rtx_pro_6000(),
            model_for_tier(ModelTier::B3),
            n,
            DvfsPolicy::Static(960),
        )
        .run(&suite, &idx, 4)
        .unwrap()
    }

    #[test]
    fn replicas_cut_makespan_at_bounded_energy_overhead() {
        let one = run_with(1);
        let four = run_with(4);
        assert_eq!(one.queries, four.queries);
        // Splitting the stream lowers decode occupancy per replica, so
        // energy may rise — but only by the occupancy-fragmentation
        // overhead, never collapse or explode.
        let ratio = four.energy_j / one.energy_j;
        assert!((0.95..1.40).contains(&ratio), "energy ratio {ratio:.3}");
        // Makespan scales down with decent efficiency.
        let speedup = one.makespan_s() / four.makespan_s();
        assert!(speedup > 2.0, "speedup {speedup:.2} with 4 replicas");
        assert!(four.balance() > 0.5, "balance {:.2}", four.balance());
    }

    #[test]
    fn single_replica_matches_serial_busy_time() {
        let one = run_with(1);
        assert_eq!(one.replica_busy_s.len(), 1);
        assert!(one.throughput_qps() > 0.0);
        assert!((one.balance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn replay_is_deterministic() {
        let a = run_with(2);
        let b = run_with(2);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.replica_busy_s, b.replica_busy_s);
    }
}
