//! Multi-GPU replica pool — the paper's named future-work direction
//! ("extensions to multi-GPU inference"). Extension feature, exercised by
//! `ewatt ablation cluster`.
//!
//! Data-parallel serving: N identical simulated devices each hold a full
//! model replica; batches are dispatched least-loaded-first. Reports
//! makespan (wall time = busiest replica), aggregate energy, and the
//! scaling efficiency of both.

use anyhow::Result;

use crate::config::{GpuSpec, ModelSpec};
use crate::engine::{Batcher, KvCacheManager};
use crate::gpu::GpuSim;
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::tokenizer::token_count;
use crate::workload::{Query, ReplaySuite};

use super::dvfs_policy::DvfsPolicy;

/// A pool of identical replicas under one DVFS policy.
pub struct Cluster {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub n_replicas: usize,
    pub policy: DvfsPolicy,
}

/// Cluster-level replay result.
#[derive(Debug, Clone, Default)]
pub struct ClusterMetrics {
    /// Per-replica busy time, seconds.
    pub replica_busy_s: Vec<f64>,
    pub energy_j: f64,
    pub queries: usize,
}

impl ClusterMetrics {
    /// Wall time = the busiest replica (replicas run concurrently).
    pub fn makespan_s(&self) -> f64 {
        self.replica_busy_s.iter().cloned().fold(0.0, f64::max)
    }

    /// Load-balance quality: mean busy / max busy (1.0 = perfect).
    pub fn balance(&self) -> f64 {
        let max = self.makespan_s();
        if max == 0.0 {
            return 1.0;
        }
        let mean: f64 =
            self.replica_busy_s.iter().sum::<f64>() / self.replica_busy_s.len() as f64;
        mean / max
    }

    pub fn throughput_qps(&self) -> f64 {
        self.queries as f64 / self.makespan_s().max(1e-12)
    }
}

impl Cluster {
    pub fn new(gpu: GpuSpec, model: ModelSpec, n_replicas: usize, policy: DvfsPolicy) -> Self {
        assert!(n_replicas >= 1);
        Cluster { gpu, model, n_replicas, policy }
    }

    /// Replay `indices` at `batch`, dispatching batches least-loaded-first.
    pub fn run(&self, suite: &ReplaySuite, indices: &[usize], batch: usize) -> Result<ClusterMetrics> {
        let pre_sim = GpuSim::new(self.gpu.clone(), self.policy.prefill_freq(&self.gpu));
        let dec_sim = GpuSim::new(self.gpu.clone(), self.policy.decode_freq(&self.gpu));
        let mut kv: Vec<KvCacheManager> = (0..self.n_replicas)
            .map(|_| KvCacheManager::new(&self.gpu, &self.model))
            .collect();
        let mut m = ClusterMetrics {
            replica_busy_s: vec![0.0; self.n_replicas],
            ..Default::default()
        };
        for group in Batcher::new(batch).batches(&suite.queries, indices) {
            // Least-loaded dispatch.
            let r = (0..self.n_replicas)
                .min_by(|&a, &b| {
                    m.replica_busy_s[a]
                        .partial_cmp(&m.replica_busy_s[b])
                        .unwrap()
                })
                .unwrap();
            let queries: Vec<&Query> = group.iter().map(|&i| &suite.queries[i]).collect();
            let seq = queries
                .iter()
                .map(|q| token_count(&q.text).max(1))
                .max()
                .unwrap();
            let steps = queries.iter().map(|q| q.output_tokens).max().unwrap();
            for q in &queries {
                kv[r].admit(q.id, seq)?;
            }
            let passes = if steps == 0 { queries[0].dataset.n_options() } else { 1 };
            for _ in 0..passes {
                let res = pre_sim.execute(&prefill_cost(&self.model, queries.len(), seq));
                m.replica_busy_s[r] += res.latency_s;
                m.energy_j += res.energy_j;
            }
            for s in 0..steps {
                let res = dec_sim.execute(&decode_step_cost(&self.model, queries.len(), seq + s));
                m.replica_busy_s[r] += res.latency_s;
                m.energy_j += res.energy_j;
            }
            for q in &queries {
                kv[r].release(q.id);
            }
            m.queries += queries.len();
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::{model_for_tier, ModelTier};

    fn run_with(n: usize) -> ClusterMetrics {
        let suite = ReplaySuite::quick(41, 12);
        let idx: Vec<usize> = (0..suite.len()).collect();
        Cluster::new(
            GpuSpec::rtx_pro_6000(),
            model_for_tier(ModelTier::B3),
            n,
            DvfsPolicy::Static(960),
        )
        .run(&suite, &idx, 4)
        .unwrap()
    }

    #[test]
    fn replicas_cut_makespan_not_energy() {
        let one = run_with(1);
        let four = run_with(4);
        assert_eq!(one.queries, four.queries);
        // Energy is work-proportional: unchanged by parallelism.
        assert!((four.energy_j / one.energy_j - 1.0).abs() < 0.01);
        // Makespan scales down with decent efficiency.
        let speedup = one.makespan_s() / four.makespan_s();
        assert!(speedup > 2.5, "speedup {speedup:.2} with 4 replicas");
        assert!(four.balance() > 0.6, "balance {:.2}", four.balance());
    }

    #[test]
    fn single_replica_matches_serial_busy_time() {
        let one = run_with(1);
        assert_eq!(one.replica_busy_s.len(), 1);
        assert!(one.throughput_qps() > 0.0);
        assert!((one.balance() - 1.0).abs() < 1e-12);
    }
}
