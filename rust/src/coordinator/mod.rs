//! The coordination layer: workload-aware routing + phase-aware DVFS —
//! the policies the paper's case study (Section VII) motivates, plus the
//! threaded serving loop that drives the real PJRT tiny-LM path.

pub mod cluster;
pub mod dvfs_policy;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use cluster::{Cluster, ClusterMetrics};
pub use dvfs_policy::{DvfsPolicy, FrequencyPolicy, Phase};
pub use metrics::ServeMetrics;
pub use router::{Router, RoutingDecision};
pub use scheduler::{Scheduler, ScheduleReport};
pub use server::{ServeConfig, Server};
