//! The serving loop over the *real* PJRT tiny-LM (the end-to-end path).
//!
//! Leader/worker structure without an async runtime (none is available
//! offline — DESIGN.md §3): the leader thread batches requests and streams
//! them over a channel; a dedicated worker thread owns the PJRT client and
//! model (XLA handles are not `Send`, so all device work stays on one
//! thread, exactly like a real single-GPU worker process) and executes
//! prefill + greedy decode; outcomes stream back to the leader.
//!
//! Energy is attributed by running the same phase schedule through the
//! simulated GPU at the active DVFS policy, while latency/throughput/quality
//! come from the real execution.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::gpu::GpuSpec;
use crate::config::model::{ModelSpec, ModelTier};
use crate::gpu::GpuSim;
use crate::perf::{decode_step_cost, prefill_cost};
use crate::text::rouge::rouge_l;
use crate::text::vocab;
use crate::workload::Query;

use super::dvfs_policy::DvfsPolicy;
use super::metrics::ServeMetrics;
use crate::engine::request::RequestOutcome;
use crate::runtime::{Manifest, RuntimeClient, TinyLm};

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifacts directory containing manifest.json.
    pub artifacts_dir: std::path::PathBuf,
    /// Tiny-LM tier to serve (t1..t5).
    pub tier: String,
    pub batch: usize,
    pub max_new_tokens: usize,
    pub policy: DvfsPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: crate::runtime::artifact::default_dir(),
            tier: "t3".into(),
            batch: 4,
            max_new_tokens: 32,
            policy: DvfsPolicy::PhaseAware { prefill: 2842, decode: 180 },
        }
    }
}

/// One unit of work sent to the device worker.
struct WorkItem {
    /// Row-major [batch, prefill_seq] token ids.
    tokens: Vec<i32>,
    batch: usize,
    budgets: Vec<usize>,
}

/// Worker reply.
struct WorkDone {
    /// Generated token ids per row.
    generated: Vec<Vec<i32>>,
    wall_s: f64,
}

/// The server: batches queries, drives the device worker, scores output.
pub struct Server {
    cfg: ServeConfig,
    gpu: GpuSpec,
}

/// Deterministic word → tiny-vocab token id.
pub fn encode_word(word: &str, vocab_size: usize) -> i32 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in word.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % vocab_size as u64) as i32
}

/// Deterministic token id → word (cycled over the corpus vocabulary), so
/// generated ids detokenize to scoreable English-like text.
pub fn decode_token(id: i32) -> &'static str {
    let words: [&[&str]; 4] = [
        vocab::FUNCTION_WORDS,
        vocab::NOUNS,
        vocab::VERBS,
        vocab::MODIFIERS,
    ];
    let total: usize = words.iter().map(|w| w.len()).sum();
    let mut k = (id.unsigned_abs() as usize) % total;
    for list in words {
        if k < list.len() {
            return list[k];
        }
        k -= list.len();
    }
    unreachable!()
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Self {
        Server { cfg, gpu: GpuSpec::rtx_pro_6000() }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Encode a query's text into a fixed prefill bucket.
    fn encode_prompt(&self, text: &str, seq: usize, vocab_size: usize) -> Vec<i32> {
        let mut ids: Vec<i32> = text
            .split_whitespace()
            .map(|w| encode_word(w, vocab_size))
            .collect();
        ids.truncate(seq);
        while ids.len() < seq {
            ids.push(0); // pad id
        }
        ids
    }

    /// Serve a replay set of queries; returns per-request outcomes plus
    /// aggregate metrics. `queries` are (index, query) pairs.
    pub fn serve(&self, queries: &[(usize, &Query)]) -> Result<(Vec<RequestOutcome>, ServeMetrics)> {
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let (done_tx, done_rx) = mpsc::channel::<Result<WorkDone>>();

        // Device worker: owns all PJRT state (not Send — single thread).
        let artifacts = self.cfg.artifacts_dir.clone();
        let tier = self.cfg.tier.clone();
        let max_new = self.cfg.max_new_tokens;
        let worker = std::thread::spawn(move || -> Result<()> {
            let client = RuntimeClient::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let lm = TinyLm::load(&client, &manifest, &tier)?;
            let max_seq = lm.config.max_seq;
            let seq = lm.prefill_seq();
            while let Ok(item) = work_rx.recv() {
                let t0 = Instant::now();
                let run = || -> Result<WorkDone> {
                    let (logits, mut state) = lm.prefill(&client, &item.tokens, item.batch)?;
                    let mut tok = lm.argmax(&logits, item.batch);
                    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); item.batch];
                    let hard_cap = max_seq - seq;
                    let steps = item
                        .budgets
                        .iter()
                        .cloned()
                        .max()
                        .unwrap_or(0)
                        .min(max_new)
                        .min(hard_cap);
                    for s in 0..steps {
                        for (row, g) in generated.iter_mut().enumerate() {
                            if s < item.budgets[row].min(max_new) {
                                g.push(tok[row]);
                            }
                        }
                        if s + 1 < steps {
                            let logits = lm.decode_step(&client, &mut state, &tok)?;
                            tok = lm.argmax(&logits, item.batch);
                        }
                    }
                    Ok(WorkDone { generated, wall_s: t0.elapsed().as_secs_f64() })
                };
                if done_tx.send(run()).is_err() {
                    break;
                }
            }
            Ok(())
        });

        // Leader: batch, dispatch, score.
        let manifest = Manifest::load(&self.cfg.artifacts_dir)?;
        let tier_cfg = manifest.tier(&self.cfg.tier)?.config;
        let vocab_size = tier_cfg.vocab;
        let seq = manifest.prefill_seq;
        let tiny_spec = tiny_model_spec(&self.cfg.tier, &manifest)?;

        let mut outcomes = Vec::with_capacity(queries.len());
        let mut metrics = ServeMetrics::default();
        let wall0 = Instant::now();
        for chunk in queries.chunks(self.cfg.batch) {
            // Pad the final chunk up to a compiled batch size by repeating
            // the last row (discarded on return).
            let real = chunk.len();
            let batch = self.cfg.batch;
            let mut tokens = Vec::with_capacity(batch * seq);
            let mut budgets = Vec::with_capacity(batch);
            for k in 0..batch {
                let (_, q) = chunk[k.min(real - 1)];
                tokens.extend(self.encode_prompt(&q.text, seq, vocab_size));
                budgets.push(q.output_tokens.max(8));
            }
            work_tx
                .send(WorkItem { tokens, batch, budgets })
                .map_err(|_| anyhow!("worker hung up"))?;
            let done = done_rx
                .recv()
                .context("worker dropped")?
                .context("batch execution failed")?;

            // Simulated energy for this batch under the active policy.
            let sim = self.simulate_batch_energy(&tiny_spec, seq, &done, batch);
            let per_row_energy = sim / real as f64;
            for (k, (qi, q)) in chunk.iter().enumerate() {
                let gen_ids = &done.generated[k];
                let text: Vec<&str> = gen_ids.iter().map(|&t| decode_token(t)).collect();
                let text = text.join(" ");
                let rouge = if q.reference.is_empty() {
                    0.0
                } else {
                    rouge_l(&text, &q.reference).f1
                };
                metrics.record(done.wall_s, per_row_energy, gen_ids.len());
                outcomes.push(RequestOutcome {
                    query_idx: *qi,
                    text,
                    tokens_out: gen_ids.len(),
                    wall_latency_s: done.wall_s,
                    sim_energy_j: per_row_energy,
                    rouge_l: rouge,
                });
            }
        }
        metrics.wall_s = wall0.elapsed().as_secs_f64();
        drop(work_tx);
        worker
            .join()
            .map_err(|_| anyhow!("worker panicked"))?
            .context("worker error")?;
        Ok((outcomes, metrics))
    }

    /// Phase-schedule energy attribution on the simulated GPU.
    fn simulate_batch_energy(
        &self,
        spec: &ModelSpec,
        seq: usize,
        done: &WorkDone,
        batch: usize,
    ) -> f64 {
        let f_pre = self.cfg.policy.prefill_freq(&self.gpu);
        let f_dec = self.cfg.policy.decode_freq(&self.gpu);
        let pre = GpuSim::new(self.gpu.clone(), f_pre).execute(&prefill_cost(spec, batch, seq));
        let steps = done.generated.iter().map(Vec::len).max().unwrap_or(0);
        let dec_sim = GpuSim::new(self.gpu.clone(), f_dec);
        let mut e = pre.energy_j;
        for s in 0..steps {
            e += dec_sim.execute(&decode_step_cost(spec, batch, seq + s)).energy_j;
        }
        e
    }
}

/// ModelSpec view of a tiny tier (for the cost model / KV accounting).
fn tiny_model_spec(tier: &str, manifest: &Manifest) -> Result<ModelSpec> {
    let c = manifest.tier(tier)?.config;
    Ok(ModelSpec {
        name: format!("tiny-{tier}"),
        tier: ModelTier::B1, // tier label is irrelevant for costing
        n_layers: c.n_layers,
        d_model: c.d_model,
        n_heads: c.n_heads,
        n_kv_heads: c.n_kv_heads,
        d_ff: c.d_ff,
        vocab: c.vocab,
        weight_bytes: 4, // f32 artifacts
        tied_embeddings: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_are_deterministic_and_in_range() {
        let a = encode_word("napoleon", 2048);
        assert_eq!(a, encode_word("napoleon", 2048));
        assert!((0..2048).contains(&a));
        let w = decode_token(a);
        assert!(!w.is_empty());
        assert_eq!(decode_token(a), w);
    }

    #[test]
    fn decode_token_covers_all_ids() {
        for id in [0, 1, 77, 1000, i32::MAX] {
            assert!(!decode_token(id).is_empty());
        }
    }

    // Full serve() round-trips are covered by the integration test
    // rust/tests/integration_serve.rs (requires built artifacts).
}
