//! Workload-aware replay scheduler: routes every query to a model tier,
//! then replays each tier's share under its DVFS policy — the combined
//! optimization of the paper's case study (Section VII-C, Table XVII).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::model::model_for_tier;
use crate::config::{GpuSpec, ModelTier};
use crate::engine::{ReplayEngine, ReplayMetrics};
use crate::workload::ReplaySuite;

use super::dvfs_policy::DvfsPolicy;
use super::router::Router;

/// Outcome of a routed, phase-aware replay.
#[derive(Debug, Clone, Default)]
pub struct ScheduleReport {
    /// Per-tier replay metrics.
    pub per_tier: BTreeMap<ModelTier, ReplayMetrics>,
    /// Queries routed to each tier.
    pub routed: BTreeMap<ModelTier, usize>,
    pub total_energy_j: f64,
    pub total_latency_s: f64,
}

/// The scheduler: router + per-tier engines + DVFS policy.
pub struct Scheduler {
    pub gpu: GpuSpec,
    pub router: Router,
    pub policy: DvfsPolicy,
    pub batch: usize,
}

impl Scheduler {
    pub fn new(gpu: GpuSpec, router: Router, policy: DvfsPolicy, batch: usize) -> Self {
        Scheduler { gpu, router, policy, batch }
    }

    /// Route and replay the whole suite.
    pub fn run(&self, suite: &ReplaySuite) -> Result<ScheduleReport> {
        let mut groups: BTreeMap<ModelTier, Vec<usize>> = BTreeMap::new();
        for i in 0..suite.len() {
            let d = self.router.route(&suite.features[i]);
            groups.entry(d.tier).or_default().push(i);
        }
        let mut report = ScheduleReport::default();
        for (tier, idx) in groups {
            let engine = ReplayEngine::new(self.gpu.clone(), model_for_tier(tier));
            let m = engine.run(suite, &idx, self.batch, &self.policy)?;
            report.total_energy_j += m.energy_j;
            report.total_latency_s += m.latency_s;
            report.routed.insert(tier, idx.len());
            report.per_tier.insert(tier, m);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routed_phase_aware_beats_monolithic_baseline() {
        // The case study's headline: routing + phase-aware DVFS cuts energy
        // by a large factor vs. 32B @ max frequency (Table XVIII).
        let suite = ReplaySuite::quick(61, 12);
        let gpu = GpuSpec::rtx_pro_6000();

        let baseline = Scheduler::new(
            gpu.clone(),
            Router::with_tiers(ModelTier::B32, ModelTier::B32),
            DvfsPolicy::baseline(&gpu),
            1,
        )
        .run(&suite)
        .unwrap();

        let combined = Scheduler::new(
            gpu.clone(),
            Router::paper_default(),
            DvfsPolicy::paper_phase_aware(&gpu),
            1,
        )
        .run(&suite)
        .unwrap();

        let savings = 1.0 - combined.total_energy_j / baseline.total_energy_j;
        assert!(savings > 0.55, "combined savings {savings:.3}");
        // Both tiers must actually be used by the router.
        assert!(combined.routed.len() >= 2, "router collapsed to one tier");
    }

    #[test]
    fn all_queries_are_routed_exactly_once() {
        let suite = ReplaySuite::quick(67, 8);
        let gpu = GpuSpec::rtx_pro_6000();
        let r = Scheduler::new(
            gpu.clone(),
            Router::paper_default(),
            DvfsPolicy::Static(960),
            4,
        )
        .run(&suite)
        .unwrap();
        let total: usize = r.routed.values().sum();
        assert_eq!(total, suite.len());
        let per_tier_total: usize = r.per_tier.values().map(|m| m.queries).sum();
        assert_eq!(per_tier_total, suite.len());
    }
}
