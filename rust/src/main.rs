//! `ewatt` — the study's command line.
//!
//! ```text
//! ewatt table <1..18> [--paper] [--seed N] [--queries N] [--out DIR]
//! ewatt figure <2..7>  [...]
//! ewatt all            [...]             # every table + figure
//! ewatt sweep          [...]             # raw DVFS sweep cells as CSV
//! ewatt slo            [...]             # SLO-aware serving comparison
//! ewatt fleet          [...]             # heterogeneous governed fleet comparison
//! ewatt autoscale      [...]             # elastic fleet: static-N vs autoscaled (+failures)
//! ewatt forecast       [...]             # predictive vs reactive scaling (+migration churn)
//! ewatt lab [--requests N] [--seed S] [--out DIR]
//!                                          # mixed-class lab: class-aware vs class-blind
//!                                          # governance (writes prompts.jsonl under --out)
//! ewatt serve [--tier t3] [--batch 4] [--n 16] [--max-new 32]
//!             [--prefill-mhz 2842] [--decode-mhz 180]   # real PJRT path
//! ewatt bench [--replicas 16] [--arrivals 1000000] [--iters 1] [--check]
//!             [--min-speedup 3.0] [--json BENCH_engine.json]
//!                                          # engine hot-path perf harness
//! ewatt trace <scenario> [--out DIR] [--top K] [--limit N] [--cadence S]
//!                                          # traced scenario replay -> traces.jsonl +
//!                                          # timeline.jsonl + manifest (+ alert replay)
//! ewatt diff <run_a> <run_b> [--out DIR] [--min-decode-share X]
//!                                          # compare two trace runs -> delta table + diff.json
//! ewatt info                              # testbed + model inventory
//! ewatt help                              # full subcommand list
//! ```
//!
//! Every report-producing subcommand run with `--out DIR` also writes a
//! `manifest.json` there (seed, config digest, report inventory) so a
//! results directory is self-describing.

use std::path::Path;

use anyhow::{bail, Context as _, Result};

use ewatt::config::model::paper_models;
use ewatt::config::GpuSpec;
use ewatt::coordinator::{DvfsPolicy, ServeConfig, Server};
use ewatt::experiments::{run_all, run_figure, run_table, Context, Report};
use ewatt::obs::RunManifest;
use ewatt::util::cli::{usage, Args, CommandSpec};
use ewatt::workload::ReplaySuite;

/// Every subcommand, with the one-line description `ewatt help` (and any
/// unknown subcommand) prints.
const COMMANDS: &[CommandSpec] = &[
    CommandSpec { name: "table", args: "<1..18>", help: "regenerate one paper table" },
    CommandSpec { name: "figure", args: "<2..7>", help: "regenerate one paper figure" },
    CommandSpec { name: "all", args: "", help: "every table and figure" },
    CommandSpec { name: "sweep", args: "", help: "raw DVFS sweep cells as CSV" },
    CommandSpec { name: "slo", args: "", help: "SLO-aware serving comparison" },
    CommandSpec { name: "fleet", args: "", help: "heterogeneous governed fleet comparison" },
    CommandSpec {
        name: "autoscale",
        args: "",
        help: "elastic fleet: static-N vs autoscaled (+failures)",
    },
    CommandSpec {
        name: "forecast",
        args: "",
        help: "predictive vs reactive autoscaling (+ migration under failures), hard-gated",
    },
    CommandSpec { name: "ablation", args: "[name]", help: "component ablations (default: all)" },
    CommandSpec {
        name: "lab",
        args: "[--out DIR]",
        help: "mixed-class workload lab: class-aware vs class-blind governance",
    },
    CommandSpec { name: "serve", args: "", help: "serve a replay slice on the real PJRT tiny-LM" },
    CommandSpec { name: "bench", args: "[--check]", help: "engine hot-path perf harness" },
    CommandSpec {
        name: "trace",
        args: "<scenario>",
        help: "traced scenario replay: traces.jsonl + timeline.jsonl + manifest + waterfall",
    },
    CommandSpec {
        name: "diff",
        args: "<run_a> <run_b>",
        help: "compare two trace runs: energy/latency deltas + diff.json",
    },
    CommandSpec { name: "info", args: "", help: "testbed + model inventory" },
    CommandSpec { name: "help", args: "", help: "show this list" },
];

fn usage_text() -> String {
    usage("ewatt", "--paper --seed N --queries N --out DIR", COMMANDS)
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn build_context(args: &Args) -> Context {
    let seed = args.get_u64("seed", 0xE1A5);
    if args.has_flag("paper") {
        eprintln!("building paper-scale context (3,817 queries) ...");
        Context::paper(seed)
    } else {
        let n = args.get_usize("queries", 200);
        Context::quick(seed, n)
    }
}

fn emit(reports: &[Report], args: &Args) -> Result<()> {
    for r in reports {
        println!("{}", r.ascii());
        if let Some(dir) = args.get("out") {
            let p = r.write_csv(dir).context("writing CSV")?;
            eprintln!("wrote {}", p.display());
        }
    }
    if let Some(dir) = args.get("out") {
        let seed = args.get_u64("seed", 0xE1A5);
        let mut m = RunManifest::new(&invocation(args), seed);
        m.set_config_digest(&format!(
            "command={}\npaper={}\nseed={seed:#x}\nqueries={}\n",
            invocation(args),
            args.has_flag("paper"),
            args.get_usize("queries", 200),
        ));
        let inventory: Vec<(String, usize)> =
            reports.iter().map(|r| (r.id.clone(), r.rows.len())).collect();
        m.set_reports(&inventory);
        let p = m.write(Path::new(dir), "manifest.json")?;
        eprintln!("wrote {}", p.display());
    }
    Ok(())
}

/// The subcommand plus its positionals, e.g. `table 11` — the manifest's
/// `command` field.
fn invocation(args: &Args) -> String {
    let mut s = args.subcommand.clone().unwrap_or_default();
    for p in &args.positional {
        s.push(' ');
        s.push_str(p);
    }
    s
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("table") => {
            let n: u32 = args
                .positional
                .first()
                .context("usage: ewatt table <1..18>")?
                .parse()
                .context("table number")?;
            let ctx = build_context(&args);
            emit(&run_table(&ctx, n)?, &args)
        }
        Some("figure") => {
            let n: u32 = args
                .positional
                .first()
                .context("usage: ewatt figure <2..7>")?
                .parse()
                .context("figure number")?;
            let ctx = build_context(&args);
            emit(&run_figure(&ctx, n)?, &args)
        }
        Some("all") => {
            let ctx = build_context(&args);
            emit(&run_all(&ctx)?, &args)
        }
        Some("sweep") => {
            let ctx = build_context(&args);
            sweep_csv(&ctx, &args)
        }
        Some("slo") => {
            let ctx = build_context(&args);
            emit(&[ewatt::experiments::slo_tables::slo_table(&ctx)?], &args)
        }
        Some("fleet") => {
            let ctx = build_context(&args);
            emit(&[ewatt::experiments::fleet_tables::fleet_table(&ctx)?], &args)
        }
        Some("autoscale") => {
            let ctx = build_context(&args);
            emit(
                &[ewatt::experiments::autoscale_tables::autoscale_table(&ctx)?],
                &args,
            )
        }
        Some("forecast") => {
            let ctx = build_context(&args);
            emit(
                &[ewatt::experiments::forecast_tables::forecast_table(&ctx)?],
                &args,
            )
        }
        Some("ablation") => {
            let name = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("all");
            let ctx = build_context(&args);
            let reports: Vec<Report> = if name == "all" {
                ewatt::experiments::ablations::ALL_ABLATIONS
                    .iter()
                    .map(|n| ewatt::experiments::ablations::run_ablation(&ctx, n))
                    .collect::<Result<_>>()?
            } else {
                vec![ewatt::experiments::ablations::run_ablation(&ctx, name)?]
            };
            emit(&reports, &args)
        }
        Some("lab") => ewatt::experiments::workload_lab::run_cli(&args),
        Some("serve") => serve(&args),
        Some("bench") => {
            use ewatt::experiments::engine_bench::{self, BenchOptions};
            let d = BenchOptions::default();
            let opts = BenchOptions {
                replicas: args.get_usize("replicas", d.replicas),
                arrivals: args.get_usize("arrivals", d.arrivals),
                seed: args.get_u64("seed", d.seed),
                iters: args.get_usize("iters", d.iters),
                check: args.has_flag("check"),
                min_speedup: match args.get("min-speedup") {
                    Some(s) => s.parse().context("parsing --min-speedup")?,
                    None => d.min_speedup,
                },
                path: args.get("json").map(Into::into).unwrap_or(d.path),
            };
            engine_bench::run(&opts)
        }
        Some("trace") => ewatt::experiments::trace::run_cli(&args),
        Some("diff") => ewatt::obs::diff::run_cli(&args),
        Some("info") => info(),
        Some("help") => {
            println!("{}", usage_text());
            Ok(())
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprintln!("{}", usage_text());
            bail!("no subcommand")
        }
    }
}

/// Raw sweep cells — every (model, batch, freq) × full-mix measurement.
fn sweep_csv(ctx: &Context, args: &Args) -> Result<()> {
    use ewatt::config::ModelTier;
    use ewatt::experiments::context::CellKey;
    let mut r = Report::new(
        "sweep",
        "raw DVFS sweep cells (full dataset mix)",
        &["model", "batch", "freq_mhz", "energy_j", "latency_s", "prefill_s",
          "decode_s", "tokens_out", "j_per_query"],
    );
    for tier in ModelTier::ALL {
        for &b in &ctx.cfg.batch_sizes {
            for &f in &ctx.gpu.freq_levels_mhz {
                let m = ctx.cell(CellKey { tier, batch: b, freq: f, dataset: None })?;
                r.row(vec![
                    tier.label().to_string(),
                    b.to_string(),
                    f.to_string(),
                    format!("{:.2}", m.energy_j),
                    format!("{:.4}", m.latency_s),
                    format!("{:.4}", m.prefill_s),
                    format!("{:.4}", m.decode_s),
                    m.tokens_out.to_string(),
                    format!("{:.3}", m.energy_per_query()),
                ]);
            }
        }
    }
    emit(&[r], args)
}

/// Serve a replay slice through the real PJRT tiny-LM.
fn serve(args: &Args) -> Result<()> {
    let gpu = GpuSpec::rtx_pro_6000();
    let prefill = args.get_usize("prefill-mhz", gpu.f_max_mhz as usize) as u32;
    let decode = args.get_usize("decode-mhz", 180) as u32;
    let cfg = ServeConfig {
        tier: args.get("tier").unwrap_or("t3").to_string(),
        batch: args.get_usize("batch", 4),
        max_new_tokens: args.get_usize("max-new", 32),
        policy: DvfsPolicy::PhaseAware { prefill, decode },
        ..Default::default()
    };
    let n = args.get_usize("n", 16);
    let suite = ReplaySuite::quick(args.get_u64("seed", 7), n.div_ceil(4));
    let queries: Vec<(usize, &ewatt::workload::Query)> = (0..suite.len().min(n))
        .map(|i| (i, &suite.queries[i]))
        .collect();
    eprintln!(
        "serving {} requests on tiny-LM {} (batch {}, policy {}) ...",
        queries.len(),
        cfg.tier,
        cfg.batch,
        cfg.policy.label()
    );
    let server = Server::new(cfg);
    let (outcomes, metrics) = server.serve(&queries)?;
    println!(
        "requests={} wall={:.2}s throughput={:.2} req/s decode={:.1} tok/s",
        metrics.requests,
        metrics.wall_s,
        metrics.throughput_rps(),
        metrics.tokens_per_s()
    );
    println!(
        "latency mean={:.1}ms p50={:.1}ms p95={:.1}ms | sim energy: {:.2} J/req, {:.4} J/tok",
        1e3 * metrics.mean_latency_s(),
        1e3 * metrics.percentile(50.0),
        1e3 * metrics.percentile(95.0),
        metrics.joules_per_request(),
        metrics.joules_per_token()
    );
    let mean_rouge: f64 =
        outcomes.iter().map(|o| o.rouge_l).sum::<f64>() / outcomes.len().max(1) as f64;
    println!("mean ROUGE-L vs references: {mean_rouge:.3} (random-weight tiny-LM)");
    for o in outcomes.iter().take(3) {
        let preview: String = o.text.chars().take(60).collect();
        println!("  [{}] {} tokens: {preview}...", o.query_idx, o.tokens_out);
    }
    Ok(())
}

fn info() -> Result<()> {
    let g = GpuSpec::rtx_pro_6000();
    println!("testbed: {} ({} GB, {:.0} GB/s, {:.0} TFLOP/s fp16 @ {} MHz)",
        g.name,
        g.mem_capacity_bytes >> 30,
        g.mem_bw_bytes / 1e9,
        g.peak_flops_fp16 / 1e12,
        g.f_max_mhz);
    println!("DVFS ladder: {:?} MHz", g.freq_levels_mhz);
    println!("\nmodels:");
    for m in paper_models() {
        println!(
            "  {:14} {:5.1}B params  {} layers  d={}  d_ff={}  kv/token={} B",
            m.name,
            m.param_count() as f64 / 1e9,
            m.n_layers,
            m.d_model,
            m.d_ff,
            m.kv_bytes_per_token()
        );
    }
    Ok(())
}
