//! Normalization utilities: min-max scaling (the paper normalizes quality
//! within each dataset before cross-model comparison) and z-standardization
//! (features are standardized before logistic regression).

/// Min-max normalize a sample in place to [0, 1]. Constant samples map to 0.5
/// (no information), matching the paper's treatment.
pub fn minmax_normalize(xs: &mut [f64]) {
    if xs.is_empty() {
        return;
    }
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = max - min;
    if range == 0.0 {
        xs.iter_mut().for_each(|x| *x = 0.5);
    } else {
        xs.iter_mut().for_each(|x| *x = (*x - min) / range);
    }
}

/// Fitted standardization parameters (zero mean, unit variance per column).
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub means: Vec<f64>,
    pub stds: Vec<f64>,
}

impl Standardizer {
    /// Fit on row-major data (`rows × dims`).
    pub fn fit(rows: &[Vec<f64>]) -> Standardizer {
        assert!(!rows.is_empty(), "Standardizer::fit on empty data");
        let dims = rows[0].len();
        let n = rows.len() as f64;
        let mut means = vec![0.0; dims];
        for r in rows {
            for (m, v) in means.iter_mut().zip(r) {
                *m += v;
            }
        }
        means.iter_mut().for_each(|m| *m /= n);
        let mut stds = vec![0.0; dims];
        for r in rows {
            for d in 0..dims {
                stds[d] += (r[d] - means[d]).powi(2);
            }
        }
        for s in stds.iter_mut() {
            *s = (*s / n).sqrt();
            if *s == 0.0 {
                *s = 1.0; // constant column: leave centered at zero
            }
        }
        Standardizer { means, stds }
    }

    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(self.means.iter().zip(&self.stds))
            .map(|(x, (m, s))| (x - m) / s)
            .collect()
    }

    pub fn transform_all(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform(r)).collect()
    }
}

/// Convenience: standardize in one shot, returning transformed rows.
pub fn standardize(rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
    Standardizer::fit(rows).transform_all(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_maps_to_unit_interval() {
        let mut xs = vec![2.0, 4.0, 6.0];
        minmax_normalize(&mut xs);
        assert_eq!(xs, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn minmax_constant_maps_to_half() {
        let mut xs = vec![3.0, 3.0];
        minmax_normalize(&mut xs);
        assert_eq!(xs, vec![0.5, 0.5]);
        let mut empty: Vec<f64> = vec![];
        minmax_normalize(&mut empty);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let rows = vec![vec![1.0, 10.0], vec![2.0, 20.0], vec![3.0, 30.0]];
        let z = standardize(&rows);
        for d in 0..2 {
            let mean: f64 = z.iter().map(|r| r[d]).sum::<f64>() / 3.0;
            let var: f64 = z.iter().map(|r| r[d].powi(2)).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn constant_column_does_not_nan() {
        let rows = vec![vec![5.0, 1.0], vec![5.0, 2.0]];
        let z = standardize(&rows);
        assert!(z.iter().all(|r| r[0] == 0.0));
        assert!(z.iter().all(|r| r.iter().all(|v| v.is_finite())));
    }
}
