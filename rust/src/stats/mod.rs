//! Statistics substrate: descriptive stats, streaming quantiles (P²),
//! correlations (Pearson and partial), normalization, an L2-regularized
//! logistic regression, and stratified k-fold cross-validation — everything
//! Section V's analysis and the serve layer's online SLO tracking need,
//! implemented natively and property-tested.

pub mod correlation;
pub mod crossval;
pub mod descriptive;
pub mod logistic;
pub mod normalize;

pub use correlation::{partial_correlation, pearson};
pub use crossval::{stratified_kfold, cross_validate_accuracy};
pub use descriptive::{exact_quantile, P2Quantile, StreamingQuantiles, Summary};
pub use logistic::LogisticRegression;
pub use normalize::{minmax_normalize, standardize, Standardizer};
