//! Stratified k-fold cross-validation (the paper's 5-fold stratified CV for
//! the difficulty-classification ablation, Table VI).

use super::logistic::LogisticRegression;
use super::normalize::Standardizer;
use crate::Rng;

/// Produce `k` stratified folds as index sets. Class proportions are
/// preserved per fold; assignment is deterministic given the RNG.
pub fn stratified_kfold(y: &[bool], k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(k >= 2, "k must be >= 2");
    let mut pos: Vec<usize> = (0..y.len()).filter(|&i| y[i]).collect();
    let mut neg: Vec<usize> = (0..y.len()).filter(|&i| !y[i]).collect();
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);
    let mut folds = vec![Vec::new(); k];
    for (j, &i) in pos.iter().enumerate() {
        folds[j % k].push(i);
    }
    for (j, &i) in neg.iter().enumerate() {
        folds[j % k].push(i);
    }
    folds
}

/// k-fold CV accuracy of an L2 logistic regression with per-fold
/// standardization (fit scaler on train only — no leakage), exactly the
/// paper's protocol: LR(C=1.0), 5 folds, standardized features.
pub fn cross_validate_accuracy(
    x: &[Vec<f64>],
    y: &[bool],
    k: usize,
    c: f64,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(x.len(), y.len());
    let folds = stratified_kfold(y, k, rng);
    let mut correct = 0usize;
    let mut total = 0usize;
    for test_fold in &folds {
        let test_set: std::collections::HashSet<usize> = test_fold.iter().cloned().collect();
        let train_idx: Vec<usize> = (0..x.len()).filter(|i| !test_set.contains(i)).collect();
        let xtrain: Vec<Vec<f64>> = train_idx.iter().map(|&i| x[i].clone()).collect();
        let ytrain: Vec<bool> = train_idx.iter().map(|&i| y[i]).collect();
        let scaler = Standardizer::fit(&xtrain);
        let xtrain_z = scaler.transform_all(&xtrain);
        let mut lr = LogisticRegression::new(c);
        lr.fit(&xtrain_z, &ytrain);
        for &i in test_fold {
            let pred = lr.predict(&scaler.transform(&x[i]));
            if pred == y[i] {
                correct += 1;
            }
            total += 1;
        }
    }
    correct as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_partition_all_indices() {
        let y: Vec<bool> = (0..103).map(|i| i % 3 == 0).collect();
        let mut rng = crate::rng(1);
        let folds = stratified_kfold(&y, 5, &mut rng);
        let mut all: Vec<usize> = folds.iter().flatten().cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn folds_are_stratified() {
        let y: Vec<bool> = (0..100).map(|i| i < 40).collect();
        let mut rng = crate::rng(2);
        let folds = stratified_kfold(&y, 5, &mut rng);
        for f in &folds {
            let pos = f.iter().filter(|&&i| y[i]).count();
            assert_eq!(pos, 8, "each fold gets 40/5 positives");
            assert_eq!(f.len(), 20);
        }
    }

    #[test]
    fn cv_on_separable_data_is_high_and_on_noise_is_chance() {
        let mut rng = crate::rng(3);
        let n = 400;
        let x: Vec<Vec<f64>> = (0..n)
            .map(|i| vec![i as f64 / n as f64, ((i * 7919) % 97) as f64])
            .collect();
        let y: Vec<bool> = (0..n).map(|i| i >= n / 2).collect();
        let acc = cross_validate_accuracy(&x, &y, 5, 1.0, &mut rng);
        assert!(acc > 0.95, "separable: {acc}");

        // Labels independent of features → ~50%.
        let y_noise: Vec<bool> = (0..n).map(|i| (i * 2654435761_usize) % 2 == 0).collect();
        let acc_noise = cross_validate_accuracy(&x, &y_noise, 5, 1.0, &mut rng);
        assert!((acc_noise - 0.5).abs() < 0.12, "noise: {acc_noise}");
    }
}
