//! Descriptive statistics (Table II's mean/std/min/max/range).

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (population std, ddof = 1 like the paper's
    /// pandas `describe`).
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, std: var.sqrt(), min, max }
    }

    /// Max/min ratio — the paper's "Range" column (e.g. 12.2×).
    pub fn range_ratio(&self) -> f64 {
        if self.min <= 0.0 {
            f64::INFINITY
        } else {
            self.max / self.min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.range_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert!(Summary::of(&[]).mean.is_nan());
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 7.0);
    }
}

/// Inverse standard-normal CDF (Acklam's approximation, |ε| < 1.15e-9).
/// Used by the quality surrogate to hit published per-dataset accuracies.
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

#[cfg(test)]
mod probit_tests {
    use super::probit;

    #[test]
    fn known_quantiles() {
        assert!(probit(0.5).abs() < 1e-8);
        assert!((probit(0.975) - 1.959964).abs() < 1e-4);
        assert!((probit(0.025) + 1.959964).abs() < 1e-4);
        assert!((probit(0.8413447) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn symmetric() {
        for p in [0.01, 0.1, 0.3] {
            assert!((probit(p) + probit(1.0 - p)).abs() < 1e-7);
        }
    }

    #[test]
    #[should_panic(expected = "probit domain")]
    fn rejects_out_of_domain() {
        probit(0.0);
    }
}
