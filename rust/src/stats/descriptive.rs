//! Descriptive statistics (Table II's mean/std/min/max/range) and streaming
//! quantile estimation (P², for online SLO tracking in the serve layer).

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (population std, ddof = 1 like the paper's
    /// pandas `describe`).
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, std: var.sqrt(), min, max }
    }

    /// Max/min ratio — the paper's "Range" column (e.g. 12.2×).
    pub fn range_ratio(&self) -> f64 {
        if self.min <= 0.0 {
            f64::INFINITY
        } else {
            self.max / self.min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.range_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert!(Summary::of(&[]).mean.is_nan());
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 7.0);
    }
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac 1985).
///
/// Tracks one quantile in O(1) memory with five markers whose heights are
/// adjusted by a piecewise-parabolic formula as observations stream in. The
/// serve layer's SLO tracker and DVFS governor both read these estimates on
/// the request path, where sorting the full latency history per decision
/// would be O(n log n) per step.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights q0..q4 (q2 estimates the p-quantile).
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: usize,
    /// Buffer for the first five observations.
    init: [f64; 5],
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!(p > 0.0 && p < 1.0, "quantile must be in (0,1), got {p}");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: [0.0; 5],
        }
    }

    /// The quantile being estimated.
    pub fn p(&self) -> f64 {
        self.p
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Feed one observation.
    pub fn observe(&mut self, x: f64) {
        if self.count < 5 {
            self.init[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                // total_cmp: a NaN observation must not panic the stream.
                self.init.sort_by(f64::total_cmp);
                self.q = self.init;
            }
            return;
        }
        // Locate the cell and stretch the extreme markers if needed.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        self.count += 1;
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Adjust interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let s = d.signum();
                let cand = self.parabolic(i, s);
                self.q[i] = if self.q[i - 1] < cand && cand < self.q[i + 1] {
                    cand
                } else {
                    self.linear(i, s)
                };
                self.n[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + s / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + s * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate (NaN before the first observation; exact for n ≤ 5).
    pub fn value(&self) -> f64 {
        if self.count < 5 {
            return exact_quantile(&self.init[..self.count], self.p);
        }
        self.q[2]
    }
}

/// The serve layer's standard percentile bundle: streaming p50/p95/p99.
#[derive(Debug, Clone)]
pub struct StreamingQuantiles {
    q50: P2Quantile,
    q95: P2Quantile,
    q99: P2Quantile,
}

impl Default for StreamingQuantiles {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingQuantiles {
    pub fn new() -> StreamingQuantiles {
        StreamingQuantiles {
            q50: P2Quantile::new(0.50),
            q95: P2Quantile::new(0.95),
            q99: P2Quantile::new(0.99),
        }
    }

    pub fn observe(&mut self, x: f64) {
        self.q50.observe(x);
        self.q95.observe(x);
        self.q99.observe(x);
    }

    pub fn count(&self) -> usize {
        self.q50.count()
    }

    pub fn p50(&self) -> f64 {
        self.q50.value()
    }

    pub fn p95(&self) -> f64 {
        self.q95.value()
    }

    pub fn p99(&self) -> f64 {
        self.q99.value()
    }
}

/// Exact quantile of a sample (nearest-rank on the sorted data) — the
/// reference the streaming estimator is validated against. NaN samples
/// sort after every finite value (total order), so a poisoned sample
/// degrades the top quantiles instead of panicking the sort.
pub fn exact_quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let idx = (p * (s.len() as f64 - 1.0)).round() as usize;
    s[idx]
}

#[cfg(test)]
mod quantile_tests {
    use super::*;
    use crate::Rng;

    #[test]
    fn empty_and_small_sample_paths() {
        let mut q = P2Quantile::new(0.5);
        assert!(q.value().is_nan());
        for x in [3.0, 1.0, 2.0] {
            q.observe(x);
        }
        assert_eq!(q.value(), 2.0); // exact median of {1,2,3}
        assert_eq!(q.count(), 3);
    }

    #[test]
    fn nan_sample_degrades_top_quantiles_without_panicking() {
        // Regression: `partial_cmp().unwrap()` panicked on a NaN latency.
        assert_eq!(exact_quantile(&[2.0, f64::NAN, 1.0, 3.0], 0.0), 1.0);
        assert!(exact_quantile(&[2.0, f64::NAN, 1.0, 3.0], 1.0).is_nan());
        // The streaming estimator's init sort tolerates NaN too.
        let mut q = P2Quantile::new(0.5);
        for x in [1.0, f64::NAN, 2.0, 3.0, 4.0, 5.0, 6.0] {
            q.observe(x);
        }
        assert_eq!(q.count(), 7);
    }

    #[test]
    fn uniform_stream_matches_exact_quantiles() {
        let mut rng = Rng::seed_from_u64(17);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.gen_f64()).collect();
        for p in [0.5, 0.95, 0.99] {
            let mut q = P2Quantile::new(p);
            for &x in &xs {
                q.observe(x);
            }
            let exact = exact_quantile(&xs, p);
            assert!(
                (q.value() - exact).abs() < 0.02,
                "p{p}: est {} vs exact {exact}",
                q.value()
            );
        }
    }

    #[test]
    fn heavy_tailed_stream_stays_within_relative_band() {
        // Exponential-ish latencies: the distribution the SLO tracker sees.
        let mut rng = Rng::seed_from_u64(23);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| -(1.0 - rng.gen_f64()).ln() * 0.1)
            .collect();
        let mut q = P2Quantile::new(0.99);
        for &x in &xs {
            q.observe(x);
        }
        let exact = exact_quantile(&xs, 0.99);
        assert!(
            (q.value() - exact).abs() / exact < 0.10,
            "p99 est {} vs exact {exact}",
            q.value()
        );
    }

    #[test]
    fn estimates_are_ordered_and_bounded() {
        let mut rng = Rng::seed_from_u64(31);
        let mut sq = StreamingQuantiles::new();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..5_000 {
            let x = rng.normal() * 3.0 + 10.0;
            lo = lo.min(x);
            hi = hi.max(x);
            sq.observe(x);
        }
        assert!(sq.p50() <= sq.p95() && sq.p95() <= sq.p99());
        assert!(sq.p50() >= lo && sq.p99() <= hi);
        assert_eq!(sq.count(), 5_000);
    }

    #[test]
    fn deterministic_given_same_stream() {
        let feed = |seed| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut q = P2Quantile::new(0.95);
            for _ in 0..1_000 {
                q.observe(rng.gen_f64());
            }
            q.value()
        };
        assert_eq!(feed(5), feed(5));
    }

    #[test]
    #[should_panic(expected = "quantile must be in")]
    fn rejects_out_of_range_p() {
        P2Quantile::new(1.0);
    }
}

/// Inverse standard-normal CDF (Acklam's approximation, |ε| < 1.15e-9).
/// Used by the quality surrogate to hit published per-dataset accuracies.
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

#[cfg(test)]
mod probit_tests {
    use super::probit;

    #[test]
    fn known_quantiles() {
        assert!(probit(0.5).abs() < 1e-8);
        assert!((probit(0.975) - 1.959964).abs() < 1e-4);
        assert!((probit(0.025) + 1.959964).abs() < 1e-4);
        assert!((probit(0.8413447) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn symmetric() {
        for p in [0.01, 0.1, 0.3] {
            assert!((probit(p) + probit(1.0 - p)).abs() < 1e-7);
        }
    }

    #[test]
    #[should_panic(expected = "probit domain")]
    fn rejects_out_of_domain() {
        probit(0.0);
    }
}
