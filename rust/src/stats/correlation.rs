//! Pearson and partial correlation (Table V's feature-independence and the
//! paper's "controlling for length" analysis in Section V-D).

/// Pearson correlation coefficient. Returns 0.0 for degenerate inputs
/// (length < 2 or zero variance) — matching the paper's treatment of
/// constant features.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "pearson: length mismatch");
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n as f64;
    let my = y.iter().sum::<f64>() / n as f64;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// First-order partial correlation r(x, y | z): the association between x
/// and y with the linear effect of z removed.
pub fn partial_correlation(x: &[f64], y: &[f64], z: &[f64]) -> f64 {
    let rxy = pearson(x, y);
    let rxz = pearson(x, z);
    let ryz = pearson(y, z);
    let denom = ((1.0 - rxz * rxz) * (1.0 - ryz * ryz)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (rxy - rxz * ryz) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_correlation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_are_zero() {
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn independent_samples_near_zero() {
        // Deterministic pseudo-independent sequences.
        let x: Vec<f64> = (0..1000).map(|i| ((i * 97) % 101) as f64).collect();
        let y: Vec<f64> = (0..1000).map(|i| ((i * 31 + 7) % 103) as f64).collect();
        assert!(pearson(&x, &y).abs() < 0.1);
    }

    #[test]
    fn partial_removes_confounder() {
        // x and y both driven by z (plus independent wiggles): partialling
        // out z kills the association. Exact collinearity is numerically
        // degenerate, so the test uses near-collinear data.
        let z: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let x: Vec<f64> = z
            .iter()
            .enumerate()
            .map(|(i, v)| 2.0 * v + 1.0 + (i as f64 * 0.7).sin())
            .collect();
        let y: Vec<f64> = z
            .iter()
            .enumerate()
            .map(|(i, v)| -0.5 * v + 3.0 + (i as f64 * 1.3).cos() * 0.5)
            .collect();
        assert!(pearson(&x, &y).abs() > 0.99);
        assert!(partial_correlation(&x, &y, &z).abs() < 0.1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        pearson(&[1.0], &[1.0, 2.0]);
    }
}
