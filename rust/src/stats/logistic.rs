//! L2-regularized logistic regression (the paper's difficulty classifier,
//! Section V-D2: C = 1.0, standardized features, 5-fold stratified CV).
//!
//! Trained by full-batch gradient descent with backtracking-free fixed step
//! and enough iterations to converge on the small feature sets involved
//! (≤ 6 dims, ≤ 4k rows); deterministic — no RNG in the optimizer.

/// Logistic regression model.
#[derive(Debug, Clone)]
pub struct LogisticRegression {
    pub weights: Vec<f64>,
    pub bias: f64,
    /// Inverse regularization strength (sklearn's C; paper uses 1.0).
    pub c: f64,
    pub max_iter: usize,
    pub lr: f64,
}

fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl LogisticRegression {
    pub fn new(c: f64) -> Self {
        LogisticRegression { weights: vec![], bias: 0.0, c, max_iter: 500, lr: 0.5 }
    }

    /// Fit on row-major features and boolean labels.
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[bool]) {
        assert_eq!(x.len(), y.len(), "fit: rows/labels mismatch");
        assert!(!x.is_empty(), "fit: empty data");
        let n = x.len();
        let dims = x[0].len();
        self.weights = vec![0.0; dims];
        self.bias = 0.0;
        let lambda = 1.0 / (self.c * n as f64); // sklearn-style scaling

        for _ in 0..self.max_iter {
            let mut gw = vec![0.0; dims];
            let mut gb = 0.0;
            for (xi, &yi) in x.iter().zip(y) {
                let z = self.decision(xi);
                let err = sigmoid(z) - f64::from(yi as u8);
                for (g, v) in gw.iter_mut().zip(xi) {
                    *g += err * v;
                }
                gb += err;
            }
            let inv_n = 1.0 / n as f64;
            for (w, g) in self.weights.iter_mut().zip(&gw) {
                *w -= self.lr * (g * inv_n + lambda * *w);
            }
            self.bias -= self.lr * gb * inv_n;
        }
    }

    /// Raw decision value w·x + b.
    pub fn decision(&self, x: &[f64]) -> f64 {
        self.weights.iter().zip(x).map(|(w, v)| w * v).sum::<f64>() + self.bias
    }

    /// P(label = true).
    pub fn predict_proba(&self, x: &[f64]) -> f64 {
        sigmoid(self.decision(x))
    }

    pub fn predict(&self, x: &[f64]) -> bool {
        self.decision(x) >= 0.0
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, x: &[Vec<f64>], y: &[bool]) -> f64 {
        assert_eq!(x.len(), y.len());
        if x.is_empty() {
            return 0.0;
        }
        let hits = x
            .iter()
            .zip(y)
            .filter(|(xi, &yi)| self.predict(xi) == yi)
            .count();
        hits as f64 / x.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn separable() -> (Vec<Vec<f64>>, Vec<bool>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..50 {
            let t = i as f64 / 50.0;
            x.push(vec![t, 1.0 - t]);
            y.push(false);
            x.push(vec![t + 2.0, 1.0 - t]);
            y.push(true);
        }
        (x, y)
    }

    #[test]
    fn learns_separable_data() {
        let (x, y) = separable();
        let mut lr = LogisticRegression::new(1.0);
        lr.fit(&x, &y);
        assert!(lr.accuracy(&x, &y) > 0.97);
        assert!(lr.weights[0] > 0.0); // first dim separates the classes
    }

    #[test]
    fn probabilities_are_calibratedish() {
        let (x, y) = separable();
        let mut lr = LogisticRegression::new(1.0);
        lr.fit(&x, &y);
        assert!(lr.predict_proba(&[3.0, 0.5]) > 0.9);
        assert!(lr.predict_proba(&[0.0, 0.5]) < 0.1);
    }

    #[test]
    fn stronger_regularization_shrinks_weights() {
        let (x, y) = separable();
        let mut loose = LogisticRegression::new(10.0);
        let mut tight = LogisticRegression::new(0.01);
        loose.fit(&x, &y);
        tight.fit(&x, &y);
        let nl: f64 = loose.weights.iter().map(|w| w * w).sum();
        let nt: f64 = tight.weights.iter().map(|w| w * w).sum();
        assert!(nt < nl);
    }

    #[test]
    fn sigmoid_is_stable_at_extremes() {
        assert!(sigmoid(1e3) <= 1.0);
        assert!(sigmoid(-1e3) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn deterministic_fit() {
        let (x, y) = separable();
        let mut a = LogisticRegression::new(1.0);
        let mut b = LogisticRegression::new(1.0);
        a.fit(&x, &y);
        b.fit(&x, &y);
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.bias, b.bias);
    }
}
