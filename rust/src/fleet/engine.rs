//! The heterogeneous fleet simulator.
//!
//! A discrete-event engine over N replicas (possibly different model
//! tiers, each under its own frequency governor) fed by one arrival
//! stream through a pluggable [`FleetRouter`]. The engine interleaves two
//! event kinds on the simulated clock:
//!
//! - **arrival**: the router reads every replica's live status (backlog,
//!   telemetry-window power, joules/token) and binds the request to
//!   exactly one live replica;
//! - **replica step**: the earliest runnable replica executes one unit of
//!   work (an admission prefill or a batched decode step) under its own
//!   governor.
//!
//! Arrivals are processed before any replica step at or after their
//! timestamp, so routing always sees the fleet state as of the arrival
//! instant — the co-design loop (router reacting to governor-driven power,
//! governor reacting to router-driven load) the paper's offline Section
//! VII analysis cannot express.

use anyhow::Result;

use crate::config::{GpuSpec, ModelSpec, ModelTier};
use crate::coordinator::dvfs_policy::DvfsPolicy;
use crate::serve::slo::{Slo, SloTracker};
use crate::serve::traffic::Arrival;
use crate::stats::exact_quantile;
use crate::workload::ReplaySuite;

use super::attribution::{EnergyLedger, PhaseEnergy};
use super::replica::{Replica, ReplicaSpec};
use super::router::FleetRouter;

/// Fleet composition and serving parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub replicas: Vec<ReplicaSpec>,
    /// Maximum sequences decoding concurrently per replica.
    pub max_batch: usize,
    pub slo: Slo,
    /// Telemetry window horizon fed to each governor, seconds.
    pub window_s: f64,
}

impl FleetConfig {
    /// `n` identical replicas of `model` under one policy.
    pub fn homogeneous(model: ModelSpec, n: usize, policy: DvfsPolicy) -> FleetConfig {
        assert!(n >= 1);
        FleetConfig {
            replicas: vec![ReplicaSpec { model, policy, live: true }; n],
            ..FleetConfig::default()
        }
    }

    /// A two-tier fleet: `n_small` small-tier plus `n_large` large-tier
    /// replicas, all under one policy (the Section VII deployment shape).
    pub fn tiered(
        small: ModelTier,
        n_small: usize,
        large: ModelTier,
        n_large: usize,
        policy: DvfsPolicy,
    ) -> FleetConfig {
        assert!(n_small + n_large >= 1);
        let mut replicas = Vec::with_capacity(n_small + n_large);
        for _ in 0..n_small {
            replicas.push(ReplicaSpec::tiered(small, policy));
        }
        for _ in 0..n_large {
            replicas.push(ReplicaSpec::tiered(large, policy));
        }
        FleetConfig { replicas, ..FleetConfig::default() }
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: Vec::new(),
            max_batch: 8,
            slo: Slo::interactive(),
            window_s: 2.0,
        }
    }
}

/// Post-run summary of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    pub tier: ModelTier,
    pub policy_label: String,
    pub live: bool,
    pub served: usize,
    pub tokens_out: u64,
    /// Busy (prefill + decode + switch) time, seconds.
    pub busy_s: f64,
    /// Active energy, joules.
    pub energy_j: f64,
    pub idle_j: f64,
    pub switch_j: f64,
    pub freq_switches: usize,
    pub mean_decode_freq_mhz: f64,
    /// Deepest admission-queue backlog this replica observed.
    pub max_queue_depth: usize,
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub served: usize,
    /// Active energy across the fleet (prefill + decode + switch), joules.
    pub energy_j: f64,
    /// Idle draw while replicas waited for routed arrivals, joules.
    pub idle_j: f64,
    /// Energy charged to DVFS transitions (subset of `energy_j`).
    pub switch_j: f64,
    /// Time the last request finished, seconds.
    pub makespan_s: f64,
    pub freq_switches: usize,
    /// Fleet-level streaming SLO percentiles + attainment.
    pub slo: SloTracker,
    /// Attributed total energy per request, indexed by arrival order.
    pub joules: Vec<f64>,
    /// Fleet-wide attributed energy by phase (sums to `total_j()`).
    pub breakdown: PhaseEnergy,
    /// Which replica served each arrival.
    pub routed: Vec<usize>,
    pub replicas: Vec<ReplicaOutcome>,
}

impl FleetOutcome {
    /// Active + idle energy, joules.
    pub fn total_j(&self) -> f64 {
        self.energy_j + self.idle_j
    }

    /// Mean *attributed* energy per request — active plus amortized idle,
    /// the full per-request bill, consistent with summing [`Self::joules`]
    /// (the same convention as
    /// [`crate::serve::ServeOutcome::joules_per_request`]). `NaN` when the
    /// run served nothing — a degenerate case the experiment tables assert
    /// against rather than silently reporting a number.
    pub fn attributed_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.total_j() / self.served as f64
    }

    /// Mean *active* (prefill + decode + switch) energy per request —
    /// the policy-controlled quantity. `NaN` when nothing was served.
    pub fn active_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.energy_j / self.served as f64
    }

    /// Quantile of the per-request attributed energy distribution.
    pub fn attributed_joules_per_request_quantile(&self, p: f64) -> f64 {
        exact_quantile(&self.joules, p)
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }
}

/// The fleet engine.
pub struct FleetSim {
    pub gpu: GpuSpec,
    pub cfg: FleetConfig,
}

impl FleetSim {
    pub fn new(gpu: GpuSpec, cfg: FleetConfig) -> FleetSim {
        assert!(!cfg.replicas.is_empty(), "fleet needs at least one replica");
        assert!(cfg.replicas.iter().any(|r| r.live), "fleet needs at least one live replica");
        assert!(cfg.max_batch >= 1);
        FleetSim { gpu, cfg }
    }

    /// Serve `arrivals` through `router`. Deterministic: identical inputs
    /// replay identical outcomes bit-for-bit.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
    ) -> Result<FleetOutcome> {
        let mut reps: Vec<Replica> = self
            .cfg
            .replicas
            .iter()
            .map(|spec| Replica::new(&self.gpu, spec.clone(), self.cfg.slo, self.cfg.window_s))
            .collect();
        let mut ledger = EnergyLedger::new(arrivals.len());
        let mut fleet_tracker = SloTracker::new(self.cfg.slo);
        let routed = drive(
            &mut reps,
            suite,
            arrivals,
            router,
            self.cfg.max_batch,
            &mut ledger,
            &mut fleet_tracker,
        )?;

        let mut out = FleetOutcome {
            served: 0,
            energy_j: 0.0,
            idle_j: 0.0,
            switch_j: 0.0,
            makespan_s: 0.0,
            freq_switches: 0,
            slo: fleet_tracker,
            joules: Vec::new(),
            breakdown: PhaseEnergy::default(),
            routed,
            replicas: Vec::with_capacity(reps.len()),
        };
        for rep in reps.iter_mut() {
            rep.finalize(&mut ledger);
            out.served += rep.served;
            out.energy_j += rep.energy_j;
            out.idle_j += rep.idle_j;
            out.switch_j += rep.switch_j;
            out.freq_switches += rep.freq_switches;
            out.makespan_s = out.makespan_s.max(rep.last_finish_s);
            out.replicas.push(ReplicaOutcome {
                tier: rep.spec.model.tier,
                policy_label: rep.spec.policy.label(),
                live: rep.spec.live,
                served: rep.served,
                tokens_out: rep.tokens_out,
                busy_s: rep.busy_s,
                energy_j: rep.energy_j,
                idle_j: rep.idle_j,
                switch_j: rep.switch_j,
                freq_switches: rep.freq_switches,
                mean_decode_freq_mhz: rep.mean_decode_freq_mhz(),
                max_queue_depth: rep.max_queue_depth,
            });
        }
        out.joules = ledger.joules();
        out.breakdown = ledger.totals();
        debug_assert!(
            (out.breakdown.total_j() - out.total_j()).abs() <= 1e-6 * out.total_j().max(1e-12),
            "attribution lost energy: {} vs {}",
            out.breakdown.total_j(),
            out.total_j()
        );
        Ok(out)
    }
}

/// The shared continuous-batching event loop: advance `reps` through one
/// arrival stream. Each arrival is routed at its own timestamp against
/// live replica state, before any replica step that would start at or
/// after it; otherwise the earliest runnable replica executes one unit of
/// work under its own governor. This is the single loop behind both
/// [`FleetSim::run`] and the one-replica [`crate::serve::ServeSim`]
/// facade — there is deliberately no second copy anywhere.
///
/// Returns which replica served each arrival, indexed by arrival order.
pub fn drive(
    reps: &mut [Replica],
    suite: &ReplaySuite,
    arrivals: &[Arrival],
    router: &mut dyn FleetRouter,
    max_batch: usize,
    ledger: &mut EnergyLedger,
    tracker: &mut SloTracker,
) -> Result<Vec<usize>> {
    let mut routed = vec![usize::MAX; arrivals.len()];
    let mut statuses = Vec::with_capacity(reps.len());
    let mut next = 0usize;

    loop {
        // Earliest runnable replica clock (work that would start next).
        let t_step = reps
            .iter()
            .filter(|r| r.runnable())
            .map(|r| r.now_s)
            .fold(f64::INFINITY, f64::min);

        if next < arrivals.len() && arrivals[next].t_s <= t_step {
            let a = arrivals[next];
            statuses.clear();
            statuses.extend(reps.iter().enumerate().map(|(i, r)| r.status(i)));
            let choice = router.route(&a, suite.features.get(a.query_idx), &statuses);
            assert!(
                choice < reps.len() && reps[choice].spec.live,
                "router {} picked replica {choice}, which is not a live replica",
                router.label()
            );
            reps[choice].enqueue(next, a);
            routed[next] = choice;
            next += 1;
        } else if t_step.is_finite() {
            // Step the earliest runnable replica (lowest index on ties;
            // total_cmp so a corrupted NaN clock loudly picks a stable
            // order instead of panicking mid-run).
            let i = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| r.runnable())
                .min_by(|(_, a), (_, b)| a.now_s.total_cmp(&b.now_s))
                .map(|(i, _)| i)
                .unwrap();
            reps[i].step(suite, max_batch, ledger, tracker)?;
        } else {
            break; // no arrivals left, nothing in flight
        }
    }
    Ok(routed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::model_for_tier;
    use crate::fleet::router::{DifficultyTiered, EnergyAware, LeastLoaded, RoundRobin};
    use crate::serve::TrafficPattern;

    fn suite() -> ReplaySuite {
        ReplaySuite::quick(91, 16)
    }

    fn arrivals(s: &ReplaySuite, n: usize) -> Vec<Arrival> {
        TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 8.0, mean_dwell_s: 3.0 }
            .generate(s, n, 0xF1EE7)
    }

    fn tiered_cfg(policy: DvfsPolicy) -> FleetConfig {
        FleetConfig::tiered(ModelTier::B1, 2, ModelTier::B8, 2, policy)
    }

    #[test]
    fn serves_everything_and_conserves_energy_under_every_router() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let routers: Vec<Box<dyn FleetRouter>> = vec![
            Box::new(RoundRobin::default()),
            Box::new(LeastLoaded),
            Box::new(DifficultyTiered::default()),
            Box::new(EnergyAware::default()),
        ];
        for mut router in routers {
            let o = sim.run(&s, &arr, router.as_mut()).unwrap();
            assert_eq!(o.served, arr.len(), "{}", router.label());
            assert_eq!(o.slo.completed(), arr.len());
            assert_eq!(o.joules.len(), arr.len());
            assert!(o.routed.iter().all(|&r| r < 4), "{}", router.label());
            let attributed: f64 = o.joules.iter().sum();
            let rel = (attributed - o.total_j()).abs() / o.total_j();
            assert!(rel < 1e-6, "{}: conservation off by {rel:e}", router.label());
            // The last arrival finishes after it arrives.
            assert!(o.makespan_s >= arr.last().unwrap().t_s);
            assert!(o.energy_j > 0.0 && o.switch_j <= o.energy_j);
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let s = suite();
        let arr = arrivals(&s, 32);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let a = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        let b = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.makespan_s, b.makespan_s);
    }

    #[test]
    fn difficulty_router_sends_hard_queries_to_the_large_tier() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::baseline(&gpu)));
        let mut router = DifficultyTiered::default();
        let o = sim.run(&s, &arr, &mut router).unwrap();
        for (i, a) in arr.iter().enumerate() {
            let hard = router.is_hard(&s.features[a.query_idx]);
            let tier = sim.cfg.replicas[o.routed[i]].model.tier;
            if hard {
                assert_eq!(tier, ModelTier::B8, "hard query {i} routed to {tier:?}");
            } else {
                assert_eq!(tier, ModelTier::B1, "easy query {i} routed to {tier:?}");
            }
        }
    }

    #[test]
    fn dead_replicas_hold_no_traffic() {
        let s = suite();
        let arr = arrivals(&s, 24);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg =
            FleetConfig::homogeneous(model_for_tier(ModelTier::B1), 3, DvfsPolicy::Static(2842));
        cfg.replicas[1].live = false;
        let sim = FleetSim::new(gpu, cfg);
        let o = sim.run(&s, &arr, &mut RoundRobin::default()).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.routed.iter().all(|&r| r != 1));
        assert_eq!(o.replicas[1].served, 0);
        assert_eq!(o.replicas[1].energy_j, 0.0);
    }

    #[test]
    fn more_replicas_cut_makespan_under_load() {
        let s = suite();
        // A slam of simultaneous arrivals: parallelism must help makespan.
        let arr: Vec<Arrival> =
            (0..32).map(|i| Arrival { t_s: 0.0, query_idx: i % s.len() }).collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let run = |n: usize| {
            let cfg = FleetConfig::homogeneous(
                model_for_tier(ModelTier::B3),
                n,
                DvfsPolicy::Static(2842),
            );
            FleetSim::new(gpu.clone(), cfg)
                .run(&s, &arr, &mut LeastLoaded)
                .unwrap()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.served, four.served);
        assert!(
            one.makespan_s / four.makespan_s > 2.0,
            "speedup {:.2}",
            one.makespan_s / four.makespan_s
        );
    }

    #[test]
    fn governed_fleet_saves_energy_vs_static_within_slo() {
        let s = suite();
        let arr = arrivals(&s, 64);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = |p| FleetConfig::homogeneous(model_for_tier(ModelTier::B8), 2, p);
        let stat = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::baseline(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let gov = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::governed(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let savings = 1.0 - gov.energy_j / stat.energy_j;
        assert!(savings > 0.15, "governed fleet savings {savings:.3}");
        assert!(
            gov.slo.e2e_p99() <= gov.slo.slo.e2e_p99_s,
            "governed p99 {:.2}s over SLO",
            gov.slo.e2e_p99()
        );
    }
}
