//! The heterogeneous fleet simulator.
//!
//! A discrete-event engine over N replicas (possibly different model
//! tiers, each under its own frequency governor) fed by one arrival
//! stream through a pluggable [`FleetRouter`]. The engine interleaves
//! three event kinds on the simulated clock:
//!
//! - **arrival**: the autoscaler reads fleet state and may start warming
//!   or draining replicas, then the router reads every replica's live
//!   status (backlog, telemetry-window power, joules/token) and binds the
//!   request to exactly one live replica;
//! - **replica step**: the earliest steppable replica — located through an
//!   indexed event queue over replica clocks ([`EventQueue`]), not a
//!   per-iteration linear rescan — executes one unit of work (an admission
//!   prefill or a batched decode step) under its own governor. When the
//!   gap to the next arrival or lifecycle point is wide enough, independent
//!   replicas step on worker threads and their ledger/tracker effects are
//!   replayed in exact sequential order, so parallelism never changes a
//!   single bit of the physics;
//! - **lifecycle event**: a warm-up completes (`Warming → Live`), a
//!   replica crashes (`Live → Cold`, in-flight requests requeued through
//!   the router with their original arrival timestamps), or a repair
//!   completes (`Cold → Warming`, charging a fresh cold start).
//!
//! Arrivals are processed before any replica step at or after their
//! timestamp, so routing always sees the fleet state as of the arrival
//! instant — the co-design loop (router reacting to governor-driven power,
//! governor reacting to router-driven load, autoscaler reacting to both)
//! the paper's offline Section VII analysis cannot express.

use std::cmp::Ordering;

use anyhow::{bail, ensure, Result};

use crate::config::{GpuSpec, ModelTier};
use crate::obs::span::{SpanEvent, Trace, TraceSink};
use crate::obs::timeline::TimelineSampler;
use crate::serve::slo::{RecordSink, Slo, SloTracker};
use crate::serve::traffic::Arrival;
use crate::stats::exact_quantile;
use crate::util::parallel::par_map_mut;
use crate::workload::ReplaySuite;

use super::attribution::{ChargeLog, EnergyLedger, PhaseEnergy};
use super::forecast::ForecastConfig;
use super::lifecycle::{
    earlier, AutoscalePolicy, ColdStart, FailureConfig, FailureModel, Lifecycle, LifecycleEvent,
    LifecycleStats, PendingCheckpoint, PendingRequeue, ReactiveConfig, ReplicaState, ScaleAction,
};
use super::migration::{MigrationPolicy, MigrationStats, SeqCheckpoint};
use super::queue::EventQueue;
use super::replica::{ClassPolicy, Replica, ReplicaSpec};
use super::router::{FleetRouter, ReplicaStatus};

/// Fleet composition and serving parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub replicas: Vec<ReplicaSpec>,
    /// Maximum sequences decoding concurrently per replica.
    pub max_batch: usize,
    pub slo: Slo,
    /// Telemetry window horizon fed to each governor, seconds.
    pub window_s: f64,
    /// Scaling discipline ([`AutoscalePolicy::Static`] = fixed fleet).
    pub autoscale: AutoscalePolicy,
    /// Seeded replica crash/repair process (`None` = no failures).
    pub failures: Option<FailureConfig>,
    /// Energy + delay of bringing a `Cold` replica `Live`.
    pub cold_start: ColdStart,
    /// Per-class admission + SLO policy (`None` = class-blind: FIFO
    /// admission, every request measured against [`FleetConfig::slo`] —
    /// bit-identical to the pre-class engine).
    pub classes: Option<ClassPolicy>,
    /// KV-state migration across drains and crashes (`None` = the
    /// original lose-and-requeue semantics, bit-identical to the
    /// pre-migration engine).
    pub migration: Option<MigrationPolicy>,
}

impl FleetConfig {
    /// Start a validated fleet configuration. Terminal [`build`]
    /// (`FleetConfigBuilder::build`) checks every cross-field invariant
    /// (non-empty fleet, hysteresis band ordering, non-negative cold-start
    /// cost, positive MTBF/MTTR) and returns a typed error instead of
    /// panicking mid-run.
    pub fn builder() -> FleetConfigBuilder {
        FleetConfigBuilder { cfg: FleetConfig::default() }
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: Vec::new(),
            max_batch: 8,
            slo: Slo::interactive(),
            window_s: 2.0,
            autoscale: AutoscalePolicy::Static,
            failures: None,
            cold_start: ColdStart::default(),
            classes: None,
            migration: None,
        }
    }
}

/// Fluent constructor for [`FleetConfig`]. All invariants are validated
/// once, at [`build`](FleetConfigBuilder::build), so a malformed config is
/// a recoverable `Err` at construction instead of an assert deep inside
/// the event loop.
#[derive(Debug, Clone)]
pub struct FleetConfigBuilder {
    cfg: FleetConfig,
}

impl FleetConfigBuilder {
    /// Append one replica.
    pub fn replica(mut self, spec: ReplicaSpec) -> Self {
        self.cfg.replicas.push(spec);
        self
    }

    /// Append `n` identical replicas.
    pub fn replicas(mut self, n: usize, spec: ReplicaSpec) -> Self {
        for _ in 0..n {
            self.cfg.replicas.push(spec.clone());
        }
        self
    }

    /// Maximum sequences decoding concurrently per replica.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    pub fn slo(mut self, slo: Slo) -> Self {
        self.cfg.slo = slo;
        self
    }

    /// Telemetry window horizon fed to each governor, seconds.
    pub fn window_s(mut self, s: f64) -> Self {
        self.cfg.window_s = s;
        self
    }

    pub fn autoscale(mut self, policy: AutoscalePolicy) -> Self {
        self.cfg.autoscale = policy;
        self
    }

    /// Shorthand for a reactive autoscaling discipline.
    pub fn reactive(self, cfg: ReactiveConfig) -> Self {
        self.autoscale(AutoscalePolicy::Reactive(cfg))
    }

    /// Shorthand for the predictive (forecasting) autoscaling discipline.
    pub fn forecast(self, cfg: ForecastConfig) -> Self {
        self.autoscale(AutoscalePolicy::Forecast(cfg))
    }

    /// Enable KV-state migration: in-flight sequences checkpoint off
    /// Draining or crashed replicas and resume on Live ones (their
    /// context replayed in one prefill pass billed to `migration_j`)
    /// instead of restarting from their original arrivals.
    pub fn migration(mut self, policy: MigrationPolicy) -> Self {
        self.cfg.migration = Some(policy);
        self
    }

    pub fn failures(mut self, f: FailureConfig) -> Self {
        self.cfg.failures = Some(f);
        self
    }

    pub fn cold_start(mut self, c: ColdStart) -> Self {
        self.cfg.cold_start = c;
        self
    }

    /// Attach a per-class admission + SLO policy. Replicas then admit by
    /// strict class priority (with starvation aging), gate lower classes
    /// on KV headroom, and report a class-weighted pressure signal to
    /// their governors.
    pub fn classes(mut self, policy: ClassPolicy) -> Self {
        self.cfg.classes = Some(policy);
        self
    }

    /// Validate every invariant and hand back the config.
    pub fn build(self) -> Result<FleetConfig> {
        let cfg = self.cfg;
        ensure!(!cfg.replicas.is_empty(), "fleet needs at least one replica");
        ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
        ensure!(
            cfg.window_s.is_finite() && cfg.window_s > 0.0,
            "telemetry window must be positive, got {} s",
            cfg.window_s
        );
        if let AutoscalePolicy::Reactive(r) = &cfg.autoscale {
            ensure!(r.min_live >= 1, "reactive autoscaler needs min_live >= 1");
            ensure!(
                r.max_live >= r.min_live,
                "max_live {} below min_live {}",
                r.max_live,
                r.min_live
            );
            ensure!(
                r.low_backlog < r.high_backlog,
                "inverted backlog hysteresis band: low {} >= high {}",
                r.low_backlog,
                r.high_backlog
            );
            ensure!(
                r.low_pressure < r.high_pressure,
                "inverted pressure hysteresis band: low {} >= high {}",
                r.low_pressure,
                r.high_pressure
            );
            ensure!(r.cooldown_s >= 0.0, "cooldown must be non-negative");
        }
        if let AutoscalePolicy::Forecast(f) = &cfg.autoscale {
            ensure!(f.min_live >= 1, "forecast autoscaler needs min_live >= 1");
            ensure!(
                f.max_live >= f.min_live,
                "max_live {} below min_live {}",
                f.max_live,
                f.min_live
            );
            let positives = [
                ("bin_s", f.bin_s),
                ("window_s", f.window_s),
                ("rate_per_replica", f.rate_per_replica),
            ];
            for (label, v) in positives {
                ensure!(v.is_finite() && v > 0.0, "forecast {label} must be positive, got {v}");
            }
            ensure!(
                f.history_s >= f.window_s,
                "forecast history {} s shorter than its rate window {} s",
                f.history_s,
                f.window_s
            );
            ensure!(f.warmup_s >= 0.0, "forecast lead time must be non-negative");
            ensure!(f.cooldown_s >= 0.0, "cooldown must be non-negative");
            ensure!(
                (0.0..=1.0).contains(&f.alpha),
                "EWMA alpha must be in [0, 1], got {}",
                f.alpha
            );
            for &p in &f.periods_s {
                ensure!(p.is_finite() && p > 0.0, "candidate period must be positive, got {p} s");
            }
        }
        if let Some(m) = &cfg.migration {
            ensure!(
                m.checkpoint_every_tokens >= 1,
                "migration checkpoint cadence must be at least 1 token"
            );
        }
        ensure!(
            cfg.cold_start.energy_j >= 0.0 && cfg.cold_start.warmup_s >= 0.0,
            "cold-start energy and warm-up delay must be non-negative"
        );
        if let Some(f) = &cfg.failures {
            ensure!(f.mtbf_s > 0.0, "MTBF must be positive");
            ensure!(f.mttr_s > 0.0, "MTTR must be positive");
        }
        if let Some(c) = &cfg.classes {
            // Zero is legal: it promotes a starved class on the very next
            // admission scan (the replica-side comparison is `>=`).
            ensure!(
                c.aging_s.is_finite() && c.aging_s >= 0.0,
                "starvation aging horizon must be non-negative and finite, got {} s",
                c.aging_s
            );
            for (label, cap) in [("batch", c.batch_kv_cap), ("background", c.background_kv_cap)] {
                ensure!(
                    cap > 0.0 && cap <= 1.0,
                    "{label} KV admission cap must be in (0, 1], got {cap}"
                );
            }
        }
        Ok(cfg)
    }
}

/// Post-run summary of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    pub tier: ModelTier,
    pub policy_label: String,
    /// Lifecycle state at the end of the run.
    pub state: ReplicaState,
    pub served: usize,
    pub tokens_out: u64,
    /// Busy (prefill + decode + switch) time, seconds.
    pub busy_s: f64,
    /// Active energy, joules.
    pub energy_j: f64,
    pub idle_j: f64,
    pub switch_j: f64,
    /// Cold-start energy this replica's warm-ups charged, joules.
    pub coldstart_j: f64,
    /// Prefill-replay energy this replica spent resuming migrated
    /// sequences, joules (disjoint from `energy_j`).
    pub migration_j: f64,
    pub freq_switches: usize,
    pub mean_decode_freq_mhz: f64,
    /// Deepest admission-queue backlog this replica observed.
    pub max_queue_depth: usize,
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub served: usize,
    /// Active energy across the fleet (prefill + decode + switch), joules.
    pub energy_j: f64,
    /// Idle draw while replicas waited for routed arrivals, joules.
    pub idle_j: f64,
    /// Energy charged to DVFS transitions (subset of `energy_j`).
    pub switch_j: f64,
    /// Cold-start (boot + weight-load) energy across all warm-ups, joules.
    pub coldstart_j: f64,
    /// Prefill-replay energy spent resuming migrated sequences, joules
    /// (disjoint from `energy_j`; zero when migration is off).
    pub migration_j: f64,
    /// Time the last request finished, seconds.
    pub makespan_s: f64,
    pub freq_switches: usize,
    /// Fleet-level streaming SLO percentiles + attainment.
    pub slo: SloTracker,
    /// Attributed total energy per request, indexed by arrival order.
    pub joules: Vec<f64>,
    /// Fleet-wide attributed energy by phase (sums to `total_j()`).
    pub breakdown: PhaseEnergy,
    /// Which replica each arrival was first routed to.
    pub routed: Vec<usize>,
    /// Which replica ultimately *completed* each arrival (differs from
    /// `routed` only for crash-requeued requests).
    pub served_by: Vec<usize>,
    /// Scale/failure/requeue counters for the run.
    pub lifecycle: LifecycleStats,
    /// Checkpoint → Handoff → Resume counters (all zero when migration
    /// is off).
    pub migration: MigrationStats,
    /// Time-weighted mean count of `Live` replicas over the makespan.
    pub mean_live_replicas: f64,
    pub replicas: Vec<ReplicaOutcome>,
}

impl FleetOutcome {
    /// Active + idle + cold-start + migration-replay energy, joules.
    pub fn total_j(&self) -> f64 {
        self.energy_j + self.idle_j + self.coldstart_j + self.migration_j
    }

    /// Mean *attributed* energy per request — active plus amortized idle
    /// and cold starts, the full per-request bill, consistent with summing
    /// [`Self::joules`] (the same convention as
    /// [`crate::serve::ServeOutcome::joules_per_request`]). `NaN` when the
    /// run served nothing — a degenerate case the experiment tables assert
    /// against rather than silently reporting a number.
    pub fn attributed_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.total_j() / self.served as f64
    }

    /// Mean *active* (prefill + decode + switch) energy per request —
    /// the policy-controlled quantity. `NaN` when nothing was served.
    pub fn active_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.energy_j / self.served as f64
    }

    /// Quantile of the per-request attributed energy distribution.
    pub fn attributed_joules_per_request_quantile(&self, p: f64) -> f64 {
        exact_quantile(&self.joules, p)
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }
}

/// The fleet engine.
pub struct FleetSim {
    pub gpu: GpuSpec,
    pub cfg: FleetConfig,
}

impl FleetSim {
    pub fn new(gpu: GpuSpec, cfg: FleetConfig) -> FleetSim {
        assert!(!cfg.replicas.is_empty(), "fleet needs at least one replica");
        assert!(cfg.max_batch >= 1);
        // NOTE: liveness is deliberately *not* asserted here. A fleet may
        // start all-`Cold` under an autoscaler that warms capacity on the
        // first arrival; a fleet that is dead when traffic actually needs
        // it is a typed error from the state machine inside [`drive`].
        FleetSim { gpu, cfg }
    }

    /// Serve `arrivals` through `router`. Deterministic: identical inputs
    /// replay identical outcomes bit-for-bit.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
    ) -> Result<FleetOutcome> {
        self.run_inner(suite, arrivals, router, StepSelector::Indexed, None, None)
    }

    /// [`Self::run`] with an explicit step-selection strategy. The
    /// [`StepSelector::LinearReference`] path is the O(fleet)-per-step
    /// oracle the indexed engine is property-tested and benchmarked
    /// against; outcomes are bit-identical by construction.
    pub fn run_with_selector(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
        selector: StepSelector,
    ) -> Result<FleetOutcome> {
        self.run_inner(suite, arrivals, router, selector, None, None)
    }

    /// [`Self::run`] with a [`TraceSink`] attached: every request-lifecycle
    /// and engine event streams into `sink` as it happens, and one
    /// `request_summary` span per request (its exact attributed
    /// [`PhaseEnergy`] bill) is emitted at the makespan. The physics is
    /// bit-identical to the untraced run — a sink only observes (pinned by
    /// `rust/tests/obs_trace.rs`).
    pub fn run_traced(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
        sink: &mut dyn TraceSink,
    ) -> Result<FleetOutcome> {
        self.run_inner(suite, arrivals, router, StepSelector::Indexed, Some(sink), None)
    }

    /// [`Self::run_traced`] with a heartbeat [`TimelineSampler`] attached
    /// as well: the engine emits one gauge row per cadence boundary into
    /// `timeline` (flushed through the makespan before this returns).
    /// Like tracing, the sampler only observes — the physics stays
    /// bit-identical to [`Self::run`] (pinned by `rust/tests/obs_trace.rs`).
    pub fn run_observed(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
        sink: &mut dyn TraceSink,
        timeline: &mut TimelineSampler,
    ) -> Result<FleetOutcome> {
        self.run_inner(suite, arrivals, router, StepSelector::Indexed, Some(sink), Some(timeline))
    }

    fn run_inner(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
        selector: StepSelector,
        mut trace: Option<&mut dyn TraceSink>,
        mut timeline: Option<&mut TimelineSampler>,
    ) -> Result<FleetOutcome> {
        let mut reps: Vec<Replica> = self
            .cfg
            .replicas
            .iter()
            .map(|spec| Replica::new(&self.gpu, spec.clone(), self.cfg.slo, self.cfg.window_s))
            .collect();
        for rep in reps.iter_mut() {
            rep.set_class_policy(self.cfg.classes.as_ref());
            if let Some(m) = &self.cfg.migration {
                rep.set_checkpoint_every(Some(m.checkpoint_every_tokens));
            }
        }
        let initial_live = reps.iter().filter(|r| r.state.routable()).count();
        let mut ledger = EnergyLedger::new(arrivals.len());
        let mut fleet_tracker = SloTracker::new(self.cfg.slo);
        let mut lifecycle = Lifecycle::new(
            self.cfg.autoscale.build(),
            self.cfg
                .failures
                .map(|f| FailureModel::new(f, self.cfg.replicas.len())),
            self.cfg.cold_start,
        );
        lifecycle.migration = self.cfg.migration;
        let routed = drive_with(
            &mut reps,
            EngineCtx {
                suite,
                arrivals,
                router,
                max_batch: self.cfg.max_batch,
                ledger: &mut ledger,
                tracker: &mut fleet_tracker,
                lifecycle: &mut lifecycle,
                trace: trace.as_mut().map(|s| &mut **s),
                timeline: timeline.as_deref_mut(),
            },
            selector,
        )?;

        // Flush the heartbeat through the makespan before finalize mutates
        // the replicas (the last rows must show end-of-run serving state,
        // not post-finalize bookkeeping).
        if let Some(tl) = timeline.as_deref_mut() {
            let makespan = reps.iter().map(|r| r.last_finish_s).fold(0.0f64, f64::max);
            tl.finish(makespan, &reps);
        }

        let mut out = FleetOutcome {
            served: 0,
            energy_j: 0.0,
            idle_j: 0.0,
            switch_j: 0.0,
            coldstart_j: 0.0,
            migration_j: 0.0,
            makespan_s: 0.0,
            freq_switches: 0,
            slo: fleet_tracker,
            joules: Vec::new(),
            breakdown: PhaseEnergy::default(),
            routed,
            served_by: vec![usize::MAX; arrivals.len()],
            lifecycle: lifecycle.stats,
            migration: lifecycle.migration_stats,
            mean_live_replicas: 0.0,
            replicas: Vec::with_capacity(reps.len()),
        };
        // Overhead (idle, cold starts) of replicas that never completed a
        // request cannot be amortized locally; spread it over the whole
        // run so the bill still sums to the meter.
        let mut unattributed = PhaseEnergy::default();
        for rep in reps.iter_mut() {
            unattributed.add(&rep.finalize(&mut ledger));
            for &req in rep.served_reqs() {
                out.served_by[req] = out.replicas.len();
            }
            out.served += rep.served;
            out.energy_j += rep.energy_j;
            out.idle_j += rep.idle_j;
            out.switch_j += rep.switch_j;
            out.coldstart_j += rep.coldstart_j;
            out.migration_j += rep.migration_j;
            out.freq_switches += rep.freq_switches;
            out.makespan_s = out.makespan_s.max(rep.last_finish_s);
            out.replicas.push(ReplicaOutcome {
                tier: rep.spec.model.tier,
                policy_label: rep.spec.policy.label(),
                state: rep.state,
                served: rep.served,
                tokens_out: rep.tokens_out,
                busy_s: rep.busy_s,
                energy_j: rep.energy_j,
                idle_j: rep.idle_j,
                switch_j: rep.switch_j,
                coldstart_j: rep.coldstart_j,
                migration_j: rep.migration_j,
                freq_switches: rep.freq_switches,
                mean_decode_freq_mhz: rep.mean_decode_freq_mhz(),
                max_queue_depth: rep.max_queue_depth,
            });
        }
        if unattributed.total_j() > 0.0 {
            let all: Vec<usize> = (0..arrivals.len()).collect();
            ledger.charge_idle(&all, unattributed.idle_j);
            ledger.charge_coldstart(&all, unattributed.coldstart_j);
        }
        out.mean_live_replicas = lifecycle.mean_live(initial_live, out.makespan_s);
        out.joules = ledger.joules();
        out.breakdown = ledger.totals();
        debug_assert!(
            out.served < arrivals.len()
                || (out.breakdown.total_j() - out.total_j()).abs()
                    <= 1e-6 * out.total_j().max(1e-12),
            "attribution lost energy: {} vs {}",
            out.breakdown.total_j(),
            out.total_j()
        );
        // Final bills: one request_summary span per request, carrying its
        // exact ledger account (amortized idle/cold-start shares included
        // — they only exist after the finalize loop above, which is why
        // these spans are stamped at the makespan rather than at serve
        // time).
        if let Some(sink) = trace {
            for req in 0..arrivals.len() {
                sink.emit(
                    out.makespan_s,
                    SpanEvent::RequestSummary {
                        req,
                        replica: out.served_by[req],
                        class: arrivals[req].class,
                        energy: ledger.request(req),
                    },
                );
            }
        }
        Ok(out)
    }
}

/// Everything [`drive`] borrows for one run: the workload and arrival
/// stream it consumes, plus the router/ledger/tracker/lifecycle state it
/// mutates. Collapsing the old 8-parameter signature into one borrowed
/// struct keeps call sites readable and lets the context grow without
/// another signature migration.
pub struct EngineCtx<'a> {
    pub suite: &'a ReplaySuite,
    pub arrivals: &'a [Arrival],
    pub router: &'a mut dyn FleetRouter,
    /// Maximum sequences decoding concurrently per replica.
    pub max_batch: usize,
    pub ledger: &'a mut EnergyLedger,
    pub tracker: &'a mut SloTracker,
    pub lifecycle: &'a mut Lifecycle,
    /// Optional span sink. `None` (the default on every pre-existing entry
    /// point) keeps each emit site a single predicted branch; a sink only
    /// observes, never feeds back into the physics.
    pub trace: Option<&'a mut dyn TraceSink>,
    /// Optional fixed-cadence heartbeat sampler. `None` (the default)
    /// costs one branch per loop iteration; attached, the engine emits
    /// one gauge row per cadence boundary. Like `trace`, a sampler only
    /// observes — it never feeds back into the physics.
    pub timeline: Option<&'a mut TimelineSampler>,
}

/// How [`drive_with`] locates the earliest steppable replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepSelector {
    /// The production path: an [`EventQueue`] keyed on replica clocks
    /// (O(log fleet) per step), cached status snapshots refreshed only for
    /// replicas that changed, and parallel stepping across wide gaps.
    Indexed,
    /// The original O(fleet)-per-step linear scan, kept as the property-
    /// test oracle and benchmark baseline. Bit-identical outcomes to
    /// [`StepSelector::Indexed`] are a hard invariant.
    LinearReference,
}

/// The shared continuous-batching event loop: advance `reps` through one
/// arrival stream. Each arrival is routed at its own timestamp against
/// live replica state, before any replica step that would start at or
/// after it; otherwise the earliest steppable replica — found through the
/// indexed event queue over replica clocks ([`EventQueue`]; invalidation
/// rule documented there), falling back to a linear scan only under
/// [`StepSelector::LinearReference`] — executes one unit of work under its
/// own governor. When the gap to the next arrival or lifecycle point is
/// wide, independent replicas step on worker threads and their
/// ledger/tracker effects replay in exact sequential order, so the
/// parallelism is unobservable in the physics. Lifecycle events
/// (warm-ups, crashes, repairs) interleave in time order while work
/// remains; once the last request drains the run ends. This is the single
/// loop behind both [`FleetSim::run`] and the one-replica
/// [`crate::serve::ServeSim`] facade — there is deliberately no second
/// copy anywhere. Under an inert lifecycle ([`Lifecycle::inert`]) the
/// loop is bit-identical to the fixed-fleet loop it grew from (pinned by
/// `rust/tests/unification.rs`).
///
/// Returns which replica each arrival was first routed to.
pub fn drive(reps: &mut [Replica], ctx: EngineCtx<'_>) -> Result<Vec<usize>> {
    drive_with(reps, ctx, StepSelector::Indexed)
}

/// [`drive`] with an explicit [`StepSelector`].
pub fn drive_with(
    reps: &mut [Replica],
    ctx: EngineCtx<'_>,
    selector: StepSelector,
) -> Result<Vec<usize>> {
    let EngineCtx {
        suite,
        arrivals,
        router,
        max_batch,
        ledger,
        tracker,
        lifecycle,
        trace,
        timeline,
    } = ctx;

    // Arm the failure clocks of initially-live replicas.
    if let Some(fm) = lifecycle.failures.as_mut() {
        for (i, r) in reps.iter().enumerate() {
            if r.state.routable() {
                fm.arm(i, 0.0);
            }
        }
    }

    let n = reps.len();
    let mut eng = Engine {
        suite,
        arrivals,
        router,
        max_batch,
        ledger,
        tracker,
        lifecycle,
        trace: Trace::new(trace),
        timeline,
        indexed: selector == StepSelector::Indexed,
        queue: EventQueue::new(n),
        statuses: Vec::with_capacity(n),
        status_dirty: vec![true; n],
        cached_ev: None,
        ev_dirty: true,
    };
    if eng.indexed {
        for i in 0..n {
            eng.touched(reps, i);
        }
    }
    eng.run(reps)
}

/// Minimum gap width (to the next arrival/lifecycle point) worth fanning
/// replica stepping out to worker threads.
const PAR_MIN_GAP_S: f64 = 0.25;
/// Minimum steppable replicas for a parallel gap.
const PAR_MIN_REPS: usize = 3;
/// Minimum total backlog (queued + active sequences) for a parallel gap.
const PAR_MIN_BACKLOG: usize = 64;

/// Per-replica result of one parallel gap: the deferred ledger charges and
/// tracker records to replay in sequential order, plus the first error (if
/// any) with its pre-step time so the merge can surface exactly the error
/// the sequential loop would have hit first.
struct GapResult {
    stepped: bool,
    charges: ChargeLog,
    /// `(pre-step time, ttft, tbt, e2e)` per completed request.
    records: Vec<(f64, f64, f64, f64)>,
    err: Option<(f64, String)>,
}

/// A [`RecordSink`] that tags every record with the pre-step clock of the
/// step that produced it, so records from concurrent replicas can be
/// re-interleaved into the exact order the sequential loop feeds the
/// fleet tracker (ascending pre-step time, then replica index).
struct RecordLog {
    t: f64,
    records: Vec<(f64, f64, f64, f64)>,
}

impl RecordSink for RecordLog {
    fn record(&mut self, ttft_s: f64, tbt_s: f64, e2e_s: f64) {
        self.records.push((self.t, ttft_s, tbt_s, e2e_s));
    }
}

/// The engine's per-run state. `reps` stays a separate `&mut [Replica]`
/// argument on every method so replica mutation composes with the indexed
/// caches held here (queue, status snapshots, next-event memo) — every
/// replica mutation funnels through [`Engine::touched`].
struct Engine<'a> {
    suite: &'a ReplaySuite,
    arrivals: &'a [Arrival],
    router: &'a mut dyn FleetRouter,
    max_batch: usize,
    ledger: &'a mut EnergyLedger,
    tracker: &'a mut SloTracker,
    lifecycle: &'a mut Lifecycle,
    /// Span emission handle (disabled = one branch per emit site).
    trace: Trace<'a>,
    /// Heartbeat sampler ticked at the top of the event loop (disabled =
    /// one branch per iteration).
    timeline: Option<&'a mut TimelineSampler>,
    /// `StepSelector::Indexed`: event queue + dirty-status caching +
    /// gap parallelism. Off, every structure below is bypassed in favor of
    /// full rescans (the reference semantics).
    indexed: bool,
    queue: EventQueue,
    /// Router/autoscaler-facing status snapshots, recomputed lazily.
    statuses: Vec<ReplicaStatus>,
    /// Which snapshot entries are stale (replica mutated since computed).
    status_dirty: Vec<bool>,
    /// Memoized earliest lifecycle event (valid while `!ev_dirty`).
    cached_ev: Option<(f64, LifecycleEvent)>,
    ev_dirty: bool,
}

impl Engine<'_> {
    /// Note that replica `i` mutated: its status snapshot is stale and its
    /// event-queue entry must be (re)scheduled or cancelled. This is the
    /// single choke point keeping the indexed caches coherent.
    fn touched(&mut self, reps: &[Replica], i: usize) {
        self.status_dirty[i] = true;
        if self.indexed {
            if reps[i].can_step() {
                self.queue.schedule(i, reps[i].now_s);
            } else {
                self.queue.cancel(i);
            }
        }
    }

    /// Bring `statuses` current. Indexed runs recompute only dirty
    /// entries; the reference path rebuilds everything, exactly like the
    /// pre-queue engine did. Either way the values are identical —
    /// [`Replica::status`] is a pure function of replica state.
    fn refresh_statuses(&mut self, reps: &[Replica]) {
        if !self.indexed || self.statuses.len() != reps.len() {
            self.statuses.clear();
            self.statuses.extend(reps.iter().enumerate().map(|(i, r)| r.status(i)));
            self.status_dirty.iter_mut().for_each(|d| *d = false);
            return;
        }
        for i in 0..reps.len() {
            if self.status_dirty[i] {
                self.statuses[i] = reps[i].status(i);
                self.status_dirty[i] = false;
            }
        }
    }

    /// Earliest pending lifecycle event, memoized between mutations on the
    /// indexed path (the reference path rescans every iteration).
    fn next_event(&mut self, reps: &[Replica]) -> Option<(f64, LifecycleEvent)> {
        if !self.indexed {
            return next_lifecycle_event_scan(reps, self.lifecycle);
        }
        if self.ev_dirty {
            self.cached_ev = next_lifecycle_event_scan(reps, self.lifecycle);
            self.ev_dirty = false;
        }
        self.cached_ev
    }

    /// Route one request against the fleet's status snapshots, enqueueing
    /// it on the chosen replica (which may not start on it before
    /// `not_before_s` — the requeue path's causality floor).
    fn route_one(
        &mut self,
        reps: &mut [Replica],
        req: usize,
        arrival: Arrival,
        not_before_s: f64,
    ) -> Result<usize> {
        self.refresh_statuses(reps);
        let choice = self
            .router
            .route(&arrival, self.suite.features.get(arrival.query_idx), &self.statuses)?;
        ensure!(
            choice < reps.len() && reps[choice].state.routable(),
            "router {} picked replica {choice}, which is not a live replica",
            self.router.label()
        );
        reps[choice].enqueue_at(req, arrival, not_before_s);
        self.touched(reps, choice);
        self.trace
            .emit(arrival.t_s.max(not_before_s), || SpanEvent::Routed { req, replica: choice });
        Ok(choice)
    }

    /// Hand one checkpointed sequence to a live replica chosen by the
    /// router (the Handoff of Checkpoint → Handoff → Resume). The router
    /// sees the sequence as an arrival at its original timestamp — the
    /// same status-driven choice as a fresh request.
    fn route_ckpt(
        &mut self,
        reps: &mut [Replica],
        ckpt: SeqCheckpoint,
        not_before_s: f64,
    ) -> Result<()> {
        self.refresh_statuses(reps);
        let arrival = Arrival { t_s: ckpt.arrival_s, query_idx: ckpt.query_idx, class: ckpt.class };
        let choice = self
            .router
            .route(&arrival, self.suite.features.get(ckpt.query_idx), &self.statuses)?;
        ensure!(
            choice < reps.len() && reps[choice].state.routable(),
            "router {} picked replica {choice}, which is not a live replica",
            self.router.label()
        );
        reps[choice].enqueue_resumed(ckpt, not_before_s);
        self.touched(reps, choice);
        self.lifecycle.migration_stats.resumed += 1;
        self.lifecycle.migration_stats.tokens_carried += ckpt.tokens;
        self.trace.emit(not_before_s, || SpanEvent::Routed { req: ckpt.req, replica: choice });
        Ok(())
    }

    /// Disposition checkpoints and plain requeues evacuated off a dead or
    /// draining replica `from` at `t_ev`: route them if anything is live,
    /// park them on the lifecycle pending queues otherwise.
    fn disperse_evacuated(
        &mut self,
        reps: &mut [Replica],
        from: usize,
        t_ev: f64,
        ckpts: Vec<SeqCheckpoint>,
        requeues: Vec<(usize, Arrival)>,
    ) -> Result<()> {
        let any_live = reps.iter().any(|r| r.state.routable());
        for ckpt in ckpts {
            self.trace.emit(t_ev, || SpanEvent::Migrated {
                req: ckpt.req,
                from,
                tokens: ckpt.tokens,
            });
            if any_live {
                self.route_ckpt(reps, ckpt, t_ev)?;
            } else {
                self.lifecycle
                    .pending_ckpts
                    .push_back(PendingCheckpoint { ckpt, not_before_s: t_ev });
            }
        }
        self.lifecycle.stats.requeued += requeues.len();
        for (req, arrival) in requeues {
            self.trace.emit(t_ev, || SpanEvent::Requeued { req, replica: from });
            if any_live {
                self.route_one(reps, req, arrival, t_ev)?;
            } else {
                self.lifecycle.pending.push_back(PendingRequeue {
                    req,
                    arrival,
                    not_before_s: t_ev,
                });
            }
        }
        Ok(())
    }

    /// Apply one lifecycle event at its scheduled time.
    fn apply_event(&mut self, reps: &mut [Replica], t_ev: f64, ev: LifecycleEvent) -> Result<()> {
        self.ev_dirty = true;
        match ev {
            LifecycleEvent::WarmDone(i) => {
                reps[i].finish_warmup(t_ev);
                self.lifecycle.log_live_delta(t_ev, 1);
                if let Some(fm) = self.lifecycle.failures.as_mut() {
                    fm.arm(i, t_ev);
                }
                self.touched(reps, i);
                self.trace.emit(t_ev, || SpanEvent::WarmDone { replica: i });
                // Work stranded while nothing was live routes now —
                // checkpoints first (they carry decoded tokens), then
                // plain requeues, each oldest (lowest request index)
                // first.
                while let Some(p) = self.lifecycle.pending_ckpts.pop_front() {
                    self.route_ckpt(reps, p.ckpt, p.not_before_s.max(t_ev))?;
                }
                while let Some(p) = self.lifecycle.pending.pop_front() {
                    self.route_one(reps, p.req, p.arrival, p.not_before_s.max(t_ev))?;
                }
            }
            LifecycleEvent::Recover(i) => {
                self.lifecycle
                    .failures
                    .as_mut()
                    .expect("recovery without a failure model")
                    .recovered(i);
                // Recovery is a fresh cold start: boot energy + warm-up
                // again. (Defensive: skip if something else already revived
                // it — the autoscaler never warms an under-repair replica,
                // so in practice the state here is always `Cold`.)
                if reps[i].state == ReplicaState::Cold {
                    self.lifecycle.stats.recoveries += 1;
                    reps[i].start_warming(t_ev, &self.lifecycle.cold_start);
                    self.touched(reps, i);
                    self.trace.emit(t_ev, || SpanEvent::Recovered { replica: i });
                }
            }
            LifecycleEvent::Fail(i) => {
                self.lifecycle
                    .failures
                    .as_mut()
                    .expect("crash without a failure model")
                    .crash(i, t_ev);
                self.lifecycle.stats.failures += 1;
                self.lifecycle.log_live_delta(t_ev, -1);
                if self.lifecycle.migration.is_some() {
                    // Recover what the periodic checkpoints captured; only
                    // the tokens decoded since each sequence's last
                    // checkpoint are lost (their energy stays charged, as
                    // a real meter would have recorded it).
                    let (ckpts, requeues, tokens_lost) = reps[i].crash_with_checkpoints(t_ev);
                    self.lifecycle.migration_stats.crash_recovered += ckpts.len();
                    self.lifecycle.migration_stats.tokens_lost += tokens_lost;
                    self.touched(reps, i);
                    let lost = ckpts.len() + requeues.len();
                    self.trace.emit(t_ev, || SpanEvent::Failed { replica: i, lost });
                    self.disperse_evacuated(reps, i, t_ev, ckpts, requeues)?;
                    return Ok(());
                }
                let lost = reps[i].crash(t_ev);
                self.lifecycle.stats.requeued += lost.len();
                self.touched(reps, i);
                self.trace.emit(t_ev, || SpanEvent::Failed { replica: i, lost: lost.len() });
                let any_live = reps.iter().any(|r| r.state.routable());
                for (req, arrival) in lost {
                    // A requeue opens a new serving attempt: its timestamp
                    // is the only point a request's span stream may rewind
                    // to (the straddling step's events carry later times).
                    self.trace.emit(t_ev, || SpanEvent::Requeued { req, replica: i });
                    if any_live {
                        // Through the router, original arrival timestamp,
                        // but no replica may start on it before the crash
                        // instant.
                        self.route_one(reps, req, arrival, t_ev)?;
                    } else {
                        self.lifecycle.pending.push_back(PendingRequeue {
                            req,
                            arrival,
                            not_before_s: t_ev,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Consult the autoscaler at an arrival instant and apply its decision.
    fn apply_autoscale(&mut self, reps: &mut [Replica], t_s: f64, slo_pressure: f64) -> Result<()> {
        self.refresh_statuses(reps);
        let action = self.lifecycle.autoscaler.decide(t_s, &self.statuses, slo_pressure);
        match action {
            ScaleAction::Hold => {}
            ScaleAction::Up(n) => {
                for _ in 0..n {
                    // Rescue a draining replica first: it is warm, holds
                    // its KV cache, and costs neither boot energy nor
                    // delay.
                    let rescue = reps.iter().position(|r| r.state == ReplicaState::Draining);
                    // A crashed machine cannot be warmed until its repair
                    // completes — only healthy cold replicas are
                    // candidates.
                    let cold = reps
                        .iter()
                        .enumerate()
                        .find(|&(i, r)| {
                            r.state == ReplicaState::Cold
                                && !self
                                    .lifecycle
                                    .failures
                                    .as_ref()
                                    .is_some_and(|fm| fm.under_repair(i))
                        })
                        .map(|(i, _)| i);
                    if let Some(i) = rescue {
                        reps[i].state = ReplicaState::Live;
                        self.lifecycle.log_live_delta(t_s, 1);
                        if let Some(fm) = self.lifecycle.failures.as_mut() {
                            fm.arm(i, t_s);
                        }
                        self.lifecycle.stats.scale_ups += 1;
                        self.ev_dirty = true;
                        self.touched(reps, i);
                        self.trace
                            .emit(t_s, || SpanEvent::ScaleUp { replica: i, cold_start: false });
                    } else if let Some(i) = cold {
                        reps[i].start_warming(t_s, &self.lifecycle.cold_start);
                        self.lifecycle.stats.scale_ups += 1;
                        self.ev_dirty = true;
                        self.touched(reps, i);
                        self.trace
                            .emit(t_s, || SpanEvent::ScaleUp { replica: i, cold_start: true });
                    } else {
                        break; // nothing healthy left to bring up
                    }
                }
            }
            ScaleAction::Down(n) => {
                for _ in 0..n {
                    let live: Vec<usize> = reps
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r.state.routable())
                        .map(|(i, _)| i)
                        .collect();
                    // Engine floor regardless of autoscaler: never drain
                    // the last live replica out from under the router.
                    if live.len() <= 1 {
                        break;
                    }
                    let i = live
                        .into_iter()
                        .min_by_key(|&i| (reps[i].queue_depth() + reps[i].active_seqs(), i))
                        .expect("live replicas exist");
                    if self.lifecycle.migration.is_some() {
                        // Checkpoint the in-flight work and power off NOW
                        // — the migration win over draining is that the
                        // replica stops burning energy immediately instead
                        // of finishing its batch first.
                        let (ckpts, requeues) = reps[i].migrate_out(t_s);
                        self.lifecycle.migration_stats.drained += ckpts.len();
                        self.lifecycle.log_live_delta(t_s, -1);
                        if let Some(fm) = self.lifecycle.failures.as_mut() {
                            fm.disarm(i);
                        }
                        self.lifecycle.stats.scale_downs += 1;
                        self.ev_dirty = true;
                        self.touched(reps, i);
                        self.trace.emit(t_s, || SpanEvent::ScaleDown { replica: i });
                        self.disperse_evacuated(reps, i, t_s, ckpts, requeues)?;
                        continue;
                    }
                    reps[i].begin_drain(t_s);
                    self.lifecycle.log_live_delta(t_s, -1);
                    if let Some(fm) = self.lifecycle.failures.as_mut() {
                        fm.disarm(i);
                    }
                    self.lifecycle.stats.scale_downs += 1;
                    self.ev_dirty = true;
                    self.touched(reps, i);
                    self.trace.emit(t_s, || SpanEvent::ScaleDown { replica: i });
                }
            }
        }
        Ok(())
    }

    /// Step every steppable replica to the edge of the current gap on
    /// worker threads, then replay the deferred ledger charges and tracker
    /// records in exact sequential order. Returns whether the gap was
    /// taken (false = not worth the fan-out; caller does one normal step).
    ///
    /// Bit-identity with sequential stepping holds because within
    /// `[t_step, t_hi)` no arrival, routing, or lifecycle event can
    /// interleave: each replica's step sequence depends only on its own
    /// state, the request sets replicas charge are disjoint, and the
    /// replay orders (replica index for the ledger, `(pre-step time,
    /// replica index)` for the tracker) reproduce the sequential
    /// interleaving exactly.
    fn parallel_gap(&mut self, reps: &mut [Replica], t_step: f64, t_arr: f64) -> Result<bool> {
        // Tracing forces sequential stepping: gap workers would have to
        // merge their span streams, and replaying them is not worth the
        // machinery — the physics of the two paths is already pinned
        // bit-identical, so a traced run reproduces exactly the untraced
        // numbers, just without the fan-out. A heartbeat sampler likewise:
        // boundaries inside the gap must observe the fleet between
        // sequential steps, which the fan-out skips past.
        if self.trace.enabled() || self.timeline.is_some() {
            return Ok(false);
        }
        let t_ev = if self.lifecycle.is_inert() {
            f64::INFINITY
        } else {
            self.next_event(reps).map(|(t, _)| t).unwrap_or(f64::INFINITY)
        };
        // Strict upper bound: the sequential loop executes a step iff the
        // replica's pre-step clock is strictly below both the next arrival
        // and the next lifecycle event.
        let t_hi = t_arr.min(t_ev);
        if t_hi - t_step < PAR_MIN_GAP_S {
            return Ok(false);
        }
        let mut steppable = 0usize;
        let mut backlog = 0usize;
        for r in reps.iter() {
            if r.can_step() && r.now_s < t_hi {
                steppable += 1;
                backlog += r.queue_depth() + r.active_seqs();
            }
        }
        if steppable < PAR_MIN_REPS || backlog < PAR_MIN_BACKLOG {
            return Ok(false);
        }

        let (suite, max_batch) = (self.suite, self.max_batch);
        let results = par_map_mut(reps, |_, rep| {
            let mut out = GapResult {
                stepped: false,
                charges: ChargeLog::default(),
                records: Vec::new(),
                err: None,
            };
            let mut sink = RecordLog { t: 0.0, records: Vec::new() };
            while rep.can_step() && rep.now_s < t_hi {
                sink.t = rep.now_s;
                if let Err(e) =
                    rep.step(suite, max_batch, &mut out.charges, &mut sink, &mut Trace::off())
                {
                    out.err = Some((sink.t, e.to_string()));
                    break;
                }
                out.stepped = true;
            }
            if out.stepped && rep.state == ReplicaState::Draining && !rep.runnable() {
                rep.power_off_drained();
            }
            out.records = sink.records;
            out
        });

        // Surface the error the sequential loop would have hit first:
        // earliest pre-step time, lowest replica index on ties (ascending
        // iteration + strictly-less replacement).
        let mut first_err: Option<(f64, String)> = None;
        for r in &results {
            if let Some((t, msg)) = &r.err {
                let replace = match &first_err {
                    None => true,
                    Some((tf, _)) => t.total_cmp(tf) == Ordering::Less,
                };
                if replace {
                    first_err = Some((*t, msg.clone()));
                }
            }
        }
        if let Some((_, msg)) = first_err {
            bail!("{msg}");
        }

        let mut records: Vec<(f64, usize, f64, f64, f64)> = Vec::new();
        for (i, r) in results.iter().enumerate() {
            // Replica charge sets are disjoint within the gap, so replaying
            // in replica order reproduces each request's sequential
            // floating-point accumulation order.
            r.charges.replay(self.ledger);
            for &(t, ttft, tbt, e2e) in &r.records {
                records.push((t, i, ttft, tbt, e2e));
            }
            if r.stepped {
                self.touched(reps, i);
            }
        }
        // The sequential loop always steps the globally earliest (clock,
        // index) replica, so its tracker feed is exactly this order.
        records.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
        for (_, _, ttft, tbt, e2e) in records {
            self.tracker.record(ttft, tbt, e2e);
        }
        Ok(true)
    }

    /// The event loop proper (see [`drive`] for the contract).
    fn run(&mut self, reps: &mut [Replica]) -> Result<Vec<usize>> {
        let mut routed = vec![usize::MAX; self.arrivals.len()];
        let mut next = 0usize;

        loop {
            // Earliest steppable replica clock (work that would start
            // next): O(log fleet) off the queue, or the reference fold.
            let t_step = if self.indexed {
                self.queue.peek().map_or(f64::INFINITY, |(t, _)| t)
            } else {
                reps.iter()
                    .filter(|r| r.can_step())
                    .map(|r| r.now_s)
                    .fold(f64::INFINITY, f64::min)
            };
            let t_arr =
                if next < self.arrivals.len() { self.arrivals[next].t_s } else { f64::INFINITY };

            // Run complete: all arrivals routed, nothing requeued, no work
            // left. Lifecycle events scheduled beyond this point never
            // fire — the simulation ends with the last request, so a quiet
            // fleet is not crashed/recovered forever after.
            if !t_arr.is_finite()
                && !t_step.is_finite()
                && self.lifecycle.pending.is_empty()
                && self.lifecycle.pending_ckpts.is_empty()
            {
                break;
            }

            // Heartbeat: before dispatching anything at `t_next`, emit
            // every pending cadence boundary strictly below it. A sample
            // at boundary `b` therefore reflects the fleet after all
            // events at times `<= b` (events exactly at `b` dispatch
            // before `b` is flushed by the first strictly-later `t_next`;
            // the run's tail is flushed by `finish` in `run_inner`).
            if self.timeline.is_some() {
                let t_ev = if self.lifecycle.is_inert() {
                    f64::INFINITY
                } else {
                    self.next_event(reps).map_or(f64::INFINITY, |(t, _)| t)
                };
                let t_next = t_step.min(t_arr).min(t_ev);
                if t_next.is_finite() {
                    if let Some(tl) = self.timeline.as_deref_mut() {
                        tl.advance_to(t_next, reps);
                    }
                }
            }

            if !self.lifecycle.is_inert() {
                if let Some((t_ev, ev)) = self.next_event(reps) {
                    if t_ev <= t_arr.min(t_step) {
                        self.apply_event(reps, t_ev, ev)?;
                        continue;
                    }
                }
            }

            if next < self.arrivals.len() && t_arr <= t_step {
                let a = self.arrivals[next];
                self.trace.emit(a.t_s, || SpanEvent::Queued {
                    req: next,
                    query_idx: a.query_idx,
                    class: a.class,
                });
                if !self.lifecycle.is_inert() {
                    // Feed the forecasting autoscaler's arrival-history
                    // estimator (a no-op for every other discipline)
                    // before it decides at this instant.
                    self.lifecycle.autoscaler.observe_arrival(a.t_s);
                    let pressure = self.tracker.pressure();
                    self.apply_autoscale(reps, a.t_s, pressure)?;
                }
                if !reps.iter().any(|r| r.state.routable()) {
                    // No live capacity for this arrival. If capacity is on
                    // its way (warming or under repair), fast-forward to
                    // that event and retry; otherwise the fleet is dead
                    // mid-run — a typed error, not a deadlock. (This is
                    // the liveness validation that used to be a
                    // constructor assert, now enforced by the state
                    // machine at the moment it matters.)
                    match self.next_event(reps) {
                        Some((t_ev, ev)) => {
                            self.apply_event(reps, t_ev, ev)?;
                            continue;
                        }
                        None => bail!(
                            "fleet has no live replica and none warming or recovering at \
                             t={:.3}s (arrival {}/{})",
                            a.t_s,
                            next,
                            self.arrivals.len()
                        ),
                    }
                }
                routed[next] = self.route_one(reps, next, a, a.t_s)?;
                next += 1;
            } else if t_step.is_finite() {
                if self.indexed && self.parallel_gap(reps, t_step, t_arr)? {
                    continue;
                }
                // Step the earliest steppable replica (lowest index on
                // ties; total_cmp so a corrupted NaN clock loudly picks a
                // stable order instead of panicking mid-run).
                let i = if self.indexed {
                    self.queue.peek().map(|(_, i)| i).expect("finite t_step came off the queue")
                } else {
                    reps.iter()
                        .enumerate()
                        .filter(|(_, r)| r.can_step())
                        .min_by(|(_, a), (_, b)| a.now_s.total_cmp(&b.now_s))
                        .map(|(i, _)| i)
                        .unwrap()
                };
                self.trace.replica = i;
                reps[i].step(
                    self.suite,
                    self.max_batch,
                    &mut *self.ledger,
                    &mut *self.tracker,
                    &mut self.trace,
                )?;
                if reps[i].state == ReplicaState::Draining && !reps[i].runnable() {
                    reps[i].power_off_drained();
                }
                self.touched(reps, i);
            } else {
                // Only reachable with requeued/checkpointed work in hand
                // and no live, warming, or recovering replica to ever
                // take it.
                ensure!(
                    self.lifecycle.pending.is_empty() && self.lifecycle.pending_ckpts.is_empty(),
                    "requeued requests stranded: fleet has no live, warming, or recovering replica"
                );
                unreachable!("event loop stalled with no work and no pending requests");
            }
        }
        Ok(routed)
    }
}

/// Earliest pending lifecycle event: warm-up completions (read off replica
/// states) merged with the failure model's crash/repair schedule.
fn next_lifecycle_event_scan(
    reps: &[Replica],
    lifecycle: &Lifecycle,
) -> Option<(f64, LifecycleEvent)> {
    let mut best = lifecycle.failures.as_ref().and_then(|f| f.next_event());
    for (i, r) in reps.iter().enumerate() {
        if let ReplicaState::Warming { until_s } = r.state {
            best = earlier(best, Some((until_s, LifecycleEvent::WarmDone(i))));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dvfs_policy::DvfsPolicy;
    use crate::fleet::router::{DifficultyTiered, EnergyAware, LeastLoaded, RoundRobin};
    use crate::serve::TrafficPattern;

    fn suite() -> ReplaySuite {
        ReplaySuite::quick(91, 16)
    }

    fn arrivals(s: &ReplaySuite, n: usize) -> Vec<Arrival> {
        TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 8.0, mean_dwell_s: 3.0 }
            .generate(s, n, 0xF1EE7)
    }

    fn spec(tier: ModelTier) -> ReplicaSpec {
        ReplicaSpec::tiered(tier, DvfsPolicy::Static(2842))
    }

    fn tiered_cfg(policy: DvfsPolicy) -> FleetConfig {
        FleetConfig::builder()
            .replicas(2, ReplicaSpec::tiered(ModelTier::B1, policy))
            .replicas(2, ReplicaSpec::tiered(ModelTier::B8, policy))
            .build()
            .unwrap()
    }

    #[test]
    fn serves_everything_and_conserves_energy_under_every_router() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let routers: Vec<Box<dyn FleetRouter>> = vec![
            Box::new(RoundRobin::default()),
            Box::new(LeastLoaded),
            Box::new(DifficultyTiered::default()),
            Box::new(EnergyAware::default()),
        ];
        for mut router in routers {
            let o = sim.run(&s, &arr, router.as_mut()).unwrap();
            assert_eq!(o.served, arr.len(), "{}", router.label());
            assert_eq!(o.slo.completed(), arr.len());
            assert_eq!(o.joules.len(), arr.len());
            assert!(o.routed.iter().all(|&r| r < 4), "{}", router.label());
            assert_eq!(o.routed, o.served_by, "no failures: first route serves");
            let attributed: f64 = o.joules.iter().sum();
            let rel = (attributed - o.total_j()).abs() / o.total_j();
            assert!(rel < 1e-6, "{}: conservation off by {rel:e}", router.label());
            // The last arrival finishes after it arrives.
            assert!(o.makespan_s >= arr.last().unwrap().t_s);
            assert!(o.energy_j > 0.0 && o.switch_j <= o.energy_j);
            // Fixed fleet: no lifecycle churn, everything stays live.
            assert_eq!(o.lifecycle, LifecycleStats::default());
            assert_eq!(o.coldstart_j, 0.0);
            assert!((o.mean_live_replicas - 4.0).abs() < 1e-12);
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let s = suite();
        let arr = arrivals(&s, 32);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let a = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        let b = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.makespan_s, b.makespan_s);
    }

    #[test]
    fn indexed_and_linear_reference_agree_bit_for_bit() {
        // The quickest end-to-end pin of the queue + caching + gap
        // machinery (the exhaustive randomized version lives in
        // rust/tests/proptest_invariants.rs): an elastic fleet with
        // failures exercises schedule, cancel, and reschedule under churn.
        let s = suite();
        let arr = arrivals(&s, 64);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replicas(2, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .reactive(ReactiveConfig { cooldown_s: 1.0, max_live: 3, ..ReactiveConfig::default() })
            .failures(FailureConfig { mtbf_s: 15.0, mttr_s: 5.0, seed: 0xABCD })
            .build()
            .unwrap();
        let sim = FleetSim::new(gpu, cfg);
        let a = sim
            .run_with_selector(&s, &arr, &mut LeastLoaded, StepSelector::Indexed)
            .unwrap();
        let b = sim
            .run_with_selector(&s, &arr, &mut LeastLoaded, StepSelector::LinearReference)
            .unwrap();
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.served_by, b.served_by);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.idle_j, b.idle_j);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.slo.e2e_p99(), b.slo.e2e_p99());
        assert_eq!(a.lifecycle, b.lifecycle);
    }

    #[test]
    fn parallel_gap_stepping_is_bit_identical_to_sequential() {
        // A simultaneous slam on many replicas with no further arrivals:
        // the gap to infinity is wide, the backlog deep — this run *must*
        // take the parallel path, and still match the reference exactly.
        let s = suite();
        let arr: Vec<Arrival> =
            (0..200).map(|i| Arrival::at(0.0, i % s.len())).collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder().replicas(6, spec(ModelTier::B3)).build().unwrap();
        let sim = FleetSim::new(gpu, cfg);
        let par = sim
            .run_with_selector(&s, &arr, &mut LeastLoaded, StepSelector::Indexed)
            .unwrap();
        let seq = sim
            .run_with_selector(&s, &arr, &mut LeastLoaded, StepSelector::LinearReference)
            .unwrap();
        assert_eq!(par.served, arr.len());
        assert_eq!(par.joules, seq.joules);
        assert_eq!(par.energy_j, seq.energy_j);
        assert_eq!(par.makespan_s, seq.makespan_s);
        assert_eq!(par.slo.e2e_p99(), seq.slo.e2e_p99());
        assert_eq!(par.slo.ttft_p99(), seq.slo.ttft_p99());
    }

    #[test]
    fn builder_validates_at_build() {
        assert!(FleetConfig::builder()
            .build()
            .unwrap_err()
            .to_string()
            .contains("at least one replica"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .max_batch(0)
            .build()
            .unwrap_err()
            .to_string()
            .contains("max_batch"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .window_s(0.0)
            .build()
            .unwrap_err()
            .to_string()
            .contains("window"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .reactive(ReactiveConfig {
                low_backlog: 5.0,
                high_backlog: 1.0,
                ..ReactiveConfig::default()
            })
            .build()
            .unwrap_err()
            .to_string()
            .contains("backlog hysteresis"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .reactive(ReactiveConfig { min_live: 3, max_live: 2, ..ReactiveConfig::default() })
            .build()
            .unwrap_err()
            .to_string()
            .contains("max_live"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .cold_start(ColdStart { energy_j: -1.0, warmup_s: 5.0 })
            .build()
            .unwrap_err()
            .to_string()
            .contains("cold-start"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .failures(FailureConfig { mtbf_s: 0.0, mttr_s: 5.0, seed: 1 })
            .build()
            .unwrap_err()
            .to_string()
            .contains("MTBF"));
        // Infinite MTTR (permanent failures) is a legal modeling choice.
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .failures(FailureConfig { mtbf_s: 10.0, mttr_s: f64::INFINITY, seed: 1 })
            .build()
            .is_ok());
        // Zero aging is legal (promote on the next scan); negative is not.
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .classes(ClassPolicy { aging_s: 0.0, ..ClassPolicy::default() })
            .build()
            .is_ok());
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .classes(ClassPolicy { aging_s: -1.0, ..ClassPolicy::default() })
            .build()
            .unwrap_err()
            .to_string()
            .contains("aging"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .forecast(ForecastConfig { bin_s: 0.0, ..ForecastConfig::default() })
            .build()
            .unwrap_err()
            .to_string()
            .contains("bin_s"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .migration(MigrationPolicy { checkpoint_every_tokens: 0 })
            .build()
            .unwrap_err()
            .to_string()
            .contains("checkpoint cadence"));
        assert!(FleetConfig::builder()
            .replica(spec(ModelTier::B1))
            .classes(ClassPolicy { batch_kv_cap: 0.0, ..ClassPolicy::default() })
            .build()
            .unwrap_err()
            .to_string()
            .contains("KV admission cap"));
    }

    #[test]
    fn class_policy_serves_every_class_and_conserves_energy() {
        let s = suite();
        let arr = crate::serve::traffic::ClassMix::default().generate(&s, 48, 0xC1A5);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replicas(2, ReplicaSpec::tiered(ModelTier::B3, DvfsPolicy::governed(&gpu)))
            .classes(ClassPolicy::default())
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // Per-class attribution partitions the fleet bill exactly.
        let mut by_class = [0.0f64; 3];
        for (a, j) in arr.iter().zip(&o.joules) {
            by_class[a.class.slot()] += j;
        }
        assert!(by_class.iter().all(|&j| j > 0.0), "every class drew energy: {by_class:?}");
        let sum: f64 = by_class.iter().sum();
        assert!((sum - o.total_j()).abs() <= 1e-6 * o.total_j());
    }

    #[test]
    fn builder_spells_every_retired_constructor_shape() {
        // The old `homogeneous`/`tiered`/`elastic` wrappers are gone; the
        // builder must still construct each of those fleet shapes exactly
        // (replica count/tier/state and the elastic max_live cap at the
        // provisioned count, which feeds the autoscaler's cooldown
        // trajectory).
        let homog = FleetConfig::builder().replicas(3, spec(ModelTier::B1)).build().unwrap();
        assert_eq!(homog.replicas.len(), 3);
        assert!(homog.replicas.iter().all(|r| r.model.tier == ModelTier::B1));
        assert!(homog.replicas.iter().all(|r| r.state == ReplicaState::Live));

        let tiered = FleetConfig::builder()
            .replicas(1, spec(ModelTier::B1))
            .replicas(2, spec(ModelTier::B8))
            .build()
            .unwrap();
        let tiers: Vec<ModelTier> = tiered.replicas.iter().map(|r| r.model.tier).collect();
        assert_eq!(tiers, vec![ModelTier::B1, ModelTier::B8, ModelTier::B8]);

        let scale = ReactiveConfig { cooldown_s: 2.0, ..ReactiveConfig::default() };
        let elastic = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replicas(2, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .reactive(ReactiveConfig { max_live: 3, ..scale })
            .build()
            .unwrap();
        assert_eq!(elastic.replicas.len(), 3);
        let live = elastic.replicas.iter().filter(|r| r.state == ReplicaState::Live).count();
        assert_eq!(live, 1, "one live seed replica, the rest provisioned cold");
        match &elastic.autoscale {
            AutoscalePolicy::Reactive(r) => {
                assert_eq!(r.max_live, 3, "capped at the provisioned count");
                assert_eq!(r.cooldown_s, 2.0);
            }
            other => panic!("expected a reactive autoscaler, got {other:?}"),
        }
    }

    #[test]
    fn difficulty_router_sends_hard_queries_to_the_large_tier() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::baseline(&gpu)));
        let mut router = DifficultyTiered::default();
        let o = sim.run(&s, &arr, &mut router).unwrap();
        for (i, a) in arr.iter().enumerate() {
            let hard = router.is_hard(&s.features[a.query_idx]);
            let tier = sim.cfg.replicas[o.routed[i]].model.tier;
            if hard {
                assert_eq!(tier, ModelTier::B8, "hard query {i} routed to {tier:?}");
            } else {
                assert_eq!(tier, ModelTier::B1, "easy query {i} routed to {tier:?}");
            }
        }
    }

    #[test]
    fn cold_replicas_hold_no_traffic() {
        let s = suite();
        let arr = arrivals(&s, 24);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg = FleetConfig::builder().replicas(3, spec(ModelTier::B1)).build().unwrap();
        cfg.replicas[1].state = ReplicaState::Cold;
        let sim = FleetSim::new(gpu, cfg);
        let o = sim.run(&s, &arr, &mut RoundRobin::default()).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.routed.iter().all(|&r| r != 1));
        assert_eq!(o.replicas[1].served, 0);
        assert_eq!(o.replicas[1].energy_j, 0.0);
        assert!((o.mean_live_replicas - 2.0).abs() < 1e-12);
    }

    #[test]
    fn all_dead_fleet_is_a_typed_error_not_a_panic() {
        let s = suite();
        let arr = arrivals(&s, 4);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replicas(2, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B1) })
            .build()
            .unwrap();
        let err = FleetSim::new(gpu, cfg)
            .run(&s, &arr, &mut RoundRobin::default())
            .unwrap_err();
        assert!(
            err.to_string().contains("no live replica"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn permanent_failure_of_the_whole_fleet_mid_run_is_a_typed_error() {
        // One replica, unrepairable failures, enough traffic that the
        // crash lands mid-run: the engine must surface a typed error for
        // the stranded work instead of deadlocking or corrupting numbers.
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 1.0 }.generate(&s, 400, 0xDEAD);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .failures(FailureConfig { mtbf_s: 20.0, mttr_s: f64::INFINITY, seed: 0xF00D })
            .build()
            .unwrap();
        let err = FleetSim::new(gpu, cfg)
            .run(&s, &arr, &mut RoundRobin::default())
            .unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("stranded") || msg.contains("no live replica"),
            "unexpected error: {msg}"
        );
    }

    #[test]
    fn more_replicas_cut_makespan_under_load() {
        let s = suite();
        // A slam of simultaneous arrivals: parallelism must help makespan.
        let arr: Vec<Arrival> =
            (0..32).map(|i| Arrival::at(0.0, i % s.len())).collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let run = |n: usize| {
            let cfg = FleetConfig::builder().replicas(n, spec(ModelTier::B3)).build().unwrap();
            FleetSim::new(gpu.clone(), cfg)
                .run(&s, &arr, &mut LeastLoaded)
                .unwrap()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.served, four.served);
        assert!(
            one.makespan_s / four.makespan_s > 2.0,
            "speedup {:.2}",
            one.makespan_s / four.makespan_s
        );
    }

    #[test]
    fn governed_fleet_saves_energy_vs_static_within_slo() {
        let s = suite();
        let arr = arrivals(&s, 64);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = |p| {
            FleetConfig::builder()
                .replicas(2, ReplicaSpec::tiered(ModelTier::B8, p))
                .build()
                .unwrap()
        };
        let stat = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::baseline(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let gov = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::governed(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let savings = 1.0 - gov.energy_j / stat.energy_j;
        assert!(savings > 0.15, "governed fleet savings {savings:.3}");
        assert!(
            gov.slo.e2e_p99() <= gov.slo.slo.e2e_p99_s,
            "governed p99 {:.2}s over SLO",
            gov.slo.e2e_p99()
        );
    }

    #[test]
    fn autoscaler_scales_up_under_pressure_and_down_on_slack() {
        let s = suite();
        // A hard burst followed by a long quiet tail: the reactive scaler
        // must warm capacity for the burst and drain it afterwards.
        let mut arr: Vec<Arrival> =
            (0..40).map(|i| Arrival::at(0.05 * i as f64, i % s.len())).collect();
        for i in 0..16 {
            arr.push(Arrival::at(60.0 + 10.0 * i as f64, i % s.len()));
        }
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replicas(3, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .reactive(ReactiveConfig { cooldown_s: 2.0, max_live: 4, ..ReactiveConfig::default() })
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.scale_ups >= 1, "never scaled up: {:?}", o.lifecycle);
        assert!(o.lifecycle.scale_downs >= 1, "never scaled down: {:?}", o.lifecycle);
        assert!(o.coldstart_j > 0.0, "cold starts must be charged");
        assert!(
            o.mean_live_replicas > 1.0 && o.mean_live_replicas < 4.0,
            "mean live {:.2} outside (1, 4)",
            o.mean_live_replicas
        );
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // The breakdown carries the cold-start energy explicitly.
        assert!((o.breakdown.coldstart_j - o.coldstart_j).abs() <= 1e-9 * o.coldstart_j);
    }

    #[test]
    fn scale_from_zero_waits_for_warmup_then_serves() {
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 2.0 }.generate(&s, 12, 0xC01D);
        let gpu = GpuSpec::rtx_pro_6000();
        // Everything cold at t = 0: the autoscaler must bootstrap.
        let cfg = FleetConfig::builder()
            .replicas(2, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .reactive(ReactiveConfig { max_live: 2, ..ReactiveConfig::default() })
            .build()
            .unwrap();
        let warmup = cfg.cold_start.warmup_s;
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.scale_ups >= 1);
        assert!(o.coldstart_j > 0.0);
        // Nothing can finish before the first warm-up elapses.
        assert!(
            o.makespan_s >= arr[0].t_s + warmup,
            "served before warm-up: makespan {:.2}",
            o.makespan_s
        );
    }

    #[test]
    fn failures_requeue_in_flight_work_and_conserve_energy() {
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 3.0 }.generate(&s, 96, 0xFA11);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replicas(3, spec(ModelTier::B3))
            .failures(FailureConfig { mtbf_s: 12.0, mttr_s: 6.0, seed: 0xBAD })
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len(), "every request survives the crashes");
        assert_eq!(o.slo.completed(), arr.len());
        assert!(o.lifecycle.failures > 0, "MTBF 12s over this run must crash something");
        assert!(o.lifecycle.recoveries > 0);
        assert!(o.coldstart_j > 0.0, "recovery cold starts are charged");
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // Requeued requests were completed by a different replica than
        // first routed (at least sometimes, given > 0 requeues).
        if o.lifecycle.requeued > 0 {
            let moved = (0..arr.len()).filter(|&i| o.routed[i] != o.served_by[i]).count();
            assert!(moved > 0, "requeues recorded but nothing moved replicas");
        }
    }

    #[test]
    fn requeued_requests_keep_original_arrival_latency_accounting() {
        // Deterministic crash construction: the failure stream for seed
        // 0x5EED crashes replica 0 at t ≈ 1.22 s; twelve generation
        // requests arriving through t = 1.1 s cannot possibly have drained
        // by then on one replica, so the crash is guaranteed to catch work
        // in flight and requeue it.
        let s = suite();
        let gen_idx: Vec<usize> =
            (0..s.len()).filter(|&i| s.queries[i].output_tokens > 0).collect();
        let arr: Vec<Arrival> = (0..12)
            .map(|i| Arrival::at(0.1 * i as f64, gen_idx[i % gen_idx.len()]))
            .collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replica(ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .reactive(ReactiveConfig {
                cooldown_s: 0.5,
                high_backlog: 2.0,
                max_live: 2,
                ..ReactiveConfig::default()
            })
            .failures(FailureConfig { mtbf_s: 1.5, mttr_s: 4.0, seed: 0x5EED })
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.failures > 0, "the t≈1.22s crash must land mid-run");
        assert!(o.lifecycle.requeued > 0, "the crash must catch work in flight");
        // A requeued request's end-to-end latency spans the crash: its
        // original arrival predates the crash, so the fleet tail must
        // include the repair or warm-up detour (several seconds), far
        // beyond any undisturbed service time.
        assert!(
            o.slo.e2e_p99() > 1.0,
            "requeued tail {:.3}s does not reflect the original arrival",
            o.slo.e2e_p99()
        );
    }

    /// Test scaler: one `Down(1)` at the first decision at or after `t`.
    struct DownAt {
        t: f64,
        fired: bool,
    }

    impl crate::fleet::lifecycle::Autoscaler for DownAt {
        fn decide(&mut self, now_s: f64, _: &[ReplicaStatus], _: f64) -> ScaleAction {
            if !self.fired && now_s >= self.t {
                self.fired = true;
                return ScaleAction::Down(1);
            }
            ScaleAction::Hold
        }

        fn label(&self) -> String {
            "down-at".into()
        }
    }

    #[test]
    fn drain_migration_checkpoints_in_flight_work_and_conserves_energy() {
        // Ten generation requests slam two live replicas at t = 0; a lone
        // trailing arrival triggers a forced down-scale while decode work
        // is still in flight, so the drained replica must checkpoint its
        // batch and hand it to the survivor. The trigger time sweeps a
        // wide range so at least one run provably catches sequences with
        // decoded tokens (the checkpointable state), whatever the step
        // latencies are.
        let s = suite();
        let gen_idx: Vec<usize> =
            (0..s.len()).filter(|&i| s.queries[i].output_tokens > 0).collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let mut saw_drain = false;
        for t_trigger in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let mut arr: Vec<Arrival> =
                (0..10).map(|i| Arrival::at(0.0, gen_idx[i % gen_idx.len()])).collect();
            arr.push(Arrival::at(t_trigger, gen_idx[0]));
            let policy = MigrationPolicy::default();
            let mut reps: Vec<Replica> = (0..2)
                .map(|_| Replica::new(&gpu, spec(ModelTier::B3), Slo::interactive(), 2.0))
                .collect();
            for r in reps.iter_mut() {
                r.set_checkpoint_every(Some(policy.checkpoint_every_tokens));
            }
            let mut ledger = EnergyLedger::new(arr.len());
            let mut tracker = SloTracker::new(Slo::interactive());
            let mut lifecycle = Lifecycle::new(
                Box::new(DownAt { t: t_trigger, fired: false }),
                None,
                ColdStart::default(),
            );
            lifecycle.migration = Some(policy);
            let mut router = LeastLoaded;
            drive(
                &mut reps,
                EngineCtx {
                    suite: &s,
                    arrivals: &arr,
                    router: &mut router,
                    max_batch: 8,
                    ledger: &mut ledger,
                    tracker: &mut tracker,
                    lifecycle: &mut lifecycle,
                    trace: None,
                    timeline: None,
                },
            )
            .unwrap();
            // Mirror run_inner's finalize pass so idle is fully billed.
            let mut unattributed = PhaseEnergy::default();
            for rep in reps.iter_mut() {
                unattributed.add(&rep.finalize(&mut ledger));
            }
            if unattributed.total_j() > 0.0 {
                let all: Vec<usize> = (0..arr.len()).collect();
                ledger.charge_idle(&all, unattributed.idle_j);
                ledger.charge_coldstart(&all, unattributed.coldstart_j);
            }
            let served: usize = reps.iter().map(|r| r.served).sum();
            assert_eq!(served, arr.len(), "trigger {t_trigger}s");
            let attributed: f64 = ledger.joules().iter().sum();
            let measured: f64 = reps
                .iter()
                .map(|r| r.energy_j + r.idle_j + r.coldstart_j + r.migration_j)
                .sum();
            let rel = (attributed - measured).abs() / measured;
            assert!(rel < 1e-6, "trigger {t_trigger}s: conservation off by {rel:e}");
            let stats = lifecycle.migration_stats;
            if stats.drained > 0 {
                saw_drain = true;
                // No crashes here: every checkpoint is a drain handoff and
                // every handoff gets replayed on the survivor.
                assert_eq!(stats.crash_recovered, 0);
                assert_eq!(stats.resumed, stats.drained, "trigger {t_trigger}s");
                assert!(stats.tokens_carried > 0, "trigger {t_trigger}s");
                assert_eq!(stats.tokens_lost, 0, "drains lose nothing");
                let migration_j: f64 = reps.iter().map(|r| r.migration_j).sum();
                assert!(migration_j > 0.0, "replay energy must be billed");
                assert!(
                    (ledger.totals().migration_j - migration_j).abs() <= 1e-9 * migration_j,
                    "ledger migration phase disagrees with the replica meters"
                );
            }
        }
        assert!(saw_drain, "no trigger time caught decode work mid-drain");
    }

    #[test]
    fn crash_migration_recovers_checkpoints_and_conserves_energy() {
        // Same seeded failure churn as the no-migration test above, with
        // checkpoint/resume on at one-token cadence: crashes must recover
        // in-flight sequences from their periodic checkpoints instead of
        // restarting them from scratch.
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 3.0 }.generate(&s, 96, 0xFA11);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replicas(3, spec(ModelTier::B3))
            .failures(FailureConfig { mtbf_s: 12.0, mttr_s: 6.0, seed: 0xBAD })
            .migration(MigrationPolicy { checkpoint_every_tokens: 1 })
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len(), "every request survives the crashes");
        assert_eq!(o.slo.completed(), arr.len());
        assert!(o.lifecycle.failures > 0, "MTBF 12s over this run must crash something");
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        assert!(
            o.migration.crash_recovered > 0,
            "one-token checkpoints over this churn must recover something: {:?}",
            o.migration
        );
        assert!(o.migration.tokens_carried > 0);
        assert!(o.migration_j > 0.0, "prefill replays must be billed");
        assert!(
            (o.breakdown.migration_j - o.migration_j).abs() <= 1e-9 * o.migration_j,
            "ledger migration phase {} vs replica meters {}",
            o.breakdown.migration_j,
            o.migration_j
        );
        // Exactly-once completion despite checkpoint handoffs.
        assert!(o.served_by.iter().all(|&r| r < 3));
    }

    #[test]
    fn migration_off_is_bit_identical_to_the_pre_migration_engine() {
        // The config default (no policy) must leave the crash/requeue
        // path untouched down to the last bit — the same guarantee the
        // golden scenario suite pins end-to-end.
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 3.0 }.generate(&s, 64, 0xFA11);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replicas(3, spec(ModelTier::B3))
            .failures(FailureConfig { mtbf_s: 12.0, mttr_s: 6.0, seed: 0xBAD })
            .build()
            .unwrap();
        let a = FleetSim::new(gpu.clone(), cfg.clone()).run(&s, &arr, &mut LeastLoaded).unwrap();
        let b = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.migration_j, 0.0);
        assert_eq!(b.migration, MigrationStats::default());
    }

    #[test]
    fn forecast_autoscaler_serves_periodic_traffic_and_conserves_energy() {
        // Three cycles of a square wave: the forecaster has two full
        // periods of history by the third, so it must detect the season
        // and pre-warm ahead of the ramps (scale_ups with cold starts)
        // while conserving every joule.
        let s = suite();
        let mut arr: Vec<Arrival> = Vec::new();
        let mut t = 0.0;
        while t < 180.0 {
            // Busy half-cycle: 4 req/s for 30 s; quiet half: 0.2 req/s.
            let rate = if (t / 30.0) as usize % 2 == 0 { 4.0 } else { 0.2 };
            arr.push(Arrival::at(t, arr.len() % s.len()));
            t += 1.0 / rate;
        }
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replicas(3, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .forecast(ForecastConfig {
                min_live: 1,
                max_live: 4,
                warmup_s: 5.0,
                periods_s: vec![60.0],
                rate_per_replica: 1.5,
                ..ForecastConfig::default()
            })
            .build()
            .unwrap();
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.scale_ups >= 1, "never scaled up: {:?}", o.lifecycle);
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // Determinism across runs (the forecaster is pure arithmetic).
        let cfg2 = FleetConfig::builder()
            .replica(spec(ModelTier::B3))
            .replicas(3, ReplicaSpec { state: ReplicaState::Cold, ..spec(ModelTier::B3) })
            .forecast(ForecastConfig {
                min_live: 1,
                max_live: 4,
                warmup_s: 5.0,
                periods_s: vec![60.0],
                rate_per_replica: 1.5,
                ..ForecastConfig::default()
            })
            .build()
            .unwrap();
        let gpu2 = GpuSpec::rtx_pro_6000();
        let o2 = FleetSim::new(gpu2, cfg2).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.joules, o2.joules);
        assert_eq!(o.routed, o2.routed);
    }
}
