//! The heterogeneous fleet simulator.
//!
//! A discrete-event engine over N replicas (possibly different model
//! tiers, each under its own frequency governor) fed by one arrival
//! stream through a pluggable [`FleetRouter`]. The engine interleaves
//! three event kinds on the simulated clock:
//!
//! - **arrival**: the autoscaler reads fleet state and may start warming
//!   or draining replicas, then the router reads every replica's live
//!   status (backlog, telemetry-window power, joules/token) and binds the
//!   request to exactly one live replica;
//! - **replica step**: the earliest steppable replica executes one unit of
//!   work (an admission prefill or a batched decode step) under its own
//!   governor;
//! - **lifecycle event**: a warm-up completes (`Warming → Live`), a
//!   replica crashes (`Live → Cold`, in-flight requests requeued through
//!   the router with their original arrival timestamps), or a repair
//!   completes (`Cold → Warming`, charging a fresh cold start).
//!
//! Arrivals are processed before any replica step at or after their
//! timestamp, so routing always sees the fleet state as of the arrival
//! instant — the co-design loop (router reacting to governor-driven power,
//! governor reacting to router-driven load, autoscaler reacting to both)
//! the paper's offline Section VII analysis cannot express.

use anyhow::{bail, ensure, Result};

use crate::config::{GpuSpec, ModelSpec, ModelTier};
use crate::coordinator::dvfs_policy::DvfsPolicy;
use crate::serve::slo::{Slo, SloTracker};
use crate::serve::traffic::Arrival;
use crate::stats::exact_quantile;
use crate::workload::ReplaySuite;

use super::attribution::{EnergyLedger, PhaseEnergy};
use super::lifecycle::{
    earlier, AutoscalePolicy, ColdStart, FailureConfig, FailureModel, Lifecycle, LifecycleEvent,
    LifecycleStats, PendingRequeue, ReactiveConfig, ReplicaState, ScaleAction,
};
use super::replica::{Replica, ReplicaSpec};
use super::router::{FleetRouter, ReplicaStatus};

/// Fleet composition and serving parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub replicas: Vec<ReplicaSpec>,
    /// Maximum sequences decoding concurrently per replica.
    pub max_batch: usize,
    pub slo: Slo,
    /// Telemetry window horizon fed to each governor, seconds.
    pub window_s: f64,
    /// Scaling discipline ([`AutoscalePolicy::Static`] = fixed fleet).
    pub autoscale: AutoscalePolicy,
    /// Seeded replica crash/repair process (`None` = no failures).
    pub failures: Option<FailureConfig>,
    /// Energy + delay of bringing a `Cold` replica `Live`.
    pub cold_start: ColdStart,
}

impl FleetConfig {
    /// `n` identical replicas of `model` under one policy.
    pub fn homogeneous(model: ModelSpec, n: usize, policy: DvfsPolicy) -> FleetConfig {
        assert!(n >= 1);
        FleetConfig {
            replicas: vec![
                ReplicaSpec { model, policy, state: ReplicaState::Live };
                n
            ],
            ..FleetConfig::default()
        }
    }

    /// A two-tier fleet: `n_small` small-tier plus `n_large` large-tier
    /// replicas, all under one policy (the Section VII deployment shape).
    pub fn tiered(
        small: ModelTier,
        n_small: usize,
        large: ModelTier,
        n_large: usize,
        policy: DvfsPolicy,
    ) -> FleetConfig {
        assert!(n_small + n_large >= 1);
        let mut replicas = Vec::with_capacity(n_small + n_large);
        for _ in 0..n_small {
            replicas.push(ReplicaSpec::tiered(small, policy));
        }
        for _ in 0..n_large {
            replicas.push(ReplicaSpec::tiered(large, policy));
        }
        FleetConfig { replicas, ..FleetConfig::default() }
    }

    /// An elastic fleet: `n` provisioned replicas of which `initial_live`
    /// start `Live` and the rest `Cold`, scaled by a reactive autoscaler
    /// capped at the provisioned count.
    pub fn elastic(
        model: ModelSpec,
        n: usize,
        initial_live: usize,
        policy: DvfsPolicy,
        scale: ReactiveConfig,
    ) -> FleetConfig {
        assert!(n >= 1 && (1..=n).contains(&initial_live));
        let mut cfg = FleetConfig::homogeneous(model, n, policy);
        for spec in cfg.replicas[initial_live..].iter_mut() {
            spec.state = ReplicaState::Cold;
        }
        cfg.autoscale =
            AutoscalePolicy::Reactive(ReactiveConfig { max_live: n.min(scale.max_live), ..scale });
        cfg
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: Vec::new(),
            max_batch: 8,
            slo: Slo::interactive(),
            window_s: 2.0,
            autoscale: AutoscalePolicy::Static,
            failures: None,
            cold_start: ColdStart::default(),
        }
    }
}

/// Post-run summary of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    pub tier: ModelTier,
    pub policy_label: String,
    /// Lifecycle state at the end of the run.
    pub state: ReplicaState,
    pub served: usize,
    pub tokens_out: u64,
    /// Busy (prefill + decode + switch) time, seconds.
    pub busy_s: f64,
    /// Active energy, joules.
    pub energy_j: f64,
    pub idle_j: f64,
    pub switch_j: f64,
    /// Cold-start energy this replica's warm-ups charged, joules.
    pub coldstart_j: f64,
    pub freq_switches: usize,
    pub mean_decode_freq_mhz: f64,
    /// Deepest admission-queue backlog this replica observed.
    pub max_queue_depth: usize,
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub served: usize,
    /// Active energy across the fleet (prefill + decode + switch), joules.
    pub energy_j: f64,
    /// Idle draw while replicas waited for routed arrivals, joules.
    pub idle_j: f64,
    /// Energy charged to DVFS transitions (subset of `energy_j`).
    pub switch_j: f64,
    /// Cold-start (boot + weight-load) energy across all warm-ups, joules.
    pub coldstart_j: f64,
    /// Time the last request finished, seconds.
    pub makespan_s: f64,
    pub freq_switches: usize,
    /// Fleet-level streaming SLO percentiles + attainment.
    pub slo: SloTracker,
    /// Attributed total energy per request, indexed by arrival order.
    pub joules: Vec<f64>,
    /// Fleet-wide attributed energy by phase (sums to `total_j()`).
    pub breakdown: PhaseEnergy,
    /// Which replica each arrival was first routed to.
    pub routed: Vec<usize>,
    /// Which replica ultimately *completed* each arrival (differs from
    /// `routed` only for crash-requeued requests).
    pub served_by: Vec<usize>,
    /// Scale/failure/requeue counters for the run.
    pub lifecycle: LifecycleStats,
    /// Time-weighted mean count of `Live` replicas over the makespan.
    pub mean_live_replicas: f64,
    pub replicas: Vec<ReplicaOutcome>,
}

impl FleetOutcome {
    /// Active + idle + cold-start energy, joules.
    pub fn total_j(&self) -> f64 {
        self.energy_j + self.idle_j + self.coldstart_j
    }

    /// Mean *attributed* energy per request — active plus amortized idle
    /// and cold starts, the full per-request bill, consistent with summing
    /// [`Self::joules`] (the same convention as
    /// [`crate::serve::ServeOutcome::joules_per_request`]). `NaN` when the
    /// run served nothing — a degenerate case the experiment tables assert
    /// against rather than silently reporting a number.
    pub fn attributed_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.total_j() / self.served as f64
    }

    /// Mean *active* (prefill + decode + switch) energy per request —
    /// the policy-controlled quantity. `NaN` when nothing was served.
    pub fn active_joules_per_request(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.energy_j / self.served as f64
    }

    /// Quantile of the per-request attributed energy distribution.
    pub fn attributed_joules_per_request_quantile(&self, p: f64) -> f64 {
        exact_quantile(&self.joules, p)
    }

    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.makespan_s.max(1e-12)
    }
}

/// The fleet engine.
pub struct FleetSim {
    pub gpu: GpuSpec,
    pub cfg: FleetConfig,
}

impl FleetSim {
    pub fn new(gpu: GpuSpec, cfg: FleetConfig) -> FleetSim {
        assert!(!cfg.replicas.is_empty(), "fleet needs at least one replica");
        assert!(cfg.max_batch >= 1);
        // NOTE: liveness is deliberately *not* asserted here. A fleet may
        // start all-`Cold` under an autoscaler that warms capacity on the
        // first arrival; a fleet that is dead when traffic actually needs
        // it is a typed error from the state machine inside [`drive`].
        FleetSim { gpu, cfg }
    }

    /// Serve `arrivals` through `router`. Deterministic: identical inputs
    /// replay identical outcomes bit-for-bit.
    pub fn run(
        &self,
        suite: &ReplaySuite,
        arrivals: &[Arrival],
        router: &mut dyn FleetRouter,
    ) -> Result<FleetOutcome> {
        let mut reps: Vec<Replica> = self
            .cfg
            .replicas
            .iter()
            .map(|spec| Replica::new(&self.gpu, spec.clone(), self.cfg.slo, self.cfg.window_s))
            .collect();
        let initial_live = reps.iter().filter(|r| r.state.routable()).count();
        let mut ledger = EnergyLedger::new(arrivals.len());
        let mut fleet_tracker = SloTracker::new(self.cfg.slo);
        let mut lifecycle = Lifecycle::new(
            self.cfg.autoscale.build(),
            self.cfg
                .failures
                .map(|f| FailureModel::new(f, self.cfg.replicas.len())),
            self.cfg.cold_start,
        );
        let routed = drive(
            &mut reps,
            suite,
            arrivals,
            router,
            self.cfg.max_batch,
            &mut ledger,
            &mut fleet_tracker,
            &mut lifecycle,
        )?;

        let mut out = FleetOutcome {
            served: 0,
            energy_j: 0.0,
            idle_j: 0.0,
            switch_j: 0.0,
            coldstart_j: 0.0,
            makespan_s: 0.0,
            freq_switches: 0,
            slo: fleet_tracker,
            joules: Vec::new(),
            breakdown: PhaseEnergy::default(),
            routed,
            served_by: vec![usize::MAX; arrivals.len()],
            lifecycle: lifecycle.stats,
            mean_live_replicas: 0.0,
            replicas: Vec::with_capacity(reps.len()),
        };
        // Overhead (idle, cold starts) of replicas that never completed a
        // request cannot be amortized locally; spread it over the whole
        // run so the bill still sums to the meter.
        let mut unattributed = PhaseEnergy::default();
        for rep in reps.iter_mut() {
            unattributed.add(&rep.finalize(&mut ledger));
            for &req in rep.served_reqs() {
                out.served_by[req] = out.replicas.len();
            }
            out.served += rep.served;
            out.energy_j += rep.energy_j;
            out.idle_j += rep.idle_j;
            out.switch_j += rep.switch_j;
            out.coldstart_j += rep.coldstart_j;
            out.freq_switches += rep.freq_switches;
            out.makespan_s = out.makespan_s.max(rep.last_finish_s);
            out.replicas.push(ReplicaOutcome {
                tier: rep.spec.model.tier,
                policy_label: rep.spec.policy.label(),
                state: rep.state,
                served: rep.served,
                tokens_out: rep.tokens_out,
                busy_s: rep.busy_s,
                energy_j: rep.energy_j,
                idle_j: rep.idle_j,
                switch_j: rep.switch_j,
                coldstart_j: rep.coldstart_j,
                freq_switches: rep.freq_switches,
                mean_decode_freq_mhz: rep.mean_decode_freq_mhz(),
                max_queue_depth: rep.max_queue_depth,
            });
        }
        if unattributed.total_j() > 0.0 {
            let all: Vec<usize> = (0..arrivals.len()).collect();
            ledger.charge_idle(&all, unattributed.idle_j);
            ledger.charge_coldstart(&all, unattributed.coldstart_j);
        }
        out.mean_live_replicas = lifecycle.mean_live(initial_live, out.makespan_s);
        out.joules = ledger.joules();
        out.breakdown = ledger.totals();
        debug_assert!(
            out.served < arrivals.len()
                || (out.breakdown.total_j() - out.total_j()).abs()
                    <= 1e-6 * out.total_j().max(1e-12),
            "attribution lost energy: {} vs {}",
            out.breakdown.total_j(),
            out.total_j()
        );
        Ok(out)
    }
}

/// Route one request against the fleet's status snapshots, enqueueing it
/// on the chosen replica (which may not start on it before `not_before_s`
/// — the requeue path's causality floor). `refresh` rebuilds `statuses`
/// from the replicas first; pass `false` only when the caller just built
/// them and nothing has mutated since (the autoscaler-held arrival path).
#[allow(clippy::too_many_arguments)]
fn route_one(
    reps: &mut [Replica],
    suite: &ReplaySuite,
    router: &mut dyn FleetRouter,
    statuses: &mut Vec<ReplicaStatus>,
    refresh: bool,
    req: usize,
    arrival: Arrival,
    not_before_s: f64,
) -> usize {
    if refresh {
        statuses.clear();
        statuses.extend(reps.iter().enumerate().map(|(i, r)| r.status(i)));
    }
    let choice = router.route(&arrival, suite.features.get(arrival.query_idx), statuses);
    assert!(
        choice < reps.len() && reps[choice].state.routable(),
        "router {} picked replica {choice}, which is not a live replica",
        router.label()
    );
    reps[choice].enqueue_at(req, arrival, not_before_s);
    choice
}

/// Earliest pending lifecycle event: warm-up completions (read off replica
/// states) merged with the failure model's crash/repair schedule.
fn next_lifecycle_event(
    reps: &[Replica],
    lifecycle: &Lifecycle,
) -> Option<(f64, LifecycleEvent)> {
    let mut best = lifecycle.failures.as_ref().and_then(|f| f.next_event());
    for (i, r) in reps.iter().enumerate() {
        if let ReplicaState::Warming { until_s } = r.state {
            best = earlier(best, Some((until_s, LifecycleEvent::WarmDone(i))));
        }
    }
    best
}

/// Apply one lifecycle event at its scheduled time.
fn apply_lifecycle_event(
    reps: &mut [Replica],
    suite: &ReplaySuite,
    router: &mut dyn FleetRouter,
    statuses: &mut Vec<ReplicaStatus>,
    lifecycle: &mut Lifecycle,
    t_ev: f64,
    ev: LifecycleEvent,
) {
    match ev {
        LifecycleEvent::WarmDone(i) => {
            reps[i].finish_warmup(t_ev);
            lifecycle.log_live_delta(t_ev, 1);
            if let Some(fm) = lifecycle.failures.as_mut() {
                fm.arm(i, t_ev);
            }
            // Requests stranded by a crash while nothing was live route
            // now, oldest (lowest request index) first.
            while let Some(p) = lifecycle.pending.pop_front() {
                route_one(
                    reps,
                    suite,
                    router,
                    statuses,
                    true,
                    p.req,
                    p.arrival,
                    p.not_before_s.max(t_ev),
                );
            }
        }
        LifecycleEvent::Recover(i) => {
            lifecycle
                .failures
                .as_mut()
                .expect("recovery without a failure model")
                .recovered(i);
            // Recovery is a fresh cold start: boot energy + warm-up again.
            // (Defensive: skip if something else already revived it — the
            // autoscaler never warms an under-repair replica, so in
            // practice the state here is always `Cold`.)
            if reps[i].state == ReplicaState::Cold {
                lifecycle.stats.recoveries += 1;
                reps[i].start_warming(t_ev, &lifecycle.cold_start);
            }
        }
        LifecycleEvent::Fail(i) => {
            lifecycle
                .failures
                .as_mut()
                .expect("crash without a failure model")
                .crash(i, t_ev);
            lifecycle.stats.failures += 1;
            lifecycle.log_live_delta(t_ev, -1);
            let lost = reps[i].crash(t_ev);
            lifecycle.stats.requeued += lost.len();
            let any_live = reps.iter().any(|r| r.state.routable());
            for (req, arrival) in lost {
                if any_live {
                    // Through the router, original arrival timestamp, but
                    // no replica may start on it before the crash instant.
                    route_one(reps, suite, router, statuses, true, req, arrival, t_ev);
                } else {
                    lifecycle.pending.push_back(PendingRequeue {
                        req,
                        arrival,
                        not_before_s: t_ev,
                    });
                }
            }
        }
    }
}

/// Consult the autoscaler at an arrival instant and apply its decision.
/// Rebuilds `statuses` as the decision input; returns whether any replica
/// was mutated (when not, the snapshot is still current for routing).
fn apply_autoscale(
    reps: &mut [Replica],
    statuses: &mut Vec<ReplicaStatus>,
    lifecycle: &mut Lifecycle,
    t_s: f64,
    slo_pressure: f64,
) -> bool {
    statuses.clear();
    statuses.extend(reps.iter().enumerate().map(|(i, r)| r.status(i)));
    let mut mutated = false;
    match lifecycle.autoscaler.decide(t_s, statuses, slo_pressure) {
        ScaleAction::Hold => {}
        ScaleAction::Up(n) => {
            for _ in 0..n {
                // Rescue a draining replica first: it is warm, holds its
                // KV cache, and costs neither boot energy nor delay.
                let rescue = reps.iter().position(|r| r.state == ReplicaState::Draining);
                // A crashed machine cannot be warmed until its repair
                // completes — only healthy cold replicas are candidates.
                let cold = reps
                    .iter()
                    .enumerate()
                    .find(|&(i, r)| {
                        r.state == ReplicaState::Cold
                            && !lifecycle
                                .failures
                                .as_ref()
                                .is_some_and(|fm| fm.under_repair(i))
                    })
                    .map(|(i, _)| i);
                if let Some(i) = rescue {
                    reps[i].state = ReplicaState::Live;
                    lifecycle.log_live_delta(t_s, 1);
                    if let Some(fm) = lifecycle.failures.as_mut() {
                        fm.arm(i, t_s);
                    }
                    lifecycle.stats.scale_ups += 1;
                    mutated = true;
                } else if let Some(i) = cold {
                    reps[i].start_warming(t_s, &lifecycle.cold_start);
                    lifecycle.stats.scale_ups += 1;
                    mutated = true;
                } else {
                    break; // nothing healthy left to bring up
                }
            }
        }
        ScaleAction::Down(n) => {
            for _ in 0..n {
                let live: Vec<usize> = reps
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| r.state.routable())
                    .map(|(i, _)| i)
                    .collect();
                // Engine floor regardless of autoscaler: never drain the
                // last live replica out from under the router.
                if live.len() <= 1 {
                    break;
                }
                let i = live
                    .into_iter()
                    .min_by_key(|&i| (reps[i].queue_depth() + reps[i].active_seqs(), i))
                    .expect("live replicas exist");
                reps[i].begin_drain(t_s);
                lifecycle.log_live_delta(t_s, -1);
                if let Some(fm) = lifecycle.failures.as_mut() {
                    fm.disarm(i);
                }
                lifecycle.stats.scale_downs += 1;
                mutated = true;
            }
        }
    }
    mutated
}

/// The shared continuous-batching event loop: advance `reps` through one
/// arrival stream. Each arrival is routed at its own timestamp against
/// live replica state, before any replica step that would start at or
/// after it; otherwise the earliest steppable replica executes one unit of
/// work under its own governor. Lifecycle events (warm-ups, crashes,
/// repairs) interleave in time order while work remains; once the last
/// request drains the run ends. This is the single loop behind both
/// [`FleetSim::run`] and the one-replica [`crate::serve::ServeSim`]
/// facade — there is deliberately no second copy anywhere. Under an inert
/// lifecycle ([`Lifecycle::inert`]) the loop is bit-identical to the
/// fixed-fleet loop it grew from (pinned by `rust/tests/unification.rs`).
///
/// Returns which replica each arrival was first routed to.
#[allow(clippy::too_many_arguments)]
pub fn drive(
    reps: &mut [Replica],
    suite: &ReplaySuite,
    arrivals: &[Arrival],
    router: &mut dyn FleetRouter,
    max_batch: usize,
    ledger: &mut EnergyLedger,
    tracker: &mut SloTracker,
    lifecycle: &mut Lifecycle,
) -> Result<Vec<usize>> {
    let mut routed = vec![usize::MAX; arrivals.len()];
    let mut statuses = Vec::with_capacity(reps.len());
    let mut next = 0usize;

    // Arm the failure clocks of initially-live replicas.
    if let Some(fm) = lifecycle.failures.as_mut() {
        for (i, r) in reps.iter().enumerate() {
            if r.state.routable() {
                fm.arm(i, 0.0);
            }
        }
    }

    loop {
        // Earliest steppable replica clock (work that would start next).
        let t_step = reps
            .iter()
            .filter(|r| r.can_step())
            .map(|r| r.now_s)
            .fold(f64::INFINITY, f64::min);
        let t_arr = if next < arrivals.len() { arrivals[next].t_s } else { f64::INFINITY };

        // Run complete: all arrivals routed, nothing requeued, no work
        // left. Lifecycle events scheduled beyond this point never fire —
        // the simulation ends with the last request, so a quiet fleet is
        // not crashed/recovered forever after.
        if !t_arr.is_finite() && !t_step.is_finite() && lifecycle.pending.is_empty() {
            break;
        }

        if !lifecycle.is_inert() {
            if let Some((t_ev, ev)) = next_lifecycle_event(reps, lifecycle) {
                if t_ev <= t_arr.min(t_step) {
                    apply_lifecycle_event(reps, suite, router, &mut statuses, lifecycle, t_ev, ev);
                    continue;
                }
            }
        }

        if next < arrivals.len() && t_arr <= t_step {
            let a = arrivals[next];
            // When the autoscaler ran and held, the status snapshot it
            // read is still current — routing can reuse it instead of
            // recomputing every replica's telemetry readout.
            let mut statuses_current = false;
            if !lifecycle.is_inert() {
                let pressure = tracker.pressure();
                statuses_current =
                    !apply_autoscale(reps, &mut statuses, lifecycle, a.t_s, pressure);
            }
            if !reps.iter().any(|r| r.state.routable()) {
                // No live capacity for this arrival. If capacity is on its
                // way (warming or under repair), fast-forward to that
                // event and retry; otherwise the fleet is dead mid-run —
                // a typed error, not a deadlock. (This is the liveness
                // validation that used to be a constructor assert, now
                // enforced by the state machine at the moment it matters.)
                match next_lifecycle_event(reps, lifecycle) {
                    Some((t_ev, ev)) => {
                        apply_lifecycle_event(
                            reps,
                            suite,
                            router,
                            &mut statuses,
                            lifecycle,
                            t_ev,
                            ev,
                        );
                        continue;
                    }
                    None => bail!(
                        "fleet has no live replica and none warming or recovering at \
                         t={:.3}s (arrival {}/{})",
                        a.t_s,
                        next,
                        arrivals.len()
                    ),
                }
            }
            routed[next] =
                route_one(reps, suite, router, &mut statuses, !statuses_current, next, a, a.t_s);
            next += 1;
        } else if t_step.is_finite() {
            // Step the earliest steppable replica (lowest index on ties;
            // total_cmp so a corrupted NaN clock loudly picks a stable
            // order instead of panicking mid-run).
            let i = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| r.can_step())
                .min_by(|(_, a), (_, b)| a.now_s.total_cmp(&b.now_s))
                .map(|(i, _)| i)
                .unwrap();
            reps[i].step(suite, max_batch, ledger, tracker)?;
            if reps[i].state == ReplicaState::Draining && !reps[i].runnable() {
                reps[i].power_off_drained();
            }
        } else {
            // Only reachable with requeued requests in hand and no live,
            // warming, or recovering replica to ever take them.
            ensure!(
                lifecycle.pending.is_empty(),
                "requeued requests stranded: fleet has no live, warming, or recovering replica"
            );
            unreachable!("event loop stalled with no work and no pending requests");
        }
    }
    Ok(routed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::model_for_tier;
    use crate::fleet::router::{DifficultyTiered, EnergyAware, LeastLoaded, RoundRobin};
    use crate::serve::TrafficPattern;

    fn suite() -> ReplaySuite {
        ReplaySuite::quick(91, 16)
    }

    fn arrivals(s: &ReplaySuite, n: usize) -> Vec<Arrival> {
        TrafficPattern::Bursty { base_rps: 2.0, burst_rps: 8.0, mean_dwell_s: 3.0 }
            .generate(s, n, 0xF1EE7)
    }

    fn tiered_cfg(policy: DvfsPolicy) -> FleetConfig {
        FleetConfig::tiered(ModelTier::B1, 2, ModelTier::B8, 2, policy)
    }

    #[test]
    fn serves_everything_and_conserves_energy_under_every_router() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let routers: Vec<Box<dyn FleetRouter>> = vec![
            Box::new(RoundRobin::default()),
            Box::new(LeastLoaded),
            Box::new(DifficultyTiered::default()),
            Box::new(EnergyAware::default()),
        ];
        for mut router in routers {
            let o = sim.run(&s, &arr, router.as_mut()).unwrap();
            assert_eq!(o.served, arr.len(), "{}", router.label());
            assert_eq!(o.slo.completed(), arr.len());
            assert_eq!(o.joules.len(), arr.len());
            assert!(o.routed.iter().all(|&r| r < 4), "{}", router.label());
            assert_eq!(o.routed, o.served_by, "no failures: first route serves");
            let attributed: f64 = o.joules.iter().sum();
            let rel = (attributed - o.total_j()).abs() / o.total_j();
            assert!(rel < 1e-6, "{}: conservation off by {rel:e}", router.label());
            // The last arrival finishes after it arrives.
            assert!(o.makespan_s >= arr.last().unwrap().t_s);
            assert!(o.energy_j > 0.0 && o.switch_j <= o.energy_j);
            // Fixed fleet: no lifecycle churn, everything stays live.
            assert_eq!(o.lifecycle, LifecycleStats::default());
            assert_eq!(o.coldstart_j, 0.0);
            assert!((o.mean_live_replicas - 4.0).abs() < 1e-12);
        }
    }

    #[test]
    fn runs_are_deterministic() {
        let s = suite();
        let arr = arrivals(&s, 32);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::governed(&gpu)));
        let a = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        let b = sim.run(&s, &arr, &mut DifficultyTiered::default()).unwrap();
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.joules, b.joules);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.makespan_s, b.makespan_s);
    }

    #[test]
    fn difficulty_router_sends_hard_queries_to_the_large_tier() {
        let s = suite();
        let arr = arrivals(&s, 48);
        let gpu = GpuSpec::rtx_pro_6000();
        let sim = FleetSim::new(gpu.clone(), tiered_cfg(DvfsPolicy::baseline(&gpu)));
        let mut router = DifficultyTiered::default();
        let o = sim.run(&s, &arr, &mut router).unwrap();
        for (i, a) in arr.iter().enumerate() {
            let hard = router.is_hard(&s.features[a.query_idx]);
            let tier = sim.cfg.replicas[o.routed[i]].model.tier;
            if hard {
                assert_eq!(tier, ModelTier::B8, "hard query {i} routed to {tier:?}");
            } else {
                assert_eq!(tier, ModelTier::B1, "easy query {i} routed to {tier:?}");
            }
        }
    }

    #[test]
    fn cold_replicas_hold_no_traffic() {
        let s = suite();
        let arr = arrivals(&s, 24);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg =
            FleetConfig::homogeneous(model_for_tier(ModelTier::B1), 3, DvfsPolicy::Static(2842));
        cfg.replicas[1].state = ReplicaState::Cold;
        let sim = FleetSim::new(gpu, cfg);
        let o = sim.run(&s, &arr, &mut RoundRobin::default()).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.routed.iter().all(|&r| r != 1));
        assert_eq!(o.replicas[1].served, 0);
        assert_eq!(o.replicas[1].energy_j, 0.0);
        assert!((o.mean_live_replicas - 2.0).abs() < 1e-12);
    }

    #[test]
    fn all_dead_fleet_is_a_typed_error_not_a_panic() {
        let s = suite();
        let arr = arrivals(&s, 4);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg =
            FleetConfig::homogeneous(model_for_tier(ModelTier::B1), 2, DvfsPolicy::Static(2842));
        for r in cfg.replicas.iter_mut() {
            r.state = ReplicaState::Cold;
        }
        let err = FleetSim::new(gpu, cfg)
            .run(&s, &arr, &mut RoundRobin::default())
            .unwrap_err();
        assert!(
            err.to_string().contains("no live replica"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn permanent_failure_of_the_whole_fleet_mid_run_is_a_typed_error() {
        // One replica, unrepairable failures, enough traffic that the
        // crash lands mid-run: the engine must surface a typed error for
        // the stranded work instead of deadlocking or corrupting numbers.
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 1.0 }.generate(&s, 400, 0xDEAD);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg =
            FleetConfig::homogeneous(model_for_tier(ModelTier::B3), 1, DvfsPolicy::Static(2842));
        cfg.failures =
            Some(FailureConfig { mtbf_s: 20.0, mttr_s: f64::INFINITY, seed: 0xF00D });
        let err = FleetSim::new(gpu, cfg)
            .run(&s, &arr, &mut RoundRobin::default())
            .unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("stranded") || msg.contains("no live replica"),
            "unexpected error: {msg}"
        );
    }

    #[test]
    fn more_replicas_cut_makespan_under_load() {
        let s = suite();
        // A slam of simultaneous arrivals: parallelism must help makespan.
        let arr: Vec<Arrival> =
            (0..32).map(|i| Arrival { t_s: 0.0, query_idx: i % s.len() }).collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let run = |n: usize| {
            let cfg = FleetConfig::homogeneous(
                model_for_tier(ModelTier::B3),
                n,
                DvfsPolicy::Static(2842),
            );
            FleetSim::new(gpu.clone(), cfg)
                .run(&s, &arr, &mut LeastLoaded)
                .unwrap()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.served, four.served);
        assert!(
            one.makespan_s / four.makespan_s > 2.0,
            "speedup {:.2}",
            one.makespan_s / four.makespan_s
        );
    }

    #[test]
    fn governed_fleet_saves_energy_vs_static_within_slo() {
        let s = suite();
        let arr = arrivals(&s, 64);
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = |p| FleetConfig::homogeneous(model_for_tier(ModelTier::B8), 2, p);
        let stat = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::baseline(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let gov = FleetSim::new(gpu.clone(), cfg(DvfsPolicy::governed(&gpu)))
            .run(&s, &arr, &mut LeastLoaded)
            .unwrap();
        let savings = 1.0 - gov.energy_j / stat.energy_j;
        assert!(savings > 0.15, "governed fleet savings {savings:.3}");
        assert!(
            gov.slo.e2e_p99() <= gov.slo.slo.e2e_p99_s,
            "governed p99 {:.2}s over SLO",
            gov.slo.e2e_p99()
        );
    }

    #[test]
    fn autoscaler_scales_up_under_pressure_and_down_on_slack() {
        let s = suite();
        // A hard burst followed by a long quiet tail: the reactive scaler
        // must warm capacity for the burst and drain it afterwards.
        let mut arr: Vec<Arrival> =
            (0..40).map(|i| Arrival { t_s: 0.05 * i as f64, query_idx: i % s.len() }).collect();
        for i in 0..16 {
            arr.push(Arrival { t_s: 60.0 + 10.0 * i as f64, query_idx: i % s.len() });
        }
        let gpu = GpuSpec::rtx_pro_6000();
        let cfg = FleetConfig::elastic(
            model_for_tier(ModelTier::B3),
            4,
            1,
            DvfsPolicy::Static(2842),
            ReactiveConfig { cooldown_s: 2.0, ..ReactiveConfig::default() },
        );
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.scale_ups >= 1, "never scaled up: {:?}", o.lifecycle);
        assert!(o.lifecycle.scale_downs >= 1, "never scaled down: {:?}", o.lifecycle);
        assert!(o.coldstart_j > 0.0, "cold starts must be charged");
        assert!(
            o.mean_live_replicas > 1.0 && o.mean_live_replicas < 4.0,
            "mean live {:.2} outside (1, 4)",
            o.mean_live_replicas
        );
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // The breakdown carries the cold-start energy explicitly.
        assert!((o.breakdown.coldstart_j - o.coldstart_j).abs() <= 1e-9 * o.coldstart_j);
    }

    #[test]
    fn scale_from_zero_waits_for_warmup_then_serves() {
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 2.0 }.generate(&s, 12, 0xC01D);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg = FleetConfig::elastic(
            model_for_tier(ModelTier::B3),
            2,
            1,
            DvfsPolicy::Static(2842),
            ReactiveConfig::default(),
        );
        // Everything cold at t = 0: the autoscaler must bootstrap.
        cfg.replicas[0].state = ReplicaState::Cold;
        let warmup = cfg.cold_start.warmup_s;
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.scale_ups >= 1);
        assert!(o.coldstart_j > 0.0);
        // Nothing can finish before the first warm-up elapses.
        assert!(
            o.makespan_s >= arr[0].t_s + warmup,
            "served before warm-up: makespan {:.2}",
            o.makespan_s
        );
    }

    #[test]
    fn failures_requeue_in_flight_work_and_conserve_energy() {
        let s = suite();
        let arr = TrafficPattern::Poisson { rps: 3.0 }.generate(&s, 96, 0xFA11);
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg =
            FleetConfig::homogeneous(model_for_tier(ModelTier::B3), 3, DvfsPolicy::Static(2842));
        cfg.failures = Some(FailureConfig { mtbf_s: 12.0, mttr_s: 6.0, seed: 0xBAD });
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len(), "every request survives the crashes");
        assert_eq!(o.slo.completed(), arr.len());
        assert!(o.lifecycle.failures > 0, "MTBF 12s over this run must crash something");
        assert!(o.lifecycle.recoveries > 0);
        assert!(o.coldstart_j > 0.0, "recovery cold starts are charged");
        let attributed: f64 = o.joules.iter().sum();
        let rel = (attributed - o.total_j()).abs() / o.total_j();
        assert!(rel < 1e-6, "conservation off by {rel:e}");
        // Requeued requests were completed by a different replica than
        // first routed (at least sometimes, given > 0 requeues).
        if o.lifecycle.requeued > 0 {
            let moved = (0..arr.len()).filter(|&i| o.routed[i] != o.served_by[i]).count();
            assert!(moved > 0, "requeues recorded but nothing moved replicas");
        }
    }

    #[test]
    fn requeued_requests_keep_original_arrival_latency_accounting() {
        // Deterministic crash construction: the failure stream for seed
        // 0x5EED crashes replica 0 at t ≈ 1.22 s; twelve generation
        // requests arriving through t = 1.1 s cannot possibly have drained
        // by then on one replica, so the crash is guaranteed to catch work
        // in flight and requeue it.
        let s = suite();
        let gen_idx: Vec<usize> =
            (0..s.len()).filter(|&i| s.queries[i].output_tokens > 0).collect();
        let arr: Vec<Arrival> = (0..12)
            .map(|i| Arrival { t_s: 0.1 * i as f64, query_idx: gen_idx[i % gen_idx.len()] })
            .collect();
        let gpu = GpuSpec::rtx_pro_6000();
        let mut cfg = FleetConfig::elastic(
            model_for_tier(ModelTier::B3),
            2,
            1,
            DvfsPolicy::Static(2842),
            ReactiveConfig { cooldown_s: 0.5, high_backlog: 2.0, ..ReactiveConfig::default() },
        );
        cfg.failures = Some(FailureConfig { mtbf_s: 1.5, mttr_s: 4.0, seed: 0x5EED });
        let o = FleetSim::new(gpu, cfg).run(&s, &arr, &mut LeastLoaded).unwrap();
        assert_eq!(o.served, arr.len());
        assert!(o.lifecycle.failures > 0, "the t≈1.22s crash must land mid-run");
        assert!(o.lifecycle.requeued > 0, "the crash must catch work in flight");
        // A requeued request's end-to-end latency spans the crash: its
        // original arrival predates the crash, so the fleet tail must
        // include the repair or warm-up detour (several seconds), far
        // beyond any undisturbed service time.
        assert!(
            o.slo.e2e_p99() > 1.0,
            "requeued tail {:.3}s does not reflect the original arrival",
            o.slo.e2e_p99()
        );
    }
}
