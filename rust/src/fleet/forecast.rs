//! Predictive autoscaling: scale *ahead* of load instead of chasing it.
//!
//! The reactive scaler reacts to backlog that has already formed, so
//! every diurnal ramp pays queueing (and a cold start at the worst
//! moment) before capacity arrives, and every trough keeps replicas
//! idling until the slack watermarks finally clear. The forecasting
//! scaler inverts both:
//!
//! - a **windowed arrival-rate estimator** (EWMA over a short trailing
//!   window) tracks where demand is *now*;
//! - a **coarse periodogram** — normalized autocorrelation of the binned
//!   arrival history over a small grid of candidate periods — detects
//!   seasonality (the diurnal cycle) once two full periods of history
//!   exist;
//! - with a confident period, demand `warmup_s` ahead is read off the
//!   previous cycle, so warm-ups are scheduled *before* a ramp (the
//!   replica finishes warming as the wave lands) and drains *before* a
//!   trough (idle joules are never burned waiting for slack watermarks).
//!
//! Everything is pure arithmetic over observed arrival timestamps — no
//! clocks, no randomness — so a forecast-scaled run replays bit-for-bit
//! under a fixed seed exactly like a reactive one (pinned by the
//! forecast-determinism proptest). A small reactive backstop (backlog /
//! SLO-pressure trip) guards the tail where the forecast is wrong.

use std::collections::VecDeque;

use super::lifecycle::{Autoscaler, ScaleAction};
use super::router::ReplicaStatus;

/// Tuning of the forecasting autoscaler.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastConfig {
    /// Never drain below this many live replicas.
    pub min_live: usize,
    /// Never warm beyond this many live-or-warming replicas.
    pub max_live: usize,
    /// Lead time, seconds: demand is predicted this far ahead. Set it to
    /// at least the cold-start warm-up so a scheduled replica is Live by
    /// the time the predicted ramp arrives.
    pub warmup_s: f64,
    /// Trailing window over which the current arrival rate is estimated.
    pub window_s: f64,
    /// Bin width of the arrival-history series the periodogram scans.
    pub bin_s: f64,
    /// How much arrival history is retained, seconds (bounds memory; must
    /// cover at least two candidate periods for detection to engage).
    pub history_s: f64,
    /// Candidate seasonal periods, seconds, scored by normalized
    /// autocorrelation. Empty disables seasonality (pure EWMA tracking).
    pub periods_s: Vec<f64>,
    /// Minimum normalized autocorrelation for a period to be trusted.
    pub min_autocorr: f64,
    /// EWMA smoothing factor for the windowed rate estimate.
    pub alpha: f64,
    /// Sustainable arrival rate one live replica absorbs at target
    /// utilization, req/s — the capacity model dividing predicted demand
    /// into a target replica count.
    pub rate_per_replica: f64,
    /// Minimum seconds between scale actions.
    pub cooldown_s: f64,
    /// Reactive backstop: scale up (cooldown permitting) when mean
    /// backlog per live replica reaches this, forecast notwithstanding.
    pub backstop_backlog: f64,
    /// Reactive backstop on the SLO pressure signal.
    pub backstop_pressure: f64,
}

impl Default for ForecastConfig {
    fn default() -> ForecastConfig {
        ForecastConfig {
            min_live: 1,
            max_live: usize::MAX,
            warmup_s: 12.0,
            window_s: 15.0,
            bin_s: 5.0,
            history_s: 400.0,
            periods_s: vec![30.0, 45.0, 60.0, 90.0, 120.0, 180.0],
            min_autocorr: 0.25,
            alpha: 0.35,
            rate_per_replica: 1.25,
            cooldown_s: 6.0,
            backstop_backlog: 4.0,
            backstop_pressure: 1.2,
        }
    }
}

/// The forecasting autoscaler. Feed it every arrival through
/// [`Autoscaler::observe_arrival`]; [`Autoscaler::decide`] then compares
/// predicted demand `warmup_s` ahead against live-or-warming capacity.
#[derive(Debug, Clone)]
pub struct ForecastAutoscaler {
    pub cfg: ForecastConfig,
    /// Arrival counts per `bin_s`-wide bin; front is bin `first_bin`.
    bins: VecDeque<u32>,
    /// Absolute index of the oldest retained bin.
    first_bin: usize,
    /// EWMA of the windowed arrival rate, req/s.
    ewma_rate: f64,
    observed: u64,
    last_action_s: f64,
    last_rescue_s: f64,
}

impl ForecastAutoscaler {
    pub fn new(cfg: ForecastConfig) -> ForecastAutoscaler {
        assert!(cfg.min_live >= 1, "forecast autoscaler needs min_live >= 1");
        assert!(cfg.max_live >= cfg.min_live, "max_live below min_live");
        assert!(cfg.warmup_s >= 0.0 && cfg.window_s > 0.0 && cfg.bin_s > 0.0);
        assert!(cfg.history_s >= cfg.window_s, "history shorter than the rate window");
        assert!(cfg.rate_per_replica > 0.0, "replica capacity must be positive");
        assert!((0.0..=1.0).contains(&cfg.alpha), "alpha outside [0, 1]");
        assert!(cfg.cooldown_s >= 0.0);
        ForecastAutoscaler {
            cfg,
            bins: VecDeque::new(),
            first_bin: 0,
            ewma_rate: 0.0,
            observed: 0,
            last_action_s: f64::NEG_INFINITY,
            last_rescue_s: f64::NEG_INFINITY,
        }
    }

    /// Count of one retained bin by absolute index (0 outside history).
    fn bin(&self, idx: usize) -> f64 {
        if idx < self.first_bin {
            return 0.0;
        }
        self.bins.get(idx - self.first_bin).copied().unwrap_or(0) as f64
    }

    /// Arrival rate over the trailing `window_s` ending at `now_s`, req/s.
    fn window_rate(&self, now_s: f64) -> f64 {
        let lo = ((now_s - self.cfg.window_s) / self.cfg.bin_s).max(0.0) as usize;
        let hi = (now_s / self.cfg.bin_s) as usize;
        let count: f64 = (lo..=hi).map(|i| self.bin(i)).sum();
        count / self.cfg.window_s
    }

    /// Coarse periodogram: the best candidate period by normalized
    /// autocorrelation of the binned series, if any clears the
    /// confidence floor with at least two full periods of history.
    fn detect_period(&self) -> Option<f64> {
        let n = self.bins.len();
        if n < 4 {
            return None;
        }
        let xs: Vec<f64> = self.bins.iter().map(|&c| c as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        if var <= 0.0 {
            return None; // flat history has no seasonality
        }
        let mut best: Option<(f64, f64)> = None; // (score, period)
        for &period_s in &self.cfg.periods_s {
            let lag = (period_s / self.cfg.bin_s).round() as usize;
            // Two full cycles of evidence before a period is trusted.
            if lag == 0 || n < 2 * lag {
                continue;
            }
            let num: f64 =
                (lag..n).map(|i| (xs[i] - mean) * (xs[i - lag] - mean)).sum();
            let score = num / var;
            let better = match best {
                // Strictly-better keeps the tie deterministic: the first
                // (shortest) candidate period wins an exact tie.
                Some((s, _)) => score > s,
                None => score >= self.cfg.min_autocorr,
            };
            if better {
                best = Some((score, period_s));
            }
        }
        best.map(|(_, p)| p)
    }

    /// Arrival rate around absolute time `t_s`, read off the binned
    /// history (mean of the covering bin and its neighbors), req/s.
    fn rate_at(&self, t_s: f64) -> f64 {
        let center = (t_s.max(0.0) / self.cfg.bin_s) as usize;
        let lo = center.saturating_sub(1);
        let count: f64 = (lo..=center + 1).map(|i| self.bin(i)).sum();
        count / (((center + 1 - lo) + 1) as f64 * self.cfg.bin_s)
    }

    /// Predicted arrival rate `warmup_s` ahead of `now_s`, req/s.
    fn predicted_rate(&self, now_s: f64) -> f64 {
        match self.detect_period() {
            // Seasonal: demand one cycle before the target instant. The
            // forecast is trusted both ways — lower than now means a
            // trough is coming and capacity can pre-drain.
            Some(period_s) => self.rate_at(now_s + self.cfg.warmup_s - period_s),
            // No confident season: track the present (EWMA ⊔ window, so a
            // fresh burst is never averaged away).
            None => self.ewma_rate.max(self.window_rate(now_s)),
        }
    }
}

impl Autoscaler for ForecastAutoscaler {
    fn observe_arrival(&mut self, t_s: f64) {
        let idx = (t_s.max(0.0) / self.cfg.bin_s) as usize;
        while self.first_bin + self.bins.len() <= idx {
            self.bins.push_back(0);
        }
        self.bins[idx - self.first_bin] += 1;
        let keep = (self.cfg.history_s / self.cfg.bin_s).ceil() as usize;
        while self.bins.len() > keep {
            self.bins.pop_front();
            self.first_bin += 1;
        }
        self.ewma_rate = if self.observed == 0 {
            self.window_rate(t_s)
        } else {
            (1.0 - self.cfg.alpha) * self.ewma_rate + self.cfg.alpha * self.window_rate(t_s)
        };
        self.observed += 1;
    }

    fn decide(
        &mut self,
        now_s: f64,
        replicas: &[ReplicaStatus],
        slo_pressure: f64,
    ) -> ScaleAction {
        let live = replicas.iter().filter(|r| r.live()).count();
        let warming = replicas
            .iter()
            .filter(|r| matches!(r.state, super::lifecycle::ReplicaState::Warming { .. }))
            .count();
        let coming = live + warming;
        // Floor restore: immediate for a dead fleet, debounced by the
        // cooldown otherwise (same anti-flap rule as the reactive scaler).
        if coming < self.cfg.min_live {
            if live == 0 || now_s - self.last_rescue_s >= self.cfg.cooldown_s {
                self.last_rescue_s = now_s;
                self.last_action_s = now_s;
                return ScaleAction::Up(self.cfg.min_live - coming);
            }
            return ScaleAction::Hold;
        }
        if now_s - self.last_action_s < self.cfg.cooldown_s {
            return ScaleAction::Hold;
        }
        let backlog: usize = replicas.iter().filter(|r| r.live()).map(|r| r.backlog()).sum();
        let per_live = if live > 0 { backlog as f64 / live as f64 } else { f64::INFINITY };
        // Reactive backstop: the forecast was wrong and load is piling up.
        if (per_live >= self.cfg.backstop_backlog || slo_pressure >= self.cfg.backstop_pressure)
            && coming < self.cfg.max_live
        {
            self.last_action_s = now_s;
            return ScaleAction::Up(1);
        }
        let target = (self.predicted_rate(now_s) / self.cfg.rate_per_replica).ceil() as usize;
        let target = target.clamp(self.cfg.min_live, self.cfg.max_live);
        if target > coming {
            self.last_action_s = now_s;
            return ScaleAction::Up(target - coming);
        }
        // Pre-drain toward the predicted trough, one replica at a time,
        // never while capacity is still in flight and never into work.
        if target < live && warming == 0 && live > self.cfg.min_live && per_live < 1.0 {
            self.last_action_s = now_s;
            return ScaleAction::Down(1);
        }
        ScaleAction::Hold
    }

    fn label(&self) -> String {
        format!(
            "forecast[{}-{};lead {}s]",
            self.cfg.min_live,
            if self.cfg.max_live == usize::MAX {
                "fleet".to_string()
            } else {
                self.cfg.max_live.to_string()
            },
            self.cfg.warmup_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelTier;
    use crate::fleet::lifecycle::ReplicaState;

    fn status(idx: usize, state: ReplicaState, backlog: usize) -> ReplicaStatus {
        ReplicaStatus {
            idx,
            state,
            tier: ModelTier::B8,
            queue_depth: backlog,
            active_seqs: 0,
            now_s: 0.0,
            window_power_w: 0.0,
            busy_fraction: 0.0,
            j_per_token: 1.0,
        }
    }

    /// A square-wave seasonal load: `burst` arrivals per second for the
    /// first half of each `period_s` cycle, silence for the second half.
    fn feed_square_wave(a: &mut ForecastAutoscaler, period_s: f64, cycles: usize, burst: usize) {
        let mut t = 0.0;
        for _ in 0..cycles {
            let start = t;
            while t < start + period_s / 2.0 {
                for k in 0..burst {
                    a.observe_arrival(t + k as f64 / burst as f64);
                }
                t += 1.0;
            }
            t = start + period_s;
        }
    }

    #[test]
    fn periodogram_finds_the_square_wave_period() {
        let mut a = ForecastAutoscaler::new(ForecastConfig::default());
        feed_square_wave(&mut a, 60.0, 4, 3);
        assert_eq!(a.detect_period(), Some(60.0));
    }

    #[test]
    fn flat_history_has_no_season() {
        let mut a = ForecastAutoscaler::new(ForecastConfig::default());
        for i in 0..200 {
            a.observe_arrival(i as f64);
        }
        assert_eq!(a.detect_period(), None);
    }

    #[test]
    fn warms_ahead_of_a_predicted_ramp() {
        let mut a = ForecastAutoscaler::new(ForecastConfig {
            max_live: 4,
            warmup_s: 12.0,
            rate_per_replica: 1.0,
            ..ForecastConfig::default()
        });
        // 3 req/s on-peak with a 60 s cycle; history ends mid-trough.
        feed_square_wave(&mut a, 60.0, 4, 3);
        // t = 230: trough (cycle position 50), next burst starts at 240.
        // The lead window (t+12 = 242) lands in the predicted burst, so
        // the scaler warms NOW even though the current rate is zero and
        // there is no backlog at all.
        let reps = vec![
            status(0, ReplicaState::Live, 0),
            status(1, ReplicaState::Cold, 0),
            status(2, ReplicaState::Cold, 0),
            status(3, ReplicaState::Cold, 0),
        ];
        match a.decide(230.0, &reps, 0.0) {
            ScaleAction::Up(n) => assert!(n >= 1, "expected a pre-ramp warm-up"),
            other => panic!("expected Up ahead of the ramp, got {other:?}"),
        }
    }

    #[test]
    fn pre_drains_ahead_of_a_predicted_trough() {
        let mut a = ForecastAutoscaler::new(ForecastConfig {
            max_live: 4,
            warmup_s: 12.0,
            rate_per_replica: 1.0,
            ..ForecastConfig::default()
        });
        feed_square_wave(&mut a, 60.0, 4, 3);
        // t = 205 is still on-peak (cycle position 25), but the lead
        // window (t+12 = 217 → previous cycle 157, position 37) lands in
        // the trough, so capacity drains while load is still up — the
        // move a reactive scaler can only make after the trough arrives.
        let reps = vec![
            status(0, ReplicaState::Live, 0),
            status(1, ReplicaState::Live, 0),
            status(2, ReplicaState::Live, 0),
            status(3, ReplicaState::Cold, 0),
        ];
        assert_eq!(a.decide(205.0, &reps, 0.0), ScaleAction::Down(1));
    }

    #[test]
    fn backstop_trips_on_backlog_when_the_forecast_is_wrong() {
        let mut a = ForecastAutoscaler::new(ForecastConfig {
            max_live: 4,
            ..ForecastConfig::default()
        });
        feed_square_wave(&mut a, 60.0, 4, 3);
        // Predicted trough, but the queues say otherwise.
        let reps = vec![
            status(0, ReplicaState::Live, 9),
            status(1, ReplicaState::Live, 9),
            status(2, ReplicaState::Cold, 0),
        ];
        assert_eq!(a.decide(205.0, &reps, 0.0), ScaleAction::Up(1));
    }

    #[test]
    fn cooldown_and_floor_are_respected() {
        let mut a = ForecastAutoscaler::new(ForecastConfig {
            min_live: 1,
            max_live: 3,
            cooldown_s: 10.0,
            ..ForecastConfig::default()
        });
        // Dead fleet: immediate rescue regardless of any cooldown.
        let dead = vec![status(0, ReplicaState::Cold, 0)];
        assert_eq!(a.decide(0.0, &dead, 0.0), ScaleAction::Up(1));
        // One live at zero load: hold at the floor, and the cooldown
        // blocks any further action regardless.
        let one = vec![status(0, ReplicaState::Live, 0)];
        assert_eq!(a.decide(1.0, &one, 0.0), ScaleAction::Hold);
        assert_eq!(a.decide(100.0, &one, 0.0), ScaleAction::Hold);
    }

    #[test]
    fn without_history_it_tracks_the_present() {
        let mut a = ForecastAutoscaler::new(ForecastConfig {
            max_live: 4,
            rate_per_replica: 1.0,
            cooldown_s: 0.0,
            ..ForecastConfig::default()
        });
        // A sudden 3 req/s burst with no seasonal history: the windowed
        // estimator drives an ordinary (reactive-like) scale-up.
        for i in 0..45 {
            a.observe_arrival(i as f64 / 3.0);
        }
        let reps = vec![status(0, ReplicaState::Live, 2), status(1, ReplicaState::Cold, 0)];
        match a.decide(15.0, &reps, 0.0) {
            ScaleAction::Up(n) => assert!(n >= 1),
            other => panic!("expected Up under a live burst, got {other:?}"),
        }
    }

    #[test]
    fn forecaster_is_deterministic() {
        let run = || {
            let mut a = ForecastAutoscaler::new(ForecastConfig::default());
            feed_square_wave(&mut a, 90.0, 3, 2);
            let reps = vec![status(0, ReplicaState::Live, 1), status(1, ReplicaState::Cold, 0)];
            (0..20)
                .map(|i| a.decide(270.0 + i as f64, &reps, 0.5))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
        assert!(!ForecastAutoscaler::new(ForecastConfig::default()).is_static());
    }
}
