//! Per-request energy attribution.
//!
//! A replica's power meter reads one number for the whole device, but a
//! serving bill needs joules per *request*. The ledger splits measured
//! energy across co-batched requests by phase, following how each phase
//! actually shares the hardware:
//!
//! - **prefill**: each admission prefill runs for exactly one sequence, so
//!   its energy is charged wholly to that request (attribution "by tokens
//!   processed" — the step processes only that request's tokens);
//! - **decode**: every co-batched sequence emits one token per step, so a
//!   step's energy splits equally across the batch ("by tokens generated");
//! - **switch**: a DVFS transition benefits the phase step that follows it
//!   and is split across that step's requests;
//! - **migration**: the prefill replay that resumes a checkpointed
//!   sequence on a new replica runs for exactly one sequence, so its
//!   energy is charged wholly to that request — kept as its own phase so
//!   the cost of moving KV state stays visible as a line item;
//! - **idle**: draw while a replica waits for arrivals is amortized equally
//!   across the requests that replica ultimately served;
//! - **cold start**: boot/weight-load energy paid when the autoscaler (or
//!   failure recovery) warms a replica up, amortized like idle — over the
//!   requests the warmed replica serves, falling back to the whole run's
//!   requests when a warm-up never ended up serving anything.
//!
//! Every split is exact by construction, so attributed energy sums back to
//! the measured total — the conservation property the proptest suite and
//! `examples/fleet_serve.rs` assert to 1e-6 relative error.
//!
//! Storage is a struct-of-arrays arena: one flat `f64` column per phase,
//! sized once at construction. A million-request run allocates five slabs
//! up front and every charge is a bare indexed `+=` into one column — no
//! per-entry allocation, and phase-local charge patterns (decode steps hit
//! only the decode column) stay cache-dense.

/// Attributed energy of one request (or an aggregate of requests), by phase.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseEnergy {
    /// Energy of this request's prefill passes, joules.
    pub prefill_j: f64,
    /// This request's share of co-batched decode steps, joules.
    pub decode_j: f64,
    /// This request's share of DVFS switch transitions, joules.
    pub switch_j: f64,
    /// Energy of this request's migration prefill replay (resuming a
    /// checkpointed sequence on a new replica), joules. Zero unless the
    /// fleet migrated KV state.
    pub migration_j: f64,
    /// This request's amortized share of replica idle draw, joules.
    pub idle_j: f64,
    /// This request's amortized share of cold-start (boot + weight-load)
    /// energy, joules. Zero unless the fleet scaled or recovered.
    pub coldstart_j: f64,
}

impl PhaseEnergy {
    /// Total attributed energy, joules.
    pub fn total_j(&self) -> f64 {
        self.prefill_j
            + self.decode_j
            + self.switch_j
            + self.migration_j
            + self.idle_j
            + self.coldstart_j
    }

    /// Accumulate another breakdown into this one.
    pub fn add(&mut self, other: &PhaseEnergy) {
        self.prefill_j += other.prefill_j;
        self.decode_j += other.decode_j;
        self.switch_j += other.switch_j;
        self.migration_j += other.migration_j;
        self.idle_j += other.idle_j;
        self.coldstart_j += other.coldstart_j;
    }

    /// Active (policy-controlled) energy: everything but idle.
    pub fn active_j(&self) -> f64 {
        self.prefill_j + self.decode_j + self.switch_j + self.migration_j
    }
}

/// Anything that can absorb serving-path energy charges.
///
/// [`EnergyLedger`] is the canonical sink. The fleet engine's parallel gap
/// stepping hands each worker thread a [`ChargeLog`] instead, then replays
/// the logs into the real ledger in replica order — per-gap charge sets are
/// disjoint across replicas, so the replay is bit-identical to having
/// charged the ledger inline.
///
/// Idle and cold-start amortization are *not* part of the sink: they are
/// finalization-time bookkeeping, never charged from inside a step.
pub trait EnergySink {
    /// Charge one prefill pass to `req`.
    fn charge_prefill(&mut self, req: usize, energy_j: f64);
    /// Split one decode step equally across the co-batched requests.
    fn charge_decode(&mut self, reqs: &[usize], energy_j: f64);
    /// Split one DVFS switch across the requests of the following step.
    fn charge_switch(&mut self, reqs: &[usize], energy_j: f64);
    /// Charge one migration prefill replay (resume) to `req`.
    fn charge_migration(&mut self, req: usize, energy_j: f64);
}

/// The attribution ledger: one [`PhaseEnergy`] account per request,
/// indexed by arrival order, stored as per-phase columns.
#[derive(Debug, Clone)]
pub struct EnergyLedger {
    prefill_j: Vec<f64>,
    decode_j: Vec<f64>,
    switch_j: Vec<f64>,
    migration_j: Vec<f64>,
    idle_j: Vec<f64>,
    coldstart_j: Vec<f64>,
}

impl EnergyLedger {
    /// A ledger with `n_requests` zeroed accounts.
    pub fn new(n_requests: usize) -> EnergyLedger {
        EnergyLedger {
            prefill_j: vec![0.0; n_requests],
            decode_j: vec![0.0; n_requests],
            switch_j: vec![0.0; n_requests],
            migration_j: vec![0.0; n_requests],
            idle_j: vec![0.0; n_requests],
            coldstart_j: vec![0.0; n_requests],
        }
    }

    pub fn len(&self) -> usize {
        self.prefill_j.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prefill_j.is_empty()
    }

    /// Charge one prefill pass to `req`.
    pub fn charge_prefill(&mut self, req: usize, energy_j: f64) {
        self.prefill_j[req] += energy_j;
    }

    /// Split one decode step equally across the co-batched requests
    /// (each generated exactly one token this step).
    pub fn charge_decode(&mut self, reqs: &[usize], energy_j: f64) {
        assert!(!reqs.is_empty(), "decode energy with no requests to charge");
        let share = energy_j / reqs.len() as f64;
        for &r in reqs {
            self.decode_j[r] += share;
        }
    }

    /// Split one DVFS switch across the requests of the following step.
    pub fn charge_switch(&mut self, reqs: &[usize], energy_j: f64) {
        assert!(!reqs.is_empty(), "switch energy with no requests to charge");
        let share = energy_j / reqs.len() as f64;
        for &r in reqs {
            self.switch_j[r] += share;
        }
    }

    /// Charge one migration prefill replay (resume) to `req`. Like a
    /// prefill, the replay processes exactly one sequence's tokens.
    pub fn charge_migration(&mut self, req: usize, energy_j: f64) {
        self.migration_j[req] += energy_j;
    }

    /// Amortize a replica's idle draw equally across the requests it served.
    pub fn charge_idle(&mut self, reqs: &[usize], energy_j: f64) {
        if energy_j == 0.0 {
            return;
        }
        assert!(!reqs.is_empty(), "idle energy with no served requests to amortize over");
        let share = energy_j / reqs.len() as f64;
        for &r in reqs {
            self.idle_j[r] += share;
        }
    }

    /// Amortize a replica's cold-start energy equally across `reqs`.
    pub fn charge_coldstart(&mut self, reqs: &[usize], energy_j: f64) {
        if energy_j == 0.0 {
            return;
        }
        assert!(!reqs.is_empty(), "cold-start energy with no requests to amortize over");
        let share = energy_j / reqs.len() as f64;
        for &r in reqs {
            self.coldstart_j[r] += share;
        }
    }

    /// One request's attributed breakdown.
    pub fn request(&self, req: usize) -> PhaseEnergy {
        PhaseEnergy {
            prefill_j: self.prefill_j[req],
            decode_j: self.decode_j[req],
            switch_j: self.switch_j[req],
            migration_j: self.migration_j[req],
            idle_j: self.idle_j[req],
            coldstart_j: self.coldstart_j[req],
        }
    }

    /// Attributed total per request, in arrival order.
    pub fn joules(&self) -> Vec<f64> {
        (0..self.len()).map(|r| self.request(r).total_j()).collect()
    }

    /// Sum of all accounts (the conservation check's left-hand side).
    pub fn totals(&self) -> PhaseEnergy {
        let mut t = PhaseEnergy::default();
        for r in 0..self.len() {
            t.add(&self.request(r));
        }
        t
    }

    /// Sum over a subset of requests (per-replica conservation checks).
    pub fn total_for(&self, reqs: &[usize]) -> f64 {
        reqs.iter().map(|&r| self.request(r).total_j()).sum()
    }
}

impl EnergySink for EnergyLedger {
    fn charge_prefill(&mut self, req: usize, energy_j: f64) {
        EnergyLedger::charge_prefill(self, req, energy_j);
    }

    fn charge_decode(&mut self, reqs: &[usize], energy_j: f64) {
        EnergyLedger::charge_decode(self, reqs, energy_j);
    }

    fn charge_switch(&mut self, reqs: &[usize], energy_j: f64) {
        EnergyLedger::charge_switch(self, reqs, energy_j);
    }

    fn charge_migration(&mut self, req: usize, energy_j: f64) {
        EnergyLedger::charge_migration(self, req, energy_j);
    }
}

/// One recorded serving-path charge. Multi-request charges index into the
/// owning [`ChargeLog`]'s request arena instead of allocating per op.
#[derive(Debug, Clone, Copy)]
enum ChargeOp {
    Prefill { req: usize, energy_j: f64 },
    /// Decode step over `reqs[lo..hi]` of the arena.
    Decode { lo: usize, hi: usize, energy_j: f64 },
    /// Switch charge over `reqs[lo..hi]` of the arena.
    Switch { lo: usize, hi: usize, energy_j: f64 },
    Migration { req: usize, energy_j: f64 },
}

/// A deferred charge buffer: records the exact sequence of serving-path
/// charges so they can be replayed into an [`EnergyLedger`] later.
///
/// Replay applies the identical operations with the identical grouping (and
/// therefore identical equal-share divisions), so `log.replay(&mut ledger)`
/// leaves the ledger bit-identical to having charged it directly.
#[derive(Debug, Clone, Default)]
pub struct ChargeLog {
    ops: Vec<ChargeOp>,
    /// Arena of request indices referenced by multi-request ops.
    reqs: Vec<usize>,
}

impl ChargeLog {
    /// Number of recorded charge operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    fn push_span(&mut self, reqs: &[usize]) -> (usize, usize) {
        let lo = self.reqs.len();
        self.reqs.extend_from_slice(reqs);
        (lo, self.reqs.len())
    }

    /// Apply every recorded charge to `ledger`, in recording order.
    pub fn replay(&self, ledger: &mut EnergyLedger) {
        for op in &self.ops {
            match *op {
                ChargeOp::Prefill { req, energy_j } => ledger.charge_prefill(req, energy_j),
                ChargeOp::Decode { lo, hi, energy_j } => {
                    ledger.charge_decode(&self.reqs[lo..hi], energy_j)
                }
                ChargeOp::Switch { lo, hi, energy_j } => {
                    ledger.charge_switch(&self.reqs[lo..hi], energy_j)
                }
                ChargeOp::Migration { req, energy_j } => ledger.charge_migration(req, energy_j),
            }
        }
    }
}

impl EnergySink for ChargeLog {
    fn charge_prefill(&mut self, req: usize, energy_j: f64) {
        self.ops.push(ChargeOp::Prefill { req, energy_j });
    }

    fn charge_decode(&mut self, reqs: &[usize], energy_j: f64) {
        assert!(!reqs.is_empty(), "decode energy with no requests to charge");
        let (lo, hi) = self.push_span(reqs);
        self.ops.push(ChargeOp::Decode { lo, hi, energy_j });
    }

    fn charge_switch(&mut self, reqs: &[usize], energy_j: f64) {
        assert!(!reqs.is_empty(), "switch energy with no requests to charge");
        let (lo, hi) = self.push_span(reqs);
        self.ops.push(ChargeOp::Switch { lo, hi, energy_j });
    }

    fn charge_migration(&mut self, req: usize, energy_j: f64) {
        self.ops.push(ChargeOp::Migration { req, energy_j });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_sum_back_to_charges() {
        let mut led = EnergyLedger::new(4);
        led.charge_prefill(0, 10.0);
        led.charge_decode(&[0, 1, 2], 9.0);
        led.charge_switch(&[1, 2], 1.0);
        led.charge_idle(&[0, 1, 2, 3], 2.0);
        let t = led.totals();
        assert!((t.prefill_j - 10.0).abs() < 1e-12);
        assert!((t.decode_j - 9.0).abs() < 1e-12);
        assert!((t.switch_j - 1.0).abs() < 1e-12);
        assert!((t.idle_j - 2.0).abs() < 1e-12);
        assert!((t.total_j() - 22.0).abs() < 1e-12);
        assert!((led.joules().iter().sum::<f64>() - 22.0).abs() < 1e-12);
    }

    #[test]
    fn decode_split_is_equal_per_token() {
        let mut led = EnergyLedger::new(3);
        led.charge_decode(&[0, 1, 2], 6.0);
        for r in 0..3 {
            assert!((led.request(r).decode_j - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn active_excludes_idle() {
        let mut led = EnergyLedger::new(1);
        led.charge_prefill(0, 3.0);
        led.charge_idle(&[0], 5.0);
        let p = led.request(0);
        assert!((p.active_j() - 3.0).abs() < 1e-12);
        assert!((p.total_j() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn zero_idle_needs_no_recipients() {
        let mut led = EnergyLedger::new(1);
        led.charge_idle(&[], 0.0); // no-op, must not panic
        assert_eq!(led.totals(), PhaseEnergy::default());
    }

    #[test]
    #[should_panic(expected = "no served requests")]
    fn idle_with_no_recipients_panics() {
        EnergyLedger::new(1).charge_idle(&[], 1.0);
    }

    #[test]
    fn coldstart_amortizes_like_idle_and_counts_in_totals() {
        let mut led = EnergyLedger::new(4);
        led.charge_coldstart(&[], 0.0); // no-op, must not panic
        led.charge_coldstart(&[0, 1], 8.0);
        led.charge_prefill(0, 2.0);
        assert!((led.request(0).coldstart_j - 4.0).abs() < 1e-12);
        assert!((led.request(1).coldstart_j - 4.0).abs() < 1e-12);
        assert!((led.totals().total_j() - 10.0).abs() < 1e-12);
        // Cold start is provisioning cost, not serving-path active energy.
        assert!((led.totals().active_j() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "no requests to amortize")]
    fn coldstart_with_no_recipients_panics() {
        EnergyLedger::new(1).charge_coldstart(&[], 1.0);
    }

    #[test]
    fn migration_is_its_own_phase_and_counts_as_active() {
        let mut led = EnergyLedger::new(2);
        led.charge_prefill(0, 3.0);
        led.charge_migration(0, 1.5);
        let p = led.request(0);
        assert!((p.migration_j - 1.5).abs() < 1e-12);
        assert!((p.active_j() - 4.5).abs() < 1e-12);
        assert!((p.total_j() - 4.5).abs() < 1e-12);
        // Phase separation: the replay is not booked as ordinary prefill.
        assert!((p.prefill_j - 3.0).abs() < 1e-12);
        assert_eq!(led.request(1), PhaseEnergy::default());
    }

    #[test]
    fn total_for_subset() {
        let mut led = EnergyLedger::new(3);
        led.charge_prefill(0, 1.0);
        led.charge_prefill(1, 2.0);
        led.charge_prefill(2, 4.0);
        assert!((led.total_for(&[0, 2]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn charge_log_replay_is_bit_identical_to_direct_charging() {
        let charge = |sink: &mut dyn EnergySink| {
            sink.charge_prefill(0, 7.25);
            sink.charge_switch(&[0], 0.125);
            sink.charge_decode(&[0, 1, 2], 10.0); // 10/3 is not exact in binary
            sink.charge_decode(&[1, 2], 0.3);
            sink.charge_prefill(2, 1.0 / 3.0);
            sink.charge_migration(1, 2.0 / 7.0);
        };
        let mut direct = EnergyLedger::new(3);
        charge(&mut direct);

        let mut log = ChargeLog::default();
        charge(&mut log);
        assert_eq!(log.len(), 6);
        let mut replayed = EnergyLedger::new(3);
        log.replay(&mut replayed);

        for r in 0..3 {
            // Bit-identity, not tolerance: replay must apply the very same
            // divisions in the very same order.
            assert_eq!(direct.request(r), replayed.request(r), "request {r}");
        }
    }

    #[test]
    #[should_panic(expected = "no requests to charge")]
    fn charge_log_rejects_empty_decode_like_the_ledger() {
        ChargeLog::default().charge_decode(&[], 1.0);
    }
}
