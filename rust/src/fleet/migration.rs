//! KV-state migration: checkpoint → handoff → resume.
//!
//! Without migration, a Draining replica must finish its batch before it
//! can power off, and a crash throws away every in-flight sequence — the
//! request re-enters routing from scratch and re-pays its full prefill.
//! Both are expensive exactly when the fleet is under churn. Migration
//! replaces them with a three-step state machine per sequence:
//!
//! 1. **Checkpoint** — the source replica serializes the sequence's
//!    decode progress (tokens emitted, context length, latency
//!    timestamps) into a [`SeqCheckpoint`]. Drains checkpoint
//!    synchronously at the drain instant (nothing is lost); crashes
//!    recover the last *periodic* checkpoint
//!    ([`MigrationPolicy::checkpoint_every_tokens`]), losing only the
//!    tokens decoded since it.
//! 2. **Handoff** — the engine routes each checkpoint through the fleet
//!    router like any arrival; the chosen Live replica accepts it into a
//!    dedicated resume queue ([`crate::fleet::Replica::enqueue_resumed`]).
//! 3. **Resume** — at admission the target replica *replays* the
//!    checkpointed context (one prefill pass over `ctx` tokens — KV
//!    state is device- and model-local, so it must be recomputed), then
//!    the sequence rejoins the continuous batch and decodes its
//!    remaining tokens. The replay energy is charged to the dedicated
//!    `migration_j` ledger phase, so the conservation invariant
//!    (attributed == measured, ≤ 1e-6) still holds with the migration
//!    bill visible as its own line item.
//!
//! Latency accounting is exactly-once end to end: a resumed request
//! keeps its **original** arrival and first-token timestamps, completes
//! on exactly one replica, and its TTFT/e2e include the full migration
//! delay. Requests still queued (no decode progress) hand off as plain
//! requeues — there is no state worth replaying.

use crate::serve::traffic::TrafficClass;

/// Opt-in migration policy. Attach one to a fleet config
/// ([`crate::fleet::FleetConfigBuilder::migration`]) to switch
/// drain/crash handling from requeue-from-arrival to checkpoint/resume.
/// `None` on the config preserves the pre-migration engine bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationPolicy {
    /// Periodic checkpoint cadence, decoded tokens between checkpoints.
    /// A crash rolls each in-flight sequence back to its latest
    /// checkpoint; the tokens decoded since are lost (their energy stays
    /// charged, exactly as a real meter would have recorded it). Drains
    /// always checkpoint synchronously and lose nothing.
    pub checkpoint_every_tokens: usize,
}

impl Default for MigrationPolicy {
    fn default() -> MigrationPolicy {
        MigrationPolicy { checkpoint_every_tokens: 8 }
    }
}

/// One checkpointed in-flight sequence — everything the target replica
/// needs to resume it with exactly-once latency accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeqCheckpoint {
    /// Fleet-wide request index.
    pub req: usize,
    /// Corpus query the request serves.
    pub query_idx: usize,
    /// Traffic class (admission priority survives the migration).
    pub class: TrafficClass,
    /// Original arrival timestamp, seconds — preserved so TTFT/e2e
    /// include the migration delay.
    pub arrival_s: f64,
    /// Original first-token timestamp, seconds (set at prefill end on
    /// the source; a resume never re-emits the first token).
    pub first_token_s: f64,
    /// Tokens decoded as of this checkpoint.
    pub tokens: usize,
    /// Tokens still to decode as of this checkpoint.
    pub remaining: usize,
    /// Context length at this checkpoint (prompt + decoded tokens) —
    /// the length of the prefill replay the target must run.
    pub ctx: usize,
}

/// Fleet-level migration counters, reported on
/// [`crate::fleet::FleetOutcome`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MigrationStats {
    /// Sequences checkpointed off a draining replica.
    pub drained: usize,
    /// Sequences recovered from a periodic checkpoint after a crash.
    pub crash_recovered: usize,
    /// Checkpoint handoffs accepted by a live target replica (each is
    /// replayed and resumed there; a re-crash before replay re-enters
    /// the handoff count).
    pub resumed: usize,
    /// Total decoded tokens the resumed sequences carried across.
    pub tokens_carried: usize,
    /// Decoded tokens lost to crash rollback (decoded after the last
    /// periodic checkpoint).
    pub tokens_lost: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_has_a_sane_cadence() {
        let p = MigrationPolicy::default();
        assert!(p.checkpoint_every_tokens >= 1);
    }

    #[test]
    fn checkpoint_is_plain_copyable_data() {
        let c = SeqCheckpoint {
            req: 3,
            query_idx: 7,
            class: TrafficClass::Batch,
            arrival_s: 1.5,
            first_token_s: 2.0,
            tokens: 12,
            remaining: 20,
            ctx: 40,
        };
        let d = c;
        assert_eq!(c, d);
        assert_eq!(d.tokens + d.remaining, 32);
    }
}
