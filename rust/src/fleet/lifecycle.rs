//! Replica lifecycle: autoscaling, cold starts, and failure/recovery.
//!
//! The paper's Section VII upper bound assumes a fixed fleet, but under
//! diurnal traffic most of a deployment's energy is burned by replicas
//! idling off-peak — idle and provisioning energy dominate real serving
//! bills, not per-token energy. This module gives the fleet a lifecycle:
//!
//! - [`ReplicaState`]: the per-replica state machine
//!   `Live → Draining → Cold → Warming → Live`. Routers only ever see
//!   `Live` replicas; `Draining` replicas finish their in-flight work and
//!   power off; `Cold` replicas draw nothing; `Warming` replicas have paid
//!   a cold-start energy charge and come live after a warm-up delay.
//! - [`Autoscaler`]: the scaling discipline consulted on every arrival.
//!   [`ReactiveAutoscaler`] applies queue-pressure/SLO-headroom hysteresis
//!   (scale up fast on backlog or SLO pressure, down slowly on sustained
//!   slack, with a cooldown between actions); [`StaticAutoscaler`] is the
//!   fixed-fleet no-op baseline.
//! - [`FailureModel`]: seeded MTBF/MTTR replica crashes on the discrete-
//!   event clock. A crash drops the replica to `Cold`, requeues its
//!   in-flight requests through the router **with their original arrival
//!   timestamps**, and schedules recovery (a fresh cold start) one
//!   exponential repair time later.
//!
//! All lifecycle randomness derives from explicit seeds (one independent
//! stream per replica), so elastic runs replay bit-for-bit — the property
//! `rust/tests/scenarios.rs` pins with golden traces.

use std::collections::VecDeque;

use crate::serve::traffic::Arrival;
use crate::Rng;

use super::router::ReplicaStatus;

/// The per-replica lifecycle state machine.
///
/// Legal transitions (driven by [`crate::fleet::engine::drive`]):
///
/// ```text
///   Live ──scale-down──▶ Draining ──queue empties──▶ Cold
///   Live ──────────────────crash─────────────────▶ Cold
///   Cold ──scale-up / recovery──▶ Warming ──warm-up elapses──▶ Live
///   Draining ──scale-up (rescue, no cold start)──▶ Live
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplicaState {
    /// Accepting traffic and executing work.
    Live,
    /// Finishing in-flight work; receives no new routes; powers off when
    /// its queue and batch drain.
    Draining,
    /// Powered off: no idle draw, no work, invisible to routers.
    Cold,
    /// Booting after a cold start; comes `Live` at `until_s`.
    Warming { until_s: f64 },
}

impl ReplicaState {
    /// Whether a router may bind new arrivals to this replica.
    pub fn routable(self) -> bool {
        matches!(self, ReplicaState::Live)
    }

    /// Whether the replica may execute work it already holds.
    pub fn can_work(self) -> bool {
        matches!(self, ReplicaState::Live | ReplicaState::Draining)
    }

    pub fn label(self) -> &'static str {
        match self {
            ReplicaState::Live => "live",
            ReplicaState::Draining => "draining",
            ReplicaState::Cold => "cold",
            ReplicaState::Warming { .. } => "warming",
        }
    }
}

/// Cost of bringing a `Cold` replica `Live`: the boot + weight-load energy
/// charged to the ledger at scale-up, and the delay before the replica can
/// take traffic. The warm-up period's draw is folded into `energy_j` (the
/// replica is not separately billed idle power while `Warming`).
#[derive(Debug, Clone, Copy)]
pub struct ColdStart {
    pub energy_j: f64,
    pub warmup_s: f64,
}

impl Default for ColdStart {
    fn default() -> Self {
        // ~10 s of near-TDP draw while the server boots, loads weights into
        // HBM, and captures graphs — the provisioning cost that makes
        // scale-to-zero a tradeoff rather than a free lunch.
        ColdStart { energy_j: 3000.0, warmup_s: 10.0 }
    }
}

/// One autoscaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    Hold,
    /// Bring up to `n` replicas toward `Live` (rescuing `Draining`
    /// replicas first, then cold-starting `Cold` ones).
    Up(usize),
    /// Drain up to `n` `Live` replicas.
    Down(usize),
}

/// A scaling discipline, consulted by the fleet engine on every arrival
/// (before the arrival is routed, so a scale-up starts warming at the
/// moment demand appears).
pub trait Autoscaler {
    /// `slo_pressure` is the fleet tracker's control signal
    /// (1.0 = at target, >1 = violating).
    fn decide(&mut self, now_s: f64, replicas: &[ReplicaStatus], slo_pressure: f64)
        -> ScaleAction;

    /// Feed one arrival timestamp into the scaler's demand model, before
    /// the corresponding [`Autoscaler::decide`] call. Default: ignored —
    /// reactive and static scalers look at queue state, not arrival
    /// history; only forecasting scalers keep history.
    fn observe_arrival(&mut self, _t_s: f64) {}

    fn label(&self) -> String;

    /// Whether this autoscaler can ever change the fleet. The engine skips
    /// status snapshots and pressure computation for static fleets, keeping
    /// the fixed-fleet hot path identical to the pre-lifecycle loop.
    fn is_static(&self) -> bool {
        false
    }
}

/// Fixed fleet: never scales (the baseline every comparison runs against).
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticAutoscaler;

impl Autoscaler for StaticAutoscaler {
    fn decide(&mut self, _: f64, _: &[ReplicaStatus], _: f64) -> ScaleAction {
        ScaleAction::Hold
    }

    fn label(&self) -> String {
        "static".into()
    }

    fn is_static(&self) -> bool {
        true
    }
}

/// Tuning of the reactive autoscaler's hysteresis band.
#[derive(Debug, Clone, Copy)]
pub struct ReactiveConfig {
    /// Never drain below this many live replicas.
    pub min_live: usize,
    /// Never warm beyond this many live-or-warming replicas.
    pub max_live: usize,
    /// Scale up when mean backlog per live replica reaches this.
    pub high_backlog: f64,
    /// Scale down only when mean backlog per live replica is at or below
    /// this (must sit well under `high_backlog` — the hysteresis band).
    pub low_backlog: f64,
    /// Scale up regardless of backlog when SLO pressure reaches this.
    pub high_pressure: f64,
    /// Scale down only when SLO pressure is at or below this (headroom).
    pub low_pressure: f64,
    /// Minimum seconds between scale actions (anti-flap; matching it to
    /// the cold-start warm-up keeps at most one replica warming per wave).
    pub cooldown_s: f64,
    /// Minimum seconds between floor-restore rescues while at least one
    /// replica is still live. A rescue with `live == 0` always fires
    /// immediately (a dead fleet cannot wait), but a partially-degraded
    /// fleet must not flap a Draining replica Live→Draining→Live on every
    /// evaluation — the debounce the plain `cooldown_s` never covered.
    pub rescue_debounce_s: f64,
}

impl Default for ReactiveConfig {
    fn default() -> Self {
        ReactiveConfig {
            min_live: 1,
            max_live: usize::MAX,
            high_backlog: 3.0,
            low_backlog: 0.75,
            high_pressure: 1.0,
            low_pressure: 0.8,
            cooldown_s: 12.0,
            rescue_debounce_s: 3.0,
        }
    }
}

/// Queue-pressure/SLO-headroom hysteresis scaler: up fast when backlog per
/// live replica or SLO pressure crosses the high watermark, down one
/// replica at a time when both sit below the low watermarks, with a
/// cooldown between actions so warm-ups are not stacked blindly.
#[derive(Debug, Clone)]
pub struct ReactiveAutoscaler {
    pub cfg: ReactiveConfig,
    last_action_s: f64,
    /// Last time the floor-restore rescue fired (tracked separately from
    /// `last_action_s` so an ordinary scale action can never starve a
    /// genuinely-needed rescue past its own debounce).
    last_rescue_s: f64,
}

impl ReactiveAutoscaler {
    pub fn new(cfg: ReactiveConfig) -> ReactiveAutoscaler {
        assert!(cfg.min_live >= 1, "reactive autoscaler needs min_live >= 1");
        assert!(cfg.max_live >= cfg.min_live, "max_live below min_live");
        assert!(
            cfg.low_backlog < cfg.high_backlog,
            "inverted backlog hysteresis band"
        );
        assert!(
            cfg.low_pressure < cfg.high_pressure,
            "inverted pressure hysteresis band"
        );
        assert!(cfg.cooldown_s >= 0.0);
        assert!(cfg.rescue_debounce_s >= 0.0);
        ReactiveAutoscaler {
            cfg,
            last_action_s: f64::NEG_INFINITY,
            last_rescue_s: f64::NEG_INFINITY,
        }
    }
}

impl Default for ReactiveAutoscaler {
    fn default() -> Self {
        ReactiveAutoscaler::new(ReactiveConfig::default())
    }
}

impl Autoscaler for ReactiveAutoscaler {
    fn decide(
        &mut self,
        now_s: f64,
        replicas: &[ReplicaStatus],
        slo_pressure: f64,
    ) -> ScaleAction {
        let live = replicas.iter().filter(|r| r.live()).count();
        let warming = replicas
            .iter()
            .filter(|r| matches!(r.state, ReplicaState::Warming { .. }))
            .count();
        let coming = live + warming;
        // Below the floor (initial cold fleet, or a crash took a replica).
        // A fully dead fleet is restored immediately — nothing can serve,
        // so waiting only grows the backlog. With capacity still live, the
        // rescue is debounced: oscillating pressure used to flap a
        // Draining replica Live→Draining→Live on every evaluation because
        // this path bypassed the cooldown unconditionally.
        if coming < self.cfg.min_live {
            if live == 0 || now_s - self.last_rescue_s >= self.cfg.rescue_debounce_s {
                self.last_rescue_s = now_s;
                self.last_action_s = now_s;
                return ScaleAction::Up(self.cfg.min_live - coming);
            }
            return ScaleAction::Hold;
        }
        if now_s - self.last_action_s < self.cfg.cooldown_s {
            return ScaleAction::Hold;
        }
        let backlog: usize =
            replicas.iter().filter(|r| r.live()).map(|r| r.backlog()).sum();
        let per_live = if live > 0 { backlog as f64 / live as f64 } else { f64::INFINITY };
        if (per_live >= self.cfg.high_backlog || slo_pressure >= self.cfg.high_pressure)
            && coming < self.cfg.max_live
        {
            self.last_action_s = now_s;
            return ScaleAction::Up(1);
        }
        // Down only with real slack on *both* signals, nothing warming
        // (capacity in flight means a recent up-wave), and floor respected.
        if warming == 0
            && per_live <= self.cfg.low_backlog
            && slo_pressure <= self.cfg.low_pressure
            && live > self.cfg.min_live
        {
            self.last_action_s = now_s;
            return ScaleAction::Down(1);
        }
        ScaleAction::Hold
    }

    fn label(&self) -> String {
        format!(
            "reactive[{}-{};bl {}/{}]",
            self.cfg.min_live,
            if self.cfg.max_live == usize::MAX {
                "fleet".to_string()
            } else {
                self.cfg.max_live.to_string()
            },
            self.cfg.low_backlog,
            self.cfg.high_backlog
        )
    }
}

/// Which autoscaler a [`crate::fleet::FleetConfig`] builds (plain data, so
/// fleet configs stay `Clone`).
#[derive(Debug, Clone)]
pub enum AutoscalePolicy {
    Static,
    Reactive(ReactiveConfig),
    /// Predictive scaling: warm ahead of forecast ramps, pre-drain ahead
    /// of forecast troughs ([`crate::fleet::forecast::ForecastAutoscaler`]).
    Forecast(super::forecast::ForecastConfig),
}

impl AutoscalePolicy {
    pub fn build(&self) -> Box<dyn Autoscaler> {
        match self {
            AutoscalePolicy::Static => Box::new(StaticAutoscaler),
            AutoscalePolicy::Reactive(cfg) => Box::new(ReactiveAutoscaler::new(*cfg)),
            AutoscalePolicy::Forecast(cfg) => {
                Box::new(super::forecast::ForecastAutoscaler::new(cfg.clone()))
            }
        }
    }

    pub fn label(&self) -> String {
        self.build().label()
    }
}

/// Seeded replica failure/recovery process: crashes strike `Live` replicas
/// after an exponential MTBF; repair completes after an exponential MTTR,
/// upon which the replica cold-starts back toward `Live`. `mttr_s` may be
/// `f64::INFINITY` to model unrepaired permanent failures.
#[derive(Debug, Clone, Copy)]
pub struct FailureConfig {
    /// Mean time between failures while live, seconds.
    pub mtbf_s: f64,
    /// Mean time to repair after a crash, seconds.
    pub mttr_s: f64,
    /// Master seed; each replica derives an independent stream.
    pub seed: u64,
}

/// A lifecycle event the failure model or state machine schedules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LifecycleEvent {
    /// A `Warming` replica reaches `Live`.
    WarmDone(usize),
    /// A crashed replica's repair completes (begins a cold start).
    Recover(usize),
    /// A `Live` replica crashes.
    Fail(usize),
}

impl LifecycleEvent {
    /// Tie-break rank at equal event times: capacity comes up before more
    /// goes down, so requeues at a coincident instant can route.
    fn rank(self) -> u8 {
        match self {
            LifecycleEvent::WarmDone(_) => 0,
            LifecycleEvent::Recover(_) => 1,
            LifecycleEvent::Fail(_) => 2,
        }
    }

    fn replica(self) -> usize {
        match self {
            LifecycleEvent::WarmDone(i)
            | LifecycleEvent::Recover(i)
            | LifecycleEvent::Fail(i) => i,
        }
    }
}

/// Pick the earlier of two optional timed events (rank, then replica index
/// on exact ties — fully deterministic).
pub(crate) fn earlier(
    a: Option<(f64, LifecycleEvent)>,
    b: Option<(f64, LifecycleEvent)>,
) -> Option<(f64, LifecycleEvent)> {
    match (a, b) {
        (None, x) | (x, None) => x,
        (Some((ta, ea)), Some((tb, eb))) => {
            let pick_a = match ta.total_cmp(&tb) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => {
                    (ea.rank(), ea.replica()) <= (eb.rank(), eb.replica())
                }
            };
            if pick_a {
                Some((ta, ea))
            } else {
                Some((tb, eb))
            }
        }
    }
}

fn exp_draw(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() * mean_s
}

/// Per-replica failure clock.
#[derive(Debug, Clone)]
struct FailClock {
    rng: Rng,
    /// Scheduled crash time while the replica is live.
    fail_at_s: Option<f64>,
    /// Scheduled repair-completion time while the replica is down.
    recover_at_s: Option<f64>,
}

/// The runtime failure process over one fleet.
#[derive(Debug, Clone)]
pub struct FailureModel {
    cfg: FailureConfig,
    clocks: Vec<FailClock>,
}

impl FailureModel {
    pub fn new(cfg: FailureConfig, n_replicas: usize) -> FailureModel {
        assert!(cfg.mtbf_s > 0.0, "MTBF must be positive");
        assert!(cfg.mttr_s > 0.0, "MTTR must be positive");
        let clocks = (0..n_replicas)
            .map(|i| FailClock {
                // Independent stream per replica: failures on one replica
                // never perturb another's schedule.
                rng: crate::rng(cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                fail_at_s: None,
                recover_at_s: None,
            })
            .collect();
        FailureModel { cfg, clocks }
    }

    /// Start the MTBF clock when replica `i` goes live.
    pub fn arm(&mut self, i: usize, now_s: f64) {
        let c = &mut self.clocks[i];
        c.fail_at_s = Some(now_s + exp_draw(&mut c.rng, self.cfg.mtbf_s));
    }

    /// Stop the MTBF clock (replica left `Live` without crashing).
    pub fn disarm(&mut self, i: usize) {
        self.clocks[i].fail_at_s = None;
    }

    /// Record the crash of replica `i` and schedule its repair.
    pub fn crash(&mut self, i: usize, now_s: f64) {
        let c = &mut self.clocks[i];
        c.fail_at_s = None;
        c.recover_at_s = Some(now_s + exp_draw(&mut c.rng, self.cfg.mttr_s));
    }

    /// Clear the repair schedule once recovery begins.
    pub fn recovered(&mut self, i: usize) {
        self.clocks[i].recover_at_s = None;
    }

    /// Whether replica `i` is down awaiting repair (an autoscaler cannot
    /// warm a crashed machine before its repair completes).
    pub fn under_repair(&self, i: usize) -> bool {
        self.clocks[i].recover_at_s.is_some()
    }

    /// Earliest scheduled crash or repair completion.
    pub fn next_event(&self) -> Option<(f64, LifecycleEvent)> {
        let mut best: Option<(f64, LifecycleEvent)> = None;
        for (i, c) in self.clocks.iter().enumerate() {
            if let Some(t) = c.fail_at_s {
                if t.is_finite() {
                    best = earlier(best, Some((t, LifecycleEvent::Fail(i))));
                }
            }
            if let Some(t) = c.recover_at_s {
                if t.is_finite() {
                    best = earlier(best, Some((t, LifecycleEvent::Recover(i))));
                }
            }
        }
        best
    }
}

/// Lifecycle counters surfaced on [`crate::fleet::FleetOutcome`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LifecycleStats {
    /// Autoscaler-initiated warm-ups (including drain rescues).
    pub scale_ups: usize,
    /// Autoscaler-initiated drains.
    pub scale_downs: usize,
    /// Replica crashes injected by the failure model.
    pub failures: usize,
    /// Repairs that completed (began a recovery cold start).
    pub recoveries: usize,
    /// In-flight requests re-routed after crashes.
    pub requeued: usize,
}

/// A checkpointed sequence waiting for a live replica to resume on (only
/// populated while the fleet has zero live replicas at the migration
/// instant, mirroring [`PendingRequeue`]).
#[derive(Debug, Clone, Copy)]
pub(crate) struct PendingCheckpoint {
    pub ckpt: super::migration::SeqCheckpoint,
    /// The earliest time the destination replica may replay it (the
    /// drain/crash instant).
    pub not_before_s: f64,
}

/// A requeued request waiting for a live replica (only populated while the
/// fleet has zero live replicas at a crash instant).
#[derive(Debug, Clone, Copy)]
pub(crate) struct PendingRequeue {
    pub req: usize,
    pub arrival: Arrival,
    /// The earliest time the replacement replica may start on it (the
    /// crash instant — the work provably hadn't finished before then).
    pub not_before_s: f64,
}

/// The engine-side lifecycle runtime: autoscaler + failure model + cold
/// start config, plus the bookkeeping `drive()` threads through a run.
pub struct Lifecycle {
    pub autoscaler: Box<dyn Autoscaler>,
    pub failures: Option<FailureModel>,
    pub cold_start: ColdStart,
    pub stats: LifecycleStats,
    /// KV-state migration policy; `None` keeps the crash/drain paths on
    /// their original lose-and-requeue semantics (bit-identical traces).
    pub migration: Option<super::migration::MigrationPolicy>,
    /// Checkpoint → Handoff → Resume counters for the run outcome.
    pub migration_stats: super::migration::MigrationStats,
    /// (time, ±1) deltas of the live-replica count, for the time-weighted
    /// mean live count reported on the outcome.
    pub(crate) live_deltas: Vec<(f64, i64)>,
    pub(crate) pending: VecDeque<PendingRequeue>,
    pub(crate) pending_ckpts: VecDeque<PendingCheckpoint>,
    /// Fast path: a static autoscaler with no failure model makes the
    /// whole lifecycle machinery inert (the fixed-fleet loop).
    inert: bool,
}

impl Lifecycle {
    pub fn new(
        autoscaler: Box<dyn Autoscaler>,
        failures: Option<FailureModel>,
        cold_start: ColdStart,
    ) -> Lifecycle {
        let inert = autoscaler.is_static() && failures.is_none();
        Lifecycle {
            autoscaler,
            failures,
            cold_start,
            stats: LifecycleStats::default(),
            migration: None,
            migration_stats: super::migration::MigrationStats::default(),
            live_deltas: Vec::new(),
            pending: VecDeque::new(),
            pending_ckpts: VecDeque::new(),
            inert,
        }
    }

    /// The fixed-fleet lifecycle: no scaling, no failures. This is the
    /// configuration under which `drive()` is bit-identical to the
    /// pre-lifecycle loop (pinned by `rust/tests/unification.rs`).
    pub fn inert() -> Lifecycle {
        Lifecycle::new(Box::new(StaticAutoscaler), None, ColdStart::default())
    }

    pub fn is_inert(&self) -> bool {
        self.inert
    }

    pub(crate) fn log_live_delta(&mut self, t_s: f64, delta: i64) {
        self.live_deltas.push((t_s, delta));
    }

    /// Time-weighted mean live-replica count over `[0, horizon_s]`.
    pub(crate) fn mean_live(&self, initial_live: usize, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return initial_live as f64;
        }
        let mut deltas = self.live_deltas.clone();
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut live = initial_live as i64;
        let mut t_prev = 0.0;
        let mut area = 0.0;
        for (t, d) in deltas {
            let tc = t.clamp(0.0, horizon_s);
            area += live as f64 * (tc - t_prev);
            t_prev = tc;
            live += d;
        }
        area += live as f64 * (horizon_s - t_prev);
        area / horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelTier;

    fn status(idx: usize, state: ReplicaState, backlog: usize) -> ReplicaStatus {
        ReplicaStatus {
            idx,
            state,
            tier: ModelTier::B8,
            queue_depth: backlog,
            active_seqs: 0,
            now_s: 0.0,
            window_power_w: 0.0,
            busy_fraction: 0.0,
            j_per_token: 1.0,
        }
    }

    #[test]
    fn state_machine_predicates() {
        assert!(ReplicaState::Live.routable() && ReplicaState::Live.can_work());
        assert!(!ReplicaState::Draining.routable() && ReplicaState::Draining.can_work());
        assert!(!ReplicaState::Cold.routable() && !ReplicaState::Cold.can_work());
        let w = ReplicaState::Warming { until_s: 5.0 };
        assert!(!w.routable() && !w.can_work());
        assert_eq!(w.label(), "warming");
    }

    #[test]
    fn reactive_scales_up_on_backlog_and_down_on_slack() {
        let mut a = ReactiveAutoscaler::new(ReactiveConfig {
            cooldown_s: 10.0,
            ..ReactiveConfig::default()
        });
        let busy = vec![status(0, ReplicaState::Live, 8), status(1, ReplicaState::Cold, 0)];
        assert_eq!(a.decide(0.0, &busy, 0.0), ScaleAction::Up(1));
        // Cooldown blocks an immediate second action.
        assert_eq!(a.decide(1.0, &busy, 0.0), ScaleAction::Hold);
        // After cooldown with slack on both live replicas: scale down.
        let slack = vec![status(0, ReplicaState::Live, 0), status(1, ReplicaState::Live, 0)];
        assert_eq!(a.decide(20.0, &slack, 0.1), ScaleAction::Down(1));
    }

    #[test]
    fn reactive_scales_up_on_slo_pressure_alone() {
        let mut a = ReactiveAutoscaler::default();
        let reps = vec![status(0, ReplicaState::Live, 0), status(1, ReplicaState::Cold, 0)];
        assert_eq!(a.decide(100.0, &reps, 1.4), ScaleAction::Up(1));
    }

    #[test]
    fn reactive_holds_inside_the_hysteresis_band() {
        let mut a = ReactiveAutoscaler::default();
        // Backlog between the watermarks, pressure moderate: hold.
        let reps = vec![status(0, ReplicaState::Live, 2), status(1, ReplicaState::Live, 1)];
        assert_eq!(a.decide(100.0, &reps, 0.9), ScaleAction::Hold);
    }

    #[test]
    fn reactive_respects_floor_ceiling_and_warming_capacity() {
        let cfg = ReactiveConfig { min_live: 1, max_live: 2, ..ReactiveConfig::default() };
        let mut a = ReactiveAutoscaler::new(cfg);
        // One live + one warming at the ceiling: no further up.
        let reps = vec![
            status(0, ReplicaState::Live, 50),
            status(1, ReplicaState::Warming { until_s: 9.0 }, 0),
            status(2, ReplicaState::Cold, 0),
        ];
        assert_eq!(a.decide(100.0, &reps, 2.0), ScaleAction::Hold);
        // Never drains below the floor, even with zero load.
        let one = vec![status(0, ReplicaState::Live, 0)];
        assert_eq!(a.decide(200.0, &one, 0.0), ScaleAction::Hold);
        // A dead fleet (crash took the last live replica) restores the
        // floor immediately, ignoring the cooldown.
        let dead = vec![status(0, ReplicaState::Cold, 0), status(1, ReplicaState::Cold, 0)];
        assert_eq!(a.decide(200.1, &dead, 0.0), ScaleAction::Up(1));
    }

    #[test]
    fn rescue_is_debounced_while_capacity_is_still_live() {
        // Regression: the floor-restore path used to bypass the cooldown
        // unconditionally, so a fleet sitting just under its floor could
        // flap a Draining replica Live→Draining→Live every evaluation.
        let cfg = ReactiveConfig {
            min_live: 2,
            rescue_debounce_s: 3.0,
            ..ReactiveConfig::default()
        };
        let mut a = ReactiveAutoscaler::new(cfg);
        let degraded = vec![
            status(0, ReplicaState::Live, 0),
            status(1, ReplicaState::Draining, 0),
            status(2, ReplicaState::Cold, 0),
        ];
        // First rescue fires (restores the floor)...
        assert_eq!(a.decide(0.0, &degraded, 0.0), ScaleAction::Up(1));
        // ...but an immediate re-evaluation of the same degraded shape
        // holds instead of flapping.
        assert_eq!(a.decide(0.5, &degraded, 0.0), ScaleAction::Hold);
        assert_eq!(a.decide(2.9, &degraded, 0.0), ScaleAction::Hold);
        // Once the debounce elapses the rescue may fire again.
        assert_eq!(a.decide(3.0, &degraded, 0.0), ScaleAction::Up(1));
        // A fully dead fleet is never debounced: nothing can serve.
        let dead = vec![status(0, ReplicaState::Cold, 0), status(1, ReplicaState::Cold, 0)];
        assert_eq!(a.decide(3.1, &dead, 0.0), ScaleAction::Up(2));
    }

    #[test]
    fn observe_arrival_default_is_a_no_op() {
        let mut a = ReactiveAutoscaler::default();
        a.observe_arrival(1.0);
        let mut s = StaticAutoscaler;
        s.observe_arrival(2.0);
        assert_eq!(s.decide(3.0, &[], 0.0), ScaleAction::Hold);
    }

    #[test]
    fn reactive_does_not_scale_down_while_warming() {
        let mut a = ReactiveAutoscaler::new(ReactiveConfig {
            min_live: 1,
            ..ReactiveConfig::default()
        });
        let reps = vec![
            status(0, ReplicaState::Live, 0),
            status(1, ReplicaState::Live, 0),
            status(2, ReplicaState::Warming { until_s: 50.0 }, 0),
        ];
        assert_eq!(a.decide(100.0, &reps, 0.0), ScaleAction::Hold);
    }

    #[test]
    fn failure_model_is_deterministic_and_per_replica_independent() {
        let cfg = FailureConfig { mtbf_s: 100.0, mttr_s: 20.0, seed: 7 };
        let mut a = FailureModel::new(cfg, 3);
        let mut b = FailureModel::new(cfg, 3);
        for fm in [&mut a, &mut b] {
            fm.arm(0, 0.0);
            fm.arm(1, 0.0);
            fm.arm(2, 0.0);
        }
        let ea = a.next_event().unwrap();
        assert_eq!(ea, b.next_event().unwrap());
        // Disarming the scheduled replica leaves the others' times intact.
        let (t_first, ev) = ea;
        a.disarm(ev.replica());
        let (t_second, ev2) = a.next_event().unwrap();
        assert!(t_second >= t_first);
        assert_ne!(ev2.replica(), ev.replica());
    }

    #[test]
    fn failure_model_crash_schedules_recovery_and_infinite_mttr_never_recovers() {
        let mut fm = FailureModel::new(FailureConfig { mtbf_s: 50.0, mttr_s: 10.0, seed: 3 }, 1);
        fm.arm(0, 0.0);
        let (t_fail, ev) = fm.next_event().unwrap();
        assert!(matches!(ev, LifecycleEvent::Fail(0)));
        fm.crash(0, t_fail);
        let (t_rec, ev) = fm.next_event().unwrap();
        assert!(matches!(ev, LifecycleEvent::Recover(0)));
        assert!(t_rec > t_fail);
        fm.recovered(0);
        assert!(fm.next_event().is_none());

        // Permanent failures: no recovery event is ever scheduled.
        let mut dead =
            FailureModel::new(FailureConfig { mtbf_s: 50.0, mttr_s: f64::INFINITY, seed: 3 }, 1);
        dead.arm(0, 0.0);
        let (t, _) = dead.next_event().unwrap();
        dead.crash(0, t);
        assert!(dead.next_event().is_none());
    }

    #[test]
    fn event_tie_breaking_is_total() {
        let warm = Some((5.0, LifecycleEvent::WarmDone(1)));
        let fail = Some((5.0, LifecycleEvent::Fail(0)));
        // Capacity up before capacity down at the same instant.
        assert_eq!(earlier(warm, fail), warm);
        assert_eq!(earlier(fail, warm), warm);
        let f0 = Some((5.0, LifecycleEvent::Fail(0)));
        let f1 = Some((5.0, LifecycleEvent::Fail(1)));
        assert_eq!(earlier(f1, f0), f0);
        assert_eq!(earlier(None, f0), f0);
    }

    #[test]
    fn mean_live_integrates_transitions() {
        let mut lc = Lifecycle::inert();
        // 2 live for 10 s, then 1 for 10 s, then 3 for 20 s.
        lc.log_live_delta(10.0, -1);
        lc.log_live_delta(20.0, 2);
        let m = lc.mean_live(2, 40.0);
        let want = (2.0 * 10.0 + 1.0 * 10.0 + 3.0 * 20.0) / 40.0;
        assert!((m - want).abs() < 1e-12, "{m} vs {want}");
        // Transitions beyond the horizon contribute nothing.
        lc.log_live_delta(100.0, -2);
        assert!((lc.mean_live(2, 40.0) - want).abs() < 1e-12);
        assert_eq!(lc.mean_live(5, 0.0), 5.0);
    }

    #[test]
    fn inert_lifecycle_detection() {
        assert!(Lifecycle::inert().is_inert());
        let reactive = Lifecycle::new(
            Box::new(ReactiveAutoscaler::default()),
            None,
            ColdStart::default(),
        );
        assert!(!reactive.is_inert());
        let failing = Lifecycle::new(
            Box::new(StaticAutoscaler),
            Some(FailureModel::new(
                FailureConfig { mtbf_s: 10.0, mttr_s: 5.0, seed: 0 },
                2,
            )),
            ColdStart::default(),
        );
        assert!(!failing.is_inert());
    }

    #[test]
    fn autoscale_policy_builds_matching_discipline() {
        assert!(AutoscalePolicy::Static.build().is_static());
        let r = AutoscalePolicy::Reactive(ReactiveConfig::default()).build();
        assert!(!r.is_static());
        assert!(r.label().starts_with("reactive"));
    }
}
