//! Indexed event queue over replica clocks.
//!
//! The continuous-batching loop needs, at every iteration, the earliest
//! steppable replica: `argmin_i (now_s, i)` over replicas with work. A
//! linear rescan is O(fleet) per step — the term that dominated
//! million-request sweeps. [`EventQueue`] replaces it with a binary heap
//! keyed on each replica's next event time, popped in `(time, index)`
//! order so ties resolve exactly like the linear scan (lowest index wins).
//!
//! # Invalidation rule
//!
//! Replica clocks do not only move forward through the heap: lifecycle
//! churn (crash, drain, warm-up, power-off) can make a scheduled replica
//! unsteppable, or reschedule it to a different time, while its old entry
//! is still buried in the heap. Entries are therefore never removed
//! eagerly. Instead each replica carries a monotonically increasing
//! **version counter**, stamped into every entry at push time:
//!
//! > A heap entry is valid if and only if its stamped version equals the
//! > replica's current version. Both [`schedule`](EventQueue::schedule)
//! > and [`cancel`](EventQueue::cancel) bump the version, so at most one
//! > entry per replica — the most recently scheduled one — is ever valid,
//! > and every earlier entry is stale by construction.
//!
//! Stale entries are discarded lazily when they surface at the top during
//! [`peek`](EventQueue::peek) / [`pop`](EventQueue::pop). Each push
//! enqueues exactly one entry and each discarded entry was pushed exactly
//! once, so the amortized cost per schedule stays O(log fleet) regardless
//! of churn.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled wake-up: replica `idx` becomes steppable at time `t`.
#[derive(Debug, Clone, Copy)]
struct Entry {
    t: f64,
    idx: usize,
    ver: u64,
}

// BinaryHeap is a max-heap; reverse the comparison so the pop order is
// ascending (t, idx) — the exact order of the reference linear scan.
impl Ord for Entry {
    fn cmp(&self, other: &Entry) -> Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

/// Min-queue of `(next event time, replica index)` with lazy,
/// version-stamped invalidation (see the module docs for the rule).
#[derive(Debug, Clone, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    /// Current version per replica; heap entries stamped with an older
    /// version are stale.
    ver: Vec<u64>,
}

impl EventQueue {
    /// An empty queue for a fleet of `n` replicas.
    pub fn new(n: usize) -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(n.max(1) * 2), ver: vec![0; n] }
    }

    /// Schedule (or reschedule) replica `idx` to wake at time `t`,
    /// superseding any earlier schedule for the same replica.
    pub fn schedule(&mut self, idx: usize, t: f64) {
        self.ver[idx] += 1;
        self.heap.push(Entry { t, idx, ver: self.ver[idx] });
    }

    /// Invalidate any outstanding schedule for replica `idx`.
    pub fn cancel(&mut self, idx: usize) {
        self.ver[idx] += 1;
    }

    /// Earliest valid `(time, replica)`, discarding stale entries.
    pub fn peek(&mut self) -> Option<(f64, usize)> {
        while let Some(e) = self.heap.peek() {
            if self.ver[e.idx] == e.ver {
                return Some((e.t, e.idx));
            }
            self.heap.pop();
        }
        None
    }

    /// Pop the earliest valid `(time, replica)`.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        let head = self.peek();
        if head.is_some() {
            self.heap.pop();
        }
        head
    }

    /// True when no valid entry remains.
    pub fn is_empty(&mut self) -> bool {
        self.peek().is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_index_order() {
        let mut q = EventQueue::new(4);
        q.schedule(2, 5.0);
        q.schedule(0, 7.0);
        q.schedule(3, 5.0);
        q.schedule(1, 4.0);
        assert_eq!(q.pop(), Some((4.0, 1)));
        // Tie at t=5.0: the lower index must win, matching the linear
        // scan's first-minimum rule.
        assert_eq!(q.pop(), Some((5.0, 2)));
        assert_eq!(q.pop(), Some((5.0, 3)));
        assert_eq!(q.pop(), Some((7.0, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn reschedule_supersedes_older_entry() {
        let mut q = EventQueue::new(2);
        q.schedule(0, 1.0);
        q.schedule(0, 9.0); // the 1.0 entry is now stale
        q.schedule(1, 3.0);
        assert_eq!(q.pop(), Some((3.0, 1)));
        assert_eq!(q.pop(), Some((9.0, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cancel_invalidates_without_removal() {
        let mut q = EventQueue::new(2);
        q.schedule(0, 1.0);
        q.schedule(1, 2.0);
        q.cancel(0);
        assert_eq!(q.peek(), Some((2.0, 1)));
        q.cancel(1);
        assert!(q.is_empty());
        // Cancelling an unscheduled replica is a harmless no-op.
        q.cancel(0);
        q.schedule(0, 4.0);
        assert_eq!(q.pop(), Some((4.0, 0)));
    }

    #[test]
    fn churn_keeps_only_latest_schedule_valid() {
        let mut q = EventQueue::new(3);
        for round in 0..100u32 {
            let t = f64::from(round);
            q.schedule(round as usize % 3, t);
        }
        // Latest schedules: replica 0 @ 99, replica 1 @ 97, replica 2 @ 98.
        assert_eq!(q.pop(), Some((97.0, 1)));
        assert_eq!(q.pop(), Some((98.0, 2)));
        assert_eq!(q.pop(), Some((99.0, 0)));
        assert!(q.is_empty());
    }
}
