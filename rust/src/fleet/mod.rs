//! Heterogeneous governed fleets: routing × DVFS co-design, online.
//!
//! The paper's Section VII combines workload-aware model selection with
//! phase-aware DVFS *offline*, as an upper bound. This layer runs the
//! combination as a closed loop under real traffic:
//!
//! - [`replica`]: one serving device — its own model tier, frequency
//!   governor, KV cache, admission queue, and telemetry window — advanced
//!   event-by-event so N replicas interleave on one simulated clock;
//! - [`router`]: pluggable arrival routing over live replica state
//!   (round-robin, least-loaded, semantic-difficulty tiering,
//!   energy-per-token-aware, and traffic-class-aware selection);
//! - [`engine`]: the discrete-event fleet simulator binding them together;
//! - [`queue`]: the indexed event queue over replica clocks the engine's
//!   hot path steps from (version-stamped lazy invalidation, O(log fleet));
//! - [`attribution`]: per-request energy attribution — each replica's
//!   measured joules split across co-batched requests by phase (prefill by
//!   tokens processed, decode by tokens generated, idle amortized), exact
//!   by construction.
//!
//! - [`lifecycle`]: the elastic layer — the per-replica state machine
//!   (`Live → Draining → Cold → Warming → Live`), autoscaling disciplines
//!   (reactive queue-pressure/SLO-headroom hysteresis vs the static
//!   baseline), cold-start energy charging, and a seeded MTBF/MTTR
//!   failure/recovery process that requeues in-flight work through the
//!   router with original arrival timestamps;
//! - [`forecast`]: the predictive autoscaler — windowed arrival-rate
//!   estimation plus a coarse periodogram over binned arrival history —
//!   scheduling warm-ups ahead of predicted ramps and drains ahead of
//!   predicted troughs;
//! - [`migration`]: KV-state migration (Checkpoint → Handoff → Resume) —
//!   in-flight sequences checkpoint off Draining or crashed replicas and
//!   resume on Live ones via the router, with the prefill-replay bill on
//!   its own `migration_j` ledger phase.
//!
//! `ewatt fleet` and `examples/fleet_serve.rs` reproduce the Section VII
//! comparison (monolithic-large vs routed fleet × static vs governed DVFS)
//! as an online result; `ewatt autoscale` and `examples/elastic_fleet.rs`
//! run the elastic comparison (static peak provisioning vs autoscaling vs
//! autoscaling under failures) on diurnal traffic. The [`engine::drive`]
//! loop is the **only** continuous-batching event loop in the codebase:
//! `FleetSim` drives N replicas through it, the single-device
//! [`crate::serve::ServeSim`] is a facade over one replica, and
//! `coordinator::Cluster` replays its offline workloads through the same
//! engine.

pub mod attribution;
pub mod engine;
pub mod forecast;
pub mod lifecycle;
pub mod migration;
pub mod queue;
pub mod replica;
pub mod router;

pub use attribution::{ChargeLog, EnergyLedger, EnergySink, PhaseEnergy};
pub use engine::{
    drive, drive_with, EngineCtx, FleetConfig, FleetConfigBuilder, FleetOutcome, FleetSim,
    ReplicaOutcome, StepSelector,
};
pub use forecast::{ForecastAutoscaler, ForecastConfig};
pub use lifecycle::{
    AutoscalePolicy, Autoscaler, ColdStart, FailureConfig, FailureModel, Lifecycle,
    LifecycleStats, ReactiveAutoscaler, ReactiveConfig, ReplicaState, ScaleAction,
    StaticAutoscaler,
};
pub use migration::{MigrationPolicy, MigrationStats, SeqCheckpoint};
pub use queue::EventQueue;
pub use replica::{ClassPolicy, Replica, ReplicaSpec};
pub use router::{
    ClassAware, DifficultyTiered, EnergyAware, FleetRouter, LeastLoaded, ReplicaStatus, RoundRobin,
    NO_LIVE_REPLICA,
};
