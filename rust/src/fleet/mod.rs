//! Heterogeneous governed fleets: routing × DVFS co-design, online.
//!
//! The paper's Section VII combines workload-aware model selection with
//! phase-aware DVFS *offline*, as an upper bound. This layer runs the
//! combination as a closed loop under real traffic:
//!
//! - [`replica`]: one serving device — its own model tier, frequency
//!   governor, KV cache, admission queue, and telemetry window — advanced
//!   event-by-event so N replicas interleave on one simulated clock;
//! - [`router`]: pluggable arrival routing over live replica state
//!   (round-robin, least-loaded, semantic-difficulty tiering, and
//!   energy-per-token-aware selection);
//! - [`engine`]: the discrete-event fleet simulator binding them together;
//! - [`attribution`]: per-request energy attribution — each replica's
//!   measured joules split across co-batched requests by phase (prefill by
//!   tokens processed, decode by tokens generated, idle amortized), exact
//!   by construction.
//!
//! `ewatt fleet` and `examples/fleet_serve.rs` reproduce the Section VII
//! comparison (monolithic-large vs routed fleet × static vs governed DVFS)
//! as an online result. The [`engine::drive`] loop is the **only**
//! continuous-batching event loop in the codebase: `FleetSim` drives N
//! replicas through it, the single-device [`crate::serve::ServeSim`] is a
//! facade over one replica, and `coordinator::Cluster` replays its offline
//! workloads through the same engine.

pub mod attribution;
pub mod engine;
pub mod replica;
pub mod router;

pub use attribution::{EnergyLedger, PhaseEnergy};
pub use engine::{drive, FleetConfig, FleetOutcome, FleetSim, ReplicaOutcome};
pub use replica::{Replica, ReplicaSpec};
pub use router::{
    DifficultyTiered, EnergyAware, FleetRouter, LeastLoaded, ReplicaStatus, RoundRobin,
};
