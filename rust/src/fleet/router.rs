//! Energy-aware fleet routing.
//!
//! Where [`crate::coordinator::router::Router`] picks a model *tier* for a
//! query offline, a fleet router must pick a live *replica* online, reading
//! each replica's instantaneous state (backlog, live joules-per-token, and
//! the telemetry window's busy fraction and mean power). Five disciplines,
//! in increasing awareness:
//!
//! - [`RoundRobin`]: cycle over live replicas (the baseline every
//!   production load balancer implements);
//! - [`LeastLoaded`]: minimize backlog (queue + in-flight sequences);
//! - [`DifficultyTiered`]: semantic-difficulty tiering — easy queries to
//!   the smallest live model tier, hard queries to the largest, using the
//!   quality surrogate's feature difficulty (Section V-E4's rule recast as
//!   a score); degrades to round-robin when features are unavailable;
//! - [`EnergyAware`]: minimize predicted joules/token from each replica's
//!   live telemetry, with a backlog penalty so cheap replicas don't drown;
//! - [`ClassAware`]: split by [`TrafficClass`] — Interactive arrivals take
//!   the least-loaded replica (queueing delay), Batch/Background take the
//!   [`EnergyAware`] score (joules/token), so deadline-tolerant work soaks
//!   up efficient capacity without crowding the fast path.
//!
//! Invariants (asserted by `rust/tests/proptest_invariants.rs`): every
//! request routes to exactly one live replica, and the difficulty router
//! without features reproduces round-robin's choices exactly.

use anyhow::{ensure, Result};

use crate::config::ModelTier;
use crate::coordinator::router::ENTITY_THRESHOLD;
use crate::features::FeatureVector;
use crate::quality::QualityModel;
use crate::serve::traffic::{Arrival, TrafficClass};

use super::lifecycle::ReplicaState;

/// The message every router returns when asked to place work on a fleet
/// with no routable replica. The engine's arrival loop normally
/// fast-forwards lifecycle events before routing, so surfacing this error
/// (instead of the panic it replaced) means routing raced an all-dead
/// fleet — the run aborts with a typed error rather than a crash.
pub const NO_LIVE_REPLICA: &str = "fleet router called with no live replicas";

/// Live, router-visible snapshot of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    /// Index into the fleet's replica array.
    pub idx: usize,
    /// Lifecycle state (autoscalers read `Warming`/`Draining`
    /// capacity-in-flight; routers only ever pick [`Self::live`]
    /// replicas).
    pub state: ReplicaState,
    /// Model size tier this replica serves.
    pub tier: ModelTier,
    /// Requests waiting in the replica's admission queue.
    pub queue_depth: usize,
    /// Sequences currently decoding.
    pub active_seqs: usize,
    /// The replica's local clock, seconds.
    pub now_s: f64,
    /// Mean power over the replica's telemetry window, watts.
    pub window_power_w: f64,
    /// Busy fraction of the telemetry window.
    pub busy_fraction: f64,
    /// Live joules per generated token (telemetry-derived once the replica
    /// has decoded; model-derived prior while cold).
    pub j_per_token: f64,
}

impl ReplicaStatus {
    /// Whether this replica accepts traffic (`state` is `Live`) — derived,
    /// so it can never disagree with the state machine.
    pub fn live(&self) -> bool {
        self.state.routable()
    }

    /// Outstanding work: queued plus in-flight.
    pub fn backlog(&self) -> usize {
        self.queue_depth + self.active_seqs
    }
}

/// A routing discipline: pick the replica index for one arrival.
///
/// Implementations must return the index of a **live** replica; the fleet
/// engine asserts this. Routing an all-dead fleet returns the typed
/// [`NO_LIVE_REPLICA`] error (never panics — the engine propagates it as
/// its no-capacity error). `features` is `None` when the serving stack has
/// no feature extractor on the request path (difficulty-aware disciplines
/// must still route — see [`DifficultyTiered`]).
pub trait FleetRouter {
    fn route(
        &mut self,
        arrival: &Arrival,
        features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize>;

    fn label(&self) -> String;
}

fn ensure_some_live(replicas: &[ReplicaStatus]) -> Result<()> {
    ensure!(replicas.iter().any(|r| r.live()), NO_LIVE_REPLICA);
    Ok(())
}

/// Cycle over live replicas in index order.
#[derive(Debug, Clone, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl FleetRouter for RoundRobin {
    fn route(
        &mut self,
        _arrival: &Arrival,
        _features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize> {
        ensure_some_live(replicas)?;
        loop {
            let i = self.cursor % replicas.len();
            self.cursor = self.cursor.wrapping_add(1);
            if replicas[i].live() {
                return Ok(i);
            }
        }
    }

    fn label(&self) -> String {
        "round-robin".into()
    }
}

/// Minimum backlog among live replicas; ties break to the lowest index.
#[derive(Debug, Clone, Default)]
pub struct LeastLoaded;

/// Least-loaded selection over an arbitrary live subset (shared by the
/// difficulty router's within-tier choice).
fn least_loaded_where(
    replicas: &[ReplicaStatus],
    keep: impl Fn(&ReplicaStatus) -> bool,
) -> Option<usize> {
    let mut best: Option<usize> = None;
    for r in replicas.iter().filter(|r| r.live() && keep(r)) {
        match best {
            None => best = Some(r.idx),
            Some(b) => {
                if r.backlog() < replicas[b].backlog() {
                    best = Some(r.idx);
                }
            }
        }
    }
    best
}

impl FleetRouter for LeastLoaded {
    fn route(
        &mut self,
        _arrival: &Arrival,
        _features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize> {
        ensure_some_live(replicas)?;
        least_loaded_where(replicas, |_| true).ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))
    }

    fn label(&self) -> String {
        "least-loaded".into()
    }
}

/// The feature-difficulty score at the paper's easy/hard rule boundary:
/// a causal-question-free query at the entity-density cutoff (Section
/// V-E4). Because `causal_question` is binary and its difficulty weight
/// exceeds this threshold on its own, scoring against it reproduces the
/// paper's rule exactly: hard ⇔ causal question ∨ entity density ≥ 0.20.
pub fn rule_boundary_difficulty() -> f64 {
    QualityModel::feature_difficulty(&FeatureVector {
        input_length: 0,
        complexity_score: 0.0,
        reasoning_complexity: 0.0,
        entity_density: ENTITY_THRESHOLD,
        token_entropy: 0.0,
        causal_question: 0.0,
    })
}

/// Semantic-difficulty tiering: easy queries go to the smallest live model
/// tier, hard queries to the largest, least-loaded within the tier group.
/// Without features it degrades to round-robin over all live replicas.
#[derive(Debug, Clone)]
pub struct DifficultyTiered {
    /// Queries with feature difficulty at or above this are "hard".
    pub threshold: f64,
    fallback: RoundRobin,
}

impl Default for DifficultyTiered {
    fn default() -> Self {
        DifficultyTiered { threshold: rule_boundary_difficulty(), fallback: RoundRobin::default() }
    }
}

impl DifficultyTiered {
    pub fn with_threshold(threshold: f64) -> Self {
        DifficultyTiered { threshold, ..Default::default() }
    }

    /// Whether this router would call the query hard.
    pub fn is_hard(&self, f: &FeatureVector) -> bool {
        QualityModel::feature_difficulty(f) >= self.threshold
    }
}

impl FleetRouter for DifficultyTiered {
    fn route(
        &mut self,
        arrival: &Arrival,
        features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize> {
        ensure_some_live(replicas)?;
        let f = match features {
            // No features on the request path: no difficulty signal, so the
            // only safe behaviour is the uniform baseline.
            None => return self.fallback.route(arrival, None, replicas),
            Some(f) => f,
        };
        let live_tiers = replicas.iter().filter(|r| r.live()).map(|r| r.tier);
        let target = if self.is_hard(f) {
            live_tiers.max().ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))?
        } else {
            live_tiers.min().ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))?
        };
        least_loaded_where(replicas, |r| r.tier == target)
            .ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))
    }

    fn label(&self) -> String {
        format!("difficulty[thr={:.3}]", self.threshold)
    }
}

/// Minimize predicted marginal joules/token, read off each replica's live
/// telemetry (the joules/token estimate plus the window's busy fraction),
/// with a backlog penalty so the cheapest replica is not swamped:
/// score = j/token · (1 + penalty·backlog) · (1 + busy_fraction).
#[derive(Debug, Clone)]
pub struct EnergyAware {
    /// Relative cost of one unit of backlog (0 = pure energy greed).
    pub load_penalty: f64,
}

impl Default for EnergyAware {
    fn default() -> Self {
        EnergyAware { load_penalty: 0.5 }
    }
}

/// The [`EnergyAware`] score minimized over live replicas: joules/token
/// scaled by backlog and window saturation (a saturated telemetry window
/// means no headroom — marginal work there queues behind a full pipeline).
fn cheapest_scored(replicas: &[ReplicaStatus], load_penalty: f64) -> Result<usize> {
    let mut best: Option<(usize, f64)> = None;
    for r in replicas.iter().filter(|r| r.live()) {
        let score =
            r.j_per_token * (1.0 + load_penalty * r.backlog() as f64) * (1.0 + r.busy_fraction);
        let better = match best {
            None => true,
            Some((_, s)) => score < s,
        };
        if better {
            best = Some((r.idx, score));
        }
    }
    best.map(|(idx, _)| idx).ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))
}

impl FleetRouter for EnergyAware {
    fn route(
        &mut self,
        _arrival: &Arrival,
        _features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize> {
        ensure_some_live(replicas)?;
        cheapest_scored(replicas, self.load_penalty)
    }

    fn label(&self) -> String {
        format!("energy-aware[penalty={:.2}]", self.load_penalty)
    }
}

/// Class-aware routing: latency-critical [`TrafficClass::Interactive`]
/// arrivals go to the least-loaded live replica (minimizing queueing
/// delay), while Batch and Background arrivals chase the cheapest
/// marginal joules/token under the [`EnergyAware`] score — deadline-
/// tolerant work soaks up the efficient capacity without crowding the
/// fast path.
#[derive(Debug, Clone)]
pub struct ClassAware {
    /// Backlog penalty for the energy-scored (Batch/Background) classes.
    pub load_penalty: f64,
}

impl Default for ClassAware {
    fn default() -> Self {
        ClassAware { load_penalty: 0.5 }
    }
}

impl FleetRouter for ClassAware {
    fn route(
        &mut self,
        arrival: &Arrival,
        _features: Option<&FeatureVector>,
        replicas: &[ReplicaStatus],
    ) -> Result<usize> {
        ensure_some_live(replicas)?;
        if arrival.class == TrafficClass::Interactive {
            least_loaded_where(replicas, |_| true).ok_or_else(|| anyhow::anyhow!(NO_LIVE_REPLICA))
        } else {
            cheapest_scored(replicas, self.load_penalty)
        }
    }

    fn label(&self) -> String {
        format!("class-aware[penalty={:.2}]", self.load_penalty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn status(idx: usize, tier: ModelTier, backlog: usize, j_tok: f64) -> ReplicaStatus {
        ReplicaStatus {
            idx,
            state: ReplicaState::Live,
            tier,
            queue_depth: backlog,
            active_seqs: 0,
            now_s: 0.0,
            window_power_w: 200.0,
            busy_fraction: 0.5,
            j_per_token: j_tok,
        }
    }

    fn arr() -> Arrival {
        Arrival::at(0.0, 0)
    }

    fn classed(class: TrafficClass) -> Arrival {
        Arrival { class, ..Arrival::at(0.0, 0) }
    }

    fn easy_features() -> FeatureVector {
        FeatureVector {
            input_length: 10,
            complexity_score: 0.2,
            reasoning_complexity: 0.0,
            entity_density: 0.05,
            token_entropy: 3.0,
            causal_question: 0.0,
        }
    }

    fn hard_features() -> FeatureVector {
        FeatureVector { entity_density: 0.5, causal_question: 1.0, ..easy_features() }
    }

    #[test]
    fn round_robin_cycles_and_skips_dead() {
        let mut rr = RoundRobin::default();
        let mut reps = vec![
            status(0, ModelTier::B3, 0, 1.0),
            status(1, ModelTier::B3, 0, 1.0),
            status(2, ModelTier::B3, 0, 1.0),
        ];
        reps[1].state = ReplicaState::Cold;
        let picks: Vec<usize> = (0..4).map(|_| rr.route(&arr(), None, &reps).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_loaded_picks_min_backlog_lowest_index_on_tie() {
        let mut ll = LeastLoaded;
        let reps = vec![
            status(0, ModelTier::B3, 3, 1.0),
            status(1, ModelTier::B3, 1, 1.0),
            status(2, ModelTier::B3, 1, 1.0),
        ];
        assert_eq!(ll.route(&arr(), None, &reps).unwrap(), 1);
    }

    #[test]
    fn difficulty_routes_easy_small_hard_large() {
        let mut dr = DifficultyTiered::default();
        let reps = vec![
            status(0, ModelTier::B14, 0, 4.0),
            status(1, ModelTier::B3, 5, 1.0),
            status(2, ModelTier::B14, 1, 4.0),
        ];
        // Easy → the (only) B3 replica even though it is busier.
        assert_eq!(dr.route(&arr(), Some(&easy_features()), &reps).unwrap(), 1);
        // Hard → least-loaded among the B14 replicas.
        assert_eq!(dr.route(&arr(), Some(&hard_features()), &reps).unwrap(), 2);
    }

    #[test]
    fn difficulty_without_features_is_round_robin() {
        let mut dr = DifficultyTiered::default();
        let mut rr = RoundRobin::default();
        let reps = vec![
            status(0, ModelTier::B3, 0, 1.0),
            status(1, ModelTier::B14, 0, 4.0),
        ];
        for _ in 0..6 {
            let (a, b) = (dr.route(&arr(), None, &reps), rr.route(&arr(), None, &reps));
            assert_eq!(a.unwrap(), b.unwrap());
        }
    }

    #[test]
    fn rule_boundary_threshold_separates_paper_examples() {
        let dr = DifficultyTiered::default();
        assert!(!dr.is_hard(&easy_features()));
        assert!(dr.is_hard(&hard_features()));
        // A causal question alone is hard (causal weight exceeds the margin
        // left under the boundary by zero entity density).
        let causal_only = FeatureVector { causal_question: 1.0, ..easy_features() };
        assert!(dr.is_hard(&causal_only));
    }

    #[test]
    fn score_threshold_agrees_with_the_paper_rule_exactly() {
        // causal_question is binary in extracted features, so the weighted
        // score against the causal-free boundary must reproduce the
        // offline router's AND-rule on every real query.
        use crate::coordinator::router::Router;
        use crate::features::FeatureExtractor;
        use crate::workload::{gen, Dataset};
        let dr = DifficultyTiered::default();
        let fx = FeatureExtractor::new();
        for case in 0..64u64 {
            let mut rng = crate::rng(0xD1FF ^ case);
            let d = *rng.choose(&Dataset::ALL);
            let q = gen::generate(d, 1, case * 101, &mut rng).remove(0);
            let f = fx.extract(&q.text);
            assert_eq!(
                dr.is_hard(&f),
                !Router::is_easy_rule(&f),
                "case {case}: score threshold diverged from the rule on {:?}",
                q.text
            );
        }
    }

    #[test]
    fn energy_aware_trades_cheapness_against_backlog() {
        let mut ea = EnergyAware::default();
        // Cheap replica, empty: wins outright.
        let reps = vec![status(0, ModelTier::B14, 0, 4.0), status(1, ModelTier::B3, 0, 1.0)];
        assert_eq!(ea.route(&arr(), None, &reps).unwrap(), 1);
        // Cheap replica deeply backlogged: 1.0·(1+0.5·12) = 7 > 4 → B14.
        let reps = vec![status(0, ModelTier::B14, 0, 4.0), status(1, ModelTier::B3, 12, 1.0)];
        assert_eq!(ea.route(&arr(), None, &reps).unwrap(), 0);
    }

    #[test]
    fn class_aware_splits_latency_and_energy_paths() {
        let mut ca = ClassAware::default();
        // Replica 0: expensive but empty; replica 1: cheap but backlogged.
        let reps = vec![status(0, ModelTier::B14, 0, 4.0), status(1, ModelTier::B3, 3, 1.0)];
        // Interactive minimizes queueing delay → the empty replica.
        assert_eq!(ca.route(&classed(TrafficClass::Interactive), None, &reps).unwrap(), 0);
        // Batch/Background minimize the energy score:
        // 1.0·(1+0.5·3)·1.5 = 3.75 < 4.0·1.0·1.5 = 6 → the cheap replica.
        assert_eq!(ca.route(&classed(TrafficClass::Batch), None, &reps).unwrap(), 1);
        assert_eq!(ca.route(&classed(TrafficClass::Background), None, &reps).unwrap(), 1);
        // Deep backlog flips the energy path too: 1.0·(1+0.5·12)·1.5 > 6.
        let reps = vec![status(0, ModelTier::B14, 0, 4.0), status(1, ModelTier::B3, 12, 1.0)];
        assert_eq!(ca.route(&classed(TrafficClass::Batch), None, &reps).unwrap(), 0);
    }

    #[test]
    fn all_dead_is_a_typed_error_not_a_panic() {
        // Every discipline must surface the typed all-dead error instead of
        // panicking when routing races a fleet with no routable replica.
        let mut reps = vec![status(0, ModelTier::B3, 0, 1.0)];
        reps[0].state = ReplicaState::Cold;
        let routers: Vec<Box<dyn FleetRouter>> = vec![
            Box::new(RoundRobin::default()),
            Box::new(LeastLoaded),
            Box::new(DifficultyTiered::default()),
            Box::new(EnergyAware::default()),
            Box::new(ClassAware::default()),
        ];
        for mut r in routers {
            let err = r.route(&arr(), None, &reps).unwrap_err().to_string();
            assert!(err.contains(NO_LIVE_REPLICA), "{}: {err}", r.label());
            let err = r.route(&arr(), Some(&hard_features()), &reps).unwrap_err().to_string();
            assert!(err.contains(NO_LIVE_REPLICA), "{} (with features): {err}", r.label());
        }
    }
}
