//! One governed serving replica — **the** continuous-batching loop.
//!
//! Each replica is a self-contained serving device: its own simulated GPU,
//! frequency governor, KV-cache manager, admission queue, SLO tracker, and
//! telemetry window, advanced event-by-event so N replicas interleave
//! correctly on the shared simulated clock. One `step()` call executes
//! exactly one unit of work (one admission prefill or one batched decode
//! step), which is the granularity arrivals can be routed between.
//!
//! This is the single batching/governor/attribution core the whole
//! codebase shares: [`crate::fleet::FleetSim`] drives N replicas through a
//! router, [`crate::serve::ServeSim`] is a thin facade over exactly one
//! replica, and `coordinator::Cluster` replays offline workloads through
//! the fleet engine. Classification (zero-output) queries are scored with
//! one prefill pass per answer option and complete at admission, with no
//! decode phase; admission is gated on KV-cache capacity (a request that
//! does not fit waits until decode drains sequences).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::config::model::model_for_tier;
use crate::config::{FreqMHz, GpuSpec, ModelSpec, ModelTier};
use crate::coordinator::dvfs_policy::{DvfsPolicy, Phase};
use crate::engine::KvCacheManager;
use crate::gpu::{GpuSim, TelemetryWindow};
use crate::perf::{decode_step_cost, prefill_cost};
use crate::serve::governor::{governor_for, FreqGovernor, GovernorSignal};
use crate::serve::slo::{Slo, SloTracker};
use crate::serve::traffic::Arrival;
use crate::text::tokenizer::token_count;
use crate::workload::ReplaySuite;

use super::attribution::EnergyLedger;
use super::router::ReplicaStatus;

/// Static description of one fleet member.
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    /// The model this replica serves (fleets may mix tiers).
    pub model: ModelSpec,
    /// Frequency policy: `Governed` bands run the closed-loop hysteresis
    /// controller; anything else runs open-loop.
    pub policy: DvfsPolicy,
    /// Dead replicas hold no traffic (router invariant fodder).
    pub live: bool,
}

impl ReplicaSpec {
    /// A live replica serving one of the paper's model tiers.
    pub fn tiered(tier: ModelTier, policy: DvfsPolicy) -> ReplicaSpec {
        ReplicaSpec { model: model_for_tier(tier), policy, live: true }
    }
}

/// One queued request (arrival plus its fleet-wide request index).
#[derive(Debug, Clone, Copy)]
struct Queued {
    req: usize,
    arrival: Arrival,
}

/// One decoding sequence.
struct ActiveSeq {
    req: usize,
    arrival_s: f64,
    first_token_s: f64,
    tokens: usize,
    remaining: usize,
    ctx: usize,
}

/// EWMA weight for the live joules/token estimate (per decode step).
const J_PER_TOKEN_ALPHA: f64 = 0.2;

/// A replica's mutable serving state.
pub struct Replica {
    pub spec: ReplicaSpec,
    gpu: GpuSim,
    gov: Box<dyn FreqGovernor>,
    wants_signal: bool,
    kv: KvCacheManager,
    queue: VecDeque<Queued>,
    active: Vec<ActiveSeq>,
    /// This replica's local clock, seconds.
    pub now_s: f64,
    /// Per-replica SLO tracker (feeds this replica's governor).
    pub tracker: SloTracker,
    window: TelemetryWindow,
    /// Completion time of the last request this replica finished.
    pub last_finish_s: f64,
    /// Deepest admission-queue backlog observed.
    pub max_queue_depth: usize,

    // Accounting.
    pub busy_s: f64,
    pub energy_j: f64,
    pub idle_j: f64,
    pub switch_j: f64,
    pub freq_switches: usize,
    pub served: usize,
    pub tokens_out: u64,
    served_reqs: Vec<usize>,
    decode_freq_dt: f64,
    decode_dt: f64,
    j_per_token_ewma: f64,
    /// Cold-start joules/token prior, precomputed at construction — the
    /// router reads replica status on every arrival, and evaluating the
    /// roofline model there would put it on the routing hot path.
    cold_j_per_token: f64,
    /// Scratch buffer of in-flight request ids (attribution hot path).
    req_scratch: Vec<usize>,
}

impl Replica {
    pub fn new(gpu: &GpuSpec, spec: ReplicaSpec, slo: Slo, window_s: f64) -> Replica {
        let gov = governor_for(&spec.policy, gpu);
        Replica::with_governor(gpu, spec, gov, slo, window_s)
    }

    /// Build a replica around a caller-supplied governor — the serve
    /// facade's pluggable path. `spec.policy` is metadata here (labels,
    /// router snapshots); `gov` makes every frequency decision.
    pub fn with_governor(
        gpu: &GpuSpec,
        spec: ReplicaSpec,
        mut gov: Box<dyn FreqGovernor>,
        slo: Slo,
        window_s: f64,
    ) -> Replica {
        let wants_signal = gov.wants_signal();
        let kv = KvCacheManager::new(gpu, &spec.model);
        // Cold-start set point: the governor's first prefill decision (for
        // every built-in policy this equals `policy.prefill_freq`).
        let f0 = gov.decide(0.0, Phase::Prefill, &GovernorSignal::default(), gpu);
        let gpu_sim = GpuSim::new(gpu.clone(), f0);
        let cold_j_per_token = gpu_sim.execute(&decode_step_cost(&spec.model, 1, 256)).energy_j;
        Replica {
            gpu: gpu_sim,
            gov,
            wants_signal,
            kv,
            queue: VecDeque::new(),
            active: Vec::new(),
            now_s: 0.0,
            tracker: SloTracker::new(slo),
            window: TelemetryWindow::new(window_s),
            last_finish_s: 0.0,
            max_queue_depth: 0,
            busy_s: 0.0,
            energy_j: 0.0,
            idle_j: 0.0,
            switch_j: 0.0,
            freq_switches: 0,
            served: 0,
            tokens_out: 0,
            served_reqs: Vec::new(),
            decode_freq_dt: 0.0,
            decode_dt: 0.0,
            j_per_token_ewma: 0.0,
            cold_j_per_token,
            req_scratch: Vec::new(),
            spec,
        }
    }

    /// Whether this replica has work to execute.
    pub fn runnable(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn active_seqs(&self) -> usize {
        self.active.len()
    }

    /// Time-weighted mean decode set point, MHz.
    pub fn mean_decode_freq_mhz(&self) -> f64 {
        if self.decode_dt > 0.0 {
            self.decode_freq_dt / self.decode_dt
        } else {
            0.0
        }
    }

    /// Requests this replica completed, by fleet-wide request index.
    pub fn served_reqs(&self) -> &[usize] {
        &self.served_reqs
    }

    /// Live joules per generated token: telemetry-derived EWMA once this
    /// replica has decoded; the construction-time roofline prior (batch 1
    /// at the cold-start set point) before that, so energy-aware routing
    /// can rank replicas from the first arrival without putting a model
    /// evaluation on the routing hot path.
    pub fn j_per_token(&self) -> f64 {
        if self.tokens_out > 0 {
            self.j_per_token_ewma
        } else {
            self.cold_j_per_token
        }
    }

    /// Router-facing snapshot.
    pub fn status(&self, idx: usize) -> ReplicaStatus {
        ReplicaStatus {
            idx,
            live: self.spec.live,
            tier: self.spec.model.tier,
            queue_depth: self.queue.len(),
            active_seqs: self.active.len(),
            now_s: self.now_s,
            window_power_w: self.window.mean_power_w(),
            busy_fraction: self.window.busy_fraction(),
            j_per_token: self.j_per_token(),
        }
    }

    /// Accept one routed arrival. If the replica was idle in the simulated
    /// past, the wait until `arrival.t_s` is charged at idle power (that
    /// draw is later amortized over the requests this replica serves).
    pub fn enqueue(&mut self, req: usize, arrival: Arrival) {
        assert!(self.spec.live, "routed to a dead replica");
        if !self.runnable() && self.now_s < arrival.t_s {
            self.idle_j += (arrival.t_s - self.now_s) * self.gpu.spec.p_idle_w;
            self.now_s = arrival.t_s;
        }
        self.queue.push_back(Queued { req, arrival });
        self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
    }

    fn signal(&self) -> GovernorSignal {
        if !self.wants_signal {
            return GovernorSignal::default();
        }
        GovernorSignal {
            pressure: self.tracker.pressure(),
            queue_depth: self.queue.len(),
            active_seqs: self.active.len(),
            completed: self.tracker.completed(),
            window_power_w: self.window.mean_power_w(),
        }
    }

    /// Apply a set-point change, charging the switch latency at idle power
    /// to the requests of the step that follows.
    fn switch_to(&mut self, f: FreqMHz, beneficiaries: &[usize], ledger: &mut EnergyLedger) {
        let dt = self.gpu.set_freq(f);
        if dt > 0.0 {
            let e = dt * self.gpu.spec.p_idle_w;
            self.now_s += dt;
            self.busy_s += dt;
            self.energy_j += e;
            self.switch_j += e;
            self.freq_switches += 1;
            ledger.charge_switch(beneficiaries, e);
        }
    }

    fn complete(
        &mut self,
        req: usize,
        arrival_s: f64,
        first_token_s: f64,
        tokens: usize,
        fleet: &mut SloTracker,
    ) {
        let ttft = first_token_s - arrival_s;
        let e2e = self.now_s - arrival_s;
        let tbt = if tokens > 0 { (self.now_s - first_token_s) / tokens as f64 } else { 0.0 };
        self.tracker.record(ttft, tbt, e2e);
        fleet.record(ttft, tbt, e2e);
        self.kv.release(req as u64);
        self.served += 1;
        self.served_reqs.push(req);
        self.last_finish_s = self.now_s;
    }

    /// Execute one unit of work: admit one queued request (its prefill
    /// passes), or run one decode step for the active batch. Requests that
    /// do not fit the KV cache wait until decode drains capacity.
    pub fn step(
        &mut self,
        suite: &ReplaySuite,
        max_batch: usize,
        ledger: &mut EnergyLedger,
        fleet: &mut SloTracker,
    ) -> Result<()> {
        debug_assert!(self.runnable(), "step() on an idle replica");
        if !self.queue.is_empty() && self.active.len() < max_batch {
            let head = *self.queue.front().unwrap();
            let q = &suite.queries[head.arrival.query_idx];
            let input = token_count(&q.text).max(1);
            // Reserve the full sequence (prompt + output budget) up front.
            if self.kv.admit(head.req as u64, input + q.output_tokens).is_ok() {
                self.queue.pop_front();
                return self.admit(head, input, suite, ledger, fleet);
            }
            if self.active.is_empty() {
                bail!(
                    "request {} ({} prompt + {} output tokens) cannot fit the \
                     empty KV cache of a {} replica",
                    head.req,
                    input,
                    q.output_tokens,
                    self.spec.model.name
                );
            }
            // KV full: fall through and decode until sequences release it.
        }
        self.decode_step(ledger, fleet);
        Ok(())
    }

    /// Prefill (and, for classification, score) one admitted request.
    fn admit(
        &mut self,
        head: Queued,
        input: usize,
        suite: &ReplaySuite,
        ledger: &mut EnergyLedger,
        fleet: &mut SloTracker,
    ) -> Result<()> {
        let q = &suite.queries[head.arrival.query_idx];
        let sig = self.signal();
        let f = self.gov.decide(self.now_s, Phase::Prefill, &sig, &self.gpu.spec);
        self.switch_to(f, &[head.req], ledger);
        // Classification scores every answer option with its own forward
        // pass (log-likelihood mode); generation prefills once.
        let passes = if q.output_tokens == 0 { q.dataset.n_options() } else { 1 };
        for _ in 0..passes {
            let r = self.gpu.execute(&prefill_cost(&self.spec.model, 1, input));
            self.now_s += r.latency_s;
            self.busy_s += r.latency_s;
            self.energy_j += r.energy_j;
            self.window.record(self.now_s, r.latency_s, r.energy_j);
            ledger.charge_prefill(head.req, r.energy_j);
        }
        if q.output_tokens == 0 {
            // No decode phase: the request completes at prefill end.
            self.complete(head.req, head.arrival.t_s, self.now_s, 0, fleet);
        } else {
            self.active.push(ActiveSeq {
                req: head.req,
                arrival_s: head.arrival.t_s,
                first_token_s: self.now_s,
                tokens: 0,
                remaining: q.output_tokens,
                ctx: input,
            });
        }
        Ok(())
    }

    /// One decode step for the whole running batch.
    fn decode_step(&mut self, ledger: &mut EnergyLedger, fleet: &mut SloTracker) {
        debug_assert!(!self.active.is_empty(), "decode with an empty batch");
        self.req_scratch.clear();
        self.req_scratch.extend(self.active.iter().map(|s| s.req));
        let sig = self.signal();
        let f = self.gov.decide(self.now_s, Phase::Decode, &sig, &self.gpu.spec);
        // The scratch slice cannot stay borrowed across `&mut self` calls;
        // take it out and put it back (no allocation either way).
        let scratch = std::mem::take(&mut self.req_scratch);
        self.switch_to(f, &scratch, ledger);
        let ctx = self.active.iter().map(|s| s.ctx).max().unwrap();
        let r = self.gpu.execute(&decode_step_cost(&self.spec.model, self.active.len(), ctx));
        self.now_s += r.latency_s;
        self.busy_s += r.latency_s;
        self.energy_j += r.energy_j;
        self.window.record(self.now_s, r.latency_s, r.energy_j);
        self.decode_freq_dt += f as f64 * r.latency_s;
        self.decode_dt += r.latency_s;
        ledger.charge_decode(&scratch, r.energy_j);
        self.req_scratch = scratch;

        let j_tok = r.energy_j / self.active.len() as f64;
        self.j_per_token_ewma = if self.tokens_out == 0 {
            j_tok
        } else {
            (1.0 - J_PER_TOKEN_ALPHA) * self.j_per_token_ewma + J_PER_TOKEN_ALPHA * j_tok
        };
        self.tokens_out += self.active.len() as u64;

        let mut finished: Vec<(usize, f64, f64, usize)> = Vec::new();
        self.active.retain_mut(|s| {
            s.remaining -= 1;
            s.tokens += 1;
            s.ctx += 1;
            if s.remaining == 0 {
                finished.push((s.req, s.arrival_s, s.first_token_s, s.tokens));
                false
            } else {
                true
            }
        });
        for (req, arrival_s, first_token_s, tokens) in finished {
            self.complete(req, arrival_s, first_token_s, tokens, fleet);
        }
    }

    /// Amortize this replica's idle draw across the requests it served.
    /// Call once, after the fleet drains.
    pub fn finalize(&mut self, ledger: &mut EnergyLedger) {
        debug_assert!(
            self.idle_j == 0.0 || !self.served_reqs.is_empty(),
            "idle energy on a replica that served nothing"
        );
        ledger.charge_idle(&self.served_reqs, self.idle_j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelTier;
    use crate::workload::Dataset;

    fn setup() -> (ReplaySuite, Replica) {
        let gpu = GpuSpec::rtx_pro_6000();
        let suite = ReplaySuite::quick(71, 8);
        let rep = Replica::new(
            &gpu,
            ReplicaSpec::tiered(ModelTier::B3, DvfsPolicy::Static(2842)),
            Slo::interactive(),
            2.0,
        );
        (suite, rep)
    }

    #[test]
    fn serves_a_generation_request_end_to_end() {
        let (suite, mut rep) = setup();
        let idx = suite.dataset_indices(Dataset::NarrativeQa)[0];
        let mut ledger = EnergyLedger::new(1);
        let mut fleet = SloTracker::new(Slo::interactive());
        rep.enqueue(0, Arrival { t_s: 0.0, query_idx: idx });
        assert!(rep.runnable());
        while rep.runnable() {
            rep.step(&suite, 4, &mut ledger, &mut fleet).unwrap();
        }
        rep.finalize(&mut ledger);
        assert_eq!(rep.served, 1);
        assert_eq!(fleet.completed(), 1);
        assert_eq!(rep.tokens_out as usize, suite.queries[idx].output_tokens);
        let total = rep.energy_j + rep.idle_j;
        let attributed = ledger.total_for(&[0]);
        assert!(
            (attributed - total).abs() / total < 1e-9,
            "attributed {attributed} vs measured {total}"
        );
    }

    #[test]
    fn classification_completes_at_admission_with_option_passes() {
        let (suite, mut rep) = setup();
        let idx = suite.dataset_indices(Dataset::BoolQ)[0];
        let mut ledger = EnergyLedger::new(1);
        let mut fleet = SloTracker::new(Slo::interactive());
        rep.enqueue(0, Arrival { t_s: 0.0, query_idx: idx });
        rep.step(&suite, 4, &mut ledger, &mut fleet).unwrap();
        assert!(!rep.runnable());
        assert_eq!(rep.served, 1);
        assert_eq!(rep.tokens_out, 0);
        // Both BoolQ option passes are charged as prefill.
        assert!(ledger.request(0).prefill_j > 0.0);
        assert_eq!(ledger.request(0).decode_j, 0.0);
    }

    #[test]
    fn idle_wait_is_charged_and_amortized() {
        let (suite, mut rep) = setup();
        let idx = suite.dataset_indices(Dataset::TruthfulQa)[0];
        let mut ledger = EnergyLedger::new(1);
        let mut fleet = SloTracker::new(Slo::interactive());
        rep.enqueue(0, Arrival { t_s: 1.5, query_idx: idx });
        let expect_idle = 1.5 * rep.gpu.spec.p_idle_w;
        assert!((rep.idle_j - expect_idle).abs() < 1e-9);
        while rep.runnable() {
            rep.step(&suite, 4, &mut ledger, &mut fleet).unwrap();
        }
        rep.finalize(&mut ledger);
        assert!((ledger.request(0).idle_j - expect_idle).abs() < 1e-9);
    }

    #[test]
    fn j_per_token_prior_orders_model_tiers() {
        let gpu = GpuSpec::rtx_pro_6000();
        let small = Replica::new(
            &gpu,
            ReplicaSpec::tiered(ModelTier::B3, DvfsPolicy::Static(2842)),
            Slo::interactive(),
            2.0,
        );
        let large = Replica::new(
            &gpu,
            ReplicaSpec::tiered(ModelTier::B14, DvfsPolicy::Static(2842)),
            Slo::interactive(),
            2.0,
        );
        assert!(small.j_per_token() < large.j_per_token());
        assert!(small.j_per_token() > 0.0);
    }
}
